//! Benchmark harness regenerating every table and figure of the paper's
//! evaluation (criterion is unavailable offline; this is a plain
//! `harness = false` bench binary using the library's timer substrate).
//!
//! Sections (paper artifact -> output):
//!   table1  — complexity forms for MM/TTM/TT/BTT (validated vs engines)
//!   fig6    — contraction cost comparison at the Table II shape
//!   fig7    — seq-len and rank sweeps
//!   fig9    — QKV rescheduling makespans
//!   fig10   — fused-BTT buffer sizes
//!   fig12   — BRAM utilization efficiency per strategy/model
//!   fig14   — BRAM vs rank
//!   table2  — layer configuration
//!   table3  — model sizes + compression (accuracy: see train_atis)
//!   table4  — resource utilization
//!   table5  — GPU vs FPGA latency/memory/energy (+ figs 1/15)
//!   wallclock — measured rust-side contraction timings (BTT vs RL vs MM)
//!   native-train — measured rust-native train/eval step latency
//!             (no artifacts needed; FP + BP + fused SGD)
//!   matrix  — precision x compute-path x checkpoint-policy grid
//!             (tokens/sec, stage split, measured at-rest bytes;
//!             writes BENCH_matrix.json, CI-gated)
//!   replicas — data-parallel replica sweep R in {1,2,4} at one global
//!             batch (tokens/sec + exchange-volume + per-device budget;
//!             writes BENCH_replicas.json, CI-gated on >= 4 cores)
//!   serve   — continuous-batching serving scheduler load test
//!             (no-batching baseline vs continuous, concurrency 1/8;
//!             writes BENCH_serve.json)
//!   trace   — instrumentation overhead (disabled-site ns/call) and the
//!             FP/BP/PU stage breakdown of one traced train step
//!   pjrt    — measured train/eval step latency through the real stack
//!             (`pjrt` feature; skipped unless artifacts/ exists)
//!
//! Run: `cargo bench --offline` (optionally `-- <section>`)

use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::{TrainBackend, Trainer};
use tt_trainer::costmodel::{compare_all, sweeps, LinearShape};
use tt_trainer::data::Dataset;
use tt_trainer::fpga::{bram, energy, resources, schedule};
use tt_trainer::optim::{OptimConfig, OptimKind};
#[cfg(feature = "pjrt")]
use tt_trainer::runtime::{Engine, Manifest};
use tt_trainer::tensor::{Precision, Tensor, TTMatrix};
use tt_trainer::train::{CheckpointPolicy, ComputePath, NativeTrainer};
use tt_trainer::util::rng::SplitMix64;
use tt_trainer::util::timer::bench;

fn main() {
    let filter = std::env::args().nth(1).unwrap_or_default();
    let run = |name: &str| filter.is_empty() || filter == "--bench" || name.contains(&filter);

    if run("table1") {
        table1();
    }
    if run("fig6") {
        fig6();
    }
    if run("fig7") {
        fig7();
    }
    if run("fig9") {
        fig9();
    }
    if run("fig10") {
        fig10();
    }
    if run("fig12") {
        fig12();
    }
    if run("fig14") {
        fig14();
    }
    if run("table2") {
        table2();
    }
    if run("table3") {
        table3();
    }
    if run("table4") {
        table4();
    }
    if run("table5") {
        table5();
    }
    if run("wallclock") {
        wallclock();
    }
    if run("ablations") {
        ablations();
    }
    if run("native-train") {
        native_train();
    }
    if run("matrix") {
        matrix();
    }
    if run("replicas") {
        replicas();
    }
    if run("serve") {
        serve();
    }
    if run("trace") {
        trace_overhead();
    }
    if run("pjrt") {
        pjrt();
    }
}

/// The observability contract, measured: per-call cost of a disabled
/// instrumentation site (one relaxed atomic load) and the per-stage
/// FP/BP/PU split of one traced paper-config train step.
fn trace_overhead() {
    use tt_trainer::trace;
    hdr("trace", "instrumentation overhead + stage breakdown (no artifacts)");
    trace::set_enabled(false);
    trace::disabled_overhead_ns(100_000); // warm the TLS + branch
    let ns = trace::disabled_overhead_ns(2_000_000);
    println!("disabled span site: {ns:.2} ns/call (contract: single relaxed atomic load)");

    let cfg = ModelConfig::paper(2);
    let mut backend = NativeTrainer::random_init(&cfg, 42).expect("paper config init");
    let data = Dataset::synth(&cfg, 42, 8);
    let ex = &data.examples[0];
    // Warm once untraced, then trace a single step.
    backend.train_step(&ex.tokens, &[ex.intent], &ex.slots, 1e-3).expect("warm step");
    trace::reset();
    trace::set_enabled(true);
    backend.train_step(&ex.tokens, &[ex.intent], &ex.slots, 1e-3).expect("traced step");
    trace::set_enabled(false);
    let events = trace::drain();
    println!("one traced train step: {} spans", events.len());
    for r in trace::stage_breakdown(&events) {
        println!(
            "  {:<6} {:>10.2} ms  {:>5.1}%  ({} spans)",
            r.stage,
            r.total_us / 1e3,
            100.0 * r.share,
            r.spans
        );
    }
}

/// Measured serving latency and saturation throughput through the
/// continuous-batching scheduler (`tt_trainer::serve`) over the shared
/// inference engine — the no-batching baseline vs continuous batching
/// at closed-loop concurrency 1 and 8.  Emits `BENCH_serve.json`
/// (p50/p95/p99 latency, throughput, batching stats per scenario), the
/// serving counterpart of `BENCH_native_train.json`.
fn serve() {
    use std::sync::Arc;
    use tt_trainer::serve::loadgen;
    hdr("serve", "continuous-batching scheduler load test (no artifacts)");
    let cfg = ModelConfig::paper(2);
    let backend = NativeTrainer::random_init(&cfg, 42).expect("paper config init");
    let engine = Arc::new(backend.model.engine().expect("merged-factor engine"));
    let data = Dataset::synth(&cfg, 42, 64);
    let corpus: Vec<Vec<i32>> = data.examples.iter().map(|e| e.tokens.clone()).collect();
    let mut reports = Vec::new();
    for spec in loadgen::default_scenarios(128) {
        // Fail loudly (see native_train): a silent skip would surface
        // only as a missing BENCH_serve.json artifact in CI.
        let r = loadgen::run_load(&engine, &corpus, &spec).expect("load scenario");
        println!(
            "{:<16} conc {:>2}: p50 {:>8.3} ms | p99 {:>8.3} ms | {:>7.1} req/s | \
             mean batch {:>5.2} | rejected {}",
            r.name, r.concurrency, r.p50_ms, r.p99_ms, r.throughput_rps, r.mean_batch, r.rejected
        );
        reports.push(r);
    }
    let find = |name: &str| reports.iter().find(|r| r.name == name);
    if let (Some(base), Some(cont)) = (find("no-batching-c8"), find("continuous-c8")) {
        if base.throughput_rps > 0.0 {
            println!(
                "continuous vs no-batching at concurrency 8: {:.2}x throughput \
                 (p99 {:.3} ms vs {:.3} ms)",
                cont.throughput_rps / base.throughput_rps,
                cont.p99_ms,
                base.p99_ms
            );
        }
    }
    match std::fs::write("BENCH_serve.json", loadgen::bench_json(&reports)) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => println!("could not write BENCH_serve.json: {e}"),
    }
}

/// Measured rust-native training throughput (FP + BP + PU) across
/// optimizer x batch x compute schedule x storage precision x
/// checkpoint policy — the artifact-free counterpart of the `pjrt`
/// section.  Also emits `BENCH_native_train.json` so the perf
/// trajectory of the native trainer is recorded across PRs; the
/// fused/batched rows, the looped baseline, the bf16 storage-path rows
/// and the cached-vs-recompute rows come from the same run, so the
/// JSON itself documents the schedule speedup, the mixed-precision
/// trade and the gradient-checkpointing memory/throughput trade
/// (`recompute_mem_reduction_b8` = at-rest Eq. 21 bytes eliminated by
/// `--checkpoint recompute` at adam/batch 8/f32; per-row
/// `eq21_cache_bytes` is the measured sum of the live caches'
/// `stored_bytes()`, the same source of truth the resource model is
/// tested against).
fn native_train() {
    hdr("native-train", "measured native training throughput (no artifacts)");
    let cfg = ModelConfig::paper(2);
    let data = Dataset::synth(&cfg, 42, 64);
    // (optimizer, batch, schedule, precision, checkpoint): the default
    // fused/batched f32 hot path across the optimizer grid, the two
    // batch-8 baselines that isolate the fused-QKV and batched-
    // attention wins, the bf16 storage-path rows (halved Eq. 21 cache +
    // optimizer state), and the recompute rows (dropped Eq. 21 cache;
    // bf16 x recompute is the paper's full memory story).
    // Elementwise fusion stays on so this row isolates the QKV knob.
    let unfused_batched =
        ComputePath { fused_qkv: false, batched_attention: true, fused_elementwise: true };
    let cache = CheckpointPolicy::CacheAll;
    let recompute = CheckpointPolicy::Recompute;
    let grid = [
        (OptimKind::Sgd, 1usize, ComputePath::fused(), Precision::F32, cache.clone()),
        (OptimKind::Sgd, 8, ComputePath::fused(), Precision::F32, cache.clone()),
        (OptimKind::Adam, 1, ComputePath::fused(), Precision::F32, cache.clone()),
        (OptimKind::Adam, 8, ComputePath::fused(), Precision::F32, cache.clone()),
        (OptimKind::Adam, 8, unfused_batched, Precision::F32, cache.clone()),
        (OptimKind::Adam, 8, ComputePath::looped(), Precision::F32, cache.clone()),
        (OptimKind::Adam, 1, ComputePath::fused(), Precision::Bf16, cache.clone()),
        (OptimKind::Adam, 8, ComputePath::fused(), Precision::Bf16, cache),
        (OptimKind::Adam, 8, ComputePath::fused(), Precision::F32, recompute.clone()),
        (OptimKind::Adam, 8, ComputePath::fused(), Precision::Bf16, recompute),
    ];
    let mut rows = Vec::new();
    let mut fused_b8 = None;
    let mut looped_b8 = None;
    let mut bf16_b8 = None;
    let mut cached_bytes_b8 = None;
    let mut recompute_bytes_b8 = None;
    for (kind, batch, path, precision, checkpoint) in grid {
        let optim = OptimConfig { kind, batch_size: batch, precision, ..Default::default() };
        // Fail loudly: a silent early return would leave
        // BENCH_native_train.json unwritten and surface only as a
        // confusing missing-artifact error in CI.
        // with_optim applies the config's storage precision model-wide.
        let backend = NativeTrainer::random_init(&cfg, 42)
            .expect("paper config init")
            .with_optim(optim)
            .with_compute_path(path)
            .with_checkpoint(checkpoint.clone());
        let mut trainer = Trainer::with_batch(backend, kind.default_lr(), batch);
        let stats = bench(
            || {
                trainer.train_steps(&data, 1).unwrap();
            },
            1,
            4,
        );
        let steps_per_sec = 1.0 / stats.p50;
        let tokens_per_sec = (batch * cfg.seq_len) as f64 / stats.p50;
        let mean_loss = trainer.metrics.recent_loss(4);
        // On-chip bytes of this configuration: the measured at-rest
        // Eq. 21 cache (sum of the live caches' stored_bytes over one
        // batch-shaped forward) plus the moments actually allocated.
        let tokens: Vec<i32> = data.examples[..batch]
            .iter()
            .flat_map(|e| e.tokens.clone())
            .collect();
        let eq21_cache_bytes = trainer
            .backend
            .model
            .measure_eq21_cache_bytes(&tokens)
            .expect("cache measurement");
        let optim_state_bytes = trainer.backend.model.optim.allocated_state_bytes();
        let qkv = if path.fused_qkv { "fused" } else { "separate" };
        let attn = if path.batched_attention { "batched" } else { "looped" };
        let is_cached = checkpoint == CheckpointPolicy::CacheAll;
        if kind == OptimKind::Adam && batch == 8 && path == ComputePath::fused() {
            match precision {
                Precision::F32 if is_cached => {
                    fused_b8 = Some(steps_per_sec);
                    cached_bytes_b8 = Some(eq21_cache_bytes);
                }
                Precision::F32 => recompute_bytes_b8 = Some(eq21_cache_bytes),
                Precision::Bf16 if is_cached => bf16_b8 = Some(steps_per_sec),
                _ => {}
            }
        }
        if kind == OptimKind::Adam && batch == 8 && path == ComputePath::looped() {
            looped_b8 = Some(steps_per_sec);
        }
        println!(
            "{:<8} batch {batch} qkv {qkv:<8} attn {attn:<7} prec {:<4} ckpt {:<9}: step {} | \
             {:.2} steps/s | {:.0} tokens/s | cache {} B | state {} B | loss {mean_loss:.4}",
            kind.name(),
            precision.name(),
            checkpoint.name(),
            stats.fmt_ms(),
            steps_per_sec,
            tokens_per_sec,
            eq21_cache_bytes,
            optim_state_bytes
        );
        rows.push(format!(
            "    {{\"optimizer\": \"{}\", \"batch\": {batch}, \"qkv\": \"{qkv}\", \
             \"attention\": \"{attn}\", \"precision\": \"{}\", \"checkpoint\": \"{}\", \
             \"p50_step_secs\": {:.6}, \
             \"steps_per_sec\": {steps_per_sec:.3}, \"tokens_per_sec\": {tokens_per_sec:.1}, \
             \"eq21_cache_bytes\": {eq21_cache_bytes}, \
             \"optim_state_bytes\": {optim_state_bytes}, \"mean_loss\": {mean_loss:.5}}}",
            kind.name(),
            precision.name(),
            checkpoint.name(),
            stats.p50
        ));
    }
    let speedup = match (fused_b8, looped_b8) {
        (Some(f), Some(l)) if l > 0.0 => f / l,
        _ => 0.0,
    };
    let bf16_speedup = match (bf16_b8, fused_b8) {
        (Some(b), Some(f)) if f > 0.0 => b / f,
        _ => 0.0,
    };
    // At-rest Eq. 21 bytes the recompute policy eliminates at the
    // adam/batch-8/f32 configuration (measured, not modeled).
    let mem_reduction = match (cached_bytes_b8, recompute_bytes_b8) {
        (Some(c), Some(r)) => c.saturating_sub(r),
        _ => 0,
    };
    println!("fused/batched vs looped baseline (adam, batch 8): {speedup:.2}x steps/s");
    println!("bf16 vs f32 storage path (adam, batch 8, fused): {bf16_speedup:.2}x steps/s");
    println!(
        "recompute vs cached Eq. 21 bytes (adam, batch 8, f32): {} B -> {} B ({mem_reduction} B saved)",
        cached_bytes_b8.unwrap_or(0),
        recompute_bytes_b8.unwrap_or(0)
    );
    // Eval latency through the merged-factor engine (batch 1).
    let backend = NativeTrainer::random_init(&cfg, 42).expect("init");
    let ex = data.examples[0].clone();
    let eval_stats = bench(
        || {
            backend.eval(&ex.tokens).unwrap();
        },
        2,
        10,
    );
    println!("eval (batch 1): {}", eval_stats.fmt_ms());
    let json = format!(
        "{{\n  \"bench\": \"native_train\",\n  \"model\": \"tt_L2\",\n  \"seq_len\": {},\n  \
         \"eval_p50_secs\": {:.6},\n  \"fused_vs_looped_speedup_b8\": {speedup:.3},\n  \
         \"bf16_vs_f32_speedup_b8\": {bf16_speedup:.3},\n  \
         \"recompute_mem_reduction_b8\": {mem_reduction},\n  \"rows\": [\n{}\n  ]\n}}\n",
        cfg.seq_len,
        eval_stats.p50,
        rows.join(",\n")
    );
    match std::fs::write("BENCH_native_train.json", &json) {
        Ok(()) => println!("wrote BENCH_native_train.json"),
        Err(e) => println!("could not write BENCH_native_train.json: {e}"),
    }
}

/// The precision x compute-path x checkpoint-policy grid
/// (`tt_trainer::benchgrid`, shared with the `bench-matrix` CLI
/// command): 4 precisions x {fused, looped} x {cache, recompute} at the
/// paper config, batch 8, with per-cell tokens/sec, the FP/BP/PU stage
/// split of a traced step and the measured at-rest packed-parameter /
/// Eq. 21 cache / optimizer-state bytes.  Writes `BENCH_matrix.json`;
/// CI gates on its `fused_bf16_vs_unfused_f32` staying above 1.0 and
/// on `int8_param_bytes_ratio` staying at or below 0.27x f32.
fn matrix() {
    hdr("matrix", "precision x path x checkpoint grid (no artifacts)");
    // Fail loudly (see native_train): a silent skip would surface only
    // as a missing BENCH_matrix.json artifact in CI.
    let report = tt_trainer::benchgrid::run_paper_matrix(1, 4).expect("matrix grid");
    print!("{}", report.render_table());
    match std::fs::write("BENCH_matrix.json", report.to_json()) {
        Ok(()) => println!("wrote BENCH_matrix.json"),
        Err(e) => println!("could not write BENCH_matrix.json: {e}"),
    }
}

/// The data-parallel replica sweep (`tt_trainer::benchgrid`, shared
/// with the `bench-replicas` CLI command): tokens/sec of the
/// deterministic fixed-order all-reduce group at R ∈ {1, 2, 4} on one
/// global batch at the paper config.  Writes `BENCH_replicas.json`;
/// CI gates on `r4_vs_r1` ≥ 1.5 when the runner has ≥ 4 cores (the
/// JSON records `host_cores` so the gate can skip loudly otherwise).
/// Also prints the exchange-volume sweep and the per-device budget
/// split so the scaling row carries its memory story.
fn replicas() {
    hdr("replicas", "data-parallel replica sweep (no artifacts)");
    let cfg = ModelConfig::paper(2);
    // Fail loudly (see native_train): a silent skip would surface only
    // as a missing BENCH_replicas.json artifact in CI.
    let report = tt_trainer::benchgrid::run_paper_replicas(1, 4).expect("replica sweep");
    print!("{}", report.render_table());
    print!("{}", sweeps::replica_exchange_table(&cfg, Precision::F32));
    let budget = resources::replica_budget(
        &cfg,
        OptimKind::Adam,
        Precision::F32,
        &CheckpointPolicy::CacheAll,
        4,
    );
    println!(
        "N=4 budget: device0 state {} B | follower state {} B | exchange buffer {} B/dev",
        budget.device0.optim_state_bytes,
        budget.device_n.optim_state_bytes,
        budget.exchange_buffer_bytes
    );
    match std::fs::write("BENCH_replicas.json", report.to_json()) {
        Ok(()) => println!("wrote BENCH_replicas.json"),
        Err(e) => println!("could not write BENCH_replicas.json: {e}"),
    }
}

fn hdr(name: &str, what: &str) {
    println!("\n==================== {name}: {what} ====================");
}

fn table1() {
    hdr("table1", "training complexity of each linear layer (m = n = 768)");
    let shape = LinearShape::paper();
    let k = 32u64;
    let f = LinearShape::training_factor();
    println!(
        "{:<6} {:>16} {:>14} {:>14}",
        "method", "training muls", "weight elems", "act elems"
    );
    for r in compare_all(&shape, k) {
        println!(
            "{:<6} {:>16} {:>14} {:>14}",
            r.method,
            r.fwd_muls * f,
            r.weight_elems,
            r.memory_elems
        );
    }
    println!("(formulas validated against instrumented contraction engines in cargo test)");
}

fn fig6() {
    hdr("fig6", "contraction cost comparison (Table II shape, K = 32)");
    let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], 12);
    for r in compare_all(&shape, 32) {
        println!(
            "{:<6} muls {:>12} | total mem {:>10} | comp-red {:>7.2}x | mem-red {:>7.2}x",
            r.method, r.fwd_muls, r.total_memory, r.compute_reduction, r.memory_reduction
        );
    }
    println!("paper: BTT vs MM = 22.51x compute / 22.67x memory");
}

fn fig7() {
    hdr("fig7", "sweeps (top: seq len @ rank 12; bottom: rank @ seq 32)");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::seq_len_sweep(12, &sweeps::paper_seq_lens()), "seq")
    );
    println!();
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::rank_sweep(32, &sweeps::paper_ranks()), "rank")
    );
}

fn fig9() {
    hdr("fig9", "QKV forward scheduling (makespan in cycles)");
    let shape = LinearShape::paper();
    let (naive, resched) = schedule::fig9_compare(&shape, 32, 12);
    println!("naive (6 MUL0 units):       {naive}");
    println!("rescheduled (2 MUL0 units): {resched}");
    assert_eq!(naive, resched, "rescheduling must not increase latency");
    println!("=> same makespan with 1/3 of the MUL0 kernel instances");
    let fused = schedule::fig9_fused_makespan(&shape, 32, 12);
    println!("fused QKV (2 MUL0 units):   {fused} (the schedule the native trainer executes)");
    println!(
        "=> fused fwd muls {} vs 3x separate {}",
        shape.btt_fwd_qkv_muls(32),
        3 * shape.btt_muls(32)
    );
}

fn fig10() {
    hdr("fig10", "BP intermediate buffer (elements)");
    let shape = LinearShape::paper();
    let unfused = schedule::fig10_buffer_elems(&shape, false);
    let fused = schedule::fig10_buffer_elems(&shape, true);
    println!("unfused: {unfused}");
    println!("fused:   {fused} (reduction {}x)", unfused / fused);
}

fn fig12() {
    hdr("fig12", "BRAM utilization efficiency by strategy");
    for layers in [2usize, 4, 6] {
        let allocs = bram::strategy_comparison(layers, 12);
        let base = allocs[0].efficiency;
        for a in &allocs {
            println!(
                "{}-ENC {:<20} blocks {:>6} eta {:.3} (x{:.1} vs partition/default)",
                layers,
                a.strategy.name(),
                a.total_blocks,
                a.efficiency,
                a.efficiency / base
            );
        }
    }
    println!("paper: grouped management is 3.9x-8.4x more efficient");
}

fn fig14() {
    hdr("fig14", "BRAM for all TT cores vs rank (2-ENC)");
    for rank in [2usize, 4, 8, 12, 16, 24, 32, 48] {
        let allocs = bram::strategy_comparison(2, rank);
        println!(
            "rank {rank:>2}: partition/default {:>6} | reshape/default {:>6} | partition/grouped {:>6} | reshape/grouped {:>6} | ideal {:>8.1}",
            allocs[0].total_blocks,
            allocs[1].total_blocks,
            allocs[2].total_blocks,
            allocs[3].total_blocks,
            allocs[3].ideal_blocks
        );
    }
}

fn table2() {
    hdr("table2", "layer configuration (paper Table II)");
    let cfg = ModelConfig::paper(2);
    println!(
        "embedding: TTM ({}, {}) modes {:?} x {:?} rank {}",
        cfg.vocab, cfg.d_hid, cfg.ttm_vocab_modes, cfg.ttm_hid_modes, cfg.ttm_rank
    );
    println!(
        "attention/ffn/classifier: TT ({}, {}) modes {:?} x {:?} rank {}",
        cfg.d_hid, cfg.d_hid, cfg.tt_m, cfg.tt_n, cfg.tt_rank
    );
}

fn table3() {
    hdr("table3", "model sizes and compression (accuracy: see examples/train_atis)");
    println!(
        "{:<8} {:>12} {:>12} {:>12} {:>12}",
        "model", "dense MB", "tensor MB", "compression", "paper ratio"
    );
    for (layers, paper) in [(2usize, 30.5), (4, 43.4), (6, 52.0)] {
        let cfg = ModelConfig::paper(layers);
        let dense = cfg.dense_equivalent_params() as f64 * 4.0 / 1e6;
        let tensor = cfg.tensor_params() as f64 * 4.0 / 1e6;
        println!(
            "{:<8} {:>12.1} {:>12.2} {:>11.1}x {:>11.1}x",
            format!("{layers}-ENC"),
            dense,
            tensor,
            dense / tensor,
            paper
        );
    }
}

fn table4() {
    hdr("table4", "resource utilization (simulator)");
    for layers in [2usize, 4, 6] {
        let r = resources::report(&ModelConfig::paper(layers));
        println!(
            "{layers}-ENC: DSP {:>5} | LUT {:>7} | FF {:>7} | BRAM {:>5} | URAM {:>4} | {:.2} W",
            r.dsp.used, r.lut.used, r.ff.used, r.bram.used, r.uram.used, r.total_power_w()
        );
    }
    println!("paper:  DSP  2396 | LUT 565-579k | FF 475-499k | BRAM 1216/1163/1089 | URAM 114/128/374 | 26.7-27.1 W");
}

fn table5() {
    hdr("table5", "GPU vs FPGA end-to-end (+ Figs. 1/15)");
    print!("{}", energy::render_table_v(&energy::table_v()));
    println!();
    for p in energy::fig15() {
        println!(
            "fig15 L{}: GPU total {:.0} MB | reserved MM {:.0} | reserved BTT {:.0} | FPGA {:.1}",
            p.n_layers, p.gpu_total_mb, p.gpu_reserved_matrix_mb, p.gpu_reserved_btt_mb, p.fpga_mb
        );
    }
}

fn wallclock() {
    hdr("wallclock", "rust contraction engines, measured (768x768, K = 32)");
    let mut rng = SplitMix64::new(77);
    let tt = TTMatrix::randn(&[12, 8, 8], &[8, 8, 12], 12, 0.03, &mut rng);
    let x = Tensor::randn(&[768, 32], 1.0, &mut rng);
    let w = tt.to_dense().unwrap();

    let s_mm = bench(
        || {
            std::hint::black_box(w.matmul(&x).unwrap());
        },
        3,
        20,
    );
    let s_rl = bench(
        || {
            std::hint::black_box(tt.matmul_right_to_left(&x).unwrap());
        },
        3,
        20,
    );
    let s_btt = bench(
        || {
            std::hint::black_box(tt.matmul_btt(&x).unwrap());
        },
        3,
        20,
    );
    println!("MM  dense: {}", s_mm.fmt_ms());
    println!("TT  r-to-l: {}", s_rl.fmt_ms());
    println!("BTT (ours): {}", s_btt.fmt_ms());
    println!(
        "speedups: BTT vs MM {:.2}x | BTT vs TT {:.2}x",
        s_mm.best / s_btt.best,
        s_rl.best / s_btt.best
    );
}

/// Design-choice ablations called out in DESIGN.md: each knob of the
/// paper's system varied in isolation.
fn ablations() {
    hdr("ablations", "design-choice studies");

    // (a) Contraction order: BTT vs right-to-left, epoch latency.
    println!("-- contraction order (Table V latency model) --");
    for layers in [2usize, 4, 6] {
        let mut m = schedule::CycleModel::paper(layers);
        let btt = m.epoch_latency_secs(schedule::ATIS_TRAIN_SAMPLES);
        m.btt = false;
        let rl = m.epoch_latency_secs(schedule::ATIS_TRAIN_SAMPLES);
        println!(
            "L{layers}: BTT {btt:>6.0} s/epoch | right-to-left {rl:>6.0} s/epoch | speedup {:.2}x",
            rl / btt
        );
    }

    // (b) Grouping factor K: BRAM blocks vs the paper's K = (d-1)L.
    println!("\n-- tensor-grouping factor (2-ENC, rank 12) --");
    let cores = bram::paper_core_set(2, 12);
    for k in [1usize, 2, 4, 8, 16, 32] {
        let a = bram::allocate(&cores, bram::Strategy::ReshapeGrouped, k);
        let mark = if k == bram::paper_group_k(3, 2) { "  <- paper K=(d-1)L" } else { "" };
        println!("K = {k:>2}: {:>5} blocks, eta {:.3}{mark}", a.total_blocks, a.efficiency);
    }

    // (c) Rank-parallel lane width vs epoch latency (the CycleModel's
    // calibration knob; the paper parallelizes over the TT rank = 12).
    println!("\n-- MAC lane width (L2 latency) --");
    for lanes in [4u64, 8, 12, 16, 24, 48] {
        let mut m = schedule::CycleModel::paper(2);
        m.lanes = lanes;
        println!(
            "lanes = {lanes:>2}: {:>5.0} s/epoch",
            m.epoch_latency_secs(schedule::ATIS_TRAIN_SAMPLES)
        );
    }

    // (d) TT rank vs model size + per-layer compute (accuracy/size knob).
    println!("\n-- TT rank (768x768 layer, K = 32) --");
    for rank in [2usize, 4, 8, 12, 16, 24] {
        let shape = LinearShape::uniform(&[12, 8, 8], &[8, 8, 12], rank);
        println!(
            "rank {rank:>2}: params {:>6} | BTT muls {:>9} | compute-reduction {:>7.1}x",
            shape.tt_params(),
            shape.btt_muls(32),
            shape.mm_muls(32) as f64 / shape.btt_muls(32) as f64
        );
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt() {
    hdr("pjrt", "measured end-to-end step latency through the AOT stack");
    println!("built without the `pjrt` feature (skipped)");
}

#[cfg(feature = "pjrt")]
fn pjrt() {
    hdr("pjrt", "measured end-to-end step latency through the AOT stack");
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(_) => {
            println!("artifacts/ not found - run `make artifacts` first (skipped)");
            return;
        }
    };
    for name in ["tt_L2", "mm_L2"] {
        let Ok(spec) = manifest.variant(name) else {
            println!("{name}: not in manifest (skipped)");
            continue;
        };
        let mut engine = match Engine::load(spec) {
            Ok(e) => e,
            Err(e) => {
                println!("{name}: load failed: {e} (skipped)");
                continue;
            }
        };
        let cfg = spec.config.clone();
        let data = Dataset::synth(&cfg, 42, 8);
        let ex = data.examples[0].clone();
        // Warmup + measure.
        let mut losses = Vec::new();
        let stats = bench(
            || {
                let out = engine
                    .train_step(&ex.tokens, &[ex.intent], &ex.slots, 4e-3)
                    .unwrap();
                losses.push(out.loss);
            },
            2,
            10,
        );
        println!("{name}: train_step {}", stats.fmt_ms());
    }
}
