//! Continuous-batching serving layer over the shared inference engine.
//!
//! The ROADMAP's production-scale story: concurrent intent/slot
//! requests are coalesced into dynamic micro-batches that ride the
//! contraction K dimension of the fused-QKV + batched-attention
//! kernels — the same `(B, S)` forward training uses
//! ([`crate::engine::NativeEngine::forward_len`]), pointed at traffic.
//!
//! **Scheduler semantics.**  One executor thread owns the engine (the
//! dense kernels already parallelize each batch across the persistent
//! worker pool, so request-level concurrency comes from batching, not
//! from competing executors).  Requests enter per-bucket FIFO queues; a
//! bucket fires as soon as it holds [`ServeConfig::max_batch`] requests
//! or its oldest request has waited [`ServeConfig::max_wait`],
//! whichever comes first; among ready buckets the oldest head wins
//! (FIFO fairness across lengths).  Shutdown drains every queued
//! request before the executor exits.
//!
//! **Bucketing policy.**  A request's trailing pads are trimmed and its
//! effective length is rounded up to the next multiple of
//! [`ServeConfig::bucket`] (capped at the model's `seq_len`); requests
//! sharing a bucket are padded to that length and batched into one
//! dense `(B, S')` block — the `bmm*` kernels never see ragged shapes.
//! Trimming is value-preserving (pad keys carry an exact-zero attention
//! probability; every other op is per-row), so bucketed serving
//! reproduces the full-length logits for every valid position.
//!
//! **Backpressure contract.**  Admission control is explicit: at most
//! [`ServeConfig::queue_cap`] requests may be queued; a submit beyond
//! that is rejected *immediately* with [`SubmitError::QueueFull`]
//! (counted in [`ServeStats::rejected`]) instead of growing the queue
//! without bound.  Accepted requests are always answered — served,
//! failed with the batch's error, or drained at shutdown.
//!
//! **Determinism guarantee.**  A request's bucket length is a pure
//! function of its effective length, and the blocked kernels accumulate
//! per output row, so its intent/slot predictions are **bitwise
//! identical** whether it is served alone, in a full bucket, or
//! interleaved with requests of other lengths — across `Precision`
//! f32/bf16/f16 and both `ComputePath`s (pinned by
//! `rust/tests/serving.rs`).

pub mod loadgen;

use crate::coordinator::metrics::{argmax, percentile};
use crate::engine::NativeEngine;
use crate::trace;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduler knobs; see the module docs for the policy they select.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Most requests coalesced into one micro-batch (>= 1).
    pub max_batch: usize,
    /// Longest a bucket's oldest request may wait before the bucket
    /// fires below `max_batch`.
    pub max_wait: Duration,
    /// Admission-control bound: most requests queued at once before
    /// submits are rejected with [`SubmitError::QueueFull`].
    pub queue_cap: usize,
    /// Padded-length bucket granularity (>= 1): an effective length is
    /// rounded up to the next multiple, capped at the model `seq_len`.
    pub bucket: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
            bucket: 8,
        }
    }
}

impl ServeConfig {
    /// The baseline policy the serve bench compares against: every
    /// request runs alone, immediately (`max_batch` 1, zero wait).
    pub fn no_batching() -> ServeConfig {
        ServeConfig { max_batch: 1, max_wait: Duration::ZERO, ..ServeConfig::default() }
    }

    /// `"continuous"` vs the `"no-batching"` baseline — the policy axis
    /// of `BENCH_serve.json`.
    pub fn policy_name(&self) -> &'static str {
        if self.max_batch <= 1 {
            "no-batching"
        } else {
            "continuous"
        }
    }

    /// The padded length a request of effective length `eff` is served
    /// at: `eff` rounded up to the bucket granularity, capped at
    /// `seq_len`.  Pure in `eff` — the determinism guarantee rests on
    /// this.
    pub fn bucket_len(&self, eff: usize, seq_len: usize) -> usize {
        let g = self.bucket.max(1);
        (eff.max(1).div_ceil(g) * g).min(seq_len)
    }
}

/// Effective length of a request: its tokens with trailing pads
/// trimmed (an all-pad request keeps one position).
pub fn effective_len(tokens: &[i32], pad_id: i32) -> usize {
    tokens.iter().rposition(|&t| t != pad_id).map_or(1, |i| i + 1)
}

/// Why a submit was refused at the door (the backpressure contract —
/// these are *admission* failures; an accepted request never surfaces
/// one).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at capacity — explicit reject, not OOM.
    QueueFull { capacity: usize },
    /// The server is shutting down.
    Closed,
    /// Empty token slice.
    Empty,
    /// More tokens than the model's configured `seq_len`.
    TooLong { len: usize, max: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { capacity } => {
                write!(f, "queue full (capacity {capacity}); retry later")
            }
            SubmitError::Closed => write!(f, "server is shutting down"),
            SubmitError::Empty => write!(f, "empty request"),
            SubmitError::TooLong { len, max } => {
                write!(f, "request has {len} tokens, model seq_len is {max}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// One served request: greedy predictions, raw logits (for parity
/// checks), and per-request latency accounting.  `slots` /
/// `slot_logits` cover the request's **effective** positions (trailing
/// pads trimmed at admission).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub intent: usize,
    pub intent_logits: Vec<f32>,
    pub slots: Vec<usize>,
    pub slot_logits: Vec<f32>,
    /// Submit -> response (queue wait + batch compute).
    pub latency: Duration,
    /// Submit -> batch launch.
    pub queue_wait: Duration,
    /// Requests in the micro-batch that served this one.
    pub batch_size: usize,
    /// Padded length the batch ran at.
    pub bucket_len: usize,
}

/// A queued request awaiting its batch.
struct Pending {
    id: u64,
    /// Tokens with trailing pads trimmed (`effective_len` positions).
    tokens: Vec<i32>,
    enqueued: Instant,
    tx: mpsc::Sender<Result<Response, String>>,
}

/// Ticket for a submitted request; [`PendingResponse::wait`] blocks
/// until the scheduler answers.
pub struct PendingResponse {
    id: u64,
    rx: mpsc::Receiver<Result<Response, String>>,
}

impl PendingResponse {
    /// The request id the response will carry.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until served (or failed / dropped at executor death).
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow!("request failed: {msg}")),
            Err(_) => Err(anyhow!("server terminated before answering")),
        }
    }
}

/// Mutable scheduler state behind the queue mutex.
struct QueueState {
    /// Per-bucket FIFO queues, keyed by padded length.  Emptied keys
    /// are removed, so every present queue is non-empty.
    buckets: BTreeMap<usize, VecDeque<Pending>>,
    /// Total queued across buckets (the admission-control count).
    queued: usize,
    /// Most requests ever queued at once (the backpressure headroom
    /// actually used; reported as [`ServeStats::queue_depth_hwm`]).
    queued_hwm: usize,
    closed: bool,
}

/// Distribution accounting updated by the executor per batch (its own
/// mutex so the hot admission path never contends on it).
#[derive(Default)]
struct TailState {
    /// `bucket_len -> (served, batches)`.
    per_bucket: BTreeMap<usize, (u64, u64)>,
    /// `batch size -> count` (sparse histogram).
    batch_hist: BTreeMap<u64, u64>,
    /// Per-request submit -> response latency in seconds, completion
    /// order; percentiles computed once at shutdown.
    latency_secs: Vec<f64>,
}

/// State shared between handles and the executor thread.
struct Shared {
    engine: Arc<NativeEngine>,
    cfg: ServeConfig,
    state: Mutex<QueueState>,
    tail: Mutex<TailState>,
    work: Condvar,
    next_id: AtomicU64,
    rejected: AtomicU64,
    served: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    batch_rows: AtomicU64,
    max_batch_seen: AtomicU64,
}

/// Per-bucket serving counters (one row per padded length that ever
/// executed a batch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketStats {
    /// Padded length of the bucket.
    pub bucket_len: usize,
    /// Requests served at this length.
    pub served: u64,
    /// Micro-batches executed at this length.
    pub batches: u64,
}

/// Lifetime counters of one server, snapshotted at shutdown.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub served: u64,
    pub failed: u64,
    /// Submits refused by admission control ([`SubmitError::QueueFull`]).
    pub rejected: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Mean requests per micro-batch (0 if none ran).
    pub mean_batch: f64,
    pub max_batch: u64,
    /// Per-bucket served/batch counts, ascending by bucket length.
    pub per_bucket: Vec<BucketStats>,
    /// Most requests ever queued at once.
    pub queue_depth_hwm: u64,
    /// Request latency percentiles (submit -> response, milliseconds)
    /// over every *served* request, computed at shutdown via the shared
    /// [`percentile`] helper (NaN when nothing was served).
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
}

/// The serving scheduler: request queue + one executor thread over a
/// shared read-only engine.  See the module docs for the scheduling,
/// bucketing, backpressure and determinism contracts.
pub struct Server {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Cloneable submit-side handle (one per client thread).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Submit one request (`1..=seq_len` token ids; trailing pads are
    /// trimmed at admission).  Non-blocking: either the request is
    /// queued and a [`PendingResponse`] is returned, or admission
    /// refuses it with a [`SubmitError`].
    pub fn submit(&self, tokens: &[i32]) -> Result<PendingResponse, SubmitError> {
        let _sp = trace::span("serve", "admit");
        let shared = &*self.shared;
        let max = shared.engine.cfg.seq_len;
        if tokens.is_empty() {
            return Err(SubmitError::Empty);
        }
        if tokens.len() > max {
            return Err(SubmitError::TooLong { len: tokens.len(), max });
        }
        let eff = effective_len(tokens, shared.engine.cfg.pad_id);
        let bucket = shared.cfg.bucket_len(eff, max);
        let (tx, rx) = mpsc::channel();
        let mut st = shared.state.lock().expect("serve queue poisoned");
        if st.closed {
            return Err(SubmitError::Closed);
        }
        if st.queued >= shared.cfg.queue_cap {
            shared.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull { capacity: shared.cfg.queue_cap });
        }
        let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
        st.queued += 1;
        st.queued_hwm = st.queued_hwm.max(st.queued);
        if trace::enabled() {
            trace::gauge_set("serve_queue_depth", st.queued as u64);
        }
        st.buckets.entry(bucket).or_default().push_back(Pending {
            id,
            tokens: tokens[..eff].to_vec(),
            enqueued: Instant::now(),
            tx,
        });
        drop(st);
        shared.work.notify_one();
        Ok(PendingResponse { id, rx })
    }

    /// Prometheus text-exposition (0.0.4) snapshot of the live serving
    /// counters — readable at any point in the server's life, not only
    /// at shutdown.  Rendered from the scheduler's own state (the same
    /// sources [`Server::shutdown`] snapshots), so it needs no tracing
    /// enablement.
    pub fn prometheus_snapshot(&self) -> String {
        use trace::prom::{render, MetricFamily, Sample};
        let s = &*self.shared;
        let (queued, hwm) = {
            let st = s.state.lock().expect("serve queue poisoned");
            (st.queued as f64, st.queued_hwm as f64)
        };
        let (bucket_rows, hist_rows) = {
            let tail = s.tail.lock().expect("serve tail poisoned");
            let buckets: Vec<(usize, u64, u64)> = tail
                .per_bucket
                .iter()
                .map(|(&len, &(served, batches))| (len, served, batches))
                .collect();
            let hist: Vec<(u64, u64)> =
                tail.batch_hist.iter().map(|(&sz, &n)| (sz, n)).collect();
            (buckets, hist)
        };
        let counter = |name: &str, help: &str, v: u64| MetricFamily {
            name: name.to_string(),
            help: help.to_string(),
            kind: "counter",
            samples: vec![Sample::plain(v as f64)],
        };
        let families = vec![
            counter(
                "serve_requests_served_total",
                "Requests answered with predictions.",
                s.served.load(Ordering::Relaxed),
            ),
            counter(
                "serve_requests_failed_total",
                "Requests answered with a batch-level error.",
                s.failed.load(Ordering::Relaxed),
            ),
            counter(
                "serve_requests_rejected_total",
                "Submits refused by admission control.",
                s.rejected.load(Ordering::Relaxed),
            ),
            counter(
                "serve_batches_total",
                "Micro-batches executed.",
                s.batches.load(Ordering::Relaxed),
            ),
            MetricFamily {
                name: "serve_queue_depth".to_string(),
                help: "Requests currently queued.".to_string(),
                kind: "gauge",
                samples: vec![Sample::plain(queued)],
            },
            MetricFamily {
                name: "serve_queue_depth_high_watermark".to_string(),
                help: "Most requests ever queued at once.".to_string(),
                kind: "gauge",
                samples: vec![Sample::plain(hwm)],
            },
            MetricFamily {
                name: "serve_batch_size_count".to_string(),
                help: "Micro-batches executed, by batch size.".to_string(),
                kind: "counter",
                samples: hist_rows
                    .iter()
                    .map(|&(sz, n)| Sample::labeled("batch_size", sz, n as f64))
                    .collect(),
            },
            MetricFamily {
                name: "serve_bucket_served_total".to_string(),
                help: "Requests served, by padded bucket length.".to_string(),
                kind: "counter",
                samples: bucket_rows
                    .iter()
                    .map(|&(len, served, _)| Sample::labeled("bucket_len", len, served as f64))
                    .collect(),
            },
            MetricFamily {
                name: "serve_bucket_batches_total".to_string(),
                help: "Micro-batches executed, by padded bucket length.".to_string(),
                kind: "counter",
                samples: bucket_rows
                    .iter()
                    .map(|&(len, _, batches)| Sample::labeled("bucket_len", len, batches as f64))
                    .collect(),
            },
        ];
        render(&families)
    }
}

impl Server {
    /// Spawn the executor thread over a shared engine.
    pub fn start(engine: Arc<NativeEngine>, cfg: ServeConfig) -> Result<Server> {
        if cfg.max_batch == 0 || cfg.queue_cap == 0 || cfg.bucket == 0 {
            return Err(anyhow!(
                "serve config must have max_batch, queue_cap and bucket >= 1 (got {cfg:?})"
            ));
        }
        let shared = Arc::new(Shared {
            engine,
            cfg,
            state: Mutex::new(QueueState {
                buckets: BTreeMap::new(),
                queued: 0,
                queued_hwm: 0,
                closed: false,
            }),
            tail: Mutex::new(TailState::default()),
            work: Condvar::new(),
            next_id: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            served: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_rows: AtomicU64::new(0),
            max_batch_seen: AtomicU64::new(0),
        });
        let worker_shared = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("serve-executor".into())
            .spawn(move || worker_loop(&worker_shared))?;
        Ok(Server { shared, worker: Some(worker) })
    }

    /// A cloneable submit handle for client threads.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// Close admission, drain every queued request, join the executor
    /// and return the lifetime counters.
    pub fn shutdown(mut self) -> ServeStats {
        self.close_and_join();
        let s = &self.shared;
        let batches = s.batches.load(Ordering::Relaxed);
        let rows = s.batch_rows.load(Ordering::Relaxed);
        let queue_depth_hwm =
            s.state.lock().expect("serve queue poisoned").queued_hwm as u64;
        let (per_bucket, lat_ms) = {
            let tail = s.tail.lock().expect("serve tail poisoned");
            let per_bucket = tail
                .per_bucket
                .iter()
                .map(|(&bucket_len, &(served, batches))| BucketStats {
                    bucket_len,
                    served,
                    batches,
                })
                .collect();
            let lat_ms: Vec<f64> = tail.latency_secs.iter().map(|&s| s * 1e3).collect();
            (per_bucket, lat_ms)
        };
        ServeStats {
            served: s.served.load(Ordering::Relaxed),
            failed: s.failed.load(Ordering::Relaxed),
            rejected: s.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 { 0.0 } else { rows as f64 / batches as f64 },
            max_batch: s.max_batch_seen.load(Ordering::Relaxed),
            per_bucket,
            queue_depth_hwm,
            latency_p50_ms: percentile(&lat_ms, 50.0),
            latency_p95_ms: percentile(&lat_ms, 95.0),
            latency_p99_ms: percentile(&lat_ms, 99.0),
        }
    }

    fn close_and_join(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("serve queue poisoned");
            st.closed = true;
        }
        self.shared.work.notify_all();
        if let Some(worker) = self.worker.take() {
            let _ = worker.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// The executor: wait for a ready bucket, drain up to `max_batch` of
/// it, run one dense forward, fan the results out.  Exits when closed
/// and fully drained.
fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().expect("serve queue poisoned");
            loop {
                let now = Instant::now();
                // Ready = full, aged out, or draining at shutdown; among
                // ready buckets the oldest head wins (FIFO fairness).
                let mut pick: Option<(usize, Instant)> = None;
                let mut earliest_deadline: Option<Instant> = None;
                for (&bucket, q) in st.buckets.iter() {
                    let head = q.front().expect("empty bucket queues are removed");
                    let deadline = head.enqueued + shared.cfg.max_wait;
                    if st.closed || q.len() >= shared.cfg.max_batch || deadline <= now {
                        if pick.map_or(true, |(_, t)| head.enqueued < t) {
                            pick = Some((bucket, head.enqueued));
                        }
                    } else if earliest_deadline.map_or(true, |d| deadline < d) {
                        earliest_deadline = Some(deadline);
                    }
                }
                if let Some((bucket, _)) = pick {
                    let q = st.buckets.get_mut(&bucket).expect("picked bucket exists");
                    let take = q.len().min(shared.cfg.max_batch);
                    let batch: Vec<Pending> = q.drain(..take).collect();
                    if q.is_empty() {
                        st.buckets.remove(&bucket);
                    }
                    st.queued -= batch.len();
                    if trace::enabled() {
                        trace::gauge_set("serve_queue_depth", st.queued as u64);
                    }
                    break Some((bucket, batch));
                }
                if st.closed {
                    break None;
                }
                st = match earliest_deadline {
                    Some(d) => {
                        let timeout = d.saturating_duration_since(now);
                        shared.work.wait_timeout(st, timeout).expect("serve queue poisoned").0
                    }
                    None => shared.work.wait(st).expect("serve queue poisoned"),
                };
            }
        };
        match job {
            Some((bucket, batch)) => run_batch(shared, bucket, batch),
            None => return,
        }
    }
}

/// Pad each request to the bucket length, run one dense `(B, S')`
/// forward, split the logits back per request.  A batch-level error
/// fans out to every member.
fn run_batch(shared: &Shared, bucket_len: usize, batch: Vec<Pending>) {
    let cfg = &shared.engine.cfg;
    let (ni, ns, pad) = (cfg.n_intents, cfg.n_slots, cfg.pad_id);
    let b = batch.len();
    let started = Instant::now();
    if trace::enabled() {
        // One retrospective queue span per batch: the oldest member's
        // enqueue to batch launch (the wait the scheduler imposed).
        if let Some(earliest) = batch.iter().map(|p| p.enqueued).min() {
            trace::record_span_at("serve", "queue", earliest, started);
        }
    }
    let mut tokens = vec![pad; b * bucket_len];
    for (i, p) in batch.iter().enumerate() {
        tokens[i * bucket_len..i * bucket_len + p.tokens.len()].copy_from_slice(&p.tokens);
    }
    shared.batches.fetch_add(1, Ordering::Relaxed);
    shared.batch_rows.fetch_add(b as u64, Ordering::Relaxed);
    shared.max_batch_seen.fetch_max(b as u64, Ordering::Relaxed);
    let sp_exec = trace::span("serve", "batch_execute");
    let result = shared.engine.forward_len(&tokens, bucket_len);
    drop(sp_exec);
    {
        let mut tail = shared.tail.lock().expect("serve tail poisoned");
        let row = tail.per_bucket.entry(bucket_len).or_insert((0, 0));
        row.1 += 1;
        if result.is_ok() {
            row.0 += b as u64;
        }
        *tail.batch_hist.entry(b as u64).or_insert(0) += 1;
    }
    if trace::enabled() {
        trace::hist_observe("serve_batch_size", b as u64);
    }
    match result {
        Ok((il, sl)) => {
            let _sp = trace::span("serve", "respond");
            let done = Instant::now();
            let mut latencies = Vec::with_capacity(b);
            for (i, p) in batch.into_iter().enumerate() {
                let eff = p.tokens.len();
                let intent_logits = il[i * ni..(i + 1) * ni].to_vec();
                let slot_logits =
                    sl[i * bucket_len * ns..i * bucket_len * ns + eff * ns].to_vec();
                let latency = done.duration_since(p.enqueued);
                latencies.push(latency.as_secs_f64());
                let resp = Response {
                    id: p.id,
                    intent: argmax(&intent_logits),
                    slots: slot_logits.chunks(ns).map(argmax).collect(),
                    intent_logits,
                    slot_logits,
                    latency,
                    queue_wait: started.duration_since(p.enqueued),
                    batch_size: b,
                    bucket_len,
                };
                shared.served.fetch_add(1, Ordering::Relaxed);
                // A dropped client is not an executor error.
                let _ = p.tx.send(Ok(resp));
            }
            shared
                .tail
                .lock()
                .expect("serve tail poisoned")
                .latency_secs
                .extend(latencies);
        }
        Err(e) => {
            let msg = e.to_string();
            for p in batch {
                shared.failed.fetch_add(1, Ordering::Relaxed);
                let _ = p.tx.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::tests::{tiny_cfg, tiny_params};
    use crate::engine::NativeEngine;

    fn tiny_engine(seed: u64) -> Arc<NativeEngine> {
        let cfg = tiny_cfg();
        Arc::new(NativeEngine::from_params(&cfg, &tiny_params(&cfg, seed)).unwrap())
    }

    /// A long max_wait + large max_batch keeps the executor from firing
    /// until shutdown (or until a bucket fills) — the deterministic
    /// fixture for queue-behavior tests.
    fn holding_config(max_batch: usize, queue_cap: usize) -> ServeConfig {
        ServeConfig {
            max_batch,
            max_wait: Duration::from_secs(3600),
            queue_cap,
            bucket: 4,
        }
    }

    #[test]
    fn bucket_len_policy() {
        let cfg = ServeConfig { bucket: 4, ..ServeConfig::default() };
        assert_eq!(cfg.bucket_len(1, 8), 4);
        assert_eq!(cfg.bucket_len(4, 8), 4);
        assert_eq!(cfg.bucket_len(5, 8), 8);
        assert_eq!(cfg.bucket_len(8, 8), 8);
        // Cap at seq_len even when the granularity overshoots.
        let coarse = ServeConfig { bucket: 16, ..ServeConfig::default() };
        assert_eq!(coarse.bucket_len(3, 8), 8);
        // eff 0 is clamped (all-pad requests keep one position).
        assert_eq!(cfg.bucket_len(0, 8), 4);
    }

    #[test]
    fn effective_len_trims_trailing_pads_only() {
        assert_eq!(effective_len(&[1, 5, 9, 0, 0], 0), 3);
        assert_eq!(effective_len(&[1, 0, 9, 0, 0], 0), 3); // interior pad kept
        assert_eq!(effective_len(&[1, 5, 9], 0), 3);
        assert_eq!(effective_len(&[0, 0], 0), 1);
    }

    #[test]
    fn serves_a_single_request() {
        let engine = tiny_engine(21);
        let reference = engine.predict(&[1, 5, 9, 13]).unwrap();
        let server = Server::start(engine, ServeConfig::no_batching()).unwrap();
        let resp = server.handle().submit(&[1, 5, 9, 13, 0, 0, 0, 0]).unwrap().wait().unwrap();
        assert_eq!(resp.intent, reference.0);
        assert_eq!(resp.slots, reference.1);
        assert_eq!(resp.batch_size, 1);
        assert_eq!(resp.bucket_len, 4); // trailing pads trimmed, bucket 4 (eff 4)
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn backpressure_rejects_beyond_queue_cap() {
        let engine = tiny_engine(22);
        // Executor held back: queue_cap 2, batch threshold unreachable.
        let server = Server::start(engine, holding_config(64, 2)).unwrap();
        let h = server.handle();
        let a = h.submit(&[1, 5, 0, 0]).unwrap();
        let b = h.submit(&[1, 9, 0, 0]).unwrap();
        match h.submit(&[1, 7, 0, 0]) {
            Err(SubmitError::QueueFull { capacity: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        // Shutdown drains the two accepted requests — the contract that
        // accepted requests are always answered.
        let (ra, rb) = (a, b);
        let stats_handle = std::thread::spawn(move || server.shutdown());
        assert!(ra.wait().is_ok());
        assert!(rb.wait().is_ok());
        let stats = stats_handle.join().unwrap();
        assert_eq!(stats.served, 2);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn full_bucket_fires_as_one_batch() {
        let engine = tiny_engine(23);
        // max_wait is an hour: the only way a batch runs before
        // shutdown is the bucket filling to max_batch.
        let server = Server::start(engine, holding_config(3, 64)).unwrap();
        let h = server.handle();
        let pending: Vec<_> =
            (0..3).map(|i| h.submit(&[1, 5 + i as i32, 9, 0]).unwrap()).collect();
        let responses: Vec<_> = pending.into_iter().map(|p| p.wait().unwrap()).collect();
        for r in &responses {
            assert_eq!(r.batch_size, 3, "bucket did not coalesce");
            assert_eq!(r.bucket_len, 4);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.max_batch, 3);
        assert!((stats.mean_batch - 3.0).abs() < 1e-12);
    }

    #[test]
    fn max_batch_caps_a_flooded_bucket() {
        let engine = tiny_engine(24);
        let server = Server::start(engine, holding_config(2, 64)).unwrap();
        let h = server.handle();
        let pending: Vec<_> =
            (0..4).map(|i| h.submit(&[1, 5 + i as i32, 0, 0]).unwrap()).collect();
        for p in pending {
            let r = p.wait().unwrap();
            assert!(r.batch_size <= 2, "batch exceeded max_batch: {}", r.batch_size);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 4);
        assert!(stats.max_batch <= 2);
        assert!(stats.batches >= 2);
    }

    #[test]
    fn responses_map_back_to_their_requests() {
        // Distinct inputs through one coalesced batch: each response
        // must carry its own request's predictions (id -> logits
        // mapping survives the scatter/gather).
        let engine = tiny_engine(25);
        let inputs: Vec<Vec<i32>> =
            (0..4).map(|i| vec![1, 3 + i, 7, (i % 2) * 5]).collect();
        let references: Vec<_> = inputs
            .iter()
            .map(|t| {
                let eff = effective_len(t, 0);
                engine.forward_len(&t[..eff], eff).unwrap()
            })
            .collect();
        let server = Server::start(Arc::clone(&engine), holding_config(4, 64)).unwrap();
        let h = server.handle();
        let pending: Vec<_> = inputs.iter().map(|t| h.submit(t).unwrap()).collect();
        for (p, (il_ref, _)) in pending.into_iter().zip(&references) {
            let r = p.wait().unwrap();
            assert_eq!(&r.intent_logits, il_ref, "response crossed wires");
        }
        server.shutdown();
    }

    #[test]
    fn rejects_malformed_requests() {
        let engine = tiny_engine(26);
        let server = Server::start(engine, ServeConfig::default()).unwrap();
        let h = server.handle();
        assert!(matches!(h.submit(&[]), Err(SubmitError::Empty)));
        match h.submit(&[1; 9]) {
            Err(SubmitError::TooLong { len: 9, max: 8 }) => {}
            other => panic!("expected TooLong, got {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn distribution_stats_and_prometheus_snapshot() {
        let engine = tiny_engine(28);
        // Bucket fires only when it holds exactly max_batch = 3, so the
        // queue-depth high-watermark and the per-bucket/batch-size
        // distributions are fully deterministic.
        let server = Server::start(engine, holding_config(3, 64)).unwrap();
        let h = server.handle();
        let pending: Vec<_> =
            (0..3).map(|i| h.submit(&[1, 5 + i as i32, 9, 0]).unwrap()).collect();
        for p in pending {
            p.wait().unwrap();
        }
        let snap = h.prometheus_snapshot();
        assert!(snap.contains("# TYPE serve_requests_served_total counter"));
        assert!(snap.contains("serve_requests_served_total 3\n"));
        assert!(snap.contains("serve_batches_total 1\n"));
        assert!(snap.contains("serve_queue_depth 0\n"));
        assert!(snap.contains("serve_queue_depth_high_watermark 3\n"));
        assert!(snap.contains("serve_batch_size_count{batch_size=\"3\"} 1\n"));
        assert!(snap.contains("serve_bucket_served_total{bucket_len=\"4\"} 3\n"));
        assert!(snap.contains("serve_bucket_batches_total{bucket_len=\"4\"} 1\n"));
        let stats = server.shutdown();
        assert_eq!(
            stats.per_bucket,
            vec![BucketStats { bucket_len: 4, served: 3, batches: 1 }]
        );
        assert_eq!(stats.queue_depth_hwm, 3);
        // Nearest-rank percentiles over 3 served latencies: finite,
        // positive, monotone.
        assert!(stats.latency_p50_ms > 0.0);
        assert!(stats.latency_p50_ms <= stats.latency_p95_ms);
        assert!(stats.latency_p95_ms <= stats.latency_p99_ms);
    }

    #[test]
    fn empty_server_latency_percentiles_are_nan() {
        let engine = tiny_engine(29);
        let server = Server::start(engine, ServeConfig::default()).unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.served, 0);
        assert!(stats.per_bucket.is_empty());
        assert_eq!(stats.queue_depth_hwm, 0);
        assert!(stats.latency_p50_ms.is_nan());
        assert!(stats.latency_p99_ms.is_nan());
    }

    #[test]
    fn submit_after_shutdown_is_closed() {
        let engine = tiny_engine(27);
        let server = Server::start(engine, ServeConfig::default()).unwrap();
        let h = server.handle();
        server.shutdown();
        assert!(matches!(h.submit(&[1, 5]), Err(SubmitError::Closed)));
    }
}
