//! Multi-threaded closed-loop load generator for the serving
//! scheduler — the measurement half of `BENCH_serve.json`.
//!
//! Each scenario spawns `concurrency` client threads against one
//! [`Server`]; every client keeps exactly one request in flight
//! (closed loop), striding the shared corpus so concurrent clients
//! carry different inputs.  Per-request latency comes from the server's
//! own accounting ([`super::Response::latency`]: submit -> response),
//! aggregated into nearest-rank percentiles via
//! [`crate::coordinator::metrics::percentile`].  Saturation throughput
//! is served requests over the scenario wall-clock.
//!
//! [`default_scenarios`] spans the grid the ISSUE asks the bench to
//! record: {no-batching baseline, continuous batching} x concurrency
//! {1, 8}.

use super::{Server, ServeConfig, SubmitError};
use crate::coordinator::metrics::percentile;
use crate::engine::NativeEngine;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One load scenario: a scheduler policy driven at a fixed closed-loop
/// concurrency for a fixed number of requests.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub name: String,
    pub serve: ServeConfig,
    /// Closed-loop client threads (each holds one request in flight).
    pub concurrency: usize,
    /// Total requests across all clients.
    pub requests: usize,
}

/// Measured outcome of one [`LoadSpec`] — one row of
/// `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub name: String,
    pub policy: &'static str,
    pub concurrency: usize,
    pub max_batch: usize,
    pub requests: usize,
    pub served: u64,
    pub failed: u64,
    pub rejected: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub wall_secs: f64,
    /// Served requests per second of scenario wall-clock.
    pub throughput_rps: f64,
    /// Mean requests per executed micro-batch.
    pub mean_batch: f64,
    pub max_batch_seen: u64,
}

impl LoadReport {
    /// One scenario as a JSON object (manual formatting — the crate
    /// stays dependency-free, same idiom as `BENCH_native_train.json`).
    /// Latency/throughput floats go through
    /// [`crate::coordinator::metrics::json_num`]: a scenario that
    /// served zero requests has NaN percentiles, and a bare `NaN`
    /// token would invalidate the whole `BENCH_serve.json` document.
    pub fn json(&self) -> String {
        let num = crate::coordinator::metrics::json_num;
        format!(
            concat!(
                "{{\"name\": \"{}\", \"policy\": \"{}\", \"concurrency\": {}, ",
                "\"max_batch\": {}, \"requests\": {}, \"served\": {}, ",
                "\"failed\": {}, \"rejected\": {}, \"p50_ms\": {}, ",
                "\"p95_ms\": {}, \"p99_ms\": {}, \"mean_ms\": {}, ",
                "\"wall_secs\": {}, \"throughput_rps\": {}, ",
                "\"mean_batch\": {}, \"max_batch_seen\": {}}}"
            ),
            self.name,
            self.policy,
            self.concurrency,
            self.max_batch,
            self.requests,
            self.served,
            self.failed,
            self.rejected,
            num(self.p50_ms, 4),
            num(self.p95_ms, 4),
            num(self.p99_ms, 4),
            num(self.mean_ms, 4),
            num(self.wall_secs, 4),
            num(self.throughput_rps, 2),
            num(self.mean_batch, 2),
            self.max_batch_seen,
        )
    }
}

/// Assemble scenario rows into the `BENCH_serve.json` document.
pub fn bench_json(reports: &[LoadReport]) -> String {
    let rows: Vec<String> = reports.iter().map(|r| r.json()).collect();
    format!(
        "{{\n  \"bench\": \"serve\",\n  \"scenarios\": [\n    {}\n  ]\n}}\n",
        rows.join(",\n    ")
    )
}

/// The bench grid: {no-batching baseline, continuous batching} x
/// concurrency {1, 8}, `requests` per scenario.
pub fn default_scenarios(requests: usize) -> Vec<LoadSpec> {
    let mut specs = Vec::new();
    for &concurrency in &[1usize, 8] {
        for serve in [ServeConfig::no_batching(), ServeConfig::default()] {
            specs.push(LoadSpec {
                name: format!("{}-c{concurrency}", serve.policy_name()),
                serve,
                concurrency,
                requests,
            });
        }
    }
    specs
}

/// Run one scenario to completion and measure it.  Corpus rows must fit
/// the engine's `seq_len`; client `c` takes rows `c, c+concurrency,
/// c+2*concurrency, ...` so concurrent requests differ.
pub fn run_load(
    engine: &Arc<NativeEngine>,
    corpus: &[Vec<i32>],
    spec: &LoadSpec,
) -> Result<LoadReport> {
    if corpus.is_empty() {
        return Err(anyhow!("load generator needs a non-empty corpus"));
    }
    if spec.concurrency == 0 || spec.requests == 0 {
        return Err(anyhow!(
            "load spec '{}' needs concurrency and requests >= 1",
            spec.name
        ));
    }
    let server = Server::start(Arc::clone(engine), spec.serve.clone())?;
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(spec.requests));
    let started = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..spec.concurrency {
            let handle = server.handle();
            let latencies = &latencies;
            let share = spec.requests / spec.concurrency
                + usize::from(c < spec.requests % spec.concurrency);
            scope.spawn(move || {
                let mut local = Vec::with_capacity(share);
                for i in 0..share {
                    let tokens = &corpus[(c + i * spec.concurrency) % corpus.len()];
                    match handle.submit(tokens) {
                        Ok(pending) => {
                            if let Ok(resp) = pending.wait() {
                                local.push(resp.latency.as_secs_f64() * 1e3);
                            }
                        }
                        // Backpressure: the request is dropped (the
                        // server counted the reject); a closed loop
                        // only hits this when concurrency > queue_cap.
                        Err(SubmitError::QueueFull { .. }) => std::thread::yield_now(),
                        Err(_) => break,
                    }
                }
                latencies.lock().expect("latency sink poisoned").extend(local);
            });
        }
    });
    let wall_secs = started.elapsed().as_secs_f64();
    let stats = server.shutdown();
    let lat = latencies.into_inner().expect("latency sink poisoned");
    let (p50_ms, p95_ms, p99_ms, mean_ms) = if lat.is_empty() {
        // No completed requests: the latency distribution is undefined.
        // Carry the NaN through — `LoadReport::json` renders it `null`;
        // a fake 0.0 here would read as "zero latency" downstream.
        (f64::NAN, f64::NAN, f64::NAN, f64::NAN)
    } else {
        (
            percentile(&lat, 50.0),
            percentile(&lat, 95.0),
            percentile(&lat, 99.0),
            lat.iter().sum::<f64>() / lat.len() as f64,
        )
    };
    Ok(LoadReport {
        name: spec.name.clone(),
        policy: spec.serve.policy_name(),
        concurrency: spec.concurrency,
        max_batch: spec.serve.max_batch,
        requests: spec.requests,
        served: stats.served,
        failed: stats.failed,
        rejected: stats.rejected,
        p50_ms,
        p95_ms,
        p99_ms,
        mean_ms,
        wall_secs,
        throughput_rps: if wall_secs > 0.0 { stats.served as f64 / wall_secs } else { 0.0 },
        mean_batch: stats.mean_batch,
        max_batch_seen: stats.max_batch,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::tests::{tiny_cfg, tiny_params};

    fn tiny_corpus() -> Vec<Vec<i32>> {
        // Mixed lengths so bucketing is exercised (tiny seq_len is 8).
        vec![
            vec![1, 5, 9, 13],
            vec![1, 7, 3],
            vec![1, 11, 9, 13, 2, 4, 6, 8],
            vec![1, 2],
            vec![1, 5, 9, 13, 2, 4],
        ]
    }

    #[test]
    fn grid_covers_both_policies_and_concurrencies() {
        let specs = default_scenarios(16);
        assert_eq!(specs.len(), 4);
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        for expect in ["no-batching-c1", "continuous-c1", "no-batching-c8", "continuous-c8"] {
            assert!(names.contains(&expect), "missing scenario {expect}");
        }
    }

    #[test]
    fn closed_loop_serves_every_request() {
        let cfg = tiny_cfg();
        let engine =
            Arc::new(NativeEngine::from_params(&cfg, &tiny_params(&cfg, 31)).unwrap());
        let corpus = tiny_corpus();
        for spec in [
            LoadSpec {
                name: "no-batching-c2".into(),
                serve: ServeConfig::no_batching(),
                concurrency: 2,
                requests: 9,
            },
            LoadSpec {
                name: "continuous-c3".into(),
                serve: ServeConfig { bucket: 4, ..ServeConfig::default() },
                concurrency: 3,
                requests: 9,
            },
        ] {
            let report = run_load(&engine, &corpus, &spec).unwrap();
            assert_eq!(report.served, 9, "{}: lost requests", spec.name);
            assert_eq!(report.failed, 0);
            assert_eq!(report.rejected, 0);
            assert!(report.p50_ms >= 0.0 && report.p99_ms >= report.p50_ms);
            assert!(report.throughput_rps > 0.0);
            assert!(report.mean_batch >= 1.0);
        }
    }

    #[test]
    fn report_json_is_self_describing() {
        let report = LoadReport {
            name: "continuous-c8".into(),
            policy: "continuous",
            concurrency: 8,
            max_batch: 16,
            requests: 64,
            served: 64,
            failed: 0,
            rejected: 0,
            p50_ms: 1.25,
            p95_ms: 2.5,
            p99_ms: 3.75,
            mean_ms: 1.5,
            wall_secs: 0.5,
            throughput_rps: 128.0,
            mean_batch: 4.0,
            max_batch_seen: 8,
        };
        let json = bench_json(std::slice::from_ref(&report));
        for key in ["\"bench\": \"serve\"", "\"p50_ms\": 1.2500", "\"p99_ms\": 3.7500",
            "\"throughput_rps\": 128.00", "\"policy\": \"continuous\""]
        {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    #[test]
    fn zero_served_report_emits_null_not_nan() {
        // Regression: a scenario with no completed requests has NaN
        // percentiles; the writer used to format them with `{:.4}` and
        // emit bare `NaN` tokens — invalid JSON that corrupted the
        // whole BENCH_serve.json document.
        let report = LoadReport {
            name: "starved".into(),
            policy: "continuous",
            concurrency: 1,
            max_batch: 16,
            requests: 4,
            served: 0,
            failed: 4,
            rejected: 0,
            p50_ms: f64::NAN,
            p95_ms: f64::NAN,
            p99_ms: f64::NAN,
            mean_ms: f64::NAN,
            wall_secs: 0.1,
            throughput_rps: 0.0,
            mean_batch: f64::NAN,
            max_batch_seen: 0,
        };
        let json = bench_json(std::slice::from_ref(&report));
        assert!(!json.contains("NaN"), "bare NaN token in {json}");
        assert!(json.contains("\"p50_ms\": null"), "{json}");
        assert!(json.contains("\"mean_batch\": null"), "{json}");
        assert!(json.contains("\"throughput_rps\": 0.00"), "{json}");
    }

    #[test]
    fn run_load_validates_inputs() {
        let cfg = tiny_cfg();
        let engine =
            Arc::new(NativeEngine::from_params(&cfg, &tiny_params(&cfg, 32)).unwrap());
        let spec = LoadSpec {
            name: "empty".into(),
            serve: ServeConfig::default(),
            concurrency: 1,
            requests: 1,
        };
        assert!(run_load(&engine, &[], &spec).is_err());
        let zero = LoadSpec { concurrency: 0, ..spec };
        assert!(run_load(&engine, &tiny_corpus(), &zero).is_err());
    }
}
