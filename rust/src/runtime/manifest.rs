//! Typed view over `artifacts/manifest.json`.

use crate::config::{ModelConfig, TrainConfig};
use crate::optim::{OptimConfig, OptimKind};
use crate::util::json::Value;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One parameter array: canonical name + shape, in argument order.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One lowered model variant (e.g. `tt_L2`, `mm_L2`).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub compressed: bool,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub init_npz: PathBuf,
    pub params: Vec<ParamSpec>,
    pub n_param_scalars: usize,
    pub dense_equivalent_scalars: usize,
    pub config: ModelConfig,
}

impl VariantSpec {
    pub fn compression_ratio(&self) -> f64 {
        self.dense_equivalent_scalars as f64 / self.n_param_scalars as f64
    }

    /// Model size in MB at fp32 (Table III basis).
    pub fn size_mb(&self) -> f64 {
        self.n_param_scalars as f64 * 4.0 / 1e6
    }
}

/// Parsed manifest: the contract between `aot.py` and this runtime.
///
/// Training fallbacks route through [`TrainConfig::default`] (the single
/// source of truth for the paper's setup); a manifest may additionally
/// carry `train.optimizer` / `train.batch_size` for the PU stage.
#[derive(Debug)]
pub struct Manifest {
    pub seed: u64,
    pub lr: f32,
    pub epochs: usize,
    /// PU-stage optimizer configuration (defaults to SGD, batch 1).
    pub optim: OptimConfig,
    pub variants: Vec<VariantSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let root = Value::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let train = root.get("train").ok_or_else(|| anyhow!("manifest: no 'train'"))?;
        let mut variants = Vec::new();
        for v in root
            .get("variants")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow!("manifest: no 'variants'"))?
        {
            let name = v
                .get("name")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("variant missing name"))?
                .to_string();
            let params = v
                .get("params")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("variant {name}: no params"))?
                .iter()
                .map(|p| -> Result<ParamSpec> {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .and_then(Value::as_str)
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: p
                            .get("shape")
                            .and_then(Value::as_arr)
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .filter_map(Value::as_usize)
                            .collect(),
                        dtype: p
                            .get("dtype")
                            .and_then(Value::as_str)
                            .unwrap_or("float32")
                            .to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let rel = |key: &str| -> Result<PathBuf> {
                Ok(dir.join(
                    v.get(key)
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("variant {name}: no {key}"))?,
                ))
            };
            variants.push(VariantSpec {
                compressed: v.get("compressed").and_then(Value::as_bool).unwrap_or(true),
                train_hlo: rel("train_hlo")?,
                eval_hlo: rel("eval_hlo")?,
                init_npz: rel("init_npz")?,
                n_param_scalars: v
                    .get("n_params_scalars")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
                dense_equivalent_scalars: v
                    .get("dense_equivalent_scalars")
                    .and_then(Value::as_usize)
                    .unwrap_or(0),
                config: ModelConfig::from_json(
                    v.get("config").ok_or_else(|| anyhow!("variant {name}: no config"))?,
                )?,
                params,
                name,
            });
        }
        let defaults = TrainConfig::default();
        let optim_defaults = OptimConfig::default();
        let optim = OptimConfig {
            kind: match train.get("optimizer").and_then(Value::as_str) {
                Some(kind) => OptimKind::parse(kind)?,
                None => optim_defaults.kind,
            },
            batch_size: train
                .get("batch_size")
                .and_then(Value::as_usize)
                .unwrap_or(defaults.batch_size),
            ..optim_defaults
        };
        Ok(Manifest {
            seed: root.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64,
            lr: train
                .get("lr")
                .and_then(Value::as_f64)
                .unwrap_or(defaults.lr as f64) as f32,
            epochs: train
                .get("epochs")
                .and_then(Value::as_usize)
                .unwrap_or(defaults.epochs),
            optim,
            variants,
            dir,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "variant '{name}' not in manifest (have: {})",
                    self.variants
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}
