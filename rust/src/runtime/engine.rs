//! Execution engine: one compiled (train, eval) executable pair plus the
//! live parameter state for a model variant.
//!
//! The engine is the only component that talks to PJRT on the hot path.
//! Parameters live as host `Literal`s between steps (they are tiny after
//! tensor compression — ~1.2 MB for the 2-encoder model — so the
//! host<->device copies are negligible next to the step compute; see
//! EXPERIMENTS.md §Perf).

use super::manifest::VariantSpec;
use super::{compile_hlo_text, literal_i32};
use crate::config::ModelConfig;
use crate::coordinator::backend::{StepOutput, TrainBackend};
use crate::util::npy;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;
use xla::{FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

/// A loaded model variant: compiled executables + parameter state.
pub struct Engine {
    pub spec: VariantSpec,
    client: PjRtClient,
    train_exe: PjRtLoadedExecutable,
    eval_exe: PjRtLoadedExecutable,
    /// Current parameters, in manifest argument order.
    params: Vec<Literal>,
}

impl Engine {
    /// Compile the variant's executables and load its initial parameters.
    pub fn load(spec: &VariantSpec) -> Result<Engine> {
        let client = PjRtClient::cpu()?;
        Self::load_with_client(spec, client)
    }

    /// Like [`Engine::load`] but sharing an existing PJRT client.
    pub fn load_with_client(spec: &VariantSpec, client: PjRtClient) -> Result<Engine> {
        let train_exe = compile_hlo_text(&client, spec.train_hlo.to_str().unwrap())
            .with_context(|| format!("compiling {:?}", spec.train_hlo))?;
        let eval_exe = compile_hlo_text(&client, spec.eval_hlo.to_str().unwrap())
            .with_context(|| format!("compiling {:?}", spec.eval_hlo))?;
        let mut engine = Engine {
            spec: spec.clone(),
            client,
            train_exe,
            eval_exe,
            params: Vec::new(),
        };
        engine.load_init()?;
        Ok(engine)
    }

    /// (Re-)load the seeded initial parameters from the artifact npz.
    pub fn load_init(&mut self) -> Result<()> {
        let named = Literal::read_npz(&self.spec.init_npz, &())?;
        // Keys are "%04d.<path>"; zip order is already argument order, but
        // sort defensively on the numeric prefix.
        let mut named: Vec<(String, Literal)> = named;
        named.sort_by(|a, b| a.0.cmp(&b.0));
        if named.len() != self.spec.params.len() {
            return Err(anyhow!(
                "init npz has {} arrays, manifest expects {}",
                named.len(),
                self.spec.params.len()
            ));
        }
        for ((key, lit), spec) in named.iter().zip(&self.spec.params) {
            let n = lit.element_count();
            if n != spec.numel() {
                return Err(anyhow!(
                    "param {key}: npz has {n} elements, manifest {} ({:?})",
                    spec.numel(),
                    spec.shape
                ));
            }
        }
        self.params = named.into_iter().map(|(_, l)| l).collect();
        Ok(())
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Read-only view of the current parameters (manifest order).
    pub fn params(&self) -> &[Literal] {
        &self.params
    }

    /// Fetch one parameter as f32 host data by manifest name.
    pub fn param_by_name(&self, name: &str) -> Result<Vec<f32>> {
        let idx = self
            .spec
            .params
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| anyhow!("no parameter named {name}"))?;
        Ok(self.params[idx].to_vec::<f32>()?)
    }

    /// One SGD step (FP -> BP -> PU fused in the HLO artifact).
    ///
    /// `tokens`/`slots` are `(batch, seq)` row-major, `intent` is
    /// `(batch,)`.  Updates the parameter state in place.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<StepOutput> {
        let cfg = &self.spec.config;
        let (b, s) = (cfg.batch as i64, cfg.seq_len as i64);
        debug_assert_eq!(tokens.len(), (b * s) as usize);
        debug_assert_eq!(intent.len(), b as usize);
        debug_assert_eq!(slots.len(), (b * s) as usize);

        let t_host = Instant::now();
        let mut args: Vec<&Literal> = self.params.iter().collect();
        let tok_lit = literal_i32(tokens, &[b, s])?;
        let int_lit = literal_i32(intent, &[b])?;
        let slot_lit = literal_i32(slots, &[b, s])?;
        let lr_lit = Literal::scalar(lr);
        args.push(&tok_lit);
        args.push(&int_lit);
        args.push(&slot_lit);
        args.push(&lr_lit);
        let host_secs = t_host.elapsed().as_secs_f64();

        let t_exec = Instant::now();
        let result = self.train_exe.execute::<&Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let execute_secs = t_exec.elapsed().as_secs_f64();

        let t_host2 = Instant::now();
        let mut parts = out.to_tuple()?;
        if parts.len() != 1 + self.params.len() {
            return Err(anyhow!(
                "train step returned {} outputs, expected {}",
                parts.len(),
                1 + self.params.len()
            ));
        }
        let loss = parts.remove(0).to_vec::<f32>()?[0];
        self.params = parts;
        let host_secs = host_secs + t_host2.elapsed().as_secs_f64();

        Ok(StepOutput { loss, execute_secs, host_secs })
    }

    /// Inference: returns `(intent_logits (B*n_intents), slot_logits
    /// (B*S*n_slots))` row-major.
    pub fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let cfg = &self.spec.config;
        let (b, s) = (cfg.batch as i64, cfg.seq_len as i64);
        let tok_lit = literal_i32(tokens, &[b, s])?;
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&tok_lit);
        let result = self.eval_exe.execute::<&Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let (intent_logits, slot_logits) = out.to_tuple2()?;
        Ok((
            intent_logits.to_vec::<f32>()?,
            slot_logits.to_vec::<f32>()?,
        ))
    }

    /// Save the current parameters as one `.npy` per array under `dir`.
    ///
    /// (The `xla` crate's own `write_npy` is broken for f32 literals —
    /// it feeds a `u8` buffer to the type-checked `copy_raw_to` — so the
    /// shared [`crate::util::npy`] writer is used instead.)
    pub fn save_checkpoint(&self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        for (i, (lit, spec)) in self.params.iter().zip(&self.spec.params).enumerate() {
            let safe = npy::safe_param_name(&spec.name);
            let data = lit.to_vec::<f32>()?;
            npy::write_npy_f32(&dir.join(format!("{i:04}.{safe}.npy")), &data, &spec.shape)?;
        }
        Ok(())
    }

    /// Restore parameters saved by [`Engine::save_checkpoint`] (or by
    /// the native trainer — the formats interchange).
    ///
    /// Each file is matched to its manifest spec by the *embedded
    /// parameter name*, never by sort position, so file numbering is
    /// irrelevant and a renamed or missing `.npy` is a hard error
    /// instead of silently loading the wrong weights.
    pub fn load_checkpoint(&mut self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        // Native checkpoints may carry PU-stage optimizer state
        // (`optim.kind` / `optim.state.*` entries); the compiled
        // artifact bakes its own optimizer in, so those are skipped —
        // parameters still interchange both ways.
        let entries: Vec<_> = npy::checkpoint_entries(dir)?
            .into_iter()
            .filter(|(name, _)| !name.starts_with("optim."))
            .collect();
        if entries.len() != self.params.len() {
            return Err(anyhow!(
                "checkpoint has {} arrays, expected {}",
                entries.len(),
                self.params.len()
            ));
        }
        let mut by_name: BTreeMap<String, std::path::PathBuf> = entries.into_iter().collect();
        let mut params = Vec::with_capacity(self.spec.params.len());
        for spec in &self.spec.params {
            let expect = npy::safe_param_name(&spec.name);
            let path = by_name.remove(&expect).ok_or_else(|| {
                anyhow!("checkpoint {dir:?} has no file for parameter '{expect}'")
            })?;
            let lit = Literal::read_npy(&path, &())?;
            if lit.element_count() != spec.numel() {
                return Err(anyhow!("checkpoint {path:?}: wrong element count"));
            }
            params.push(lit);
        }
        self.params = params;
        Ok(())
    }
}

impl TrainBackend for Engine {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn config(&self) -> &ModelConfig {
        &self.spec.config
    }

    fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<StepOutput> {
        Engine::train_step(self, tokens, intent, slots, lr)
    }

    fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        Engine::eval(self, tokens)
    }

    fn save_checkpoint(&self, dir: &Path) -> Result<()> {
        Engine::save_checkpoint(self, dir)
    }

    fn load_checkpoint(&mut self, dir: &Path) -> Result<()> {
        Engine::load_checkpoint(self, dir)
    }
}
