//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The interchange contract with `python/compile/aot.py`:
//!
//! * `<variant>_train.hlo.txt` — inputs `[*params, tokens, intent, slots,
//!   lr]`, output tuple `(loss, *new_params)`.
//! * `<variant>_eval.hlo.txt` — inputs `[*params, tokens]`, output tuple
//!   `(intent_logits, slot_logits)`.
//! * `<variant>_init.npz` — initial parameters; zip entry order ==
//!   argument order (keys are `%04d.<path>`).
//! * `manifest.json` — parameter names/shapes, input specs, model config.
//!
//! HLO **text** (not serialized protos) is loaded: jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The manifest reader is always available; the executing engine
//! ([`Engine`]) and the literal helpers need the `pjrt` feature (the
//! default build is the artifact-free native stack, see
//! [`crate::train`]).

#[cfg(feature = "pjrt")]
mod engine;
mod manifest;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use crate::coordinator::backend::StepOutput;
pub use manifest::{Manifest, ParamSpec, VariantSpec};

#[cfg(feature = "pjrt")]
use anyhow::Result;
#[cfg(feature = "pjrt")]
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compile an HLO-text file on the given PJRT client.
#[cfg(feature = "pjrt")]
pub fn compile_hlo_text(client: &PjRtClient, path: &str) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)?;
    let comp = XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

/// Build an i32 literal of the given shape from a slice.
#[cfg(feature = "pjrt")]
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}

/// Build an f32 literal of the given shape from a slice.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let lit = Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}
