//! Minimal recursive-descent JSON parser for `artifacts/manifest.json`.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null).  Object member order is preserved — the
//! manifest relies on it for the parameter argument order.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Members in document order (plus an index for O(log n) lookup).
    Obj(Vec<(String, Value)>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Value {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Value, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(items) => items.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Convenience: object as a map (loses duplicate keys, keeps last).
    pub fn to_map(&self) -> Option<BTreeMap<&str, &Value>> {
        match self {
            Value::Obj(members) => {
                Some(members.iter().map(|(k, v)| (k.as_str(), v)).collect())
            }
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            members.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(members)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // Surrogate pairs: JSON-escape of non-BMP chars.
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        out.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(
            Value::parse(r#""a\nbA""#).unwrap(),
            Value::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn preserves_member_order() {
        let v = Value::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Value::Obj(members) = &v {
            let keys: Vec<_> = members.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, ["z", "a", "m"]);
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("tru").is_err());
        assert!(Value::parse("1 2").is_err());
    }

    #[test]
    fn parses_unicode_strings() {
        let v = Value::parse(r#""héllo ☃""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }
}
