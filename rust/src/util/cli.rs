//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and defaults.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed() {
        // NB: a bare `--name` followed by a non-`--` token is read as
        // `--name value` (documented ambiguity of the minimal grammar),
        // so boolean flags go last or before another `--` option.
        let a = parse(&["train", "x", "--epochs", "5", "--lr=0.004", "--verbose"]);
        assert_eq!(a.positional, ["train", "x"]);
        assert_eq!(a.get("epochs"), Some("5"));
        assert_eq!(a.get_f64("lr", 0.0), 0.004);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.has_flag("fast"));
    }
}
