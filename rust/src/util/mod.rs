//! Self-contained substrates that would normally come from crates.io.
//!
//! The build environment is offline and only ships the `xla` crate's
//! dependency closure, so the library carries its own minimal JSON parser
//! ([`json`]), CLI argument parser ([`cli`]), deterministic RNG shared
//! with the python data generator ([`rng`]), property-testing loop
//! ([`prop`]), `.npy` checkpoint reader/writer ([`npy`]) and wall-clock
//! measurement helpers ([`timer`]).

pub mod cli;
pub mod json;
pub mod npy;
pub mod prop;
pub mod rng;
pub mod timer;
