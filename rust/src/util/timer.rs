//! Wall-clock measurement helpers used by the bench harness and the
//! coordinator's metrics.

use crate::coordinator::metrics::percentile;
use std::time::Instant;

/// Run `f` repeatedly and return (best, mean, total_iters).
///
/// Warmup runs are discarded; iterations adapt so cheap closures are
/// measured over enough repeats to be meaningful.
pub fn bench<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(samples)
}

/// Summary statistics for a set of timing samples (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub best: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub n: usize,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        // Percentiles go through the one shared nearest-rank definition
        // (`coordinator::metrics::percentile`), not ad-hoc indexing.
        BenchStats {
            best: samples[0],
            mean,
            p50: percentile(&samples, 50.0),
            p95: percentile(&samples, 95.0),
            n,
        }
    }

    pub fn fmt_ms(&self) -> String {
        format!(
            "best {:.3} ms | p50 {:.3} ms | p95 {:.3} ms (n={})",
            self.best * 1e3,
            self.p50 * 1e3,
            self.p95 * 1e3,
            self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.best, 1.0);
        assert_eq!(s.p50, 2.0);
        assert!(s.mean > 1.9 && s.mean < 2.1);
    }

    #[test]
    fn percentiles_match_shared_definition() {
        // BenchStats must agree with the single shared nearest-rank
        // helper — pins the dedup so the two can't drift apart again.
        let raw = vec![0.4, 0.1, 0.3, 0.2, 0.5];
        let s = BenchStats::from_samples(raw.clone());
        assert_eq!(s.p50, percentile(&raw, 50.0));
        assert_eq!(s.p95, percentile(&raw, 95.0));
        assert_eq!(s.p50, 0.3);
        assert_eq!(s.p95, 0.5);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0;
        let s = bench(|| count += 1, 2, 5);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
