//! Minimal `.npy` (format 1.0/2.0) reader/writer for little-endian f32
//! row-major arrays — the checkpoint format shared by the PJRT engine,
//! the native trainer and numpy.
//!
//! Also carries the checkpoint directory convention: one
//! `%04d.<param-name>.npy` file per array, where `<param-name>` is the
//! manifest name with `/` mapped to `_`.  [`checkpoint_entries`] parses
//! the directory back so loaders can verify each file's *embedded
//! parameter name* instead of trusting sort order — a renamed or
//! swapped file becomes a hard error, not silently-wrong weights.

use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Write one f32 array as `.npy` format 1.0.
pub fn write_npy_f32(path: &Path, data: &[f32], shape: &[usize]) -> Result<()> {
    let dims = shape
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({dims},)"),
        _ => format!("({dims})"),
    };
    let mut header =
        format!("{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}");
    // Pad so magic(6) + version(2) + len(2) + header is a multiple of 64.
    let base = 6 + 2 + 2;
    let total = (base + header.len() + 1).div_ceil(64) * 64;
    while base + header.len() + 1 < total {
        header.push(' ');
    }
    header.push('\n');
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"\x93NUMPY")?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&bytes)?;
    Ok(())
}

/// Read a `.npy` file written by [`write_npy_f32`] or numpy
/// (`<f4`, C order only).  Returns `(shape, data)`.
pub fn read_npy_f32(path: &Path) -> Result<(Vec<usize>, Vec<f32>)> {
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(anyhow!("{path:?}: not an npy file"));
    }
    let header_len = match magic[6] {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 | 3 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => return Err(anyhow!("{path:?}: unsupported npy version {v}")),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") {
        return Err(anyhow!("{path:?}: only '<f4' dtype supported ({header})"));
    }
    if header.contains("'fortran_order': True") {
        return Err(anyhow!("{path:?}: fortran order not supported"));
    }
    let shape = parse_shape(&header)
        .ok_or_else(|| anyhow!("{path:?}: cannot parse shape from header ({header})"))?;
    let numel: usize = shape.iter().product::<usize>().max(1);
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    if payload.len() < numel * 4 {
        return Err(anyhow!(
            "{path:?}: payload {} bytes < {} expected",
            payload.len(),
            numel * 4
        ));
    }
    let data = payload[..numel * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((shape, data))
}

fn parse_shape(header: &str) -> Option<Vec<usize>> {
    let start = header.find("'shape':")? + "'shape':".len();
    let open = header[start..].find('(')? + start;
    let close = header[open..].find(')')? + open;
    let inner = &header[open + 1..close];
    let mut shape = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        shape.push(part.parse().ok()?);
    }
    Some(shape)
}

/// List a checkpoint directory's `.npy` files sorted by filename,
/// returning each file's embedded parameter-name component
/// (`<index>.<name>.npy` -> `<name>`).
pub fn checkpoint_entries(dir: &Path) -> Result<Vec<(String, PathBuf)>> {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading checkpoint dir {dir:?}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "npy").unwrap_or(false))
        .collect();
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for path in paths {
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow!("bad checkpoint filename {path:?}"))?;
        let name = stem
            .split_once('.')
            .map(|(_, rest)| rest.to_string())
            .ok_or_else(|| {
                anyhow!("checkpoint file {path:?} lacks the <index>.<name>.npy layout")
            })?;
        out.push((name, path));
    }
    Ok(out)
}

/// Filesystem-safe form of a parameter name (manifest convention).
pub fn safe_param_name(name: &str) -> String {
    name.replace('/', "_")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npy_roundtrip() {
        let dir = std::env::temp_dir().join(format!("npy_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("0000.a.b.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5 - 2.0).collect();
        write_npy_f32(&path, &data, &[3, 4]).unwrap();
        let (shape, back) = read_npy_f32(&path).unwrap();
        assert_eq!(shape, vec![3, 4]);
        assert_eq!(back, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn npy_scalar_and_1d_shapes() {
        let dir = std::env::temp_dir().join(format!("npy_sh_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("0000.x.npy");
        write_npy_f32(&path, &[1.0, 2.0, 3.0], &[3]).unwrap();
        let (shape, data) = read_npy_f32(&path).unwrap();
        assert_eq!(shape, vec![3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_entries_extract_names_in_order() {
        let dir = std::env::temp_dir().join(format!("npy_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_npy_f32(&dir.join("0001.beta.npy"), &[1.0], &[1]).unwrap();
        write_npy_f32(&dir.join("0000.alpha.x.npy"), &[2.0], &[1]).unwrap();
        let entries = checkpoint_entries(&dir).unwrap();
        let names: Vec<&str> = entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["alpha.x", "beta"]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
