//! Deterministic RNG shared with the python data generator.
//!
//! SplitMix64 — tiny, fast, identical output in both languages
//! (`python/compile/data.py` carries the mirror implementation).  The
//! synthetic-ATIS corpora on the rust and python sides must match
//! token-for-token so the Fig. 13 parity curves compare like-for-like.

/// SplitMix64 PRNG (public-domain algorithm by Sebastiano Vigna).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, bound)` (bound > 0), via 128-bit multiply —
    /// bias < 2^-64, and trivially mirrored in python.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick a slice element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Standard normal via Box-Muller (used by tensor init in tests).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.unit().max(1e-300);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_sequence() {
        // First outputs for seed 42 — these constants are asserted by
        // python/tests/test_data_parity.py as well; do not change.
        let mut r = SplitMix64::new(42);
        assert_eq!(r.next_u64(), 13679457532755275413);
        assert_eq!(r.next_u64(), 2949826092126892291);
        assert_eq!(r.next_u64(), 5139283748462763858);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn unit_is_in_range() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            let x = r.unit();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
