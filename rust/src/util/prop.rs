//! Minimal property-based testing loop (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`SplitMix64`]; `check` runs it
//! for `cases` random seeds and reports the failing seed so a failure is
//! reproducible by construction.

use super::rng::SplitMix64;

/// Run `prop` for `cases` seeds derived from `base_seed`.
///
/// The closure should panic (e.g. via `assert!`) on a violated property.
/// On panic, the failing case index and derived seed are printed before
/// the panic is propagated — re-running with that seed reproduces it.
pub fn check<F: Fn(&mut SplitMix64)>(base_seed: u64, cases: usize, prop: F) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = SplitMix64::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases} (derived seed {seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Random shape helper: a vector of `ndims` dims, each in [1, max_dim].
pub fn shape(rng: &mut SplitMix64, ndims: usize, max_dim: usize) -> Vec<usize> {
    (0..ndims)
        .map(|_| 1 + rng.below(max_dim as u64) as usize)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        check(1, 25, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 25);
    }

    #[test]
    #[should_panic]
    fn propagates_failure() {
        check(2, 10, |rng| {
            assert!(rng.below(10) < 5, "will fail eventually");
        });
    }

    #[test]
    fn shapes_in_range() {
        check(3, 20, |rng| {
            let s = shape(rng, 4, 8);
            assert_eq!(s.len(), 4);
            assert!(s.iter().all(|&d| (1..=8).contains(&d)));
        });
    }
}
