//! `tt-trainer` — CLI for tensor-compressed transformer training and the
//! paper's experiment suite.
//!
//! ```text
//! tt-trainer info                              # manifest + Table II/III view
//! tt-trainer train --steps 200                 # train natively (no artifacts)
//! tt-trainer train --backend pjrt --steps 200  # train via PJRT HLO artifacts
//! tt-trainer eval  --ckpt DIR                  # accuracy on the test split
//! tt-trainer cost-model                        # Fig. 6 + Fig. 7 sweeps
//! tt-trainer serve-bench --ckpt DIR            # continuous-batching load test
//! tt-trainer bench-matrix                      # precision x path x policy grid
//! tt-trainer trace-report                      # FP/BP/PU wall-clock breakdown
//! tt-trainer bram                              # Figs. 11/12/14
//! tt-trainer schedule                          # Figs. 9/10
//! tt-trainer fpga-report                       # Tables IV/V, Figs. 1/15
//! ```
//!
//! The default backend is `native` (self-contained rust training); the
//! `pjrt` backend needs the crate's `pjrt` feature and `make artifacts`.

// Index-heavy report formatting mirrors the library's kernel style.
#![allow(clippy::needless_range_loop)]

use anyhow::{anyhow, Result};
use std::path::Path;
use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::{TrainBackend, Trainer};
use tt_trainer::costmodel::{compare_all, sweeps, LinearShape};
use tt_trainer::data::Dataset;
use tt_trainer::fpga::{bram, energy, resources, schedule};
use tt_trainer::optim::{OptimConfig, OptimKind};
use tt_trainer::runtime::Manifest;
use tt_trainer::tensor::Precision;
use tt_trainer::trace;
use tt_trainer::train::{CheckpointPolicy, NativeTrainer};
use tt_trainer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    // Global --threads N: pin the shared matmul worker pool's width
    // before any command touches it (0 / absent = one lane per
    // available core).  Must run before the first large contraction —
    // the pool is process-global and built once.
    if let Some(t) = args.get("threads") {
        let threads: usize = t.parse().map_err(|_| anyhow!("bad --threads"))?;
        tt_trainer::tensor::configure_worker_threads(threads);
    }
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "cost-model" => cmd_cost_model(),
        "serve-bench" => cmd_serve_bench(&args),
        "bench-matrix" => cmd_bench_matrix(&args),
        "bench-replicas" => cmd_bench_replicas(&args),
        "trace-report" => cmd_trace_report(&args),
        "bram" => cmd_bram(),
        "schedule" => cmd_schedule(),
        "fpga-report" => cmd_fpga_report(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
tt-trainer: tensor-compressed transformer training (rust native + JAX/Pallas AOT)

USAGE: tt-trainer <command> [options]

GLOBAL:
  --threads N   width of the shared matmul worker pool (default: one
                lane per available core; set before anything else runs
                — the pool is process-global and built once).  With
                --replicas R the peak thread count is R + pool width;
                --threads 1 keeps contractions serial so replicas are
                the only parallelism axis.

COMMANDS:
  info          manifest summary (Table II/III view)
  train         train on synthetic ATIS
                  --backend native|pjrt (default: native)
                  --steps N | --epochs E [--limit N]
                  --lr 0.004 --seed 42 --ckpt DIR --loss-csv FILE
                  native:  --layers 2 [--init-ckpt DIR]
                           --optimizer sgd|momentum|adam|adamw --batch N
                           --weight-decay 0.0
                           --precision f32|bf16|f16|int8 (storage path:
                             Eq. 21 caches, optimizer moments and stored
                             params at 16 bits — or block-scaled int8 at
                             ~1 byte/element; compute stays f32, and the
                             dynamic loss scaler guards half/int8 steps
                             against non-finite gradients)
                           --checkpoint cache|recompute (gradient
                             checkpointing: recompute drops the Eq. 21
                             caches and rebuilds them in the BP stage;
                             f32 gradients stay bitwise identical)
                           --trace FILE (Chrome trace-event JSON of the
                             fp/bp/pu + contraction spans; load in
                             ui.perfetto.dev or chrome://tracing)
                           --replicas R (deterministic data-parallel
                             training: R model shards, strided batch
                             sharding, fixed-order compressed-core
                             gradient all-reduce; R=1 is bitwise the
                             plain trainer, same-R reruns are bitwise
                             reproducible; needs --batch >= R)
                  pjrt:    --variant tt_L2 --artifacts DIR
  eval          evaluate on the test split
                  --backend native|pjrt [--limit N]
                  native:  --layers 2 --ckpt DIR (or --init-ckpt DIR)
                           --precision f32|bf16|f16|int8 (round stored
                             params first: weights-at-rest preview)
                  pjrt:    --variant tt_L2 --artifacts DIR
  cost-model    Fig. 6 comparison + Fig. 7 sweeps
  serve-bench   load-test the continuous-batching serving scheduler
                  --ckpt DIR | --init-ckpt DIR (else random init)
                  --layers 2 --requests 256 --seed 42
                  --precision f32|bf16|f16|int8
                  --out BENCH_serve.json
                  --trace FILE (Chrome trace of admit/queue/execute spans)
                  grid: {no-batching, continuous} x concurrency {1, 8}
  bench-matrix  precision x compute-path x checkpoint-policy training
                grid ({f32,bf16,f16,int8} x {fused,looped} x
                {cache,recompute}): tokens/sec with speedups vs the
                f32/looped/cache baseline, traced FP/BP/PU stage split,
                measured at-rest packed-param / Eq. 21 cache /
                optimizer-state bytes
                  --layers 2 --batch 8 --warmup 1 --iters 4
                  --out FILE (also write the BENCH_matrix.json document)
  bench-replicas  data-parallel replica sweep (R in {1,2,4} at one
                global batch): tokens/sec with speedups vs R=1, plus
                the exchange-volume table and the per-device budget
                split (optimizer state lives once, on the lead)
                  --layers 2 --batch 8 --warmup 1 --iters 4
                  --out FILE (also write the BENCH_replicas.json document)
  trace-report  FP/BP/PU wall-clock breakdown from a short traced
                native run, next to the Eq. 20 cost-model prediction
                  --steps 4 --layers 2 --batch N --seed 42
                  --precision f32|bf16|f16|int8
                  --trace FILE (also dump the Chrome trace)
  bram          BRAM allocator study (Figs. 11/12/14)
  schedule      kernel scheduling study (Figs. 9/10)
  fpga-report   hardware simulator report (Tables IV/V, Figs. 1/15)
";

fn manifest(args: &Args) -> Result<Manifest> {
    Manifest::load(args.get_or("artifacts", "artifacts"))
}

/// The default backend is always the self-contained native trainer;
/// `--backend pjrt` opts into the artifact path explicitly.
const DEFAULT_BACKEND: &str = "native";

fn cmd_info(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    println!("manifest: seed={} lr={} epochs={}", m.seed, m.lr, m.epochs);
    println!(
        "PU stage: optimizer={} batch={}",
        m.optim.kind.name(),
        m.optim.batch_size
    );
    println!("\nTable II/III view:");
    println!(
        "{:<8} {:>7} {:>12} {:>12} {:>11} {:>9}",
        "variant", "layers", "params", "dense-equiv", "compression", "size(MB)"
    );
    for v in &m.variants {
        println!(
            "{:<8} {:>7} {:>12} {:>12} {:>10.1}x {:>9.1}",
            v.name,
            v.config.n_layers,
            v.n_param_scalars,
            v.dense_equivalent_scalars,
            v.compression_ratio(),
            v.size_mb()
        );
    }
    Ok(())
}

/// Build the native backend from CLI options (no artifacts needed).
/// `load_keys` are the options that may name a checkpoint to load —
/// `--init-ckpt` everywhere, plus `--ckpt` for eval (where it cannot
/// mean anything else).  The PU-stage configuration (including its
/// storage precision, which `with_optim` applies model-wide) and the
/// `--checkpoint` policy go in **before** any checkpoint load:
/// restoring optimizer state requires the configured rule to be in
/// place when the checkpoint's `optim.kind` is matched (and
/// `set_optim` would discard already-imported moments), and
/// `load_checkpoint` preserves the configured policy the same way it
/// preserves the compute path.
fn native_backend(
    args: &Args,
    seed: u64,
    load_keys: &[&str],
    optim: OptimConfig,
) -> Result<NativeTrainer> {
    let layers = args.get_usize("layers", 2);
    let checkpoint = CheckpointPolicy::parse(args.get_or("checkpoint", "cache"))?;
    let cfg = ModelConfig::paper(layers);
    let mut backend = NativeTrainer::random_init(&cfg, seed)?
        .with_optim(optim)
        .with_checkpoint(checkpoint.clone());
    if let Some(dir) = load_keys.iter().find_map(|k| args.get(k)) {
        backend.load_checkpoint(Path::new(dir))?;
        println!("loaded checkpoint from {dir}");
    }
    println!(
        "native backend: {layers} encoder blocks, {} tensor-compressed scalars, \
         checkpoint policy {}",
        cfg.tensor_params(),
        checkpoint.name()
    );
    Ok(backend)
}

/// PU-stage configuration from the CLI (`--optimizer`, `--batch`,
/// `--weight-decay`); everything else falls back to the
/// [`OptimConfig::default`] / [`tt_trainer::config::TrainConfig`] chain.
fn optim_from_args(args: &Args) -> Result<OptimConfig> {
    let defaults = OptimConfig::default();
    Ok(OptimConfig {
        kind: OptimKind::parse(args.get_or("optimizer", defaults.kind.name()))?,
        batch_size: args.get_usize("batch", defaults.batch_size).max(1),
        weight_decay: args.get_f64("weight-decay", defaults.weight_decay as f64) as f32,
        ..defaults
    })
}

/// `--trace FILE`: turn the span tracer on for the duration of the
/// command.  The returned path goes to [`trace_finish`] once the
/// traced work is done.
fn trace_setup(args: &Args) -> Option<String> {
    let path = args.get("trace").map(str::to_string);
    if path.is_some() {
        trace::set_enabled(true);
    }
    path
}

/// Export everything collected since [`trace_setup`] as Chrome
/// trace-event JSON.  No-op when `--trace` was not given.
fn trace_finish(path: Option<String>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    trace::set_enabled(false);
    let events = trace::drain();
    std::fs::write(&path, trace::to_chrome_json(&events))?;
    println!(
        "chrome trace ({} spans) written to {path} — load in ui.perfetto.dev",
        events.len()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let seed = args.get_usize("seed", 42) as u64;
    let trace_path = trace_setup(args);
    let result = match args.get_or("backend", DEFAULT_BACKEND) {
        "native" => {
            let precision = Precision::parse(args.get_or("precision", "f32"))?;
            let optim = OptimConfig { precision, ..optim_from_args(args)? };
            // Per-rule default lr; explicit --lr always wins.
            let lr = args.get_f64("lr", optim.kind.default_lr() as f64) as f32;
            let batch = optim.batch_size;
            println!(
                "optimizer {} | batch {batch} | weight decay {} | precision {}",
                optim.kind.name(),
                optim.weight_decay,
                precision.name()
            );
            // Validate the (replicas, batch) pairing before anything is
            // built: a global batch below R would make the tail rule
            // drop every batch and train zero steps.
            let replicas = args.get_usize("replicas", 1);
            tt_trainer::replica::validate_replica_batch(replicas, batch)?;
            let backend = native_backend(args, seed, &["init-ckpt"], optim)?;
            if replicas > 1 {
                println!(
                    "data-parallel: {replicas} replicas, strided batch sharding, \
                     fixed-order compressed-core all-reduce"
                );
                let group = tt_trainer::replica::ReplicaGroup::new(backend, replicas)?;
                run_training(Trainer::with_batch(group, lr, batch), args, seed)
            } else {
                run_training(Trainer::with_batch(backend, lr, batch), args, seed)
            }
        }
        "pjrt" => cmd_train_pjrt(args, seed),
        other => Err(anyhow!("unknown --backend '{other}' (native|pjrt)")),
    };
    trace_finish(trace_path)?;
    result
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args, seed: u64) -> Result<()> {
    use tt_trainer::runtime::Engine;
    let m = manifest(args)?;
    let name = args.get_or("variant", "tt_L2");
    let spec = m.variant(name)?;
    let lr = args.get_f64("lr", m.lr as f64) as f32;
    println!(
        "loading {name}: {} param arrays, {:.1}x compression",
        spec.params.len(),
        spec.compression_ratio()
    );
    // The PJRT artifact bakes its PU stage in at compile time: the
    // manifest records which optimizer was lowered, and the runtime
    // batch must be the compiled one.
    let batch = spec.config.batch.max(1);
    println!(
        "PU stage (compiled into the artifact): optimizer {} | batch {batch}",
        m.optim.kind.name()
    );
    if m.optim.batch_size != batch {
        println!(
            "note: manifest train.batch_size {} != compiled batch {batch}; using the compiled batch",
            m.optim.batch_size
        );
    }
    let engine = Engine::load(spec)?;
    run_training(Trainer::with_batch(engine, lr, batch), args, seed)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args, _seed: u64) -> Result<()> {
    Err(anyhow!(
        "this binary was built without the `pjrt` feature; \
         use --backend native or rebuild with --features pjrt"
    ))
}

fn run_training<B: TrainBackend>(mut trainer: Trainer<B>, args: &Args, seed: u64) -> Result<()> {
    let cfg = trainer.backend.config().clone();
    let (train, test) = Dataset::paper_splits(&cfg, seed);
    println!(
        "backend {} | lr {} | {} train / {} test utterances",
        trainer.backend.backend_name(),
        trainer.lr,
        train.len(),
        test.len()
    );

    if let Some(steps) = args.get("steps") {
        let steps: usize = steps.parse().map_err(|_| anyhow!("bad --steps"))?;
        println!("training {steps} steps");
        let mean = trainer.train_steps(&train, steps)?;
        println!("mean loss over {steps} steps: {mean:.4}");
        println!(
            "final loss (mean of last 20): {:.4}",
            trainer.metrics.recent_loss(20)
        );
    } else {
        let epochs = args.get_usize("epochs", 1);
        let limit = args.get("limit").and_then(|v| v.parse().ok());
        for e in 0..epochs {
            let mean = trainer.train_epoch(&train, limit)?;
            let ev = trainer.evaluate(&test, Some(200))?;
            trainer.metrics.record_eval(e, ev.intent_acc, ev.slot_acc);
            println!(
                "epoch {e}: loss {mean:.4} | intent acc {:.3} | slot acc {:.3} | {:.2}s wall",
                ev.intent_acc,
                ev.slot_acc,
                trainer.metrics.epoch_secs.last().copied().unwrap_or(f64::NAN)
            );
        }
    }
    println!(
        "timing: {:.2}s execute, {:.2}s host ({:.1}% overhead), {} steps | {:.1} steps/s | {:.0} tokens/s",
        trainer.metrics.execute_secs,
        trainer.metrics.host_secs,
        100.0 * trainer.metrics.host_overhead_frac(),
        trainer.metrics.steps,
        trainer.metrics.steps_per_sec(),
        trainer.metrics.tokens_per_sec()
    );
    if trainer.metrics.steps > 0 {
        println!(
            "step time (execute): p50 {:.2} ms | p95 {:.2} ms over {} steps",
            1e3 * trainer.metrics.execute_percentile_secs(50.0),
            1e3 * trainer.metrics.execute_percentile_secs(95.0),
            trainer.metrics.steps
        );
    }
    if let Some(dir) = args.get("ckpt") {
        trainer.backend.save_checkpoint(Path::new(dir))?;
        println!("checkpoint saved to {dir}");
    }
    if let Some(path) = args.get("loss-csv") {
        std::fs::write(path, trainer.metrics.loss_csv())?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let seed = args.get_usize("seed", 42) as u64;
    match args.get_or("backend", DEFAULT_BACKEND) {
        "native" => {
            // Eval reads parameters only (optimizer state in the
            // checkpoint is irrelevant here); --precision rounds the
            // stored parameters first, previewing weights-at-rest
            // accuracy at a half format.
            let precision = Precision::parse(args.get_or("precision", "f32"))?;
            if precision.is_half() {
                println!("evaluating with parameters rounded to {}", precision.name());
            }
            // Stateless default rule; the config only carries the
            // storage precision for the weights-at-rest rounding.
            let optim = OptimConfig { precision, ..OptimConfig::default() };
            let backend = native_backend(args, seed, &["init-ckpt", "ckpt"], optim)?;
            run_eval(Trainer::evaluator(backend), args, seed)
        }
        "pjrt" => cmd_eval_pjrt(args, seed),
        other => Err(anyhow!("unknown --backend '{other}' (native|pjrt)")),
    }
}

#[cfg(feature = "pjrt")]
fn cmd_eval_pjrt(args: &Args, seed: u64) -> Result<()> {
    use tt_trainer::runtime::Engine;
    let m = manifest(args)?;
    let spec = m.variant(args.get_or("variant", "tt_L2"))?;
    let engine = Engine::load(spec)?;
    run_eval(Trainer::evaluator(engine), args, seed)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_eval_pjrt(_args: &Args, _seed: u64) -> Result<()> {
    Err(anyhow!(
        "this binary was built without the `pjrt` feature; \
         use --backend native or rebuild with --features pjrt"
    ))
}

fn run_eval<B: TrainBackend>(trainer: Trainer<B>, args: &Args, seed: u64) -> Result<()> {
    let cfg = trainer.backend.config().clone();
    let (_, test) = Dataset::paper_splits(&cfg, seed);
    let limit = args.get("limit").and_then(|v| v.parse().ok());
    let ev = trainer.evaluate(&test, limit)?;
    println!(
        "{}: intent acc {:.3} | slot acc {:.3} (n={})",
        trainer.backend.backend_name(),
        ev.intent_acc,
        ev.slot_acc,
        ev.n
    );
    Ok(())
}

/// Load-test the serving scheduler over the shared engine: the
/// no-batching baseline vs continuous batching at concurrency {1, 8},
/// writing per-scenario p50/p99 latency and saturation throughput into
/// `BENCH_serve.json` (the CI artifact next to `BENCH_native_train.json`).
fn cmd_serve_bench(args: &Args) -> Result<()> {
    use std::sync::Arc;
    use tt_trainer::serve::loadgen;
    let trace_path = trace_setup(args);
    let seed = args.get_usize("seed", 42) as u64;
    let requests = args.get_usize("requests", 256);
    let out = args.get_or("out", "BENCH_serve.json");
    let precision = Precision::parse(args.get_or("precision", "f32"))?;
    let optim = OptimConfig { precision, ..OptimConfig::default() };
    // Same checkpoint semantics as eval: --ckpt / --init-ckpt load a
    // native checkpoint, otherwise the engine serves the random init
    // (latency is weight-value-independent, so the bench stands alone).
    let backend = native_backend(args, seed, &["init-ckpt", "ckpt"], optim)?;
    let engine = Arc::new(backend.model.engine()?);
    let (_, test) = Dataset::paper_splits(backend.config(), seed);
    let corpus: Vec<Vec<i32>> = test.examples.iter().map(|ex| ex.tokens.clone()).collect();
    println!(
        "serve-bench: {} corpus rows | {requests} requests/scenario | precision {}",
        corpus.len(),
        precision.name()
    );
    let mut reports = Vec::new();
    println!(
        "{:<16} {:>5} {:>9} {:>9} {:>9} {:>11} {:>10} {:>9}",
        "scenario", "conc", "p50(ms)", "p99(ms)", "mean(ms)", "thru(req/s)", "mean-batch", "rejected"
    );
    for spec in loadgen::default_scenarios(requests) {
        let r = loadgen::run_load(&engine, &corpus, &spec)?;
        println!(
            "{:<16} {:>5} {:>9.3} {:>9.3} {:>9.3} {:>11.1} {:>10.2} {:>9}",
            r.name, r.concurrency, r.p50_ms, r.p99_ms, r.mean_ms, r.throughput_rps,
            r.mean_batch, r.rejected
        );
        reports.push(r);
    }
    std::fs::write(out, loadgen::bench_json(&reports))?;
    println!("scenario reports written to {out}");
    trace_finish(trace_path)?;
    Ok(())
}

/// Run the precision x compute-path x checkpoint-policy training grid
/// (`tt_trainer::benchgrid`, the same implementation `cargo bench
/// --offline -- matrix` records into `BENCH_matrix.json`) and print the
/// table with speedups against the f32/looped/cache baseline.
fn cmd_bench_matrix(args: &Args) -> Result<()> {
    let layers = args.get_usize("layers", 2);
    let batch = args.get_usize("batch", 8).max(1);
    let warmup = args.get_usize("warmup", 1);
    let iters = args.get_usize("iters", 4).max(1);
    let cfg = ModelConfig::paper(layers);
    println!(
        "bench-matrix: {layers}-layer paper config | batch {batch} | {warmup} warmup + {iters} \
         timed steps per cell"
    );
    let report = tt_trainer::benchgrid::run_matrix(&cfg, batch, warmup, iters)?;
    print!("{}", report.render_table());
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json())?;
        println!("grid written to {out}");
    }
    Ok(())
}

/// Run the data-parallel replica sweep (`tt_trainer::benchgrid`, the
/// same implementation `cargo bench --offline -- replicas` records into
/// `BENCH_replicas.json`): tokens/sec at R in {1, 2, 4} on one global
/// batch, plus the exchange-volume sweep and the per-device budget
/// split showing the optimizer state charged once, on the lead.
fn cmd_bench_replicas(args: &Args) -> Result<()> {
    let layers = args.get_usize("layers", 2);
    let batch = args.get_usize("batch", 8).max(1);
    let warmup = args.get_usize("warmup", 1);
    let iters = args.get_usize("iters", 4).max(1);
    let cfg = ModelConfig::paper(layers);
    println!(
        "bench-replicas: {layers}-layer paper config | global batch {batch} | {warmup} warmup + \
         {iters} timed steps per replica count"
    );
    let report = tt_trainer::benchgrid::run_replicas(&cfg, batch, warmup, iters)?;
    print!("{}", report.render_table());
    print!("{}", sweeps::replica_exchange_table(&cfg, Precision::F32));
    let budget = resources::replica_budget(
        &cfg,
        OptimKind::Adam,
        Precision::F32,
        &CheckpointPolicy::CacheAll,
        4,
    );
    println!(
        "N=4 budget: device0 state {} B | follower state {} B | exchange buffer {} B/dev \
         ({} URAM block(s))",
        budget.device0.optim_state_bytes,
        budget.device_n.optim_state_bytes,
        budget.exchange_buffer_bytes,
        budget.exchange_uram_blocks
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json())?;
        println!("replica sweep written to {out}");
    }
    Ok(())
}

/// Run a short traced native training loop and print the measured
/// FP/BP/PU wall-clock split next to the Eq. 20 cost-model prediction
/// (BP ~= 2x FP multiplies; PU is contraction-free).
fn cmd_trace_report(args: &Args) -> Result<()> {
    let seed = args.get_usize("seed", 42) as u64;
    let steps = args.get_usize("steps", 4).max(1);
    let precision = Precision::parse(args.get_or("precision", "f32"))?;
    let optim = OptimConfig { precision, ..optim_from_args(args)? };
    let lr = args.get_f64("lr", optim.kind.default_lr() as f64) as f32;
    let batch = optim.batch_size;
    let backend = native_backend(args, seed, &["init-ckpt", "ckpt"], optim)?;
    let cfg = backend.config().clone();
    let (train, _) = Dataset::paper_splits(&cfg, seed);
    let mut trainer = Trainer::with_batch(backend, lr, batch);
    println!("tracing {steps} native steps (batch {batch}, precision {})...", precision.name());
    trace::set_enabled(true);
    trainer.train_steps(&train, steps)?;
    trace::set_enabled(false);
    let events = trace::drain();

    // Eq. 20 prediction for the stage split: the backward pass costs
    // 2x the forward multiplies of each contraction; the PU stage does
    // no contractions at all.
    let shape = LinearShape::paper();
    let k = (batch * cfg.seq_len) as u64;
    let (fwd, bwd) = (shape.btt_muls(k), shape.btt_bwd_muls(k));
    let predicted = |stage: &str| match stage {
        "fp" => format!("{:>5.1}%", 100.0 * fwd as f64 / (fwd + bwd) as f64),
        "bp" => format!("{:>5.1}%", 100.0 * bwd as f64 / (fwd + bwd) as f64),
        _ => "     -".to_string(),
    };
    println!("\n=== FP/BP/PU breakdown ({steps} steps, measured spans) ===");
    println!(
        "{:<8} {:>12} {:>8} {:>8} {:>10}",
        "stage", "total(ms)", "share", "spans", "eq20-pred"
    );
    for r in trace::stage_breakdown(&events) {
        println!(
            "{:<8} {:>12.2} {:>7.1}% {:>8} {:>10}",
            r.stage,
            r.total_us / 1e3,
            100.0 * r.share,
            r.spans,
            predicted(&r.stage)
        );
    }
    println!("(eq20-pred splits contraction muls only: BP = 2x FP, PU has none)");

    let gauges = trace::gauges();
    if !gauges.is_empty() {
        println!("\n=== byte gauges at the last sampled stage boundary ===");
        for (name, v) in gauges {
            println!("{name:<24} {v:>12} B");
        }
    }
    if let Some(path) = args.get("trace") {
        std::fs::write(path, trace::to_chrome_json(&events))?;
        println!("\nchrome trace ({} spans) written to {path}", events.len());
    }
    Ok(())
}

fn cmd_cost_model() -> Result<()> {
    println!("=== Fig. 6: costs at the Table II shape, seq len 32 ===");
    let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], 12);
    println!(
        "{:<6} {:>14} {:>14} {:>14} {:>10} {:>10}",
        "method", "fwd muls", "act mem", "total mem", "comp-red", "mem-red"
    );
    for r in compare_all(&shape, 32) {
        println!(
            "{:<6} {:>14} {:>14} {:>14} {:>9.2}x {:>9.2}x",
            r.method,
            r.fwd_muls,
            r.memory_elems,
            r.total_memory,
            r.compute_reduction,
            r.memory_reduction
        );
    }
    println!("\n=== BP stage (native backward, 2x Eq. 20) ===");
    println!(
        "BTT bwd muls at K=32: {} (training cache: {} elements)",
        shape.btt_bwd_muls(32),
        shape.btt_training_cache_elems(32)
    );
    println!("\n=== Fused QKV (Fig. 9 rescheduling, executed) ===");
    println!(
        "3x separate fwd: {} muls | fused fwd: {} muls ({:.1}% saved) | fused bwd: {} | cache: {} elements",
        3 * shape.btt_muls(32),
        shape.btt_fwd_qkv_muls(32),
        100.0 * (3 * shape.btt_muls(32) - shape.btt_fwd_qkv_muls(32)) as f64
            / (3 * shape.btt_muls(32)) as f64,
        shape.btt_qkv_bwd_muls(32),
        shape.btt_qkv_memory(32)
    );
    println!("\n=== Gradient checkpointing (Eq. 21 cache vs recompute) ===");
    println!(
        "per TT linear at K=32: cache {} B at rest -> {} B (recompute) | \
         extra BP muls {} = {:.1}% of one forward",
        shape.btt_memory_bytes(32, Precision::F32),
        shape.btt_memory_bytes_checkpointed(32, Precision::F32, true),
        shape.btt_recompute_muls(32),
        100.0 * shape.btt_recompute_muls(32) as f64 / shape.btt_muls(32) as f64
    );
    println!("\n=== PU stage: optimizer state in compressed TT space (2-ENC) ===");
    print!("{}", sweeps::optimizer_state_table(&ModelConfig::paper(2)));
    println!(
        "per TT linear at K-independent state: 1x = {} elems, 2x = {} elems",
        shape.optimizer_state_elems(1),
        shape.optimizer_state_elems(2)
    );
    println!("\n=== PU stage at bf16 storage (mixed-precision path, halved bytes) ===");
    print!(
        "{}",
        sweeps::optimizer_state_table_prec(&ModelConfig::paper(2), Precision::Bf16)
    );
    println!(
        "Eq. 21 cache per TT linear at K=32: {} B (f32) -> {} B (bf16)",
        shape.btt_memory_bytes(32, Precision::F32),
        shape.btt_memory_bytes(32, Precision::Bf16)
    );
    println!("\n=== Serving: batched inference on merged factors (no Eq. 21 charge) ===");
    println!(
        "merged factors at rest: {} elements per linear (vs {} TT-core elements)",
        shape.merged_factor_elems(),
        shape.tt_params()
    );
    println!(
        "{:<6} {:>14} {:>18} {:>16}",
        "B", "serve muls", "fused-QKV muls", "transient elems"
    );
    for b in [1u64, 4, 16] {
        let k = b * 32; // K = B * S at the paper's seq len
        println!(
            "{:<6} {:>14} {:>18} {:>16}",
            b,
            shape.btt_serve_muls(k),
            shape.btt_serve_qkv_muls(k),
            shape.btt_serve_transient_elems(k)
        );
    }
    println!(
        "(training forward at K=32 is {} muls: serving amortizes the {} merge muls \
         across all requests)",
        shape.btt_muls(32),
        shape.btt_left_merge_muls() + shape.btt_right_merge_muls()
    );
    println!("\n=== Fig. 7 (top): sequence-length sweep at rank 12 ===");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::seq_len_sweep(12, &sweeps::paper_seq_lens()), "seq")
    );
    println!("\n=== Fig. 7 (bottom): rank sweep at seq len 32 ===");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::rank_sweep(32, &sweeps::paper_ranks()), "rank")
    );
    Ok(())
}

fn cmd_bram() -> Result<()> {
    println!("=== Fig. 12: BRAM utilization efficiency by strategy ===");
    println!("{:<10} {:<20} {:>8} {:>10} {:>8}", "model", "strategy", "blocks", "ideal", "eta");
    for layers in [2usize, 4, 6] {
        for a in bram::strategy_comparison(layers, 12) {
            println!(
                "{:<10} {:<20} {:>8} {:>10.1} {:>8.3}",
                format!("{layers}-ENC"),
                a.strategy.name(),
                a.total_blocks,
                a.ideal_blocks,
                a.efficiency
            );
        }
    }
    println!("\n=== Fig. 14: BRAM blocks for all TT cores vs rank (2-ENC) ===");
    println!(
        "{:<6} {:>22} {:>22} {:>10}",
        "rank", "partition/default", "reshape/grouped", "ideal"
    );
    for rank in [2usize, 4, 8, 12, 16, 24, 32, 48] {
        let allocs = bram::strategy_comparison(2, rank);
        println!(
            "{:<6} {:>22} {:>22} {:>10.1}",
            rank, allocs[0].total_blocks, allocs[3].total_blocks, allocs[3].ideal_blocks
        );
    }
    Ok(())
}

fn cmd_schedule() -> Result<()> {
    let shape = LinearShape::paper();
    println!("=== Fig. 9: QKV forward scheduling ===");
    let (naive, resched) = schedule::fig9_compare(&shape, 32, 12);
    println!("naive     (6 MUL0 units): makespan {naive} cycles");
    println!("resched   (2 MUL0 units): makespan {resched} cycles");
    println!("=> task rescheduling saves 4 MUL0 kernel instances at equal latency\n");
    println!("=== Fig. 10: BP intermediate buffer, unfused vs fused ===");
    println!("unfused: {} elements", schedule::fig10_buffer_elems(&shape, false));
    println!("fused:   {} elements (O(r))", schedule::fig10_buffer_elems(&shape, true));
    println!("\n=== Per-epoch latency model (Table V FPGA rows) ===");
    for layers in [2usize, 4, 6] {
        let m = schedule::CycleModel::paper(layers);
        println!(
            "L{layers}: {:.0}s per epoch ({} cycles/sample, {} samples)",
            m.epoch_latency_secs(schedule::ATIS_TRAIN_SAMPLES),
            m.cycles_per_sample(),
            schedule::ATIS_TRAIN_SAMPLES
        );
    }
    Ok(())
}

fn cmd_fpga_report() -> Result<()> {
    println!("=== Table IV: resource utilization ===");
    println!(
        "{:<7} {:>6} {:>8} {:>8} {:>6} {:>6} {:>8} {:>8} {:>8}",
        "model", "DSP", "LUT", "FF", "BRAM", "URAM", "dyn(W)", "stat(W)", "total(W)"
    );
    for layers in [2usize, 4, 6] {
        let r = resources::report(&ModelConfig::paper(layers));
        println!(
            "{:<7} {:>6} {:>8} {:>8} {:>6} {:>6} {:>8.2} {:>8.2} {:>8.2}",
            format!("{layers}-ENC"),
            r.dsp.used,
            r.lut.used,
            r.ff.used,
            r.bram.used,
            r.uram.used,
            r.dynamic_power_w,
            r.static_power_w,
            r.total_power_w()
        );
    }
    println!("\n=== Optimizer state vs the U50 budget (PU stage) ===");
    println!(
        "{:<7} {:<10} {:>11} {:>11} {:>10} {:>9} {:>9}",
        "model", "optimizer", "state BRAM", "state URAM", "state MB", "BRAM", "URAM"
    );
    for layers in [2usize, 4, 6] {
        for kind in OptimKind::all() {
            let r = resources::report_with_optim(&ModelConfig::paper(layers), kind);
            println!(
                "{:<7} {:<10} {:>11} {:>11} {:>10.2} {:>9} {:>9}",
                format!("{layers}-ENC"),
                kind.name(),
                r.optim_state_bram,
                r.optim_state_uram,
                r.optim_state_mb(),
                format!("{}/{}", r.bram.used, r.bram.available),
                format!("{}/{}", r.uram.used, r.uram.available)
            );
        }
    }

    println!("\n=== Mixed-precision storage path (Adam): f32 vs bf16 bytes ===");
    println!(
        "{:<7} {:>16} {:>16} {:>16} {:>16}",
        "model", "eq21 f32 (KB)", "eq21 bf16 (KB)", "state f32 (KB)", "state bf16 (KB)"
    );
    for layers in [2usize, 4, 6] {
        let cfg = ModelConfig::paper(layers);
        let f = resources::report_with_optim_prec(&cfg, OptimKind::Adam, Precision::F32);
        let b = resources::report_with_optim_prec(&cfg, OptimKind::Adam, Precision::Bf16);
        println!(
            "{:<7} {:>16.1} {:>16.1} {:>16.1} {:>16.1}",
            format!("{layers}-ENC"),
            f.eq21_cache_bytes as f64 / 1e3,
            b.eq21_cache_bytes as f64 / 1e3,
            f.optim_state_bytes as f64 / 1e3,
            b.optim_state_bytes as f64 / 1e3
        );
    }

    println!("\n=== Gradient checkpointing (Adam, f32): cached vs recompute ===");
    println!(
        "{:<7} {:>15} {:>15} {:>11} {:>11}",
        "model", "eq21 (KB)", "eq21 ckpt (KB)", "URAM req", "URAM ckpt"
    );
    for layers in [2usize, 4, 6] {
        let cfg = ModelConfig::paper(layers);
        let ca = resources::report_for_policy(
            &cfg,
            OptimKind::Adam,
            Precision::F32,
            &CheckpointPolicy::CacheAll,
        );
        let re = resources::report_for_policy(
            &cfg,
            OptimKind::Adam,
            Precision::F32,
            &CheckpointPolicy::Recompute,
        );
        println!(
            "{:<7} {:>15.1} {:>15.1} {:>11} {:>11}",
            format!("{layers}-ENC"),
            ca.eq21_cache_bytes as f64 / 1e3,
            re.eq21_cache_bytes as f64 / 1e3,
            ca.uram_required,
            re.uram_required
        );
    }

    println!("\n=== Table V: GPU vs FPGA ===");
    print!("{}", energy::render_table_v(&energy::table_v()));
    println!("\n=== Fig. 1 summary (GPU-TT vs FPGA) ===");
    for p in energy::fig1() {
        println!(
            "L{}: memory {:.0} MB -> {:.1} MB ({:.1}x) | energy {:.1} kJ -> {:.1} kJ ({:.1}x)",
            p.n_layers,
            p.gpu_tt_memory_mb,
            p.fpga_memory_mb,
            p.gpu_tt_memory_mb / p.fpga_memory_mb,
            p.gpu_tt_energy_kj,
            p.fpga_energy_kj,
            p.gpu_tt_energy_kj / p.fpga_energy_kj
        );
    }
    println!("\n=== Fig. 15: computing memory ===");
    for p in energy::fig15() {
        println!(
            "L{}: GPU total {:.0} MB | GPU reserved (MM) {:.0} MB | GPU reserved (BTT) {:.0} MB | FPGA {:.1} MB",
            p.n_layers, p.gpu_total_mb, p.gpu_reserved_matrix_mb, p.gpu_reserved_btt_mb, p.fpga_mb
        );
    }
    Ok(())
}
