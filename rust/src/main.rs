//! `tt-trainer` — CLI for tensor-compressed transformer training and the
//! paper's experiment suite.
//!
//! ```text
//! tt-trainer info                              # manifest + Table II/III view
//! tt-trainer train --variant tt_L2 --steps 200 # train on synthetic ATIS
//! tt-trainer eval  --variant tt_L2             # accuracy on the test split
//! tt-trainer cost-model                        # Fig. 6 + Fig. 7 sweeps
//! tt-trainer bram                              # Figs. 11/12/14
//! tt-trainer schedule                          # Figs. 9/10
//! tt-trainer fpga-report                       # Tables IV/V, Figs. 1/15
//! ```

use anyhow::{anyhow, Result};
use tt_trainer::config::ModelConfig;
use tt_trainer::coordinator::Trainer;
use tt_trainer::costmodel::{compare_all, sweeps, LinearShape};
use tt_trainer::data::Dataset;
use tt_trainer::fpga::{bram, energy, resources, schedule};
use tt_trainer::runtime::{Engine, Manifest};
use tt_trainer::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "cost-model" => cmd_cost_model(),
        "bram" => cmd_bram(),
        "schedule" => cmd_schedule(),
        "fpga-report" => cmd_fpga_report(),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
tt-trainer: tensor-compressed transformer training (rust + JAX/Pallas AOT)

USAGE: tt-trainer <command> [options]

COMMANDS:
  info          manifest summary (Table II/III view)
  train         train a variant on synthetic ATIS
                  --variant tt_L2 --steps N | --epochs E [--limit N]
                  --lr 0.004 --seed 42 --artifacts DIR --ckpt DIR
                  --loss-csv FILE
  eval          evaluate a variant   --variant tt_L2 [--limit N]
  cost-model    Fig. 6 comparison + Fig. 7 sweeps
  bram          BRAM allocator study (Figs. 11/12/14)
  schedule      kernel scheduling study (Figs. 9/10)
  fpga-report   hardware simulator report (Tables IV/V, Figs. 1/15)
";

fn manifest(args: &Args) -> Result<Manifest> {
    Manifest::load(args.get_or("artifacts", "artifacts"))
}

fn cmd_info(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    println!("manifest: seed={} lr={} epochs={}", m.seed, m.lr, m.epochs);
    println!("\nTable II/III view:");
    println!(
        "{:<8} {:>7} {:>12} {:>12} {:>11} {:>9}",
        "variant", "layers", "params", "dense-equiv", "compression", "size(MB)"
    );
    for v in &m.variants {
        println!(
            "{:<8} {:>7} {:>12} {:>12} {:>10.1}x {:>9.1}",
            v.name,
            v.config.n_layers,
            v.n_param_scalars,
            v.dense_equivalent_scalars,
            v.compression_ratio(),
            v.size_mb()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    let name = args.get_or("variant", "tt_L2");
    let spec = m.variant(name)?;
    let seed = args.get_usize("seed", 42) as u64;
    let lr = args.get_f64("lr", m.lr as f64) as f32;
    let cfg = spec.config.clone();
    println!(
        "loading {name}: {} param arrays, {:.1}x compression",
        spec.params.len(),
        spec.compression_ratio()
    );
    let engine = Engine::load(spec)?;
    let (train, test) = Dataset::paper_splits(&cfg, seed);
    let mut trainer = Trainer::new(engine, lr);

    if let Some(steps) = args.get("steps") {
        let steps: usize = steps.parse().map_err(|_| anyhow!("bad --steps"))?;
        println!("training {steps} steps (lr={lr})");
        trainer.train_steps(&train, steps)?;
        println!(
            "final loss (mean of last 20): {:.4}",
            trainer.metrics.recent_loss(20)
        );
    } else {
        let epochs = args.get_usize("epochs", 1);
        let limit = args.get("limit").and_then(|v| v.parse().ok());
        for e in 0..epochs {
            let mean = trainer.train_epoch(&train, limit)?;
            let ev = trainer.evaluate(&test, Some(200))?;
            trainer.metrics.record_eval(e, ev.intent_acc, ev.slot_acc);
            println!(
                "epoch {e}: loss {mean:.4} | intent acc {:.3} | slot acc {:.3}",
                ev.intent_acc, ev.slot_acc
            );
        }
    }
    println!(
        "timing: {:.2}s execute, {:.2}s host ({:.1}% overhead), {} steps",
        trainer.metrics.execute_secs,
        trainer.metrics.host_secs,
        100.0 * trainer.metrics.host_overhead_frac(),
        trainer.metrics.steps
    );
    if let Some(dir) = args.get("ckpt") {
        trainer.engine.save_checkpoint(dir)?;
        println!("checkpoint saved to {dir}");
    }
    if let Some(path) = args.get("loss-csv") {
        std::fs::write(path, trainer.metrics.loss_csv())?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let m = manifest(args)?;
    let name = args.get_or("variant", "tt_L2");
    let spec = m.variant(name)?;
    let engine = Engine::load(spec)?;
    let (_, test) = Dataset::paper_splits(&spec.config, 42);
    let trainer = Trainer::new(engine, m.lr);
    let limit = args.get("limit").and_then(|v| v.parse().ok());
    let ev = trainer.evaluate(&test, limit)?;
    println!(
        "{name}: intent acc {:.3} | slot acc {:.3} (n={})",
        ev.intent_acc, ev.slot_acc, ev.n
    );
    Ok(())
}

fn cmd_cost_model() -> Result<()> {
    println!("=== Fig. 6: costs at the Table II shape, seq len 32 ===");
    let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], 12);
    println!(
        "{:<6} {:>14} {:>14} {:>14} {:>10} {:>10}",
        "method", "fwd muls", "act mem", "total mem", "comp-red", "mem-red"
    );
    for r in compare_all(&shape, 32) {
        println!(
            "{:<6} {:>14} {:>14} {:>14} {:>9.2}x {:>9.2}x",
            r.method,
            r.fwd_muls,
            r.memory_elems,
            r.total_memory,
            r.compute_reduction,
            r.memory_reduction
        );
    }
    println!("\n=== Fig. 7 (top): sequence-length sweep at rank 12 ===");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::seq_len_sweep(12, &sweeps::paper_seq_lens()), "seq")
    );
    println!("\n=== Fig. 7 (bottom): rank sweep at seq len 32 ===");
    print!(
        "{}",
        sweeps::render_sweep(&sweeps::rank_sweep(32, &sweeps::paper_ranks()), "rank")
    );
    Ok(())
}

fn cmd_bram() -> Result<()> {
    println!("=== Fig. 12: BRAM utilization efficiency by strategy ===");
    println!("{:<10} {:<20} {:>8} {:>10} {:>8}", "model", "strategy", "blocks", "ideal", "eta");
    for layers in [2usize, 4, 6] {
        for a in bram::strategy_comparison(layers, 12) {
            println!(
                "{:<10} {:<20} {:>8} {:>10.1} {:>8.3}",
                format!("{layers}-ENC"),
                a.strategy.name(),
                a.total_blocks,
                a.ideal_blocks,
                a.efficiency
            );
        }
    }
    println!("\n=== Fig. 14: BRAM blocks for all TT cores vs rank (2-ENC) ===");
    println!(
        "{:<6} {:>22} {:>22} {:>10}",
        "rank", "partition/default", "reshape/grouped", "ideal"
    );
    for rank in [2usize, 4, 8, 12, 16, 24, 32, 48] {
        let allocs = bram::strategy_comparison(2, rank);
        println!(
            "{:<6} {:>22} {:>22} {:>10.1}",
            rank, allocs[0].total_blocks, allocs[3].total_blocks, allocs[3].ideal_blocks
        );
    }
    Ok(())
}

fn cmd_schedule() -> Result<()> {
    let shape = LinearShape::paper();
    println!("=== Fig. 9: QKV forward scheduling ===");
    let (naive, resched) = schedule::fig9_compare(&shape, 32, 12);
    println!("naive     (6 MUL0 units): makespan {naive} cycles");
    println!("resched   (2 MUL0 units): makespan {resched} cycles");
    println!("=> task rescheduling saves 4 MUL0 kernel instances at equal latency\n");
    println!("=== Fig. 10: BP intermediate buffer, unfused vs fused ===");
    println!("unfused: {} elements", schedule::fig10_buffer_elems(&shape, false));
    println!("fused:   {} elements (O(r))", schedule::fig10_buffer_elems(&shape, true));
    println!("\n=== Per-epoch latency model (Table V FPGA rows) ===");
    for layers in [2usize, 4, 6] {
        let m = schedule::CycleModel::paper(layers);
        println!(
            "L{layers}: {:.0}s per epoch ({} cycles/sample, {} samples)",
            m.epoch_latency_secs(schedule::ATIS_TRAIN_SAMPLES),
            m.cycles_per_sample(),
            schedule::ATIS_TRAIN_SAMPLES
        );
    }
    Ok(())
}

fn cmd_fpga_report() -> Result<()> {
    println!("=== Table IV: resource utilization ===");
    println!(
        "{:<7} {:>6} {:>8} {:>8} {:>6} {:>6} {:>8} {:>8} {:>8}",
        "model", "DSP", "LUT", "FF", "BRAM", "URAM", "dyn(W)", "stat(W)", "total(W)"
    );
    for layers in [2usize, 4, 6] {
        let r = resources::report(&ModelConfig::paper(layers));
        println!(
            "{:<7} {:>6} {:>8} {:>8} {:>6} {:>6} {:>8.2} {:>8.2} {:>8.2}",
            format!("{layers}-ENC"),
            r.dsp.used,
            r.lut.used,
            r.ff.used,
            r.bram.used,
            r.uram.used,
            r.dynamic_power_w,
            r.static_power_w,
            r.total_power_w()
        );
    }
    println!("\n=== Table V: GPU vs FPGA ===");
    print!("{}", energy::render_table_v(&energy::table_v()));
    println!("\n=== Fig. 1 summary (GPU-TT vs FPGA) ===");
    for p in energy::fig1() {
        println!(
            "L{}: memory {:.0} MB -> {:.1} MB ({:.1}x) | energy {:.1} kJ -> {:.1} kJ ({:.1}x)",
            p.n_layers,
            p.gpu_tt_memory_mb,
            p.fpga_memory_mb,
            p.gpu_tt_memory_mb / p.fpga_memory_mb,
            p.gpu_tt_energy_kj,
            p.fpga_energy_kj,
            p.gpu_tt_energy_kj / p.fpga_energy_kj
        );
    }
    println!("\n=== Fig. 15: computing memory ===");
    for p in energy::fig15() {
        println!(
            "L{}: GPU total {:.0} MB | GPU reserved (MM) {:.0} MB | GPU reserved (BTT) {:.0} MB | FPGA {:.1} MB",
            p.n_layers, p.gpu_total_mb, p.gpu_reserved_matrix_mb, p.gpu_reserved_btt_mb, p.fpga_mb
        );
    }
    Ok(())
}
