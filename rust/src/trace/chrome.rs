//! Chrome trace-event JSON exporter (Perfetto / `chrome://tracing`).
//!
//! Emits the JSON-object form `{"traceEvents": [...]}` with one `"M"`
//! (metadata) `thread_name` event per thread lane followed by one `"X"`
//! (complete) event per span.  Perfetto reconstructs nesting from time
//! containment within a `(pid, tid)` lane, so the per-thread lanes show
//! the worker-pool fan-out and the serving executor's batching directly.
//! Timestamps (`ts`) and durations (`dur`) are microseconds, the format's
//! native unit — exactly what [`super::SpanEvent`] carries.
//!
//! The writer is hand-rolled (the crate is `anyhow`-only by policy);
//! the escaping + parse round-trip is pinned against the in-repo JSON
//! parser (`util::json`) in `rust/tests/tracing.rs`.

use super::span::SpanEvent;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Escape a string for embedding in a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render spans as a Chrome trace-event JSON document.
pub fn to_chrome_json(events: &[SpanEvent]) -> String {
    // One thread_name metadata event per lane; first span on a lane
    // names it (thread names are stable per thread, so any span works).
    let mut lanes: BTreeMap<u64, &str> = BTreeMap::new();
    for e in events {
        lanes.entry(e.tid).or_insert(e.thread.as_str());
    }
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    for (tid, name) in &lanes {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            escape(name)
        );
    }
    for e in events {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(
            out,
            "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"name\":\"{}\",\"cat\":\"{}\",\
             \"ts\":{:.3},\"dur\":{:.3},\"args\":{{\"depth\":{},\"seq\":{}}}}}",
            e.tid,
            escape(&e.name),
            escape(e.cat),
            e.start_us,
            e.dur_us,
            e.depth,
            e.seq
        );
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, tid: u64) -> SpanEvent {
        SpanEvent {
            name: name.to_string(),
            cat: "t",
            thread: format!("lane-{tid}"),
            tid,
            depth: 0,
            seq: 0,
            start_us: 1.0,
            dur_us: 2.0,
        }
    }

    #[test]
    fn escapes_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("\u{1}"), "\\u0001");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn emits_metadata_and_complete_events() {
        let json = to_chrome_json(&[ev("fp.layer0", 1), ev("job", 2)]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"name\":\"lane-2\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"fp.layer0\""));
        // Two lanes -> two metadata + two complete events.
        assert_eq!(json.matches("\"ph\":\"M\"").count(), 2);
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 2);
    }
}
