//! Counter/gauge/histogram registry sampled at stage boundaries.
//!
//! This is the live-observability counterpart of the static accounting
//! in `fpga::resources`: the trainer publishes the *measured* on-chip
//! byte figures (Eq. 21 cache, optimizer state, packed params) here at
//! each stage boundary, and `rust/tests/tracing.rs` pins them against
//! `ResourceReport` so the paper's U50 budget claims hold at runtime,
//! not just on paper.  Histograms are sparse (`BTreeMap<u64, u64>`
//! value -> count), which fits the small-integer distributions we track
//! (serving batch sizes).
//!
//! All operations take one global mutex; callers on hot paths gate on
//! [`crate::trace::enabled`] so the disabled cost stays a single
//! relaxed atomic load.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

#[derive(Default)]
struct Registry {
    gauges: BTreeMap<String, u64>,
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, BTreeMap<u64, u64>>,
}

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Registry::default()))
}

fn with_registry<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    f(&mut registry().lock().unwrap_or_else(|e| e.into_inner()))
}

/// Set a gauge to an absolute value (last write wins).
pub fn gauge_set(name: &str, value: u64) {
    with_registry(|r| {
        r.gauges.insert(name.to_string(), value);
    });
}

/// Current value of a gauge, if it has ever been set.
pub fn gauge(name: &str) -> Option<u64> {
    with_registry(|r| r.gauges.get(name).copied())
}

/// Add to a monotonic counter (created at 0 on first touch).
pub fn counter_add(name: &str, delta: u64) {
    with_registry(|r| {
        *r.counters.entry(name.to_string()).or_insert(0) += delta;
    });
}

/// Current value of a counter (0 if never touched).
pub fn counter(name: &str) -> u64 {
    with_registry(|r| r.counters.get(name).copied().unwrap_or(0))
}

/// Record one observation of `value` in a sparse histogram.
pub fn hist_observe(name: &str, value: u64) {
    with_registry(|r| {
        *r.hists.entry(name.to_string()).or_default().entry(value).or_insert(0) += 1;
    });
}

/// Sorted `(value, count)` pairs of a histogram (empty if untouched).
pub fn hist(name: &str) -> Vec<(u64, u64)> {
    with_registry(|r| {
        r.hists
            .get(name)
            .map(|h| h.iter().map(|(k, v)| (*k, *v)).collect())
            .unwrap_or_default()
    })
}

/// All gauges, sorted by name.
pub fn gauges() -> Vec<(String, u64)> {
    with_registry(|r| r.gauges.iter().map(|(k, v)| (k.clone(), *v)).collect())
}

/// All counters, sorted by name.
pub fn counters() -> Vec<(String, u64)> {
    with_registry(|r| r.counters.iter().map(|(k, v)| (k.clone(), *v)).collect())
}

/// Clear every gauge/counter/histogram.
pub fn reset() {
    with_registry(|r| *r = Registry::default());
}

#[cfg(test)]
mod tests {
    use super::super::span::TestSession;
    use super::*;

    #[test]
    fn registry_roundtrip() {
        let _s = TestSession::begin();
        assert_eq!(gauge("bytes"), None);
        gauge_set("bytes", 7);
        gauge_set("bytes", 42);
        assert_eq!(gauge("bytes"), Some(42));
        assert_eq!(counter("steps"), 0);
        counter_add("steps", 2);
        counter_add("steps", 3);
        assert_eq!(counter("steps"), 5);
        hist_observe("batch", 4);
        hist_observe("batch", 4);
        hist_observe("batch", 8);
        assert_eq!(hist("batch"), vec![(4, 2), (8, 1)]);
        assert_eq!(gauges(), vec![("bytes".to_string(), 42)]);
        assert_eq!(counters(), vec![("steps".to_string(), 5)]);
        reset();
        assert_eq!(gauge("bytes"), None);
        assert!(hist("batch").is_empty());
    }
}
