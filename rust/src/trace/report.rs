//! FP/BP/PU stage aggregation over recorded spans.
//!
//! Groups `cat == "train"` spans by their stage prefix (the text before
//! the first `.` — the taxonomy emits `fp.layer{i}`, `bp.embed`,
//! `pu.heads`, ...) and computes each stage's share of the total
//! FP + BP + PU time.  The `trace-report` CLI command prints these rows
//! next to the cost model's analytic prediction; double counting is
//! avoided by construction because the trainer never nests two
//! `train`-category spans with the same stage prefix.

use super::span::SpanEvent;

/// Aggregated wall-clock for one stage prefix.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRow {
    pub stage: String,
    pub total_us: f64,
    /// Fraction of the FP + BP + PU total (0.0 when that total is 0).
    pub share: f64,
    pub spans: usize,
}

/// The paper's three training stages, in pipeline order.
pub const STAGES: [&str; 3] = ["fp", "bp", "pu"];

fn stage_of(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

/// Aggregate `train`-category spans into per-stage totals and shares.
/// FP/BP/PU come first in pipeline order; any other prefix follows
/// alphabetically (shares still relative to the FP + BP + PU total).
pub fn stage_breakdown(events: &[SpanEvent]) -> Vec<StageRow> {
    let mut totals: Vec<(String, f64, usize)> = Vec::new();
    for e in events.iter().filter(|e| e.cat == "train") {
        let stage = stage_of(&e.name);
        match totals.iter_mut().find(|(s, _, _)| s == stage) {
            Some((_, us, n)) => {
                *us += e.dur_us;
                *n += 1;
            }
            None => totals.push((stage.to_string(), e.dur_us, 1)),
        }
    }
    let core: f64 = totals
        .iter()
        .filter(|(s, _, _)| STAGES.contains(&s.as_str()))
        .map(|(_, us, _)| *us)
        .sum();
    let mut rows: Vec<StageRow> = totals
        .into_iter()
        .map(|(stage, total_us, spans)| StageRow {
            share: if core > 0.0 { total_us / core } else { 0.0 },
            stage,
            total_us,
            spans,
        })
        .collect();
    rows.sort_by_key(|r| {
        (
            STAGES
                .iter()
                .position(|s| *s == r.stage)
                .unwrap_or(STAGES.len()),
            r.stage.clone(),
        )
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, cat: &'static str, dur_us: f64) -> SpanEvent {
        SpanEvent {
            name: name.to_string(),
            cat,
            thread: "t".into(),
            tid: 1,
            depth: 0,
            seq: 0,
            start_us: 0.0,
            dur_us,
        }
    }

    #[test]
    fn groups_by_prefix_and_orders_stages() {
        let rows = stage_breakdown(&[
            ev("pu.layer0", "train", 10.0),
            ev("bp.layer0", "train", 60.0),
            ev("fp.layer0", "train", 20.0),
            ev("fp.embed", "train", 10.0),
            ev("merge_left", "ttlinear", 999.0), // other cat: ignored
        ]);
        let stages: Vec<&str> = rows.iter().map(|r| r.stage.as_str()).collect();
        assert_eq!(stages, ["fp", "bp", "pu"]);
        assert_eq!(rows[0].total_us, 30.0);
        assert_eq!(rows[0].spans, 2);
        assert!((rows[0].share - 0.3).abs() < 1e-12);
        assert!((rows[1].share - 0.6).abs() < 1e-12);
        assert!((rows[2].share - 0.1).abs() < 1e-12);
    }

    #[test]
    fn empty_events_no_rows() {
        assert!(stage_breakdown(&[]).is_empty());
    }
}
