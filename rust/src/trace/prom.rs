//! Prometheus text-exposition renderer (version 0.0.4 format).
//!
//! Small hand-rolled writer for `# HELP` / `# TYPE` headers plus
//! `name{label="value"} sample` lines — enough for the serving
//! snapshot (`ServerHandle::prometheus_snapshot`) to be scraped or
//! eyeballed without any dependency.

use std::fmt::Write as _;

/// One sample line of a family: optional labels plus a value.
pub struct Sample {
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    pub fn plain(value: f64) -> Sample {
        Sample { labels: Vec::new(), value }
    }

    pub fn labeled(label: &str, label_value: impl ToString, value: f64) -> Sample {
        Sample {
            labels: vec![(label.to_string(), label_value.to_string())],
            value,
        }
    }
}

/// A metric family: one `# HELP`/`# TYPE` header and its samples.
pub struct MetricFamily {
    pub name: String,
    pub help: String,
    /// `"counter"` or `"gauge"`.
    pub kind: &'static str,
    pub samples: Vec<Sample>,
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render families in Prometheus text exposition format.
pub fn render(families: &[MetricFamily]) -> String {
    let mut out = String::new();
    for f in families {
        let _ = writeln!(out, "# HELP {} {}", f.name, f.help);
        let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind);
        for s in &f.samples {
            if s.labels.is_empty() {
                let _ = writeln!(out, "{} {}", f.name, s.value);
            } else {
                let labels: Vec<String> = s
                    .labels
                    .iter()
                    .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
                    .collect();
                let _ = writeln!(out, "{}{{{}}} {}", f.name, labels.join(","), s.value);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_families_and_labels() {
        let text = render(&[
            MetricFamily {
                name: "serve_requests_served_total".into(),
                help: "Requests completed successfully.".into(),
                kind: "counter",
                samples: vec![Sample::plain(5.0)],
            },
            MetricFamily {
                name: "serve_batch_size_count".into(),
                help: "Executed batches by batch size.".into(),
                kind: "gauge",
                samples: vec![
                    Sample::labeled("batch_size", 4, 2.0),
                    Sample::labeled("batch_size", 8, 1.0),
                ],
            },
        ]);
        assert!(text.contains("# HELP serve_requests_served_total Requests completed successfully."));
        assert!(text.contains("# TYPE serve_requests_served_total counter"));
        assert!(text.contains("serve_requests_served_total 5\n"));
        assert!(text.contains("serve_batch_size_count{batch_size=\"4\"} 2\n"));
        assert!(text.contains("serve_batch_size_count{batch_size=\"8\"} 1\n"));
    }
}
