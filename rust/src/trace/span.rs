//! Span tracer core: thread-local span stacks feeding a mutex-buffered
//! global sink.
//!
//! Cost model: when tracing is disabled every instrumentation site is a
//! single `Relaxed` atomic load returning an inert guard — no
//! allocation, no lock, no clock read (bound asserted by
//! [`disabled_overhead_ns`] in `rust/tests/tracing.rs`).  When enabled,
//! opening a span touches only thread-local state plus one `Instant`
//! read; the global mutex is taken once per span, at close, to push the
//! completed [`SpanEvent`].  Completed events go straight to the global
//! sink rather than a thread-local buffer because the worker-pool
//! threads (`tt-matmul-*`) are persistent and never run TLS destructors
//! — a flush-on-thread-exit design would silently drop their spans.
//!
//! Determinism: each thread stamps spans with a monotonically
//! increasing per-thread `seq` at open; [`snapshot`]/[`drain`] sort by
//! `(tid, seq)`, so the per-thread span order (names, depths, nesting)
//! is identical across runs even though wall-clock durations differ.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// One completed span, as delivered by [`snapshot`]/[`drain`].
///
/// `start_us`/`dur_us` are microseconds relative to the trace epoch
/// (pinned by the first [`set_enabled`]`(true)`), matching the Chrome
/// trace-event `ts`/`dur` convention.  `depth` is the thread-local
/// nesting level at open (0 = top level); `seq` orders spans within a
/// thread by open time.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub name: String,
    pub cat: &'static str,
    pub thread: String,
    pub tid: u64,
    pub depth: u32,
    pub seq: u64,
    pub start_us: f64,
    pub dur_us: f64,
}

struct ThreadState {
    tid: u64,
    name: String,
    depth: u32,
    seq: u64,
}

thread_local! {
    static THREAD: RefCell<ThreadState> = RefCell::new({
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        ThreadState { tid, name, depth: 0, seq: 0 }
    });
}

fn sink() -> &'static Mutex<Vec<SpanEvent>> {
    static SINK: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

/// The trace epoch: all timestamps are relative to this instant.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Is tracing on?  One `Relaxed` atomic load — this is the entire
/// disabled-mode cost of every instrumentation site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on/off.  Enabling pins the trace epoch (idempotently)
/// so span timestamps are comparable across the whole run.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

/// Open a span with a static name.  Returns a guard that records the
/// span when dropped; inert (and allocation-free) when disabled.
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { inner: None };
    }
    open(cat, name.to_string())
}

/// Open a span with a lazily formatted name: the closure only runs when
/// tracing is enabled, so `format!` cost never leaks into the disabled
/// fast path.
#[inline]
pub fn span_fmt(cat: &'static str, name: impl FnOnce() -> String) -> SpanGuard {
    if !enabled() {
        return SpanGuard { inner: None };
    }
    open(cat, name())
}

fn open(cat: &'static str, name: String) -> SpanGuard {
    let (tid, depth, seq) = THREAD.with(|t| {
        let mut t = t.borrow_mut();
        let depth = t.depth;
        t.depth += 1;
        let seq = t.seq;
        t.seq += 1;
        (t.tid, depth, seq)
    });
    SpanGuard {
        inner: Some(OpenSpan { name, cat, tid, depth, seq, start: Instant::now() }),
    }
}

/// Record a span from explicit endpoints (attributed to the calling
/// thread at its current depth).  Used where the interval is only known
/// after the fact — e.g. the serving `queue` span, which starts at the
/// earliest enqueue of a batch and ends when the batch launches.
pub fn record_span_at(cat: &'static str, name: &str, start: Instant, end: Instant) {
    if !enabled() {
        return;
    }
    let (tid, depth, seq, thread) = THREAD.with(|t| {
        let mut t = t.borrow_mut();
        let seq = t.seq;
        t.seq += 1;
        (t.tid, t.depth, seq, t.name.clone())
    });
    let e0 = epoch();
    let ev = SpanEvent {
        name: name.to_string(),
        cat,
        thread,
        tid,
        depth,
        seq,
        start_us: start.saturating_duration_since(e0).as_secs_f64() * 1e6,
        dur_us: end.saturating_duration_since(start).as_secs_f64() * 1e6,
    };
    sink().lock().unwrap_or_else(|e| e.into_inner()).push(ev);
}

struct OpenSpan {
    name: String,
    cat: &'static str,
    tid: u64,
    depth: u32,
    seq: u64,
    start: Instant,
}

/// RAII guard returned by [`span`]/[`span_fmt`]; the span closes (and
/// is pushed to the sink) when the guard drops.
pub struct SpanGuard {
    inner: Option<OpenSpan>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(s) = self.inner.take() else { return };
        let end = Instant::now();
        let thread = THREAD.with(|t| {
            let mut t = t.borrow_mut();
            t.depth = t.depth.saturating_sub(1);
            t.name.clone()
        });
        let e0 = epoch();
        let ev = SpanEvent {
            name: s.name,
            cat: s.cat,
            thread,
            tid: s.tid,
            depth: s.depth,
            seq: s.seq,
            start_us: s.start.saturating_duration_since(e0).as_secs_f64() * 1e6,
            dur_us: end.saturating_duration_since(s.start).as_secs_f64() * 1e6,
        };
        sink().lock().unwrap_or_else(|e| e.into_inner()).push(ev);
    }
}

fn sorted(mut events: Vec<SpanEvent>) -> Vec<SpanEvent> {
    events.sort_by(|a, b| (a.tid, a.seq).cmp(&(b.tid, b.seq)));
    events
}

/// Copy of all buffered events, sorted by `(tid, seq)` (deterministic
/// per-thread open order).
pub fn snapshot() -> Vec<SpanEvent> {
    sorted(sink().lock().unwrap_or_else(|e| e.into_inner()).clone())
}

/// Take (and clear) all buffered events, sorted like [`snapshot`].
pub fn drain() -> Vec<SpanEvent> {
    sorted(std::mem::take(
        &mut *sink().lock().unwrap_or_else(|e| e.into_inner()),
    ))
}

/// Clear the span buffer without touching the enabled flag.
pub fn reset() {
    sink().lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Measured per-call cost of a disabled instrumentation site, in
/// nanoseconds.  Self-test hook for the "near-zero cost when disabled"
/// contract; callers must ensure tracing is disabled first.
pub fn disabled_overhead_ns(iters: u32) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters.max(1) {
        let g = span("trace", "overhead-probe");
        std::hint::black_box(&g);
    }
    t0.elapsed().as_nanos() as f64 / f64::from(iters.max(1))
}

/// Serializes tests that flip the global enabled flag or read the
/// global sink/registry (shared across `cargo test` threads).  Restores
/// a clean disabled state on drop.
pub struct TestSession {
    _guard: MutexGuard<'static, ()>,
}

impl TestSession {
    pub fn begin() -> TestSession {
        static LOCK: Mutex<()> = Mutex::new(());
        let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        reset();
        super::metrics::reset();
        TestSession { _guard: guard }
    }
}

impl Drop for TestSession {
    fn drop(&mut self) {
        set_enabled(false);
        reset();
        super::metrics::reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        let _s = TestSession::begin();
        {
            let _g = span("t", "nothing");
            let _h = span_fmt("t", || unreachable!("closure must not run when disabled"));
        }
        record_span_at("t", "also-nothing", Instant::now(), Instant::now());
        assert!(snapshot().is_empty());
    }

    #[test]
    fn nesting_depth_and_order() {
        let _s = TestSession::begin();
        set_enabled(true);
        {
            let _a = span("t", "outer");
            {
                let _b = span_fmt("t", || "inner".to_string());
            }
            let _c = span("t", "sibling");
        }
        set_enabled(false);
        let ev = drain();
        let names: Vec<&str> = ev.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names, ["outer", "inner", "sibling"]);
        assert_eq!(ev[0].depth, 0);
        assert_eq!(ev[1].depth, 1);
        assert_eq!(ev[2].depth, 1);
        // Nesting by time containment (what Perfetto renders).
        assert!(ev[0].start_us <= ev[1].start_us);
        assert!(ev[1].start_us + ev[1].dur_us <= ev[0].start_us + ev[0].dur_us + 1e-3);
    }

    #[test]
    fn cross_thread_spans_get_own_lanes() {
        let _s = TestSession::begin();
        set_enabled(true);
        let _main = span("t", "main-side");
        std::thread::Builder::new()
            .name("span-worker".into())
            .spawn(|| {
                let _g = span("t", "worker-side");
            })
            .unwrap()
            .join()
            .unwrap();
        drop(_main);
        set_enabled(false);
        let ev = drain();
        let worker = ev.iter().find(|e| e.name == "worker-side").unwrap();
        let main = ev.iter().find(|e| e.name == "main-side").unwrap();
        assert_ne!(worker.tid, main.tid);
        assert_eq!(worker.thread, "span-worker");
        assert_eq!(worker.depth, 0, "depth is per-thread, not inherited");
    }
}
