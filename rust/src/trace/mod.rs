//! Structured tracing + metrics: the runtime measurement substrate.
//!
//! Zero-dependency (std-only) observability for the whole stack, in
//! four pieces:
//!
//! - [`span`]: a span tracer with thread-local stacks and a
//!   mutex-buffered global sink.  Disabled cost is one relaxed atomic
//!   load per site (self-tested); enabled spans carry deterministic
//!   per-thread `(tid, seq, depth)` so span *trees* — not just
//!   durations — are reproducible across runs.
//! - [`metrics`]: a counter/gauge/histogram registry the trainer and
//!   server sample at stage boundaries (Eq. 21 cache bytes, optimizer
//!   state bytes, packed param bytes, queue depth, batch sizes),
//!   cross-checked against `fpga::resources::ResourceReport` in tests.
//! - [`chrome`]: Chrome trace-event JSON export (Perfetto-loadable,
//!   per-thread lanes) behind `--trace <path>` on `train` /
//!   `serve-bench`.
//! - [`prom`] + [`report`]: a Prometheus text snapshot for the serving
//!   counters and the FP/BP/PU aggregation behind the `trace-report`
//!   CLI command.
//!
//! Span taxonomy (category → names):
//!
//! - `train`: `fp.embed` / `fp.layer{i}` / `fp.heads`, `bp.*` and
//!   `pu.*` over the same units plus `bp.pool`/`pu.pool` — the paper's
//!   three stages, per layer.  Never nested within the same stage
//!   prefix, so prefix sums are double-count-free.
//! - `ttlinear`: `merge_left` / `merge_right` / `apply` — the BTT
//!   contraction steps (Z3, Z1→Z2, Y) inside each projection.
//! - `pool`: `job` — one span per worker-pool job execution, on the
//!   `tt-matmul-{i}` threads.
//! - `engine`: `forward` — one shared-engine `(B, S)` forward.
//! - `serve`: `admit` → `queue` → `batch_execute` → `respond` — the
//!   life of a request through the continuous-batching scheduler.
//! - `step`: `train_step` — the whole backend step, for totals.

pub mod chrome;
pub mod metrics;
pub mod prom;
pub mod report;
pub mod span;

pub use chrome::to_chrome_json;
pub use metrics::{
    counter, counter_add, counters, gauge, gauge_set, gauges, hist, hist_observe,
};
pub use report::{stage_breakdown, StageRow, STAGES};
pub use span::{
    disabled_overhead_ns, drain, enabled, record_span_at, reset, set_enabled, snapshot, span,
    span_fmt, SpanEvent, SpanGuard, TestSession,
};
