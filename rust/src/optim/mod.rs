//! Pluggable tensor-compressed optimizers — the paper's **PU stage** as
//! a subsystem.
//!
//! The paper's parameter-update stage keeps *all* optimizer information
//! on chip in the same compressed TT-core / TTM-core layout as the
//! parameters themselves; that is what makes its <6 MB BRAM + 22.5 MB
//! URAM budget possible (related: Zhang et al., arXiv:2104.03420, which
//! trains the same tensorized models with momentum/Adam-style low-
//! precision updates on FPGA).  This module provides:
//!
//! * [`Optimizer`] — the per-parameter update rule
//!   (`step(param, grad, hyper)`), with [`Sgd`], [`Momentum`], [`Adam`]
//!   and [`AdamW`] implementations.  Each instance owns the state of
//!   **one** parameter tensor, so state buffers have exactly the shape
//!   of the core they update — optimizer state lives in compressed
//!   space by construction (1x the parameter count for momentum, 2x for
//!   Adam/AdamW, 0x for plain SGD).
//! * [`ModelOptim`] — a name-keyed bundle of per-parameter optimizers
//!   covering a whole model (names follow the checkpoint/manifest
//!   parameter naming scheme), used by the native trainer's PU stage.
//! * [`StateFootprint`] — the analytic optimizer-state memory report
//!   that feeds [`crate::costmodel`] and [`crate::fpga`] so state is
//!   counted against the U50 on-chip budget exactly like the cores and
//!   the Eq. 21 caches.
//! * [`OptimConfig`] — the `{kind, batch_size, betas, weight_decay, …}`
//!   knob set threaded from the CLI / manifest down to the PU stage.
//! * [`mean_accumulate`] — the *reference* order-preserving reduction
//!   for averaging per-example gradients.  The production mini-batch
//!   path realizes the same semantics inside its widened-K matmuls
//!   (ascending example order + loss-level `1/B`); tests pin that
//!   contract against this helper.

use crate::config::{ModelConfig, TrainConfig};
use crate::tensor::{PackedVec, Precision};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Per-step hyper-parameters handed to every [`Optimizer::step`] call.
///
/// Carrying them per step (rather than baking them into the optimizer)
/// keeps learning-rate schedules and CLI overrides trivial: the state
/// buffers never have to be rebuilt when a knob changes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    pub lr: f32,
    /// Heavy-ball coefficient (Momentum only).
    pub momentum: f32,
    /// First-moment decay (Adam/AdamW).
    pub beta1: f32,
    /// Second-moment decay (Adam/AdamW).
    pub beta2: f32,
    /// Adam denominator fuzz.
    pub eps: f32,
    /// L2 penalty (coupled for Sgd/Momentum/Adam, decoupled for AdamW).
    pub weight_decay: f32,
}

/// Which update rule the PU stage runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Momentum,
    Adam,
    AdamW,
}

impl OptimKind {
    pub fn all() -> [OptimKind; 4] {
        [OptimKind::Sgd, OptimKind::Momentum, OptimKind::Adam, OptimKind::AdamW]
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::Sgd => "sgd",
            OptimKind::Momentum => "momentum",
            OptimKind::Adam => "adam",
            OptimKind::AdamW => "adamw",
        }
    }

    /// Parse a CLI / manifest spelling.
    pub fn parse(s: &str) -> Result<OptimKind> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Ok(OptimKind::Sgd),
            "momentum" | "sgdm" => Ok(OptimKind::Momentum),
            "adam" => Ok(OptimKind::Adam),
            "adamw" => Ok(OptimKind::AdamW),
            other => Err(anyhow!("unknown optimizer '{other}' (sgd|momentum|adam|adamw)")),
        }
    }

    /// Optimizer-state elements per parameter element (the paper's
    /// on-chip accounting: 0x for SGD, 1x for momentum, 2x for Adam).
    pub fn state_multiplier(&self) -> usize {
        match self {
            OptimKind::Sgd => 0,
            OptimKind::Momentum => 1,
            OptimKind::Adam | OptimKind::AdamW => 2,
        }
    }

    /// Default learning rate per rule.  SGD/momentum use the paper's
    /// Sec. VI-A setting ([`TrainConfig::default`], the single source of
    /// truth); the Adam family defaults to the conventional 1e-3.
    pub fn default_lr(&self) -> f32 {
        match self {
            OptimKind::Sgd | OptimKind::Momentum => TrainConfig::default().lr,
            OptimKind::Adam | OptimKind::AdamW => 1e-3,
        }
    }

    /// Fresh per-parameter state for this rule (f32 state).
    pub fn build(&self) -> Box<dyn Optimizer> {
        self.build_prec(Precision::F32)
    }

    /// Fresh per-parameter state with moments stored at `prec` —
    /// halving the on-chip state footprint for the 16-bit formats
    /// (updates still accumulate in f32; see [`PackedVec`]).
    pub fn build_prec(&self, prec: Precision) -> Box<dyn Optimizer> {
        match self {
            OptimKind::Sgd => Box::new(Sgd),
            OptimKind::Momentum => Box::new(Momentum::new(prec)),
            OptimKind::Adam => Box::new(Adam::new(prec)),
            OptimKind::AdamW => Box::new(AdamW::new(prec)),
        }
    }

    /// Stable numeric code for checkpoint metadata
    /// (`optim.kind` entry; see `crate::train::NativeTrainer`).
    pub fn code(&self) -> u32 {
        match self {
            OptimKind::Sgd => 0,
            OptimKind::Momentum => 1,
            OptimKind::Adam => 2,
            OptimKind::AdamW => 3,
        }
    }

    pub fn from_code(code: u32) -> Option<OptimKind> {
        OptimKind::all().into_iter().find(|k| k.code() == code)
    }
}

/// Full optimizer configuration threaded from the CLI / manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct OptimConfig {
    pub kind: OptimKind,
    /// Requested mini-batch size B (the contraction K dimension becomes
    /// `B * S`).  This is configuration plumbing only: the runtime batch
    /// is owned by the coordinator — pass this value to
    /// `Trainer::with_batch` (as the CLI/bench/example call sites do);
    /// nothing reads it implicitly.
    pub batch_size: usize,
    pub momentum: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Storage precision of the PU stage: optimizer moments are kept
    /// packed at this width (except the Adam-family second moment,
    /// which stores at bf16 under an f16 path — see
    /// `moment2_precision` — and in sqrt domain under block-scaled
    /// int8 — see `moment2_sqrt_domain`) and every updated parameter
    /// is rounded on store (round-to-nearest-even per scalar for the
    /// half formats, blockwise requantization for int8), so the cores
    /// a sub-f32 model trains are always exactly representable at this
    /// width.  Updates themselves accumulate in f32.
    pub precision: Precision,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig {
            kind: OptimKind::Sgd,
            batch_size: TrainConfig::default().batch_size,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            precision: Precision::F32,
        }
    }
}

impl OptimConfig {
    /// The per-step [`Hyper`] at a given learning rate.
    pub fn hyper(&self, lr: f32) -> Hyper {
        Hyper {
            lr,
            momentum: self.momentum,
            beta1: self.beta1,
            beta2: self.beta2,
            eps: self.eps,
            weight_decay: self.weight_decay,
        }
    }
}

/// One parameter tensor's update rule + state (the PU stage for one
/// core).  `param` and `grad` must have the same length on every call,
/// and state buffers are sized lazily on the first step.
///
/// `Send + Sync` is a supertrait so that models holding boxed
/// optimizers can be shared immutably across replica threads
/// ([`crate::replica`]); every built-in rule is plain owned data, so
/// the bound is free.
pub trait Optimizer: Send + Sync {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper);

    /// State elements currently held (0 until the first step for
    /// stateful rules).
    fn state_elems(&self) -> u64;

    fn name(&self) -> &'static str;

    /// State bytes at rest (half-width rules report half the f32
    /// figure).  Default: f32 storage.
    fn state_bytes(&self) -> u64 {
        4 * self.state_elems()
    }

    /// Serialize the state as named f32 slots (widened — exact for the
    /// half formats) for optimizer-state checkpointing.  Stateless
    /// rules export nothing.
    fn export_state(&self) -> Vec<(&'static str, Vec<f32>)> {
        Vec::new()
    }

    /// Restore one named slot written by [`Optimizer::export_state`].
    fn import_state(&mut self, slot: &str, _values: &[f32]) -> Result<()> {
        Err(anyhow!("optimizer '{}' has no state slot '{slot}'", self.name()))
    }

    /// Re-pack already-allocated state at a new storage precision
    /// (rounding when narrowing, exact when widening).  No-op for
    /// stateless rules.
    fn set_state_precision(&mut self, _prec: Precision) {}
}

/// Plain SGD: `p -= lr * (g + wd * p)` — stateless, the seed trainer's
/// fused update.  With `weight_decay == 0` the arithmetic is bitwise
/// identical to the historical `sgd_vec` / `sgd_update` path.
#[derive(Debug, Default, Clone)]
pub struct Sgd;

impl Optimizer for Sgd {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        let (lr, wd) = (hyper.lr, hyper.weight_decay);
        if wd == 0.0 {
            for (p, &g) in param.iter_mut().zip(grad) {
                *p -= lr * g;
            }
        } else {
            for (p, &g) in param.iter_mut().zip(grad) {
                *p -= lr * (g + wd * *p);
            }
        }
    }

    fn state_elems(&self) -> u64 {
        0
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Heavy-ball momentum: `v = mu*v + (g + wd*p); p -= lr * v` —
/// 1x parameter-count state in the compressed layout (stored at the
/// configured [`Precision`]; the update accumulates in f32).
#[derive(Debug, Clone)]
pub struct Momentum {
    prec: Precision,
    v: PackedVec,
}

impl Default for Momentum {
    fn default() -> Self {
        Momentum::new(Precision::F32)
    }
}

impl Momentum {
    pub fn new(prec: Precision) -> Momentum {
        Momentum { prec, v: PackedVec::empty(prec) }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        if self.v.is_empty() {
            self.v = PackedVec::zeros(self.prec, param.len());
        }
        // A mis-restored state buffer must fail loudly: zip would
        // otherwise silently stop updating the tail parameters.
        assert_eq!(self.v.len(), param.len(), "momentum state length mismatch");
        let (lr, mu, wd) = (hyper.lr, hyper.momentum, hyper.weight_decay);
        self.v.update_in_place(|v| {
            for ((p, &g), v) in param.iter_mut().zip(grad).zip(v.iter_mut()) {
                let g = g + wd * *p;
                *v = mu * *v + g;
                *p -= lr * *v;
            }
        });
    }

    fn state_elems(&self) -> u64 {
        self.v.len() as u64
    }

    fn state_bytes(&self) -> u64 {
        self.v.bytes()
    }

    fn name(&self) -> &'static str {
        "momentum"
    }

    fn export_state(&self) -> Vec<(&'static str, Vec<f32>)> {
        if self.v.is_empty() {
            return Vec::new();
        }
        vec![("v", self.v.to_f32())]
    }

    fn import_state(&mut self, slot: &str, values: &[f32]) -> Result<()> {
        match slot {
            "v" => {
                self.v = PackedVec::from_f32(self.prec, values);
                Ok(())
            }
            other => Err(anyhow!("momentum: unknown state slot '{other}'")),
        }
    }

    fn set_state_precision(&mut self, prec: Precision) {
        self.prec = prec;
        self.v = PackedVec::from_f32(prec, &self.v.to_f32());
    }
}

/// Shared moment-slot plumbing of the Adam family: two [`PackedVec`]
/// moments and the step counter, with export/import for exact training
/// resume.
macro_rules! adam_family_state {
    ($name:literal) => {
        fn state_elems(&self) -> u64 {
            (self.m.len() + self.v.len()) as u64
        }

        fn state_bytes(&self) -> u64 {
            self.m.bytes() + self.v.bytes()
        }

        fn name(&self) -> &'static str {
            $name
        }

        fn export_state(&self) -> Vec<(&'static str, Vec<f32>)> {
            if self.m.is_empty() {
                return Vec::new();
            }
            // The exported "v" slot always holds the *true* second
            // moment, whatever the storage domain — checkpoints stay
            // meaningful across precision changes, and the sqrt-domain
            // round trip is still bitwise (sqrt(fl(u^2)) == u).
            let mut v = self.v.to_f32();
            moment2_to_true(self.prec, &mut v);
            vec![
                ("m", self.m.to_f32()),
                ("v", v),
                // f32 represents the step count exactly up to 2^24.
                ("t", vec![self.t as f32]),
            ]
        }

        fn import_state(&mut self, slot: &str, values: &[f32]) -> Result<()> {
            match slot {
                "m" => self.m = PackedVec::from_f32(self.prec, values),
                "v" => {
                    let mut v = values.to_vec();
                    moment2_from_true(self.prec, &mut v);
                    self.v = PackedVec::from_f32(moment2_precision(self.prec), &v);
                }
                "t" => {
                    self.t = *values
                        .first()
                        .ok_or_else(|| anyhow!(concat!($name, ": empty 't' slot")))?
                        as u32
                }
                other => {
                    return Err(anyhow!(concat!($name, ": unknown state slot '{}'"), other))
                }
            }
            Ok(())
        }

        fn set_state_precision(&mut self, prec: Precision) {
            let mut v = self.v.to_f32();
            moment2_to_true(self.prec, &mut v);
            moment2_from_true(prec, &mut v);
            self.prec = prec;
            self.m = PackedVec::from_f32(prec, &self.m.to_f32());
            self.v = PackedVec::from_f32(moment2_precision(prec), &v);
        }
    };
}

/// Storage precision of the Adam-family **second** moment for a
/// configured precision: f16's narrow exponent flushes the tiny
/// squared-gradient increments `(1 - beta2) g^2` to zero below the
/// 2^-24 subnormal floor (any |g| < ~2.5e-4), leaving `v = 0` while
/// `m` stays finite — the update `m_hat / (sqrt(0) + eps)` then blows
/// up by ~1/eps.  bf16 has f32's exponent range at the same 16-bit
/// width, so the range-critical moment stores at bf16 under an f16
/// path; the byte accounting is unchanged.  Int8 keeps int8 storage
/// but switches the *domain* — see [`moment2_sqrt_domain`].
fn moment2_precision(prec: Precision) -> Precision {
    match prec {
        Precision::F16 => Precision::Bf16,
        p => p,
    }
}

/// Whether the Adam-family second moment stores `sqrt(v)` instead of
/// `v`.  Block-scaled int8 shares one scale across 64 elements, so a
/// squared moment whose block-mate is 254x larger quantizes to zero —
/// and a zero denominator under a *surviving* first moment is the
/// 1/eps explosion all over again.  Storing `u = sqrt(v)` makes the
/// flush thresholds of `m` and of the denominator coincide (both are
/// ~|g|-proportional): whenever the stored denominator dies, the
/// stored numerator died with it and the update is exactly 0 instead
/// of explosive.  The half/f32 formats keep linear-domain storage
/// bitwise unchanged.
fn moment2_sqrt_domain(prec: Precision) -> bool {
    matches!(prec, Precision::Int8)
}

/// Widen a stored second-moment buffer to true `v` values (squares
/// the sqrt-domain int8 representation; identity otherwise).
fn moment2_to_true(prec: Precision, vals: &mut [f32]) {
    if moment2_sqrt_domain(prec) {
        for x in vals.iter_mut() {
            *x *= *x;
        }
    }
}

/// Convert true `v` values to the stored domain for `prec` (square
/// root for int8; identity otherwise).  `sqrt(fl(u^2)) == u` in
/// round-to-nearest, so export -> import round trips bitwise.
fn moment2_from_true(prec: Precision, vals: &mut [f32]) {
    if moment2_sqrt_domain(prec) {
        for x in vals.iter_mut() {
            *x = x.sqrt();
        }
    }
}

/// Adam (Kingma & Ba) with coupled L2: 2x parameter-count state
/// (first + second moment) in the compressed layout, stored at the
/// configured [`Precision`] with f32-accumulated updates.
#[derive(Debug, Clone)]
pub struct Adam {
    prec: Precision,
    m: PackedVec,
    v: PackedVec,
    t: u32,
}

impl Default for Adam {
    fn default() -> Self {
        Adam::new(Precision::F32)
    }
}

impl Adam {
    pub fn new(prec: Precision) -> Adam {
        Adam { prec, m: PackedVec::empty(prec), v: PackedVec::empty(moment2_precision(prec)), t: 0 }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        if self.m.is_empty() {
            self.m = PackedVec::zeros(self.prec, param.len());
            self.v = PackedVec::zeros(moment2_precision(self.prec), param.len());
        }
        // A mis-restored state buffer must fail loudly and clearly.
        assert_eq!(self.m.len(), param.len(), "moment state length mismatch");
        assert_eq!(self.v.len(), param.len(), "moment state length mismatch");
        self.t += 1;
        let (b1, b2) = (hyper.beta1, hyper.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let sqrt_dom = moment2_sqrt_domain(self.prec);
        let v_sv = &mut self.v;
        self.m.update_in_place(|m| {
            v_sv.update_in_place(|v| {
                for (i, (p, &g)) in param.iter_mut().zip(grad).enumerate() {
                    let g = g + hyper.weight_decay * *p;
                    m[i] = b1 * m[i] + (1.0 - b1) * g;
                    let vt = if sqrt_dom {
                        let vt = b2 * (v[i] * v[i]) + (1.0 - b2) * g * g;
                        v[i] = vt.sqrt();
                        vt
                    } else {
                        v[i] = b2 * v[i] + (1.0 - b2) * g * g;
                        v[i]
                    };
                    let mhat = m[i] / bc1;
                    let vhat = vt / bc2;
                    *p -= hyper.lr * mhat / (vhat.sqrt() + hyper.eps);
                }
            });
        });
    }

    adam_family_state!("adam");
}

/// AdamW (Loshchilov & Hutter): Adam moments with *decoupled* weight
/// decay applied directly to the parameter.
#[derive(Debug, Clone)]
pub struct AdamW {
    prec: Precision,
    m: PackedVec,
    v: PackedVec,
    t: u32,
}

impl Default for AdamW {
    fn default() -> Self {
        AdamW::new(Precision::F32)
    }
}

impl AdamW {
    pub fn new(prec: Precision) -> AdamW {
        AdamW { prec, m: PackedVec::empty(prec), v: PackedVec::empty(moment2_precision(prec)), t: 0 }
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        if self.m.is_empty() {
            self.m = PackedVec::zeros(self.prec, param.len());
            self.v = PackedVec::zeros(moment2_precision(self.prec), param.len());
        }
        // A mis-restored state buffer must fail loudly and clearly.
        assert_eq!(self.m.len(), param.len(), "moment state length mismatch");
        assert_eq!(self.v.len(), param.len(), "moment state length mismatch");
        self.t += 1;
        let (b1, b2) = (hyper.beta1, hyper.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let sqrt_dom = moment2_sqrt_domain(self.prec);
        let v_sv = &mut self.v;
        self.m.update_in_place(|m| {
            v_sv.update_in_place(|v| {
                for (i, (p, &g)) in param.iter_mut().zip(grad).enumerate() {
                    *p -= hyper.lr * hyper.weight_decay * *p;
                    m[i] = b1 * m[i] + (1.0 - b1) * g;
                    let vt = if sqrt_dom {
                        let vt = b2 * (v[i] * v[i]) + (1.0 - b2) * g * g;
                        v[i] = vt.sqrt();
                        vt
                    } else {
                        v[i] = b2 * v[i] + (1.0 - b2) * g * g;
                        v[i]
                    };
                    let mhat = m[i] / bc1;
                    let vhat = vt / bc2;
                    *p -= hyper.lr * mhat / (vhat.sqrt() + hyper.eps);
                }
            });
        });
    }

    adam_family_state!("adamw");
}

/// Name-keyed optimizer bundle for a whole model's PU stage.
///
/// Each parameter tensor (keyed by its checkpoint/manifest name, e.g.
/// `layers.0.wq.cores.3`) gets its own [`Optimizer`] instance, created
/// on the first step that touches it — state buffers therefore have
/// exactly the compressed shapes of the cores they track.
pub struct ModelOptim {
    pub cfg: OptimConfig,
    slots: BTreeMap<String, Box<dyn Optimizer>>,
}

impl ModelOptim {
    pub fn new(cfg: OptimConfig) -> ModelOptim {
        ModelOptim { cfg, slots: BTreeMap::new() }
    }

    /// The per-step hypers at learning rate `lr`.
    pub fn hyper(&self, lr: f32) -> Hyper {
        self.cfg.hyper(lr)
    }

    /// Apply one update to the named parameter tensor.  Under a
    /// half-precision storage path the updated parameter is rounded on
    /// store, so the cores at rest are always exactly representable at
    /// the configured width (the update itself accumulated in f32).
    pub fn step(&mut self, name: &str, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len(), "grad shape mismatch for '{name}'");
        let (kind, prec) = (self.cfg.kind, self.cfg.precision);
        let slot = self
            .slots
            .entry(name.to_string())
            .or_insert_with(|| kind.build_prec(prec));
        slot.step(param, grad, hyper);
        prec.round_slice_in_place(param);
    }

    /// Switch the PU stage's storage precision: future slots build at
    /// `prec`, and **already-allocated** moment buffers are re-packed
    /// (rounding when narrowing, exact when widening) so the
    /// moments-at-this-width contract holds mid-lifecycle too.
    pub fn set_precision(&mut self, prec: Precision) {
        self.cfg.precision = prec;
        for slot in self.slots.values_mut() {
            slot.set_state_precision(prec);
        }
    }

    /// Optimizer-state elements currently allocated across all slots.
    pub fn allocated_state_elems(&self) -> u64 {
        self.slots.values().map(|s| s.state_elems()).sum()
    }

    /// Optimizer-state bytes at rest across all slots (half the f32
    /// figure under the 16-bit storage path).
    pub fn allocated_state_bytes(&self) -> u64 {
        self.slots.values().map(|s| s.state_bytes()).sum()
    }

    /// Serialize every slot's state as `<param-name>.<slot>` entries
    /// (widened to f32 — exact for the half formats), in deterministic
    /// name order, for optimizer-state checkpointing.
    pub fn export_state(&self) -> Vec<(String, Vec<f32>)> {
        let mut out = Vec::new();
        for (name, slot) in &self.slots {
            for (tag, vals) in slot.export_state() {
                out.push((format!("{name}.{tag}"), vals));
            }
        }
        out
    }

    /// Restore state written by [`ModelOptim::export_state`].  Entries
    /// are name-verified: an unknown slot tag is a hard error, and each
    /// `<param-name>` keys the same per-core slot the PU stage uses.
    pub fn import_state(&mut self, entries: &[(String, Vec<f32>)]) -> Result<()> {
        let (kind, prec) = (self.cfg.kind, self.cfg.precision);
        for (key, vals) in entries {
            let (param, slot) = key
                .rsplit_once('.')
                .ok_or_else(|| anyhow!("malformed optimizer-state key '{key}'"))?;
            self.slots
                .entry(param.to_string())
                .or_insert_with(|| kind.build_prec(prec))
                .import_state(slot, vals)?;
        }
        Ok(())
    }
}

impl std::fmt::Debug for ModelOptim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelOptim")
            .field("cfg", &self.cfg)
            .field("slots", &self.slots.len())
            .field("state_elems", &self.allocated_state_elems())
            .finish()
    }
}

/// Analytic optimizer-state memory report for one model configuration —
/// the row the cost model and the FPGA resource simulator charge against
/// the U50 budget alongside cores and Eq. 21 caches.
///
/// **Data parallelism does not multiply this.**  Under
/// [`crate::replica::ReplicaGroup`] the optimizer state lives exactly
/// once — on the lead model that applies the reduced gradients;
/// followers never step and never allocate moment slots.  A replicated
/// deployment therefore charges one `StateFootprint` total, not one
/// per device (see `crate::fpga::resources::ReplicaBudget`).
#[derive(Debug, Clone, Copy)]
pub struct StateFootprint {
    pub kind: OptimKind,
    /// Trainable parameter elements in compressed (tensor) space.
    pub param_elems: u64,
    /// Optimizer-state elements (multiplier x `param_elems`).
    pub state_elems: u64,
    /// Storage precision of the moments — the element count is
    /// precision-independent, the bytes are not.
    pub precision: Precision,
}

impl StateFootprint {
    /// Footprint of a whole model at fp32: state mirrors every trainable
    /// scalar ([`ModelConfig::tensor_params`]) times the rule's
    /// multiplier.
    pub fn for_model(cfg: &ModelConfig, kind: OptimKind) -> StateFootprint {
        StateFootprint::for_model_prec(cfg, kind, Precision::F32)
    }

    /// [`StateFootprint::for_model`] with moments stored at `precision`
    /// — the 16-bit formats halve the Adam pair the U50 report charges.
    pub fn for_model_prec(
        cfg: &ModelConfig,
        kind: OptimKind,
        precision: Precision,
    ) -> StateFootprint {
        let param_elems = cfg.tensor_params() as u64;
        StateFootprint {
            kind,
            param_elems,
            state_elems: kind.state_multiplier() as u64 * param_elems,
            precision,
        }
    }

    pub fn state_bytes(&self) -> u64 {
        // Charge per moment buffer (multiplier contiguous buffers of
        // `param_elems`), so the int8 per-block scale overhead is
        // counted the way the slots actually allocate it.
        self.kind.state_multiplier() as u64 * self.precision.storage_bytes(self.param_elems)
    }

    pub fn state_mb(&self) -> f64 {
        self.state_bytes() as f64 / 1e6
    }
}

/// Order-preserving deterministic mean of per-example gradients: sums
/// in ascending example order (the same left-to-right accumulation the
/// blocked matmul kernels use), then scales once by `1/B`.  Bitwise
/// reproducible across calls.
///
/// This is the **reference implementation** of the mini-batch reduction
/// contract — the native trainer's widened-K backward realizes the same
/// semantics inside its matmuls (see `crate::train::model`), so
/// production code does not call this directly; tests pin the contract
/// against it, and it is the building block for explicit
/// gradient-accumulation schedules (e.g. micro-batching) that cannot
/// widen K.
pub fn mean_accumulate(per_example: &[Vec<f32>]) -> Vec<f32> {
    let b = per_example.len();
    if b == 0 {
        return Vec::new();
    }
    let mut acc = per_example[0].clone();
    for g in &per_example[1..] {
        debug_assert_eq!(g.len(), acc.len());
        for (a, &v) in acc.iter_mut().zip(g) {
            *a += v;
        }
    }
    let inv = 1.0 / b as f32;
    for a in acc.iter_mut() {
        *a *= inv;
    }
    acc
}

/// Default dynamic loss scale (2^16 — the conventional AMP start).
pub const LOSS_SCALE_INIT: f32 = 65536.0;
/// Loss-scale floor: never scale below 1 (identity).
pub const LOSS_SCALE_MIN: f32 = 1.0;
/// Loss-scale ceiling: 2^24, beyond which growth stops.
pub const LOSS_SCALE_MAX: f32 = 16777216.0;
/// Consecutive finite steps before the scale doubles.
pub const LOSS_SCALE_GROWTH_INTERVAL: u32 = 2000;

/// Dynamic loss scaler — the overflow guard of the PU stage under
/// sub-f32 storage (and the half-precision bug fix: an f16 run
/// previously had *no* non-finite guard at all, so one inf gradient
/// silently poisoned the Adam moments and every packed store after
/// them).
///
/// The scale is **always a power of two** (power-of-two init, x2
/// growth, x0.5 backoff, power-of-two clamps), so multiplying the loss
/// and dividing the gradients back is bitwise the identity whenever
/// everything stays finite — which is also why this codebase, whose
/// gradients accumulate in f32 end to end, does not need to execute
/// the multiply/divide pair at all: f32 accumulation cannot underflow
/// at the magnitudes half-storage training produces, so the scale's
/// numeric effect is vacuous and applying it would only burn cycles.
/// What the scaler *does* drive is the guard protocol the trainer
/// runs every step:
///
/// 1. scan the raw f32 gradients (and the loss) for non-finite values;
/// 2. if any: **skip the step entirely** (parameters and moments
///    untouched), call [`LossScaler::on_overflow`] — scale halves,
///    the good-step run resets;
/// 3. otherwise apply the update and call
///    [`LossScaler::on_good_step`] — after
///    [`LOSS_SCALE_GROWTH_INTERVAL`] consecutive good steps the scale
///    doubles (clamped to [`LOSS_SCALE_MAX`]).
///
/// The `{scale, good_steps}` pair is checkpointed with the optimizer
/// state (`optim.loss_scale`) so a resumed run continues the same
/// schedule bitwise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LossScaler {
    scale: f32,
    good_steps: u32,
    growth_interval: u32,
    /// Steps skipped due to non-finite gradients (session diagnostic,
    /// not checkpointed).
    overflow_steps: u64,
}

impl Default for LossScaler {
    fn default() -> Self {
        LossScaler::new()
    }
}

impl LossScaler {
    pub fn new() -> LossScaler {
        LossScaler::with_scale(LOSS_SCALE_INIT, LOSS_SCALE_GROWTH_INTERVAL)
    }

    /// Custom start scale / growth interval (tests, CLI overrides).
    /// The scale is clamped into [`LOSS_SCALE_MIN`]..[`LOSS_SCALE_MAX`];
    /// a zero growth interval is treated as 1.
    pub fn with_scale(scale: f32, growth_interval: u32) -> LossScaler {
        LossScaler {
            scale: scale.clamp(LOSS_SCALE_MIN, LOSS_SCALE_MAX),
            good_steps: 0,
            growth_interval: growth_interval.max(1),
            overflow_steps: 0,
        }
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    pub fn good_steps(&self) -> u32 {
        self.good_steps
    }

    pub fn overflow_steps(&self) -> u64 {
        self.overflow_steps
    }

    /// Record a step whose loss and gradients were all finite; doubles
    /// the scale after `growth_interval` consecutive good steps.
    pub fn on_good_step(&mut self) {
        self.good_steps += 1;
        if self.good_steps >= self.growth_interval {
            self.scale = (self.scale * 2.0).min(LOSS_SCALE_MAX);
            self.good_steps = 0;
        }
    }

    /// Record a non-finite loss/gradient: halve the scale (floored at
    /// [`LOSS_SCALE_MIN`]) and reset the good-step run.  The caller
    /// must also skip the parameter update for this step.
    pub fn on_overflow(&mut self) {
        self.scale = (self.scale * 0.5).max(LOSS_SCALE_MIN);
        self.good_steps = 0;
        self.overflow_steps += 1;
    }

    /// True when `loss` and every gradient value are finite — the
    /// trainer's per-step overflow probe.
    pub fn step_is_finite<'a, I>(loss: f32, grads: I) -> bool
    where
        I: IntoIterator<Item = &'a f32>,
    {
        loss.is_finite() && grads.into_iter().all(|g| g.is_finite())
    }

    /// Checkpoint payload: `[scale, good_steps]` (both exact in f32 —
    /// the scale is a power of two, the counter stays far below 2^24).
    pub fn export(&self) -> Vec<f32> {
        vec![self.scale, self.good_steps as f32]
    }

    /// Restore a payload written by [`LossScaler::export`]; the growth
    /// interval is configuration, not state, and is kept.
    pub fn import(&mut self, values: &[f32]) -> Result<()> {
        if values.len() != 2 {
            return Err(anyhow!("loss-scale entry: expected 2 values, got {}", values.len()));
        }
        if !(values[0].is_finite() && values[0] > 0.0) {
            return Err(anyhow!("loss-scale entry: bad scale {}", values[0]));
        }
        self.scale = values[0].clamp(LOSS_SCALE_MIN, LOSS_SCALE_MAX);
        self.good_steps = values[1] as u32;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper(lr: f32) -> Hyper {
        OptimConfig { weight_decay: 0.01, ..OptimConfig::default() }.hyper(lr)
    }

    /// Synthetic gradient stream: deterministic, element-dependent.
    fn grad_at(step: usize, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| ((step * 7 + i * 13) % 17) as f32 / 17.0 - 0.45)
            .collect()
    }

    /// Scalar reference implementations, written independently of the
    /// vectorized `Optimizer` impls (same update rules, one scalar at a
    /// time).  The vector paths must match them **bitwise** over 100
    /// steps.
    struct ScalarRef {
        kind: OptimKind,
        v: Vec<f32>,
        m: Vec<f32>,
        t: u32,
    }

    impl ScalarRef {
        fn new(kind: OptimKind, n: usize) -> ScalarRef {
            ScalarRef { kind, v: vec![0.0; n], m: vec![0.0; n], t: 0 }
        }

        fn step(&mut self, p: &mut [f32], g: &[f32], h: &Hyper) {
            self.t += 1;
            for i in 0..p.len() {
                match self.kind {
                    OptimKind::Sgd => {
                        let gi = if h.weight_decay == 0.0 {
                            g[i]
                        } else {
                            g[i] + h.weight_decay * p[i]
                        };
                        p[i] -= h.lr * gi;
                    }
                    OptimKind::Momentum => {
                        let gi = g[i] + h.weight_decay * p[i];
                        self.v[i] = h.momentum * self.v[i] + gi;
                        p[i] -= h.lr * self.v[i];
                    }
                    OptimKind::Adam => {
                        let gi = g[i] + h.weight_decay * p[i];
                        self.m[i] = h.beta1 * self.m[i] + (1.0 - h.beta1) * gi;
                        self.v[i] = h.beta2 * self.v[i] + (1.0 - h.beta2) * gi * gi;
                        let mhat = self.m[i] / (1.0 - h.beta1.powi(self.t as i32));
                        let vhat = self.v[i] / (1.0 - h.beta2.powi(self.t as i32));
                        p[i] -= h.lr * mhat / (vhat.sqrt() + h.eps);
                    }
                    OptimKind::AdamW => {
                        p[i] -= h.lr * h.weight_decay * p[i];
                        self.m[i] = h.beta1 * self.m[i] + (1.0 - h.beta1) * g[i];
                        self.v[i] = h.beta2 * self.v[i] + (1.0 - h.beta2) * g[i] * g[i];
                        let mhat = self.m[i] / (1.0 - h.beta1.powi(self.t as i32));
                        let vhat = self.v[i] / (1.0 - h.beta2.powi(self.t as i32));
                        p[i] -= h.lr * mhat / (vhat.sqrt() + h.eps);
                    }
                }
            }
        }
    }

    #[test]
    fn every_optimizer_matches_scalar_reference_over_100_steps() {
        let n = 9usize;
        let h = hyper(0.05);
        for kind in OptimKind::all() {
            let mut opt = kind.build();
            let mut reference = ScalarRef::new(kind, n);
            let mut p_opt: Vec<f32> = (0..n).map(|i| 0.3 * (i as f32 - 4.0)).collect();
            let mut p_ref = p_opt.clone();
            for step in 0..100 {
                let g = grad_at(step, n);
                opt.step(&mut p_opt, &g, &h);
                reference.step(&mut p_ref, &g, &h);
                assert_eq!(
                    p_opt, p_ref,
                    "{kind:?} diverged from scalar reference at step {step}"
                );
            }
        }
    }

    #[test]
    fn optimizers_minimize_a_quadratic() {
        // L(p) = ||p - target||^2 / 2, gradient p - target: every rule
        // must shrink the loss substantially from a cold start.
        let target: Vec<f32> = vec![1.0, -2.0, 0.5, 3.0];
        for kind in OptimKind::all() {
            let mut opt = kind.build();
            let h = OptimConfig::default().hyper(0.1);
            let mut p = vec![0.0f32; 4];
            let loss = |p: &[f32]| -> f32 {
                p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / 2.0
            };
            let start = loss(&p);
            for _ in 0..200 {
                let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
                opt.step(&mut p, &g, &h);
            }
            let end = loss(&p);
            assert!(end < 0.05 * start, "{:?}: loss {end} vs start {start}", kind);
        }
    }

    #[test]
    fn state_multipliers_match_allocated_state() {
        for kind in OptimKind::all() {
            let mut opt = kind.build();
            let mut p = vec![0.1f32; 12];
            let g = vec![0.01f32; 12];
            assert_eq!(opt.state_elems(), 0, "{:?}: state before first step", kind);
            opt.step(&mut p, &g, &OptimConfig::default().hyper(0.01));
            assert_eq!(
                opt.state_elems(),
                (kind.state_multiplier() * 12) as u64,
                "{:?}: state after first step",
                kind
            );
        }
    }

    #[test]
    fn model_optim_tracks_per_name_state() {
        let mut mo = ModelOptim::new(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        let h = mo.hyper(0.01);
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 5];
        mo.step("a", &mut a, &[0.1; 8], &h);
        mo.step("b", &mut b, &[0.1; 5], &h);
        assert_eq!(mo.allocated_state_elems(), 2 * (8 + 5));
        // Re-stepping an existing name must not allocate new slots.
        mo.step("a", &mut a, &[0.1; 8], &h);
        assert_eq!(mo.allocated_state_elems(), 2 * (8 + 5));
    }

    #[test]
    fn mean_accumulate_is_order_preserving_and_reproducible() {
        // The reduction pins ascending example order and one final 1/B
        // scale: repeated calls are bitwise identical, and the result
        // matches the same left-to-right chain done by hand in f64
        // within one rounding step.
        let gs = vec![
            vec![1.0e7f32, 3.0e-3],
            vec![1.5f32, -3.0e-3],
            vec![-1.0e7f32, 1.0e-4],
        ];
        let m1 = mean_accumulate(&gs);
        let m2 = mean_accumulate(&gs);
        assert_eq!(m1, m2, "reduction must be bitwise reproducible");
        // Hand-rolled identical chain (f32, same order) is bit-for-bit.
        let mut by_hand = [0.0f32; 2];
        for j in 0..2 {
            by_hand[j] = ((gs[0][j] + gs[1][j]) + gs[2][j]) * (1.0 / 3.0);
        }
        assert_eq!(m1, by_hand.to_vec());
        // And feeding the pinned mean to an optimizer is bitwise stable.
        let h = OptimConfig::default().hyper(0.01);
        let mut p1 = vec![0.5f32, -0.5];
        let mut p2 = p1.clone();
        Sgd.step(&mut p1, &m1, &h);
        Sgd.step(&mut p2, &m2, &h);
        assert_eq!(p1, p2);
    }

    #[test]
    fn footprint_multiplies_tensor_params() {
        let cfg = ModelConfig::paper(2);
        for kind in OptimKind::all() {
            let fp = StateFootprint::for_model(&cfg, kind);
            assert_eq!(fp.param_elems, cfg.tensor_params() as u64);
            assert_eq!(fp.state_elems, fp.param_elems * kind.state_multiplier() as u64);
        }
        let adam = StateFootprint::for_model(&cfg, OptimKind::Adam);
        assert_eq!(adam.state_elems, 2 * cfg.tensor_params() as u64);
    }

    #[test]
    fn kind_parsing_roundtrips() {
        for kind in OptimKind::all() {
            assert_eq!(OptimKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(OptimKind::from_code(kind.code()), Some(kind));
        }
        assert!(OptimKind::parse("rmsprop").is_err());
        assert_eq!(OptimKind::from_code(99), None);
    }

    #[test]
    fn half_precision_moments_halve_bytes_and_still_minimize() {
        // The 16-bit moment path keeps the element count and halves the
        // bytes, stores only representable values (round-on-store), and
        // still drives the quadratic to near its minimum.
        let target: Vec<f32> = vec![1.0, -2.0, 0.5, 3.0];
        for prec in [Precision::Bf16, Precision::F16] {
            for kind in [OptimKind::Momentum, OptimKind::Adam, OptimKind::AdamW] {
                let mut opt = kind.build_prec(prec);
                let h = OptimConfig::default().hyper(0.1);
                let mut p = vec![0.0f32; 4];
                let loss = |p: &[f32]| -> f32 {
                    p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / 2.0
                };
                let start = loss(&p);
                for _ in 0..200 {
                    let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
                    opt.step(&mut p, &g, &h);
                }
                assert!(
                    loss(&p) < 0.05 * start,
                    "{kind:?}@{prec:?}: loss {} vs start {start}",
                    loss(&p)
                );
                assert_eq!(opt.state_elems(), (kind.state_multiplier() * 4) as u64);
                assert_eq!(opt.state_bytes(), (kind.state_multiplier() * 4 * 2) as u64);
                // Every stored moment is a fixed point of its slot's
                // storage rounding (the Adam-family second moment 'v'
                // stores at bf16 under an f16 path — range, not
                // mantissa, is what the squared-gradient buffer needs).
                let adam_family = matches!(kind, OptimKind::Adam | OptimKind::AdamW);
                for (tag, vals) in opt.export_state() {
                    if tag == "t" {
                        continue;
                    }
                    let slot_prec = if tag == "v" && adam_family {
                        moment2_precision(prec)
                    } else {
                        prec
                    };
                    for v in vals {
                        assert_eq!(slot_prec.round(v).to_bits(), v.to_bits(), "{tag} not stored");
                    }
                }
            }
        }
    }

    #[test]
    fn f16_second_moment_does_not_underflow_to_explosive_updates() {
        // Gradients of ~1e-4 make the squared-gradient increment
        // (1-b2) g^2 = 1e-11 — far below f16's 2^-24 subnormal floor.
        // Because the second moment stores at bf16 under an f16 path,
        // v accumulates instead of flushing to zero, and the update
        // stays ~lr-sized rather than blowing up by ~1/sqrt(0)+eps.
        for kind in [OptimKind::Adam, OptimKind::AdamW] {
            let mut opt = kind.build_prec(Precision::F16);
            let h = OptimConfig::default().hyper(1e-2);
            let mut p = vec![0.5f32; 4];
            for step in 0..50 {
                let g = vec![1e-4f32; 4];
                opt.step(&mut p, &g, &h);
                for &v in &p {
                    assert!(
                        v.is_finite() && v.abs() < 10.0,
                        "{kind:?}: update exploded to {v} at step {step}"
                    );
                }
            }
        }
    }

    #[test]
    fn export_import_state_resumes_exactly() {
        // Freeze an Adam slot mid-run, restore it into a fresh
        // optimizer, continue both: trajectories must stay bitwise
        // equal (the optimizer-state-checkpointing contract).
        for prec in [Precision::F32, Precision::Bf16] {
            let n = 7usize;
            let h = hyper(0.05);
            let mut a = OptimKind::Adam.build_prec(prec);
            let mut p_a: Vec<f32> = (0..n).map(|i| 0.2 * (i as f32 - 3.0)).collect();
            for step in 0..5 {
                a.step(&mut p_a, &grad_at(step, n), &h);
            }
            let mut b = OptimKind::Adam.build_prec(prec);
            for (tag, vals) in a.export_state() {
                b.import_state(tag, &vals).unwrap();
            }
            let mut p_b = p_a.clone();
            for step in 5..15 {
                let g = grad_at(step, n);
                a.step(&mut p_a, &g, &h);
                b.step(&mut p_b, &g, &h);
                assert_eq!(p_a, p_b, "{prec:?}: resumed Adam diverged at step {step}");
            }
            assert!(b.import_state("bogus", &[0.0]).is_err());
        }
    }

    #[test]
    fn set_precision_repacks_existing_moment_slots() {
        // Switching precision mid-lifecycle must re-pack moments that
        // were already allocated, not only future slots.
        let mut mo = ModelOptim::new(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        let h = mo.hyper(0.01);
        let mut p = vec![0.5f32; 6];
        mo.step("a", &mut p, &[0.1; 6], &h);
        assert_eq!(mo.allocated_state_bytes(), 2 * 6 * 4);
        mo.set_precision(Precision::Bf16);
        assert_eq!(mo.allocated_state_bytes(), 2 * 6 * 2, "existing moments not re-packed");
        // Further steps keep working and round the params on store.
        mo.step("a", &mut p, &[0.1; 6], &h);
        for v in &p {
            assert_eq!(Precision::Bf16.round(*v).to_bits(), v.to_bits());
        }
        // Widening back is exact and restores 4-byte accounting.
        mo.set_precision(Precision::F32);
        assert_eq!(mo.allocated_state_bytes(), 2 * 6 * 4);
    }

    #[test]
    fn bf16_state_footprint_is_half_the_bytes() {
        let cfg = ModelConfig::paper(2);
        let f32_fp = StateFootprint::for_model(&cfg, OptimKind::Adam);
        for prec in [Precision::Bf16, Precision::F16] {
            let half = StateFootprint::for_model_prec(&cfg, OptimKind::Adam, prec);
            assert_eq!(half.state_elems, f32_fp.state_elems);
            assert_eq!(2 * half.state_bytes(), f32_fp.state_bytes());
        }
    }

    #[test]
    fn int8_moments_minimize_and_resume_bitwise() {
        // Block-scaled int8 moments (second moment in sqrt domain)
        // still drive the quadratic down, charge ~1.0625 B/elem, and
        // export/import resumes the trajectory bitwise.
        let target: Vec<f32> = vec![1.0, -2.0, 0.5, 3.0];
        for kind in [OptimKind::Momentum, OptimKind::Adam, OptimKind::AdamW] {
            let mut opt = kind.build_prec(Precision::Int8);
            let h = OptimConfig::default().hyper(0.1);
            let mut p = vec![0.0f32; 4];
            let loss = |p: &[f32]| -> f32 {
                p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / 2.0
            };
            let start = loss(&p);
            for _ in 0..200 {
                let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
                opt.step(&mut p, &g, &h);
            }
            assert!(
                loss(&p) < 0.10 * start,
                "{kind:?}@int8: loss {} vs start {start}",
                loss(&p)
            );
            // 4 elems = 1 block per moment buffer: 4 codes + 4 scale
            // bytes each.
            let per_moment = Precision::Int8.storage_bytes(4);
            assert_eq!(per_moment, 8);
            assert_eq!(opt.state_bytes(), kind.state_multiplier() as u64 * per_moment);
            // Export -> fresh import -> both continue bitwise equal.
            let mut resumed = kind.build_prec(Precision::Int8);
            for (tag, vals) in opt.export_state() {
                resumed.import_state(tag, &vals).unwrap();
            }
            let mut p2 = p.clone();
            for step in 0..10 {
                let g = grad_at(step, 4);
                opt.step(&mut p, &g, &h);
                resumed.step(&mut p2, &g, &h);
                assert_eq!(p, p2, "{kind:?}@int8 diverged after resume at step {step}");
            }
        }
    }

    #[test]
    fn int8_second_moment_block_flush_is_not_explosive() {
        // One huge-gradient element sharing a 64-block with tiny ones:
        // in linear domain the tiny elements' v quantizes to 0 while
        // their m survives (the 1/eps explosion); the sqrt-domain
        // storage keeps both alive or kills both, so updates stay
        // ~lr-bounded.
        for kind in [OptimKind::Adam, OptimKind::AdamW] {
            let mut opt = kind.build_prec(Precision::Int8);
            let h = OptimConfig::default().hyper(1e-2);
            let n = 64usize;
            let mut p = vec![0.5f32; n];
            for step in 0..50 {
                // Element 0 dominates the block by 50x; the rest sit in
                // the dangerous v/vmax in (1/64516, 1/254) band.
                let g: Vec<f32> =
                    (0..n).map(|i| if i == 0 { 5.0 } else { 0.1 }).collect();
                opt.step(&mut p, &g, &h);
                for (i, &v) in p.iter().enumerate() {
                    assert!(
                        v.is_finite() && v.abs() < 10.0,
                        "{kind:?}@int8: p[{i}] = {v} exploded at step {step}"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_state_footprint_is_quarter_class_bytes() {
        // The analytic footprint charges 1 code byte + 4/64 scale bytes
        // per element: 1.0625/4 = 0.265625x the f32 figure per moment.
        let cfg = ModelConfig::paper(2);
        let f32_fp = StateFootprint::for_model(&cfg, OptimKind::Adam);
        let int8 = StateFootprint::for_model_prec(&cfg, OptimKind::Adam, Precision::Int8);
        assert_eq!(int8.state_elems, f32_fp.state_elems);
        let ratio = int8.state_bytes() as f64 / f32_fp.state_bytes() as f64;
        assert!(
            ratio <= 0.27,
            "int8 optimizer state is {ratio:.4}x f32 (want <= 0.27)"
        );
        assert!(ratio >= 0.25, "int8 state ratio {ratio:.4} below the 1 B/elem floor");
    }

    #[test]
    fn loss_scaler_backs_off_grows_and_roundtrips() {
        let mut s = LossScaler::with_scale(1024.0, 3);
        assert_eq!(s.scale(), 1024.0);
        // Backoff halves and resets the good-step run.
        s.on_good_step();
        s.on_good_step();
        s.on_overflow();
        assert_eq!(s.scale(), 512.0);
        assert_eq!(s.good_steps(), 0);
        assert_eq!(s.overflow_steps(), 1);
        // Growth doubles only after the full interval.
        s.on_good_step();
        s.on_good_step();
        assert_eq!(s.scale(), 512.0);
        s.on_good_step();
        assert_eq!(s.scale(), 1024.0);
        assert_eq!(s.good_steps(), 0);
        // Clamps: floor at 1, ceiling at 2^24.
        let mut floor = LossScaler::with_scale(1.0, 3);
        floor.on_overflow();
        assert_eq!(floor.scale(), LOSS_SCALE_MIN);
        let mut ceil = LossScaler::with_scale(LOSS_SCALE_MAX, 1);
        ceil.on_good_step();
        assert_eq!(ceil.scale(), LOSS_SCALE_MAX);
        // Export/import restores {scale, good_steps} exactly.
        s.on_good_step();
        let payload = s.export();
        let mut restored = LossScaler::with_scale(LOSS_SCALE_INIT, 3);
        restored.import(&payload).unwrap();
        assert_eq!(restored.scale(), s.scale());
        assert_eq!(restored.good_steps(), s.good_steps());
        assert!(restored.import(&[0.0, 0.0]).is_err());
        assert!(restored.import(&[f32::NAN, 0.0]).is_err());
        assert!(restored.import(&[2.0]).is_err());
    }

    #[test]
    fn loss_scaler_finiteness_probe() {
        assert!(LossScaler::step_is_finite(0.5, [0.1f32, -0.2].iter()));
        assert!(!LossScaler::step_is_finite(f32::NAN, [0.1f32].iter()));
        assert!(!LossScaler::step_is_finite(0.5, [0.1f32, f32::INFINITY].iter()));
        assert!(!LossScaler::step_is_finite(0.5, [f32::NEG_INFINITY].iter()));
        assert!(LossScaler::step_is_finite(0.0, core::iter::empty()));
    }

    #[test]
    fn model_optim_rounds_params_on_store_under_half_precision() {
        let mut mo = ModelOptim::new(OptimConfig {
            kind: OptimKind::Adam,
            precision: Precision::Bf16,
            ..Default::default()
        });
        let h = mo.hyper(0.01);
        let mut p = vec![0.123456789f32, -0.987654321, 3.14159265];
        mo.step("probe", &mut p, &[0.1, -0.2, 0.3], &h);
        for v in &p {
            assert_eq!(Precision::Bf16.round(*v).to_bits(), v.to_bits());
        }
        // Bytes at rest: 2 moments x 3 elems x 2 bytes.
        assert_eq!(mo.allocated_state_bytes(), 2 * 3 * 2);
        assert_eq!(mo.allocated_state_elems(), 2 * 3);
    }
}
