//! Pluggable tensor-compressed optimizers — the paper's **PU stage** as
//! a subsystem.
//!
//! The paper's parameter-update stage keeps *all* optimizer information
//! on chip in the same compressed TT-core / TTM-core layout as the
//! parameters themselves; that is what makes its <6 MB BRAM + 22.5 MB
//! URAM budget possible (related: Zhang et al., arXiv:2104.03420, which
//! trains the same tensorized models with momentum/Adam-style low-
//! precision updates on FPGA).  This module provides:
//!
//! * [`Optimizer`] — the per-parameter update rule
//!   (`step(param, grad, hyper)`), with [`Sgd`], [`Momentum`], [`Adam`]
//!   and [`AdamW`] implementations.  Each instance owns the state of
//!   **one** parameter tensor, so state buffers have exactly the shape
//!   of the core they update — optimizer state lives in compressed
//!   space by construction (1x the parameter count for momentum, 2x for
//!   Adam/AdamW, 0x for plain SGD).
//! * [`ModelOptim`] — a name-keyed bundle of per-parameter optimizers
//!   covering a whole model (names follow the checkpoint/manifest
//!   parameter naming scheme), used by the native trainer's PU stage.
//! * [`StateFootprint`] — the analytic optimizer-state memory report
//!   that feeds [`crate::costmodel`] and [`crate::fpga`] so state is
//!   counted against the U50 on-chip budget exactly like the cores and
//!   the Eq. 21 caches.
//! * [`OptimConfig`] — the `{kind, batch_size, betas, weight_decay, …}`
//!   knob set threaded from the CLI / manifest down to the PU stage.
//! * [`mean_accumulate`] — the *reference* order-preserving reduction
//!   for averaging per-example gradients.  The production mini-batch
//!   path realizes the same semantics inside its widened-K matmuls
//!   (ascending example order + loss-level `1/B`); tests pin that
//!   contract against this helper.

use crate::config::{ModelConfig, TrainConfig};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Per-step hyper-parameters handed to every [`Optimizer::step`] call.
///
/// Carrying them per step (rather than baking them into the optimizer)
/// keeps learning-rate schedules and CLI overrides trivial: the state
/// buffers never have to be rebuilt when a knob changes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    pub lr: f32,
    /// Heavy-ball coefficient (Momentum only).
    pub momentum: f32,
    /// First-moment decay (Adam/AdamW).
    pub beta1: f32,
    /// Second-moment decay (Adam/AdamW).
    pub beta2: f32,
    /// Adam denominator fuzz.
    pub eps: f32,
    /// L2 penalty (coupled for Sgd/Momentum/Adam, decoupled for AdamW).
    pub weight_decay: f32,
}

/// Which update rule the PU stage runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Momentum,
    Adam,
    AdamW,
}

impl OptimKind {
    pub fn all() -> [OptimKind; 4] {
        [OptimKind::Sgd, OptimKind::Momentum, OptimKind::Adam, OptimKind::AdamW]
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimKind::Sgd => "sgd",
            OptimKind::Momentum => "momentum",
            OptimKind::Adam => "adam",
            OptimKind::AdamW => "adamw",
        }
    }

    /// Parse a CLI / manifest spelling.
    pub fn parse(s: &str) -> Result<OptimKind> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Ok(OptimKind::Sgd),
            "momentum" | "sgdm" => Ok(OptimKind::Momentum),
            "adam" => Ok(OptimKind::Adam),
            "adamw" => Ok(OptimKind::AdamW),
            other => Err(anyhow!("unknown optimizer '{other}' (sgd|momentum|adam|adamw)")),
        }
    }

    /// Optimizer-state elements per parameter element (the paper's
    /// on-chip accounting: 0x for SGD, 1x for momentum, 2x for Adam).
    pub fn state_multiplier(&self) -> usize {
        match self {
            OptimKind::Sgd => 0,
            OptimKind::Momentum => 1,
            OptimKind::Adam | OptimKind::AdamW => 2,
        }
    }

    /// Default learning rate per rule.  SGD/momentum use the paper's
    /// Sec. VI-A setting ([`TrainConfig::default`], the single source of
    /// truth); the Adam family defaults to the conventional 1e-3.
    pub fn default_lr(&self) -> f32 {
        match self {
            OptimKind::Sgd | OptimKind::Momentum => TrainConfig::default().lr,
            OptimKind::Adam | OptimKind::AdamW => 1e-3,
        }
    }

    /// Fresh per-parameter state for this rule.
    pub fn build(&self) -> Box<dyn Optimizer> {
        match self {
            OptimKind::Sgd => Box::new(Sgd),
            OptimKind::Momentum => Box::new(Momentum::default()),
            OptimKind::Adam => Box::new(Adam::default()),
            OptimKind::AdamW => Box::new(AdamW::default()),
        }
    }
}

/// Full optimizer configuration threaded from the CLI / manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct OptimConfig {
    pub kind: OptimKind,
    /// Requested mini-batch size B (the contraction K dimension becomes
    /// `B * S`).  This is configuration plumbing only: the runtime batch
    /// is owned by the coordinator — pass this value to
    /// `Trainer::with_batch` (as the CLI/bench/example call sites do);
    /// nothing reads it implicitly.
    pub batch_size: usize,
    pub momentum: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for OptimConfig {
    fn default() -> Self {
        OptimConfig {
            kind: OptimKind::Sgd,
            batch_size: TrainConfig::default().batch_size,
            momentum: 0.9,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

impl OptimConfig {
    /// The per-step [`Hyper`] at a given learning rate.
    pub fn hyper(&self, lr: f32) -> Hyper {
        Hyper {
            lr,
            momentum: self.momentum,
            beta1: self.beta1,
            beta2: self.beta2,
            eps: self.eps,
            weight_decay: self.weight_decay,
        }
    }
}

/// One parameter tensor's update rule + state (the PU stage for one
/// core).  `param` and `grad` must have the same length on every call,
/// and state buffers are sized lazily on the first step.
pub trait Optimizer {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper);

    /// State elements currently held (0 until the first step for
    /// stateful rules).
    fn state_elems(&self) -> u64;

    fn name(&self) -> &'static str;
}

/// Plain SGD: `p -= lr * (g + wd * p)` — stateless, the seed trainer's
/// fused update.  With `weight_decay == 0` the arithmetic is bitwise
/// identical to the historical `sgd_vec` / `sgd_update` path.
#[derive(Debug, Default, Clone)]
pub struct Sgd;

impl Optimizer for Sgd {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        let (lr, wd) = (hyper.lr, hyper.weight_decay);
        if wd == 0.0 {
            for (p, &g) in param.iter_mut().zip(grad) {
                *p -= lr * g;
            }
        } else {
            for (p, &g) in param.iter_mut().zip(grad) {
                *p -= lr * (g + wd * *p);
            }
        }
    }

    fn state_elems(&self) -> u64 {
        0
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Heavy-ball momentum: `v = mu*v + (g + wd*p); p -= lr * v` —
/// 1x parameter-count state in the compressed layout.
#[derive(Debug, Default, Clone)]
pub struct Momentum {
    v: Vec<f32>,
}

impl Optimizer for Momentum {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        if self.v.is_empty() {
            self.v = vec![0.0; param.len()];
        }
        let (lr, mu, wd) = (hyper.lr, hyper.momentum, hyper.weight_decay);
        for ((p, &g), v) in param.iter_mut().zip(grad).zip(self.v.iter_mut()) {
            let g = g + wd * *p;
            *v = mu * *v + g;
            *p -= lr * *v;
        }
    }

    fn state_elems(&self) -> u64 {
        self.v.len() as u64
    }

    fn name(&self) -> &'static str {
        "momentum"
    }
}

/// Adam (Kingma & Ba) with coupled L2: 2x parameter-count state
/// (first + second moment) in the compressed layout.
#[derive(Debug, Default, Clone)]
pub struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Optimizer for Adam {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        if self.m.is_empty() {
            self.m = vec![0.0; param.len()];
            self.v = vec![0.0; param.len()];
        }
        self.t += 1;
        let (b1, b2) = (hyper.beta1, hyper.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for (i, (p, &g)) in param.iter_mut().zip(grad).enumerate() {
            let g = g + hyper.weight_decay * *p;
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            *p -= hyper.lr * mhat / (vhat.sqrt() + hyper.eps);
        }
    }

    fn state_elems(&self) -> u64 {
        (self.m.len() + self.v.len()) as u64
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// AdamW (Loshchilov & Hutter): Adam moments with *decoupled* weight
/// decay applied directly to the parameter.
#[derive(Debug, Default, Clone)]
pub struct AdamW {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Optimizer for AdamW {
    fn step(&mut self, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len());
        if self.m.is_empty() {
            self.m = vec![0.0; param.len()];
            self.v = vec![0.0; param.len()];
        }
        self.t += 1;
        let (b1, b2) = (hyper.beta1, hyper.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for (i, (p, &g)) in param.iter_mut().zip(grad).enumerate() {
            *p -= hyper.lr * hyper.weight_decay * *p;
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            *p -= hyper.lr * mhat / (vhat.sqrt() + hyper.eps);
        }
    }

    fn state_elems(&self) -> u64 {
        (self.m.len() + self.v.len()) as u64
    }

    fn name(&self) -> &'static str {
        "adamw"
    }
}

/// Name-keyed optimizer bundle for a whole model's PU stage.
///
/// Each parameter tensor (keyed by its checkpoint/manifest name, e.g.
/// `layers.0.wq.cores.3`) gets its own [`Optimizer`] instance, created
/// on the first step that touches it — state buffers therefore have
/// exactly the compressed shapes of the cores they track.
pub struct ModelOptim {
    pub cfg: OptimConfig,
    slots: BTreeMap<String, Box<dyn Optimizer>>,
}

impl ModelOptim {
    pub fn new(cfg: OptimConfig) -> ModelOptim {
        ModelOptim { cfg, slots: BTreeMap::new() }
    }

    /// The per-step hypers at learning rate `lr`.
    pub fn hyper(&self, lr: f32) -> Hyper {
        self.cfg.hyper(lr)
    }

    /// Apply one update to the named parameter tensor.
    pub fn step(&mut self, name: &str, param: &mut [f32], grad: &[f32], hyper: &Hyper) {
        debug_assert_eq!(param.len(), grad.len(), "grad shape mismatch for '{name}'");
        let kind = self.cfg.kind;
        let slot = self.slots.entry(name.to_string()).or_insert_with(|| kind.build());
        slot.step(param, grad, hyper);
    }

    /// Optimizer-state elements currently allocated across all slots.
    pub fn allocated_state_elems(&self) -> u64 {
        self.slots.values().map(|s| s.state_elems()).sum()
    }
}

impl std::fmt::Debug for ModelOptim {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelOptim")
            .field("cfg", &self.cfg)
            .field("slots", &self.slots.len())
            .field("state_elems", &self.allocated_state_elems())
            .finish()
    }
}

/// Analytic optimizer-state memory report for one model configuration —
/// the row the cost model and the FPGA resource simulator charge against
/// the U50 budget alongside cores and Eq. 21 caches.
#[derive(Debug, Clone, Copy)]
pub struct StateFootprint {
    pub kind: OptimKind,
    /// Trainable parameter elements in compressed (tensor) space.
    pub param_elems: u64,
    /// Optimizer-state elements (multiplier x `param_elems`).
    pub state_elems: u64,
}

impl StateFootprint {
    /// Footprint of a whole model at fp32: state mirrors every trainable
    /// scalar ([`ModelConfig::tensor_params`]) times the rule's
    /// multiplier.
    pub fn for_model(cfg: &ModelConfig, kind: OptimKind) -> StateFootprint {
        let param_elems = cfg.tensor_params() as u64;
        StateFootprint {
            kind,
            param_elems,
            state_elems: kind.state_multiplier() as u64 * param_elems,
        }
    }

    pub fn state_bytes(&self) -> u64 {
        4 * self.state_elems
    }

    pub fn state_mb(&self) -> f64 {
        self.state_bytes() as f64 / 1e6
    }
}

/// Order-preserving deterministic mean of per-example gradients: sums
/// in ascending example order (the same left-to-right accumulation the
/// blocked matmul kernels use), then scales once by `1/B`.  Bitwise
/// reproducible across calls.
///
/// This is the **reference implementation** of the mini-batch reduction
/// contract — the native trainer's widened-K backward realizes the same
/// semantics inside its matmuls (see `crate::train::model`), so
/// production code does not call this directly; tests pin the contract
/// against it, and it is the building block for explicit
/// gradient-accumulation schedules (e.g. micro-batching) that cannot
/// widen K.
pub fn mean_accumulate(per_example: &[Vec<f32>]) -> Vec<f32> {
    let b = per_example.len();
    if b == 0 {
        return Vec::new();
    }
    let mut acc = per_example[0].clone();
    for g in &per_example[1..] {
        debug_assert_eq!(g.len(), acc.len());
        for (a, &v) in acc.iter_mut().zip(g) {
            *a += v;
        }
    }
    let inv = 1.0 / b as f32;
    for a in acc.iter_mut() {
        *a *= inv;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper(lr: f32) -> Hyper {
        OptimConfig { weight_decay: 0.01, ..OptimConfig::default() }.hyper(lr)
    }

    /// Synthetic gradient stream: deterministic, element-dependent.
    fn grad_at(step: usize, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| ((step * 7 + i * 13) % 17) as f32 / 17.0 - 0.45)
            .collect()
    }

    /// Scalar reference implementations, written independently of the
    /// vectorized `Optimizer` impls (same update rules, one scalar at a
    /// time).  The vector paths must match them **bitwise** over 100
    /// steps.
    struct ScalarRef {
        kind: OptimKind,
        v: Vec<f32>,
        m: Vec<f32>,
        t: u32,
    }

    impl ScalarRef {
        fn new(kind: OptimKind, n: usize) -> ScalarRef {
            ScalarRef { kind, v: vec![0.0; n], m: vec![0.0; n], t: 0 }
        }

        fn step(&mut self, p: &mut [f32], g: &[f32], h: &Hyper) {
            self.t += 1;
            for i in 0..p.len() {
                match self.kind {
                    OptimKind::Sgd => {
                        let gi = if h.weight_decay == 0.0 {
                            g[i]
                        } else {
                            g[i] + h.weight_decay * p[i]
                        };
                        p[i] -= h.lr * gi;
                    }
                    OptimKind::Momentum => {
                        let gi = g[i] + h.weight_decay * p[i];
                        self.v[i] = h.momentum * self.v[i] + gi;
                        p[i] -= h.lr * self.v[i];
                    }
                    OptimKind::Adam => {
                        let gi = g[i] + h.weight_decay * p[i];
                        self.m[i] = h.beta1 * self.m[i] + (1.0 - h.beta1) * gi;
                        self.v[i] = h.beta2 * self.v[i] + (1.0 - h.beta2) * gi * gi;
                        let mhat = self.m[i] / (1.0 - h.beta1.powi(self.t as i32));
                        let vhat = self.v[i] / (1.0 - h.beta2.powi(self.t as i32));
                        p[i] -= h.lr * mhat / (vhat.sqrt() + h.eps);
                    }
                    OptimKind::AdamW => {
                        p[i] -= h.lr * h.weight_decay * p[i];
                        self.m[i] = h.beta1 * self.m[i] + (1.0 - h.beta1) * g[i];
                        self.v[i] = h.beta2 * self.v[i] + (1.0 - h.beta2) * g[i] * g[i];
                        let mhat = self.m[i] / (1.0 - h.beta1.powi(self.t as i32));
                        let vhat = self.v[i] / (1.0 - h.beta2.powi(self.t as i32));
                        p[i] -= h.lr * mhat / (vhat.sqrt() + h.eps);
                    }
                }
            }
        }
    }

    #[test]
    fn every_optimizer_matches_scalar_reference_over_100_steps() {
        let n = 9usize;
        let h = hyper(0.05);
        for kind in OptimKind::all() {
            let mut opt = kind.build();
            let mut reference = ScalarRef::new(kind, n);
            let mut p_opt: Vec<f32> = (0..n).map(|i| 0.3 * (i as f32 - 4.0)).collect();
            let mut p_ref = p_opt.clone();
            for step in 0..100 {
                let g = grad_at(step, n);
                opt.step(&mut p_opt, &g, &h);
                reference.step(&mut p_ref, &g, &h);
                assert_eq!(
                    p_opt, p_ref,
                    "{kind:?} diverged from scalar reference at step {step}"
                );
            }
        }
    }

    #[test]
    fn optimizers_minimize_a_quadratic() {
        // L(p) = ||p - target||^2 / 2, gradient p - target: every rule
        // must shrink the loss substantially from a cold start.
        let target: Vec<f32> = vec![1.0, -2.0, 0.5, 3.0];
        for kind in OptimKind::all() {
            let mut opt = kind.build();
            let h = OptimConfig::default().hyper(0.1);
            let mut p = vec![0.0f32; 4];
            let loss = |p: &[f32]| -> f32 {
                p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / 2.0
            };
            let start = loss(&p);
            for _ in 0..200 {
                let g: Vec<f32> = p.iter().zip(&target).map(|(a, b)| a - b).collect();
                opt.step(&mut p, &g, &h);
            }
            let end = loss(&p);
            assert!(end < 0.05 * start, "{:?}: loss {end} vs start {start}", kind);
        }
    }

    #[test]
    fn state_multipliers_match_allocated_state() {
        for kind in OptimKind::all() {
            let mut opt = kind.build();
            let mut p = vec![0.1f32; 12];
            let g = vec![0.01f32; 12];
            assert_eq!(opt.state_elems(), 0, "{:?}: state before first step", kind);
            opt.step(&mut p, &g, &OptimConfig::default().hyper(0.01));
            assert_eq!(
                opt.state_elems(),
                (kind.state_multiplier() * 12) as u64,
                "{:?}: state after first step",
                kind
            );
        }
    }

    #[test]
    fn model_optim_tracks_per_name_state() {
        let mut mo = ModelOptim::new(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        let h = mo.hyper(0.01);
        let mut a = vec![0.0f32; 8];
        let mut b = vec![0.0f32; 5];
        mo.step("a", &mut a, &[0.1; 8], &h);
        mo.step("b", &mut b, &[0.1; 5], &h);
        assert_eq!(mo.allocated_state_elems(), 2 * (8 + 5));
        // Re-stepping an existing name must not allocate new slots.
        mo.step("a", &mut a, &[0.1; 8], &h);
        assert_eq!(mo.allocated_state_elems(), 2 * (8 + 5));
    }

    #[test]
    fn mean_accumulate_is_order_preserving_and_reproducible() {
        // The reduction pins ascending example order and one final 1/B
        // scale: repeated calls are bitwise identical, and the result
        // matches the same left-to-right chain done by hand in f64
        // within one rounding step.
        let gs = vec![
            vec![1.0e7f32, 3.0e-3],
            vec![1.5f32, -3.0e-3],
            vec![-1.0e7f32, 1.0e-4],
        ];
        let m1 = mean_accumulate(&gs);
        let m2 = mean_accumulate(&gs);
        assert_eq!(m1, m2, "reduction must be bitwise reproducible");
        // Hand-rolled identical chain (f32, same order) is bit-for-bit.
        let mut by_hand = [0.0f32; 2];
        for j in 0..2 {
            by_hand[j] = ((gs[0][j] + gs[1][j]) + gs[2][j]) * (1.0 / 3.0);
        }
        assert_eq!(m1, by_hand.to_vec());
        // And feeding the pinned mean to an optimizer is bitwise stable.
        let h = OptimConfig::default().hyper(0.01);
        let mut p1 = vec![0.5f32, -0.5];
        let mut p2 = p1.clone();
        Sgd.step(&mut p1, &m1, &h);
        Sgd.step(&mut p2, &m2, &h);
        assert_eq!(p1, p2);
    }

    #[test]
    fn footprint_multiplies_tensor_params() {
        let cfg = ModelConfig::paper(2);
        for kind in OptimKind::all() {
            let fp = StateFootprint::for_model(&cfg, kind);
            assert_eq!(fp.param_elems, cfg.tensor_params() as u64);
            assert_eq!(fp.state_elems, fp.param_elems * kind.state_multiplier() as u64);
        }
        let adam = StateFootprint::for_model(&cfg, OptimKind::Adam);
        assert_eq!(adam.state_elems, 2 * cfg.tensor_params() as u64);
    }

    #[test]
    fn kind_parsing_roundtrips() {
        for kind in OptimKind::all() {
            assert_eq!(OptimKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(OptimKind::parse("rmsprop").is_err());
    }
}
