//! Model / training / hardware configuration.
//!
//! [`ModelConfig`] mirrors `python/compile/configs.py` (paper Table II) and
//! is deserialized from `artifacts/manifest.json` so the two sides can
//! never drift.  [`U50`] carries the AMD Alveo U50 budget the paper
//! targets, and [`Rtx3090`] the paper's measured GPU reference points used
//! to calibrate the energy comparisons (we have no 3090; see DESIGN.md).

use crate::util::json::Value;
use anyhow::{anyhow, Context, Result};

/// Transformer + tensorization hyper-parameters (paper Table II).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub d_hid: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub vocab: usize,
    pub n_intents: usize,
    pub n_slots: usize,
    pub tt_m: Vec<usize>,
    pub tt_n: Vec<usize>,
    pub tt_rank: usize,
    pub ttm_vocab_modes: Vec<usize>,
    pub ttm_hid_modes: Vec<usize>,
    pub ttm_rank: usize,
    pub pad_id: i32,
    pub cls_id: i32,
    pub unk_id: i32,
}

impl ModelConfig {
    /// The paper's configuration with `n` encoder blocks (Table II).
    pub fn paper(n_layers: usize) -> Self {
        ModelConfig {
            n_layers,
            d_hid: 768,
            n_heads: 12,
            seq_len: 32,
            batch: 1,
            vocab: 1000,
            n_intents: 26,
            n_slots: 129,
            tt_m: vec![12, 8, 8],
            tt_n: vec![8, 8, 12],
            tt_rank: 12,
            ttm_vocab_modes: vec![10, 10, 10],
            ttm_hid_modes: vec![12, 8, 8],
            ttm_rank: 30,
            pad_id: 0,
            cls_id: 1,
            unk_id: 2,
        }
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let usz = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(Value::as_usize)
                .ok_or_else(|| anyhow!("manifest config missing '{k}'"))
        };
        let vec_usz = |k: &str| -> Result<Vec<usize>> {
            Ok(v.get(k)
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("manifest config missing '{k}'"))?
                .iter()
                .filter_map(Value::as_usize)
                .collect())
        };
        Ok(ModelConfig {
            n_layers: usz("n_layers")?,
            d_hid: usz("d_hid")?,
            n_heads: usz("n_heads")?,
            seq_len: usz("seq_len")?,
            batch: usz("batch")?,
            vocab: usz("vocab")?,
            n_intents: usz("n_intents")?,
            n_slots: usz("n_slots")?,
            tt_m: vec_usz("tt_m")?,
            tt_n: vec_usz("tt_n")?,
            tt_rank: usz("tt_rank")?,
            ttm_vocab_modes: vec_usz("ttm_vocab_modes")?,
            ttm_hid_modes: vec_usz("ttm_hid_modes")?,
            ttm_rank: usz("ttm_rank")?,
            pad_id: usz("pad_id")? as i32,
            cls_id: usz("cls_id")? as i32,
            unk_id: usz("unk_id")? as i32,
        })
    }

    /// Per-head attention dimension.
    pub fn d_head(&self) -> usize {
        self.d_hid / self.n_heads
    }

    /// TT rank tuple (r_0, ..., r_2d), r_0 = r_2d = 1.
    pub fn tt_ranks(&self) -> Vec<usize> {
        let d2 = self.tt_m.len() + self.tt_n.len();
        let mut r = vec![self.tt_rank; d2 + 1];
        r[0] = 1;
        r[d2] = 1;
        r
    }

    /// Parameter count of one TT-format (d_hid x d_hid) linear layer.
    pub fn tt_linear_params(&self) -> usize {
        let modes: Vec<usize> = self.tt_m.iter().chain(&self.tt_n).copied().collect();
        let ranks = self.tt_ranks();
        modes
            .iter()
            .enumerate()
            .map(|(k, &m)| ranks[k] * m * ranks[k + 1])
            .sum()
    }

    /// Parameter count of the TTM embedding table factors.
    pub fn ttm_params(&self) -> usize {
        let d = self.ttm_vocab_modes.len();
        let mut ranks = vec![self.ttm_rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        (0..d)
            .map(|k| ranks[k] * self.ttm_hid_modes[k] * self.ttm_vocab_modes[k] * ranks[k + 1])
            .sum()
    }

    /// Uncompressed model size in scalars (Table III "Size" column basis).
    pub fn dense_equivalent_params(&self) -> usize {
        let per_lin = self.d_hid * self.d_hid + self.d_hid;
        let per_layer = 6 * per_lin + 4 * self.d_hid;
        self.vocab * self.d_hid
            + self.seq_len * self.d_hid
            + self.n_layers * per_layer
            + per_lin
            + self.n_intents * (self.d_hid + 1)
            + self.n_slots * (self.d_hid + 1)
    }

    /// Tensor-compressed model size in scalars.
    pub fn tensor_params(&self) -> usize {
        let per_layer = 6 * (self.tt_linear_params() + self.d_hid) + 4 * self.d_hid;
        self.ttm_params()
            + self.seq_len * self.d_hid
            + self.n_layers * per_layer
            + self.tt_linear_params()
            + self.d_hid
            + self.n_intents * (self.d_hid + 1)
            + self.n_slots * (self.d_hid + 1)
    }
}

/// Training-loop hyper-parameters (paper Sec. VI-A).
///
/// `Default` is the **single source of truth** for the paper's training
/// setup: the lr / batch-size fallbacks of the CLI and the manifest
/// route through it, as do [`crate::optim::OptimConfig`]'s defaults.
/// (The CLI's `--epochs` fallback is deliberately 1 — a smoke-run
/// default — not the paper's 40-epoch `epochs` here, which manifests
/// inherit.)
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub lr: f32,
    pub epochs: usize,
    /// Mini-batch size (the paper's on-device setting is 1).
    pub batch_size: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { lr: 4e-3, epochs: 40, batch_size: 1 }
    }
}

/// AMD Alveo U50 programmable-logic budget (paper Sec. VI-A).
#[derive(Debug, Clone, Copy)]
pub struct U50;

impl U50 {
    pub const LUT: usize = 872_000;
    pub const FF: usize = 1_743_000;
    pub const DSP: usize = 5_952;
    pub const BRAM_BLOCKS: usize = 1_344; // 36 Kib each => 5.9 MB
    pub const URAM_BLOCKS: usize = 640; // 288 Kib each => 22.5 MB
    pub const BRAM_BITS: usize = 36_864;
    pub const URAM_BITS: usize = 294_912;
    pub const CLOCK_HZ: f64 = 100e6;
    pub const STATIC_POWER_W: f64 = 6.0; // paper Table IV static column
}

/// Paper-measured RTX 3090 reference points (Table V) used as calibration
/// constants for the GPU side of the energy/memory comparisons.
#[derive(Debug, Clone, Copy)]
pub struct Rtx3090;

impl Rtx3090 {
    pub const CLOCK_HZ: f64 = 1.395e9;
    /// (layers, latency s/epoch, power W, computing memory MB) per mode.
    pub const MATRIX: [(usize, f64, f64, f64); 3] =
        [(2, 47.0, 150.0, 829.0), (4, 77.0, 150.0, 915.0), (6, 108.0, 152.0, 1022.0)];
    pub const TT: [(usize, f64, f64, f64); 3] =
        [(2, 144.0, 140.0, 726.0), (4, 243.0, 138.0, 720.0), (6, 347.0, 138.0, 716.0)];
    pub const BTT: [(usize, f64, f64, f64); 3] =
        [(2, 129.0, 138.0, 721.0), (4, 222.0, 138.0, 718.0), (6, 324.0, 138.0, 713.0)];
}

/// Load a manifest file and return the parsed JSON.
pub fn load_manifest(path: &str) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading manifest {path} (run `make artifacts`)"))?;
    Value::parse(&text).map_err(|e| anyhow!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_match_table3() {
        // Table III: uncompressed sizes 36.7 / 65.1 / 93.5 MB (fp32).
        for (layers, mb) in [(2usize, 36.7), (4, 65.1), (6, 93.5)] {
            let cfg = ModelConfig::paper(layers);
            let ours = cfg.dense_equivalent_params() as f64 * 4.0 / 1e6;
            assert!(
                (ours - mb).abs() / mb < 0.08,
                "L{layers}: {ours:.1} MB vs paper {mb} MB"
            );
        }
    }

    #[test]
    fn compression_ratio_matches_table3() {
        // Table III reports 30.5x / 43.4x / 52.0x for 2/4/6 encoders.
        for (layers, ratio) in [(2usize, 30.5), (4, 43.4), (6, 52.0)] {
            let cfg = ModelConfig::paper(layers);
            let ours = cfg.dense_equivalent_params() as f64 / cfg.tensor_params() as f64;
            assert!(
                (ours - ratio).abs() / ratio < 0.15,
                "L{layers}: {ours:.1}x vs paper {ratio}x"
            );
        }
    }

    #[test]
    fn tt_linear_param_count() {
        let cfg = ModelConfig::paper(2);
        // (1*12*12) + (12*8*12) + (12*8*12) + (12*8*12) + (12*8*12) + (12*12*1)
        assert_eq!(cfg.tt_linear_params(), 144 + 4 * 1152 + 144);
    }
}
