//! The precision x compute-path x checkpoint-policy benchmark grid.
//!
//! One implementation shared by the bench binary (`cargo bench --offline
//! -- matrix`, which writes `BENCH_matrix.json`) and the CLI
//! (`bench-matrix`, which prints the table): for every cell of
//! {f32, bf16, f16, int8} x {fused, looped} x {cache, recompute} it runs
//! real paper-config train steps and records
//!
//! * throughput — p50 step latency, steps/sec, tokens/sec,
//! * the FP/BP/PU stage split of one traced step
//!   ([`crate::trace::stage_breakdown`]),
//! * the **measured** at-rest byte footprints: packed parameters
//!   ([`crate::train::NativeTrainModel::param_bytes`] sums the physical
//!   `u16`/`f32` stores, not an analytic formula), the live Eq. 21
//!   caches and the allocated optimizer moments.
//!
//! The summary ratios compare each cell against the
//! f32 / looped / cache baseline; `fused_bf16_vs_unfused_f32` is the
//! headline number the CI regression gate asserts to stay above 1.0.
//!
//! [`run_replicas`] is the data-parallel companion sweep (`cargo bench
//! --offline -- replicas` / CLI `bench-replicas`): tokens/sec of
//! [`crate::replica::ReplicaGroup`] at R ∈ {1, 2, 4} on one global
//! batch, written to `BENCH_replicas.json` with the `r4_vs_r1` headline
//! the CI scaling gate reads (skipping on hosts with fewer than 4
//! cores).

use crate::config::ModelConfig;
use crate::coordinator::Trainer;
use crate::data::Dataset;
use crate::optim::{OptimConfig, OptimKind};
use crate::tensor::Precision;
use crate::trace;
use crate::train::{CheckpointPolicy, ComputePath, NativeTrainer};
use crate::util::timer::bench;
use anyhow::Result;

/// One measured cell of the grid.
#[derive(Debug, Clone)]
pub struct MatrixCell {
    pub precision: Precision,
    /// `true` = fully fused schedule ([`ComputePath::fused`]: fused QKV,
    /// batched attention, fused elementwise lanes); `false` = the
    /// pre-fusion looped baseline ([`ComputePath::looped`]).
    pub fused: bool,
    /// `true` = [`CheckpointPolicy::CacheAll`]; `false` = `Recompute`.
    pub cached: bool,
    pub p50_step_secs: f64,
    pub steps_per_sec: f64,
    pub tokens_per_sec: f64,
    /// Measured at-rest parameter bytes (packed representation).
    pub param_bytes: u64,
    /// Measured live Eq. 21 cache bytes over one batch-shaped forward.
    pub eq21_cache_bytes: u64,
    /// Allocated optimizer-moment bytes after the measured steps.
    pub optim_state_bytes: u64,
    /// `(stage, total_us)` rows of one traced step (fp / bp / pu).
    pub stage_us: Vec<(String, f64)>,
    pub mean_loss: f32,
}

impl MatrixCell {
    pub fn path_name(&self) -> &'static str {
        if self.fused {
            "fused"
        } else {
            "looped"
        }
    }

    pub fn ckpt_name(&self) -> &'static str {
        if self.cached {
            "cache"
        } else {
            "recompute"
        }
    }

    /// `"fp 47% bp 44% pu 9%"` — the traced stage split, normalized.
    pub fn stage_split(&self) -> String {
        let total: f64 = self.stage_us.iter().map(|(_, us)| us).sum();
        if total <= 0.0 {
            return String::from("-");
        }
        self.stage_us
            .iter()
            .map(|(s, us)| format!("{s} {:.0}%", 100.0 * us / total))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// The full grid plus the workload shape it was measured at.
#[derive(Debug, Clone)]
pub struct MatrixReport {
    pub batch: usize,
    pub seq_len: usize,
    pub warmup: usize,
    pub iters: usize,
    pub cells: Vec<MatrixCell>,
}

impl MatrixReport {
    pub fn find(&self, precision: Precision, fused: bool, cached: bool) -> Option<&MatrixCell> {
        self.cells
            .iter()
            .find(|c| c.precision == precision && c.fused == fused && c.cached == cached)
    }

    /// The f32 / looped / cache reference cell every speedup is against.
    pub fn baseline(&self) -> Option<&MatrixCell> {
        self.find(Precision::F32, false, true)
    }

    /// tokens/sec ratio of `(precision, fused, cached)` over the
    /// baseline cell (0.0 when either cell is missing).
    pub fn speedup_vs_baseline(&self, precision: Precision, fused: bool, cached: bool) -> f64 {
        match (self.find(precision, fused, cached), self.baseline()) {
            (Some(c), Some(b)) if b.tokens_per_sec > 0.0 => c.tokens_per_sec / b.tokens_per_sec,
            _ => 0.0,
        }
    }

    /// The CI-gated headline: fused-elementwise bf16 over unfused f32.
    pub fn fused_bf16_vs_unfused_f32(&self) -> f64 {
        self.speedup_vs_baseline(Precision::Bf16, true, true)
    }

    /// Measured at-rest parameter bytes saved by packing (f32 cell minus
    /// the given half-precision cell, at fused/cache).
    pub fn param_bytes_saved(&self, precision: Precision) -> u64 {
        match (self.find(Precision::F32, true, true), self.find(precision, true, true)) {
            (Some(f), Some(h)) => f.param_bytes.saturating_sub(h.param_bytes),
            _ => 0,
        }
    }

    /// Measured at-rest parameter bytes of the given precision as a
    /// fraction of the f32 cell (fused/cache corner; 0.0 when a cell is
    /// missing).  The CI gate reads `int8_param_bytes_ratio` from
    /// `BENCH_matrix.json` and asserts it stays at or below 0.27 —
    /// block-scaled int8 is 1 code byte plus one f32 scale per
    /// 64-element block, i.e. ~0.266x the f32 bytes.
    pub fn param_bytes_ratio(&self, precision: Precision) -> f64 {
        match (self.find(Precision::F32, true, true), self.find(precision, true, true)) {
            (Some(f), Some(p)) if f.param_bytes > 0 => {
                p.param_bytes as f64 / f.param_bytes as f64
            }
            _ => 0.0,
        }
    }

    /// The `BENCH_matrix.json` document (hand-rolled, no serde).  Every
    /// float goes through [`crate::coordinator::metrics::json_num`]: an
    /// unmeasured cell (empty sample set) carries NaN, and a bare `NaN`
    /// token would invalidate the whole document.
    pub fn to_json(&self) -> String {
        let num = crate::coordinator::metrics::json_num;
        let rows: Vec<String> = self
            .cells
            .iter()
            .map(|c| {
                let stages = c
                    .stage_us
                    .iter()
                    .map(|(s, us)| format!("\"{s}\": {}", num(*us, 1)))
                    .collect::<Vec<_>>()
                    .join(", ");
                format!(
                    "    {{\"precision\": \"{}\", \"path\": \"{}\", \"checkpoint\": \"{}\", \
                     \"p50_step_secs\": {}, \"steps_per_sec\": {}, \
                     \"tokens_per_sec\": {}, \"param_bytes\": {}, \
                     \"eq21_cache_bytes\": {}, \"optim_state_bytes\": {}, \
                     \"stage_us\": {{{stages}}}, \"mean_loss\": {}}}",
                    c.precision.name(),
                    c.path_name(),
                    c.ckpt_name(),
                    num(c.p50_step_secs, 6),
                    num(c.steps_per_sec, 3),
                    num(c.tokens_per_sec, 1),
                    c.param_bytes,
                    c.eq21_cache_bytes,
                    c.optim_state_bytes,
                    num(c.mean_loss as f64, 5)
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"matrix\",\n  \"model\": \"tt_L2\",\n  \"batch\": {},\n  \
             \"seq_len\": {},\n  \"fused_bf16_vs_unfused_f32\": {},\n  \
             \"fused_f16_vs_unfused_f32\": {},\n  \"fused_vs_looped_f32\": {},\n  \
             \"bf16_param_bytes_saved\": {},\n  \"f16_param_bytes_saved\": {},\n  \
             \"int8_param_bytes_saved\": {},\n  \"int8_param_bytes_ratio\": {},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            self.batch,
            self.seq_len,
            num(self.fused_bf16_vs_unfused_f32(), 3),
            num(self.speedup_vs_baseline(Precision::F16, true, true), 3),
            num(self.speedup_vs_baseline(Precision::F32, true, true), 3),
            self.param_bytes_saved(Precision::Bf16),
            self.param_bytes_saved(Precision::F16),
            self.param_bytes_saved(Precision::Int8),
            num(self.param_bytes_ratio(Precision::Int8), 4),
            rows.join(",\n")
        )
    }

    /// The human table the CLI prints: one row per cell, speedups
    /// against the f32/looped/cache baseline, measured bytes, stage
    /// split.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<5} {:<7} {:<10} {:>12} {:>10} {:>8} {:>11} {:>11} {:>11}  {}\n",
            "prec",
            "path",
            "ckpt",
            "p50 step ms",
            "tokens/s",
            "speedup",
            "param B",
            "cache B",
            "state B",
            "stage split"
        ));
        for c in &self.cells {
            let speedup = self.speedup_vs_baseline(c.precision, c.fused, c.cached);
            out.push_str(&format!(
                "{:<5} {:<7} {:<10} {:>12.3} {:>10.0} {:>7.2}x {:>11} {:>11} {:>11}  {}\n",
                c.precision.name(),
                c.path_name(),
                c.ckpt_name(),
                c.p50_step_secs * 1e3,
                c.tokens_per_sec,
                speedup,
                c.param_bytes,
                c.eq21_cache_bytes,
                c.optim_state_bytes,
                c.stage_split()
            ));
        }
        out.push_str(&format!(
            "fused bf16 vs unfused f32: {:.2}x tokens/s | fused f32 vs looped f32: {:.2}x | \
             bf16 packs away {} param bytes (f16: {}, int8: {} at {:.4}x f32)\n",
            self.fused_bf16_vs_unfused_f32(),
            self.speedup_vs_baseline(Precision::F32, true, true),
            self.param_bytes_saved(Precision::Bf16),
            self.param_bytes_saved(Precision::F16),
            self.param_bytes_saved(Precision::Int8),
            self.param_bytes_ratio(Precision::Int8)
        ));
        out
    }
}

/// Measure the full 4 x 2 x 2 grid at the given batch size.
///
/// Every cell trains the same seed-42 paper 2-layer model on the same
/// synthetic dataset under the Adam optimizer; only the storage
/// precision, the compute path and the checkpoint policy vary.  The
/// stage split comes from one *extra* traced step after the timed ones
/// (tracing is off while timing, so instrumentation never skews the
/// throughput numbers).
pub fn run_matrix(
    cfg: &ModelConfig,
    batch: usize,
    warmup: usize,
    iters: usize,
) -> Result<MatrixReport> {
    let data = Dataset::synth(cfg, 42, 64);
    let tokens: Vec<i32> =
        data.examples[..batch].iter().flat_map(|e| e.tokens.clone()).collect();
    let mut cells = Vec::new();
    for precision in Precision::all() {
        for fused in [true, false] {
            for cached in [true, false] {
                let path = if fused { ComputePath::fused() } else { ComputePath::looped() };
                let checkpoint =
                    if cached { CheckpointPolicy::CacheAll } else { CheckpointPolicy::Recompute };
                let optim = OptimConfig {
                    kind: OptimKind::Adam,
                    batch_size: batch,
                    precision,
                    ..Default::default()
                };
                let backend = NativeTrainer::random_init(cfg, 42)?
                    .with_optim(optim)
                    .with_compute_path(path)
                    .with_checkpoint(checkpoint);
                let mut trainer =
                    Trainer::with_batch(backend, OptimKind::Adam.default_lr(), batch);
                let stats = bench(
                    || {
                        trainer.train_steps(&data, 1).unwrap();
                    },
                    warmup,
                    iters,
                );
                let steps_per_sec = 1.0 / stats.p50;
                let tokens_per_sec = (batch * cfg.seq_len) as f64 / stats.p50;
                let mean_loss = trainer.metrics.recent_loss(iters);
                // One traced step for the FP/BP/PU split.
                let was_enabled = trace::enabled();
                trace::reset();
                trace::set_enabled(true);
                trainer.train_steps(&data, 1)?;
                trace::set_enabled(was_enabled);
                let events = trace::drain();
                let stage_us: Vec<(String, f64)> = trace::stage_breakdown(&events)
                    .into_iter()
                    .map(|r| (r.stage, r.total_us))
                    .collect();
                let model = &trainer.backend.model;
                cells.push(MatrixCell {
                    precision,
                    fused,
                    cached,
                    p50_step_secs: stats.p50,
                    steps_per_sec,
                    tokens_per_sec,
                    param_bytes: model.param_bytes(),
                    eq21_cache_bytes: model.measure_eq21_cache_bytes(&tokens)?,
                    optim_state_bytes: model.optim.allocated_state_bytes(),
                    stage_us,
                    mean_loss,
                });
            }
        }
    }
    Ok(MatrixReport { batch, seq_len: cfg.seq_len, warmup, iters, cells })
}

/// The paper-config grid the bench section and the CI gate run:
/// 2 encoder layers, batch 8.
pub fn run_paper_matrix(warmup: usize, iters: usize) -> Result<MatrixReport> {
    run_matrix(&ModelConfig::paper(2), 8, warmup, iters)
}

/// One measured replica-count cell of the data-parallel sweep.
#[derive(Debug, Clone)]
pub struct ReplicaCell {
    pub replicas: usize,
    pub batch: usize,
    pub p50_step_secs: f64,
    pub steps_per_sec: f64,
    pub tokens_per_sec: f64,
    pub mean_loss: f32,
}

/// The replica sweep plus the host shape it was measured on.  The CI
/// regression gate reads `r4_vs_r1` from `BENCH_replicas.json` and
/// skips (loudly) when `host_cores < 4` — scaling numbers from an
/// oversubscribed runner would gate on noise.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub batch: usize,
    pub seq_len: usize,
    pub warmup: usize,
    pub iters: usize,
    /// `std::thread::available_parallelism()` on the measuring host.
    pub host_cores: usize,
    pub rows: Vec<ReplicaCell>,
}

impl ReplicaReport {
    pub fn find(&self, replicas: usize) -> Option<&ReplicaCell> {
        self.rows.iter().find(|c| c.replicas == replicas)
    }

    /// tokens/sec ratio of a replica count over the R=1 baseline
    /// (0.0 when either cell is missing).
    pub fn speedup_vs_r1(&self, replicas: usize) -> f64 {
        match (self.find(replicas), self.find(1)) {
            (Some(c), Some(b)) if b.tokens_per_sec > 0.0 => c.tokens_per_sec / b.tokens_per_sec,
            _ => 0.0,
        }
    }

    /// The CI-gated headline: R=4 tokens/sec over R=1 at the same
    /// global batch.
    pub fn r4_vs_r1(&self) -> f64 {
        self.speedup_vs_r1(4)
    }

    /// The `BENCH_replicas.json` document (hand-rolled, no serde).
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .rows
            .iter()
            .map(|c| {
                format!(
                    "    {{\"replicas\": {}, \"batch\": {}, \"p50_step_secs\": {:.6}, \
                     \"steps_per_sec\": {:.3}, \"tokens_per_sec\": {:.1}, \"mean_loss\": {:.5}}}",
                    c.replicas,
                    c.batch,
                    c.p50_step_secs,
                    c.steps_per_sec,
                    c.tokens_per_sec,
                    c.mean_loss
                )
            })
            .collect();
        format!(
            "{{\n  \"bench\": \"replicas\",\n  \"model\": \"tt_L2\",\n  \"batch\": {},\n  \
             \"seq_len\": {},\n  \"host_cores\": {},\n  \"r2_vs_r1\": {:.3},\n  \
             \"r4_vs_r1\": {:.3},\n  \"rows\": [\n{}\n  ]\n}}\n",
            self.batch,
            self.seq_len,
            self.host_cores,
            self.speedup_vs_r1(2),
            self.r4_vs_r1(),
            rows.join(",\n")
        )
    }

    /// The human table the CLI prints: one row per replica count,
    /// speedups against the R=1 baseline.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:>8} {:>7} {:>12} {:>10} {:>8} {:>10}\n",
            "replicas", "batch", "p50 step ms", "tokens/s", "speedup", "mean loss"
        ));
        for c in &self.rows {
            out.push_str(&format!(
                "{:>8} {:>7} {:>12.3} {:>10.0} {:>7.2}x {:>10.4}\n",
                c.replicas,
                c.batch,
                c.p50_step_secs * 1e3,
                c.tokens_per_sec,
                self.speedup_vs_r1(c.replicas),
                c.mean_loss
            ));
        }
        out.push_str(&format!(
            "R=4 vs R=1: {:.2}x tokens/s on {} host core(s)\n",
            self.r4_vs_r1(),
            self.host_cores
        ));
        out
    }
}

/// Measure the data-parallel sweep at R ∈ {1, 2, 4} on one global
/// batch.  Every cell trains the same seed-42 model on the same
/// synthetic dataset under Adam at the fused/cache/f32 corner; only the
/// replica count varies, so the tokens/sec column isolates the
/// fork-join scaling of [`crate::replica::ReplicaGroup`].
pub fn run_replicas(
    cfg: &ModelConfig,
    batch: usize,
    warmup: usize,
    iters: usize,
) -> Result<ReplicaReport> {
    let data = Dataset::synth(cfg, 42, 64);
    let host_cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut rows = Vec::new();
    for replicas in [1usize, 2, 4] {
        let optim = OptimConfig {
            kind: OptimKind::Adam,
            batch_size: batch,
            precision: Precision::F32,
            ..Default::default()
        };
        let lead = NativeTrainer::random_init(cfg, 42)?.with_optim(optim);
        let group = crate::replica::ReplicaGroup::new(lead, replicas)?;
        let mut trainer = Trainer::with_batch(group, OptimKind::Adam.default_lr(), batch);
        let stats = bench(
            || {
                trainer.train_steps(&data, 1).unwrap();
            },
            warmup,
            iters,
        );
        rows.push(ReplicaCell {
            replicas,
            batch,
            p50_step_secs: stats.p50,
            steps_per_sec: 1.0 / stats.p50,
            tokens_per_sec: (batch * cfg.seq_len) as f64 / stats.p50,
            mean_loss: trainer.metrics.recent_loss(iters),
        });
    }
    Ok(ReplicaReport { batch, seq_len: cfg.seq_len, warmup, iters, host_cores, rows })
}

/// The paper-config replica sweep the bench section and the CI gate
/// run: 2 encoder layers, global batch 8, R ∈ {1, 2, 4}.
pub fn run_paper_replicas(warmup: usize, iters: usize) -> Result<ReplicaReport> {
    run_replicas(&ModelConfig::paper(2), 8, warmup, iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(precision: Precision, fused: bool, cached: bool, tps: f64, pb: u64) -> MatrixCell {
        MatrixCell {
            precision,
            fused,
            cached,
            p50_step_secs: 0.5,
            steps_per_sec: 2.0,
            tokens_per_sec: tps,
            param_bytes: pb,
            eq21_cache_bytes: 100,
            optim_state_bytes: 200,
            stage_us: vec![("fp".into(), 50.0), ("bp".into(), 40.0), ("pu".into(), 10.0)],
            mean_loss: 1.0,
        }
    }

    fn report() -> MatrixReport {
        MatrixReport {
            batch: 8,
            seq_len: 32,
            warmup: 1,
            iters: 2,
            cells: vec![
                cell(Precision::F32, false, true, 100.0, 400),
                cell(Precision::F32, true, true, 150.0, 400),
                cell(Precision::Bf16, true, true, 180.0, 200),
                cell(Precision::F16, true, true, 175.0, 200),
                // 400 f32 bytes = 100 elems: int8 stores 100 codes +
                // 2 block scales = 108 bytes, ratio 0.27.
                cell(Precision::Int8, true, true, 185.0, 108),
            ],
        }
    }

    #[test]
    fn speedups_are_against_the_looped_f32_cache_baseline() {
        let r = report();
        assert_eq!(r.baseline().unwrap().tokens_per_sec, 100.0);
        assert!((r.fused_bf16_vs_unfused_f32() - 1.8).abs() < 1e-12);
        assert!((r.speedup_vs_baseline(Precision::F32, true, true) - 1.5).abs() < 1e-12);
        // Missing cells degrade to 0.0, never panic.
        assert_eq!(r.speedup_vs_baseline(Precision::Bf16, false, false), 0.0);
    }

    #[test]
    fn byte_savings_compare_packed_cells_at_the_fused_cache_corner() {
        let r = report();
        assert_eq!(r.param_bytes_saved(Precision::Bf16), 200);
        assert_eq!(r.param_bytes_saved(Precision::F16), 200);
        assert_eq!(r.param_bytes_saved(Precision::Int8), 292);
        assert!((r.param_bytes_ratio(Precision::Int8) - 0.27).abs() < 1e-12);
        assert!((r.param_bytes_ratio(Precision::Bf16) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_carries_the_gate_field_and_every_row() {
        let r = report();
        let json = r.to_json();
        assert!(json.contains("\"fused_bf16_vs_unfused_f32\": 1.800"));
        assert!(json.contains("\"bench\": \"matrix\""));
        assert_eq!(json.matches("\"precision\"").count(), 5);
        assert!(json.contains("\"stage_us\": {\"fp\": 50.0, \"bp\": 40.0, \"pu\": 10.0}"));
        assert!(json.contains("\"int8_param_bytes_ratio\": 0.2700"));
        assert!(json.contains("\"int8_param_bytes_saved\": 292"));
    }

    #[test]
    fn unmeasured_cell_serializes_null_not_nan() {
        // Regression: `recent_loss` over zero samples is NaN and the
        // writer used `{:.5}` — a bare `NaN` token corrupts the whole
        // BENCH_matrix.json document.
        let mut r = report();
        r.cells[0].mean_loss = f32::NAN;
        r.cells[0].p50_step_secs = f64::NAN;
        let json = r.to_json();
        assert!(!json.contains("NaN"), "bare NaN token in {json}");
        assert!(json.contains("\"mean_loss\": null"), "{json}");
        assert!(json.contains("\"p50_step_secs\": null"), "{json}");
    }

    #[test]
    fn table_renders_one_line_per_cell_plus_header_and_summary() {
        let r = report();
        let table = r.render_table();
        assert_eq!(table.lines().count(), 1 + r.cells.len() + 1);
        assert!(table.contains("fp 50% bp 40% pu 10%"));
    }

    fn replica_report() -> ReplicaReport {
        let row = |replicas: usize, tps: f64| ReplicaCell {
            replicas,
            batch: 8,
            p50_step_secs: 0.5,
            steps_per_sec: 2.0,
            tokens_per_sec: tps,
            mean_loss: 1.5,
        };
        ReplicaReport {
            batch: 8,
            seq_len: 32,
            warmup: 1,
            iters: 2,
            host_cores: 8,
            rows: vec![row(1, 100.0), row(2, 170.0), row(4, 260.0)],
        }
    }

    #[test]
    fn replica_speedups_are_against_the_r1_baseline() {
        let r = replica_report();
        assert!((r.r4_vs_r1() - 2.6).abs() < 1e-12);
        assert!((r.speedup_vs_r1(2) - 1.7).abs() < 1e-12);
        // Missing cells degrade to 0.0, never panic.
        assert_eq!(r.speedup_vs_r1(8), 0.0);
    }

    #[test]
    fn replica_json_carries_the_gate_fields_and_every_row() {
        let r = replica_report();
        let json = r.to_json();
        assert!(json.contains("\"bench\": \"replicas\""));
        assert!(json.contains("\"r4_vs_r1\": 2.600"));
        assert!(json.contains("\"host_cores\": 8"));
        assert_eq!(json.matches("\"replicas\":").count(), 3);
    }

    #[test]
    fn replica_table_renders_one_line_per_row_plus_header_and_summary() {
        let r = replica_report();
        let table = r.render_table();
        assert_eq!(table.lines().count(), 1 + r.rows.len() + 1);
        assert!(table.contains("R=4 vs R=1: 2.60x"));
    }
}
