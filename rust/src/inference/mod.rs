//! Native inference — now a thin façade over the shared batched
//! engine.
//!
//! This module used to carry its own single-example encoder forward.
//! That duplicate (and lagging: no fused QKV, no batched attention, no
//! precision awareness) implementation is gone: **the forward lives in
//! [`crate::engine`]** and is the single source of truth shared by
//! training ([`crate::train::NativeTrainModel::eval`] is pinned bitwise
//! equal to it), single-example `predict`, and the serving scheduler
//! ([`crate::serve`]).
//!
//! What remains here:
//!
//! * the historical `NativeModel` name as an alias of
//!   [`crate::engine::NativeEngine`] (same constructor and `forward` /
//!   `predict` contracts, now batch-capable), so existing deployment
//!   code and the parity tests keep compiling;
//! * [`params_from_engine`] — the PJRT-runtime bridge that pulls a
//!   [`ParamMap`] out of a live [`crate::runtime::Engine`] (behind the
//!   `pjrt` feature).

pub use crate::engine::{NativeEngine, ParamMap};

/// Back-compat alias for the shared engine: the historical name of the
/// native deployment path.  Construct with
/// [`NativeEngine::from_params`]; `forward` accepts whole `(B, S)`
/// blocks (a single example is the `B = 1` case of the old contract).
pub type NativeModel = NativeEngine;

/// Pull a [`ParamMap`] out of a live PJRT engine (for parity tests and
/// for exporting trained weights to the native path).
#[cfg(feature = "pjrt")]
pub fn params_from_engine(engine: &crate::runtime::Engine) -> anyhow::Result<ParamMap> {
    let mut map = ParamMap::new();
    for (spec, lit) in engine.spec.params.iter().zip(engine.params()) {
        map.insert(spec.name.clone(), (spec.shape.clone(), lit.to_vec::<f32>()?));
    }
    Ok(map)
}
