//! Rust-native tensorized-transformer inference engine.
//!
//! Runs the complete forward pass (TTM embedding, BTT linears, masked
//! attention, LayerNorm/GELU, intent + slot heads) directly on the
//! [`crate::tensor`] substrate — no XLA, no Python, no artifacts beyond
//! the trained parameters.  Two purposes:
//!
//! * **deployment path**: a trained checkpoint can serve predictions on
//!   targets where a PJRT runtime is unavailable (the embedded-device
//!   story the paper motivates);
//! * **cross-validation**: `rust/tests/native_parity.rs` asserts this
//!   engine's logits match the PJRT/HLO path on the same parameters —
//!   an end-to-end oracle spanning the whole stack.
//!
//! Computation follows the paper exactly: every linear layer is applied
//! via the **BTT contraction** (merge once per layer, K-wide applies),
//! and the merged `Z1`/`Z3` factors are cached like the accelerator's
//! on-chip core buffers.
//!
//! The forward blocks (BTT apply, [`ops::multi_head_attention`],
//! LayerNorm/GELU) are shared with the native *training* path
//! ([`crate::train`]), which runs the same math plus activation caching
//! and the hand-derived backward — the two paths cannot drift.

use crate::config::ModelConfig;
use crate::tensor::ops;
use crate::tensor::{Tensor, TTMEmbedding, TTMatrix};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// A TT linear layer with pre-merged BTT factors.
struct BttLinear {
    /// Z3 (M, r) — merged output-mode cores.
    z3: Tensor,
    /// Z1 (r, N) — merged input-mode cores.
    z1: Tensor,
    bias: Vec<f32>,
}

impl BttLinear {
    fn from_tt(tt: &TTMatrix, bias: Vec<f32>) -> Result<BttLinear> {
        Ok(BttLinear { z3: tt.merge_left()?, z1: tt.merge_right()?, bias })
    }

    /// `y = W x + b` with x as rows: (K, N) -> (K, M).
    fn apply(&self, x_rows: &Tensor) -> Result<Tensor> {
        // Row-major apply: Y^T = X Z1^T Z3^T.
        let z2 = x_rows.matmul(&self.z1.t()?)?; // (K, r)
        let y = z2.matmul(&self.z3.t()?)?; // (K, M)
        Ok(ops::add_row(&y, &self.bias))
    }
}

struct LayerNormParams {
    g: Vec<f32>,
    b: Vec<f32>,
}

struct EncoderLayer {
    wq: BttLinear,
    wk: BttLinear,
    wv: BttLinear,
    wo: BttLinear,
    w1: BttLinear,
    w2: BttLinear,
    ln1: LayerNormParams,
    ln2: LayerNormParams,
}

/// The native model: parameters assembled from a flat name->array map
/// (the manifest naming scheme of `python/compile/model.py`).
pub struct NativeModel {
    pub cfg: ModelConfig,
    embedding: TTMEmbedding,
    pos: Tensor, // (S, H)
    layers: Vec<EncoderLayer>,
    pool: BttLinear,
    intent_w: Tensor, // (n_intents, H)
    intent_b: Vec<f32>,
    slot_w: Tensor, // (n_slots, H)
    slot_b: Vec<f32>,
}

/// Flat parameter map: manifest name -> (shape, data).
pub type ParamMap = BTreeMap<String, (Vec<usize>, Vec<f32>)>;

impl NativeModel {
    /// Assemble from named parameters (e.g. pulled from a live
    /// [`crate::runtime::Engine`] or a checkpoint directory).
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeModel> {
        let get = |name: &str| -> Result<(&Vec<usize>, &Vec<f32>)> {
            params
                .get(name)
                .map(|(s, d)| (s, d))
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))
        };
        let tensor = |name: &str| -> Result<Tensor> {
            let (shape, data) = get(name)?;
            Tensor::from_vec(data.clone(), shape)
        };
        let vec1 = |name: &str| -> Result<Vec<f32>> { Ok(get(name)?.1.clone()) };

        // TTM embedding cores.
        let d = cfg.ttm_vocab_modes.len();
        let mut ttm_cores = Vec::with_capacity(d);
        for k in 0..d {
            ttm_cores.push(tensor(&format!("embed.ttm.{k}"))?);
        }
        let mut ranks = vec![cfg.ttm_rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        let embedding = TTMEmbedding {
            cores: ttm_cores,
            hid_modes: cfg.ttm_hid_modes.clone(),
            vocab_modes: cfg.ttm_vocab_modes.clone(),
            ranks,
        };

        let tt_linear = |prefix: &str| -> Result<BttLinear> {
            let d2 = cfg.tt_m.len() + cfg.tt_n.len();
            let mut cores = Vec::with_capacity(d2);
            for k in 0..d2 {
                cores.push(tensor(&format!("{prefix}.cores.{k}"))?);
            }
            let tt = TTMatrix {
                cores,
                m_modes: cfg.tt_m.clone(),
                n_modes: cfg.tt_n.clone(),
                ranks: cfg.tt_ranks(),
            };
            BttLinear::from_tt(&tt, vec1(&format!("{prefix}.bias"))?)
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |name: &str| format!("layers.{i}.{name}");
            layers.push(EncoderLayer {
                wq: tt_linear(&p("wq"))?,
                wk: tt_linear(&p("wk"))?,
                wv: tt_linear(&p("wv"))?,
                wo: tt_linear(&p("wo"))?,
                w1: tt_linear(&p("w1"))?,
                w2: tt_linear(&p("w2"))?,
                ln1: LayerNormParams { g: vec1(&p("ln1.g"))?, b: vec1(&p("ln1.b"))? },
                ln2: LayerNormParams { g: vec1(&p("ln2.g"))?, b: vec1(&p("ln2.b"))? },
            });
        }

        Ok(NativeModel {
            cfg: cfg.clone(),
            embedding,
            pos: tensor("embed.pos")?,
            layers,
            pool: tt_linear("cls.pool")?,
            intent_w: tensor("cls.intent_w")?,
            intent_b: vec1("cls.intent_b")?,
            slot_w: tensor("cls.slot_w")?,
            slot_b: vec1("cls.slot_b")?,
        })
    }

    /// Forward pass for one sequence of token ids (batch 1, the paper's
    /// deployment setting).  Returns `(intent_logits, slot_logits)` with
    /// slot logits row-major (S, n_slots).
    pub fn forward(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let cfg = &self.cfg;
        let s = cfg.seq_len;
        let h = cfg.d_hid;
        if tokens.len() != s {
            return Err(anyhow!("expected {s} tokens, got {}", tokens.len()));
        }
        let mask: Vec<f32> = tokens
            .iter()
            .map(|&t| if t == cfg.pad_id { 0.0 } else { 1.0 })
            .collect();

        // Embedding: TTM lookup + positional table.
        let mut x = Tensor::zeros(&[s, h]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = self.embedding.lookup(t as usize)?;
            for j in 0..h {
                x.data[i * h + j] = row.data[j] + self.pos.at2(i, j);
            }
        }

        for layer in &self.layers {
            x = self.encoder_block(&x, &mask, layer)?;
        }

        // Classifier: shared TT pooler + heads.
        let pooled = ops::tanh(&self.pool.apply(&x)?); // (S, H)
        let cls_row = Tensor::from_vec(pooled.data[..h].to_vec(), &[1, h])?;
        let intent = ops::add_row(&cls_row.matmul(&self.intent_w.t()?)?, &self.intent_b);
        let slots = ops::add_row(&pooled.matmul(&self.slot_w.t()?)?, &self.slot_b);
        Ok((intent.data, slots.data))
    }

    /// Greedy predictions: `(intent_id, slot_ids)`.
    pub fn predict(&self, tokens: &[i32]) -> Result<(usize, Vec<usize>)> {
        let (il, sl) = self.forward(tokens)?;
        let intent = argmax(&il);
        let ns = self.cfg.n_slots;
        let slots = (0..self.cfg.seq_len)
            .map(|i| argmax(&sl[i * ns..(i + 1) * ns]))
            .collect();
        Ok((intent, slots))
    }

    fn encoder_block(&self, x: &Tensor, mask: &[f32], layer: &EncoderLayer) -> Result<Tensor> {
        let cfg = &self.cfg;

        let q = layer.wq.apply(x)?;
        let k = layer.wk.apply(x)?;
        let v = layer.wv.apply(x)?;

        // Masked attention via the shared block (the accelerator's MM +
        // softmax path); inference discards the probabilities that the
        // training path ([`crate::train`]) keeps for backward.
        let (attn, _probs) = ops::multi_head_attention(&q, &k, &v, mask, cfg.n_heads)?;

        let o = layer.wo.apply(&attn)?;
        let x = ops::layer_norm(&ops::add(x, &o), &layer.ln1.g, &layer.ln1.b, 1e-5);
        let ffn = layer.w2.apply(&ops::gelu(&layer.w1.apply(&x)?))?;
        Ok(ops::layer_norm(&ops::add(&x, &ffn), &layer.ln2.g, &layer.ln2.b, 1e-5))
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Pull a [`ParamMap`] out of a live PJRT engine (for parity tests and
/// for exporting trained weights to the native path).
#[cfg(feature = "pjrt")]
pub fn params_from_engine(engine: &crate::runtime::Engine) -> Result<ParamMap> {
    let mut map = ParamMap::new();
    for (spec, lit) in engine.spec.params.iter().zip(engine.params()) {
        map.insert(spec.name.clone(), (spec.shape.clone(), lit.to_vec::<f32>()?));
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn put(map: &mut ParamMap, rng: &mut SplitMix64, name: &str, shape: Vec<usize>, std: f32) {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        map.insert(name.to_string(), (shape, data));
    }

    fn put_const(map: &mut ParamMap, name: &str, shape: Vec<usize>, value: f32) {
        let n: usize = shape.iter().product();
        map.insert(name.to_string(), (shape, vec![value; n]));
    }

    fn put_linear(map: &mut ParamMap, rng: &mut SplitMix64, cfg: &ModelConfig, prefix: &str) {
        let modes: Vec<usize> = cfg.tt_m.iter().chain(&cfg.tt_n).copied().collect();
        let ranks = cfg.tt_ranks();
        for k in 0..modes.len() {
            put(
                map,
                rng,
                &format!("{prefix}.cores.{k}"),
                vec![ranks[k], modes[k], ranks[k + 1]],
                0.3,
            );
        }
        put(map, rng, &format!("{prefix}.bias"), vec![cfg.d_hid], 0.01);
    }

    /// Build a random ParamMap at a small config for unit tests.
    fn tiny_params(cfg: &ModelConfig, seed: u64) -> ParamMap {
        let mut rng = SplitMix64::new(seed);
        let mut map = ParamMap::new();
        let d = cfg.ttm_vocab_modes.len();
        let mut rr = vec![cfg.ttm_rank; d + 1];
        rr[0] = 1;
        rr[d] = 1;
        for k in 0..d {
            put(
                &mut map,
                &mut rng,
                &format!("embed.ttm.{k}"),
                vec![rr[k], cfg.ttm_hid_modes[k], cfg.ttm_vocab_modes[k], rr[k + 1]],
                0.25,
            );
        }
        put(&mut map, &mut rng, "embed.pos", vec![cfg.seq_len, cfg.d_hid], 0.02);
        for i in 0..cfg.n_layers {
            for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
                put_linear(&mut map, &mut rng, cfg, &format!("layers.{i}.{w}"));
            }
            put_const(&mut map, &format!("layers.{i}.ln1.g"), vec![cfg.d_hid], 1.0);
            put_const(&mut map, &format!("layers.{i}.ln1.b"), vec![cfg.d_hid], 0.0);
            put_const(&mut map, &format!("layers.{i}.ln2.g"), vec![cfg.d_hid], 1.0);
            put_const(&mut map, &format!("layers.{i}.ln2.b"), vec![cfg.d_hid], 0.0);
        }
        put_linear(&mut map, &mut rng, cfg, "cls.pool");
        put(&mut map, &mut rng, "cls.intent_w", vec![cfg.n_intents, cfg.d_hid], 0.05);
        put_const(&mut map, "cls.intent_b", vec![cfg.n_intents], 0.0);
        put(&mut map, &mut rng, "cls.slot_w", vec![cfg.n_slots, cfg.d_hid], 0.05);
        put_const(&mut map, "cls.slot_b", vec![cfg.n_slots], 0.0);
        map
    }

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_layers: 1,
            d_hid: 48,
            n_heads: 4,
            seq_len: 8,
            batch: 1,
            vocab: 27,
            n_intents: 5,
            n_slots: 7,
            tt_m: vec![4, 4, 3],
            tt_n: vec![3, 4, 4],
            tt_rank: 3,
            ttm_vocab_modes: vec![3, 3, 3],
            ttm_hid_modes: vec![4, 4, 3],
            ttm_rank: 4,
            pad_id: 0,
            cls_id: 1,
            unk_id: 2,
        }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = tiny_cfg();
        let model = NativeModel::from_params(&cfg, &tiny_params(&cfg, 1)).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let (il, sl) = model.forward(&tokens).unwrap();
        assert_eq!(il.len(), cfg.n_intents);
        assert_eq!(sl.len(), cfg.seq_len * cfg.n_slots);
        assert!(il.iter().all(|v| v.is_finite()));
        assert!(sl.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_deterministic() {
        let cfg = tiny_cfg();
        let model = NativeModel::from_params(&cfg, &tiny_params(&cfg, 2)).unwrap();
        let tokens = vec![1, 3, 4, 5, 6, 0, 0, 0];
        assert_eq!(model.forward(&tokens).unwrap(), model.forward(&tokens).unwrap());
    }

    #[test]
    fn padding_is_inert() {
        // Changing nothing (same PAD ids) must not change logits, and
        // logits must not be NaN for an all-PAD-after-CLS input.
        let cfg = tiny_cfg();
        let model = NativeModel::from_params(&cfg, &tiny_params(&cfg, 3)).unwrap();
        let tokens = vec![1, 0, 0, 0, 0, 0, 0, 0];
        let (il, _) = model.forward(&tokens).unwrap();
        assert!(il.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predict_ranges() {
        let cfg = tiny_cfg();
        let model = NativeModel::from_params(&cfg, &tiny_params(&cfg, 4)).unwrap();
        let tokens = vec![1, 7, 8, 2, 11, 0, 0, 0];
        let (intent, slots) = model.predict(&tokens).unwrap();
        assert!(intent < cfg.n_intents);
        assert_eq!(slots.len(), cfg.seq_len);
        assert!(slots.iter().all(|&s| s < cfg.n_slots));
    }

    #[test]
    fn missing_param_is_reported() {
        let cfg = tiny_cfg();
        let mut p = tiny_params(&cfg, 5);
        p.remove("cls.intent_w");
        let err = match NativeModel::from_params(&cfg, &p) {
            Err(e) => e,
            Ok(_) => panic!("expected missing-parameter error"),
        };
        assert!(err.to_string().contains("cls.intent_w"));
    }
}
