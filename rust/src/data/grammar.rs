//! Template grammar for synthetic ATIS-like utterances.
//!
//! MIRROR CONTRACT: `python/compile/data.py` re-implements this file
//! verbatim (same template order, same word-list order, same RNG call
//! sequence).  Any change here must be mirrored there; the cross-language
//! parity test pins generated utterances on both sides.

use crate::util::rng::SplitMix64;

/// Intent label set (26 classes, ATIS-style).
pub const INTENTS: [&str; 26] = [
    "flight",
    "airfare",
    "ground_service",
    "airline",
    "abbreviation",
    "aircraft",
    "flight_time",
    "quantity",
    "distance",
    "city",
    "airport",
    "ground_fare",
    "capacity",
    "flight_no",
    "meal",
    "restriction",
    "cheapest",
    "flight+airfare",
    "airline+flight_no",
    "ground_service+ground_fare",
    "airfare+flight_time",
    "flight+airline",
    "flight_no+airline",
    "day_name",
    "period_of_day",
    "seat",
];

/// Slot types; label ids are O = 0, B-type = 1 + 2i, I-type = 2 + 2i.
pub const SLOT_TYPES: [&str; 20] = [
    "fromloc.city_name",
    "toloc.city_name",
    "depart_date.day_name",
    "depart_date.month_name",
    "depart_date.day_number",
    "depart_time.period_of_day",
    "arrive_time.period_of_day",
    "airline_name",
    "class_type",
    "meal_description",
    "flight_number",
    "aircraft_code",
    "airport_name",
    "city_name",
    "transport_type",
    "cost_relative",
    "round_trip",
    "fare_basis_code",
    "arrive_date.day_name",
    "stoploc.city_name",
];

pub const CITIES: [&str; 24] = [
    "boston",
    "denver",
    "atlanta",
    "pittsburgh",
    "baltimore",
    "dallas",
    "oakland",
    "philadelphia",
    "washington",
    "charlotte",
    "milwaukee",
    "phoenix",
    "detroit",
    "chicago",
    "memphis",
    "seattle",
    "orlando",
    "cleveland",
    "nashville",
    "miami",
    "new york",
    "san francisco",
    "los angeles",
    "salt lake city",
];

pub const AIRLINES: [&str; 10] = [
    "united airlines",
    "american airlines",
    "delta",
    "continental",
    "us air",
    "northwest",
    "lufthansa",
    "twa",
    "canadian airlines",
    "alaska airlines",
];

pub const DAYS: [&str; 7] = [
    "monday", "tuesday", "wednesday", "thursday", "friday", "saturday", "sunday",
];

pub const MONTHS: [&str; 12] = [
    "january",
    "february",
    "march",
    "april",
    "may",
    "june",
    "july",
    "august",
    "september",
    "october",
    "november",
    "december",
];

pub const DAY_NUMBERS: [&str; 12] = [
    "first", "second", "third", "fourth", "fifth", "sixth", "seventh", "eighth",
    "ninth", "tenth", "twentieth", "thirtieth",
];

pub const PERIODS: [&str; 6] = [
    "morning", "afternoon", "evening", "night", "noon", "midnight",
];

pub const CLASSES: [&str; 4] = ["first class", "coach", "business class", "economy"];

pub const MEALS: [&str; 4] = ["breakfast", "lunch", "dinner", "snack"];

pub const FLIGHT_NUMBERS: [&str; 8] = [
    "one", "two", "three", "four", "five", "six", "seven", "eight",
];

pub const AIRCRAFT: [&str; 6] = ["boeing", "airbus", "dc ten", "md eighty", "jet", "turboprop"];

pub const TRANSPORT: [&str; 4] = ["taxi", "limousine", "rental car", "bus"];

pub const COST_REL: [&str; 3] = ["cheapest", "lowest", "most expensive"];

pub const ROUND_TRIP: [&str; 2] = ["round trip", "one way"];

pub const FARE_CODES: [&str; 5] = ["q", "qw", "f", "y", "h"];

/// A placeholder in a template: which word list, which slot type
/// (usize::MAX = no slot, words labeled O).
#[derive(Debug, Clone, Copy)]
pub struct Slot {
    pub list: WordList,
    pub slot_type: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WordList {
    Cities,
    Airlines,
    Days,
    Months,
    DayNumbers,
    Periods,
    Classes,
    Meals,
    FlightNumbers,
    Aircraft,
    Transport,
    CostRel,
    RoundTrip,
    FareCodes,
}

impl WordList {
    pub fn words(&self) -> &'static [&'static str] {
        match self {
            WordList::Cities => &CITIES,
            WordList::Airlines => &AIRLINES,
            WordList::Days => &DAYS,
            WordList::Months => &MONTHS,
            WordList::DayNumbers => &DAY_NUMBERS,
            WordList::Periods => &PERIODS,
            WordList::Classes => &CLASSES,
            WordList::Meals => &MEALS,
            WordList::FlightNumbers => &FLIGHT_NUMBERS,
            WordList::Aircraft => &AIRCRAFT,
            WordList::Transport => &TRANSPORT,
            WordList::CostRel => &COST_REL,
            WordList::RoundTrip => &ROUND_TRIP,
            WordList::FareCodes => &FARE_CODES,
        }
    }
}

/// One template: intent id + mix of literal words and placeholders.
#[derive(Debug, Clone)]
pub struct Template {
    pub intent: usize,
    pub parts: Vec<Part>,
}

#[derive(Debug, Clone)]
pub enum Part {
    Lit(&'static str),
    Hole(WordList, usize), // word list + slot type index
}

macro_rules! lit {
    ($($w:expr),*) => { vec![$(Part::Lit($w)),*] };
}

/// The template bank.  ORDER MATTERS (mirrored in python).
pub fn templates() -> Vec<Template> {
    use Part::{Hole, Lit};
    use WordList::*;
    let mut t: Vec<Template> = Vec::new();
    let mut add = |intent: usize, parts: Vec<Part>| {
        t.push(Template { intent, parts });
    };
    // 0: flight
    add(0, vec![
        Lit("show"), Lit("me"), Lit("flights"), Lit("from"), Hole(Cities, 0),
        Lit("to"), Hole(Cities, 1), Lit("on"), Hole(Days, 2),
    ]);
    add(0, vec![
        Lit("i"), Lit("want"), Lit("to"), Lit("fly"), Lit("from"), Hole(Cities, 0),
        Lit("to"), Hole(Cities, 1), Lit("in"), Lit("the"), Hole(Periods, 5),
    ]);
    add(0, vec![
        Lit("list"), Lit("all"), Lit("flights"), Lit("leaving"), Hole(Cities, 0),
        Lit("arriving"), Lit("in"), Hole(Cities, 1), Lit("on"), Hole(Months, 3),
        Hole(DayNumbers, 4),
    ]);
    add(0, vec![
        Lit("are"), Lit("there"), Hole(RoundTrip, 16), Lit("flights"), Lit("between"),
        Hole(Cities, 0), Lit("and"), Hole(Cities, 1), Lit("with"), Lit("a"),
        Lit("stop"), Lit("in"), Hole(Cities, 19),
    ]);
    // 1: airfare
    add(1, vec![
        Lit("what"), Lit("is"), Lit("the"), Hole(CostRel, 15), Lit("fare"),
        Lit("from"), Hole(Cities, 0), Lit("to"), Hole(Cities, 1),
    ]);
    add(1, vec![
        Lit("how"), Lit("much"), Lit("does"), Lit("a"), Hole(Classes, 8),
        Lit("ticket"), Lit("to"), Hole(Cities, 1), Lit("cost"),
    ]);
    add(1, vec![
        Lit("show"), Lit("fare"), Lit("code"), Hole(FareCodes, 17), Lit("for"),
        Hole(Airlines, 7),
    ]);
    // 2: ground_service
    add(2, vec![
        Lit("what"), Lit("ground"), Lit("transportation"), Lit("is"),
        Lit("available"), Lit("in"), Hole(Cities, 13),
    ]);
    add(2, vec![
        Lit("is"), Lit("there"), Lit("a"), Hole(Transport, 14), Lit("service"),
        Lit("in"), Hole(Cities, 13),
    ]);
    // 3: airline
    add(3, vec![
        Lit("which"), Lit("airlines"), Lit("fly"), Lit("from"), Hole(Cities, 0),
        Lit("to"), Hole(Cities, 1),
    ]);
    add(3, vec![
        Lit("tell"), Lit("me"), Lit("about"), Hole(Airlines, 7),
    ]);
    // 4: abbreviation
    add(4, vec![
        Lit("what"), Lit("does"), Lit("fare"), Lit("code"), Hole(FareCodes, 17),
        Lit("mean"),
    ]);
    // 5: aircraft
    add(5, vec![
        Lit("what"), Lit("type"), Lit("of"), Lit("aircraft"), Lit("is"),
        Lit("used"), Lit("flying"), Lit("from"), Hole(Cities, 0), Lit("to"),
        Hole(Cities, 1),
    ]);
    add(5, vec![
        Lit("show"), Lit("me"), Lit("all"), Hole(Aircraft, 11), Lit("flights"),
    ]);
    // 6: flight_time
    add(6, vec![
        Lit("what"), Lit("are"), Lit("the"), Lit("departure"), Lit("times"),
        Lit("from"), Hole(Cities, 0), Lit("to"), Hole(Cities, 1), Lit("in"),
        Lit("the"), Hole(Periods, 5),
    ]);
    // 7: quantity
    add(7, vec![
        Lit("how"), Lit("many"), Hole(Airlines, 7), Lit("flights"), Lit("leave"),
        Hole(Cities, 0), Lit("each"), Hole(Days, 2),
    ]);
    // 8: distance
    add(8, vec![
        Lit("how"), Lit("far"), Lit("is"), Lit("the"), Lit("airport"), Lit("from"),
        Lit("downtown"), Hole(Cities, 13),
    ]);
    // 9: city
    add(9, vec![
        Lit("what"), Lit("city"), Lit("is"), Lit("served"), Lit("by"),
        Hole(Airlines, 7),
    ]);
    // 10: airport
    add(10, vec![
        Lit("which"), Lit("airports"), Lit("are"), Lit("near"), Hole(Cities, 13),
    ]);
    // 11: ground_fare
    add(11, vec![
        Lit("how"), Lit("much"), Lit("is"), Lit("a"), Hole(Transport, 14),
        Lit("in"), Hole(Cities, 13),
    ]);
    // 12: capacity
    add(12, vec![
        Lit("how"), Lit("many"), Lit("passengers"), Lit("fit"), Lit("on"),
        Lit("a"), Hole(Aircraft, 11),
    ]);
    // 13: flight_no
    add(13, vec![
        Lit("what"), Lit("is"), Lit("the"), Lit("flight"), Lit("number"),
        Lit("from"), Hole(Cities, 0), Lit("to"), Hole(Cities, 1), Lit("on"),
        Hole(Airlines, 7),
    ]);
    // 14: meal
    add(14, vec![
        Lit("is"), Hole(Meals, 9), Lit("served"), Lit("on"), Lit("flight"),
        Hole(FlightNumbers, 10),
    ]);
    // 15: restriction
    add(15, vec![
        Lit("what"), Lit("restrictions"), Lit("apply"), Lit("to"), Lit("the"),
        Hole(CostRel, 15), Lit("fare"),
    ]);
    // 16: cheapest
    add(16, vec![
        Lit("show"), Lit("the"), Hole(CostRel, 15), Hole(RoundTrip, 16),
        Lit("ticket"), Lit("from"), Hole(Cities, 0), Lit("to"), Hole(Cities, 1),
    ]);
    // 17: flight+airfare
    add(17, vec![
        Lit("show"), Lit("flights"), Lit("and"), Lit("fares"), Lit("from"),
        Hole(Cities, 0), Lit("to"), Hole(Cities, 1), Lit("on"), Hole(Days, 2),
    ]);
    // 18: airline+flight_no
    add(18, vec![
        Lit("which"), Lit("airline"), Lit("operates"), Lit("flight"),
        Hole(FlightNumbers, 10),
    ]);
    // 19: ground_service+ground_fare
    add(19, vec![
        Lit("what"), Lit("is"), Lit("the"), Lit("cost"), Lit("of"), Lit("a"),
        Hole(Transport, 14), Lit("from"), Lit("the"), Lit("airport"), Lit("in"),
        Hole(Cities, 13),
    ]);
    // 20: airfare+flight_time
    add(20, vec![
        Lit("give"), Lit("me"), Lit("the"), Lit("fares"), Lit("and"),
        Lit("times"), Lit("for"), Lit("flights"), Lit("from"), Hole(Cities, 0),
        Lit("to"), Hole(Cities, 1), Lit("on"), Hole(Days, 2), Hole(Periods, 5),
    ]);
    // 21: flight+airline
    add(21, vec![
        Lit("list"), Hole(Airlines, 7), Lit("flights"), Lit("from"),
        Hole(Cities, 0), Lit("to"), Hole(Cities, 1), Lit("arriving"),
        Hole(Days, 18),
    ]);
    // 22: flight_no+airline
    add(22, vec![
        Lit("flight"), Lit("number"), Lit("and"), Lit("carrier"), Lit("from"),
        Hole(Cities, 0), Lit("to"), Hole(Cities, 1), Lit("please"),
    ]);
    // 23: day_name
    add(23, vec![
        Lit("what"), Lit("day"), Lit("does"), Lit("flight"),
        Hole(FlightNumbers, 10), Lit("leave"),
    ]);
    // 24: period_of_day
    add(24, vec![
        Lit("do"), Lit("you"), Lit("have"), Lit("anything"), Lit("in"),
        Lit("the"), Hole(Periods, 5), Lit("to"), Hole(Cities, 1),
    ]);
    // 25: seat
    add(25, vec![
        Lit("i"), Lit("need"), Lit("a"), Hole(Classes, 8), Lit("seat"),
        Lit("to"), Hole(Cities, 1), Lit("on"), Hole(Months, 3),
        Hole(DayNumbers, 4),
    ]);
    // A couple of extra high-frequency flight templates (class balance
    // roughly mimics ATIS, where `flight` dominates).
    add(0, lit!["flights", "please"]
        .into_iter()
        .chain(vec![Lit("from"), Hole(Cities, 0), Lit("to"), Hole(Cities, 1)])
        .collect());
    add(0, vec![
        Hole(Airlines, 7), Lit("from"), Hole(Cities, 0), Lit("to"),
        Hole(Cities, 1), Lit("on"), Hole(Days, 2), Hole(Periods, 5),
    ]);
    t
}

/// One generated utterance: words + intent + per-word slot labels.
#[derive(Debug, Clone, PartialEq)]
pub struct Utterance {
    pub words: Vec<String>,
    pub intent: usize,
    /// Slot label id per word (O = 0, B = 1+2t, I = 2+2t).
    pub labels: Vec<usize>,
}

/// Seeded utterance generator.
pub struct Generator {
    rng: SplitMix64,
    templates: Vec<Template>,
}

impl Generator {
    pub fn new(seed: u64) -> Generator {
        Generator { rng: SplitMix64::new(seed), templates: templates() }
    }

    /// Draw the next utterance.  RNG call order: template index, then one
    /// draw per hole, in template order (mirror contract).
    pub fn utterance(&mut self) -> Utterance {
        let ti = self.rng.below(self.templates.len() as u64) as usize;
        let tpl = self.templates[ti].clone();
        let mut words = Vec::new();
        let mut labels = Vec::new();
        for part in &tpl.parts {
            match part {
                Part::Lit(w) => {
                    words.push((*w).to_string());
                    labels.push(0);
                }
                Part::Hole(list, slot_type) => {
                    let choices = list.words();
                    let pick = choices[self.rng.below(choices.len() as u64) as usize];
                    for (wi, w) in pick.split(' ').enumerate() {
                        words.push(w.to_string());
                        labels.push(if wi == 0 { 1 + 2 * slot_type } else { 2 + 2 * slot_type });
                    }
                }
            }
        }
        Utterance { words, intent: tpl.intent, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_intents_have_templates() {
        let t = templates();
        let covered: std::collections::BTreeSet<usize> = t.iter().map(|x| x.intent).collect();
        assert_eq!(covered.len(), INTENTS.len(), "every intent needs a template");
    }

    #[test]
    fn slot_ids_in_range() {
        let mut g = Generator::new(99);
        for _ in 0..500 {
            let u = g.utterance();
            assert_eq!(u.words.len(), u.labels.len());
            for &l in &u.labels {
                assert!(l < 1 + 2 * SLOT_TYPES.len());
            }
            assert!(u.intent < INTENTS.len());
        }
    }

    #[test]
    fn bio_consistency() {
        // An I- label must follow a B- or I- of the same type.
        let mut g = Generator::new(100);
        for _ in 0..500 {
            let u = g.utterance();
            for i in 0..u.labels.len() {
                let l = u.labels[i];
                if l != 0 && l % 2 == 0 {
                    // I-label
                    let prev = u.labels[i - 1];
                    assert!(prev == l - 1 || prev == l, "dangling I- in {:?}", u.words);
                }
            }
        }
    }

    #[test]
    fn utterances_fit_paper_seq_len() {
        let mut g = Generator::new(101);
        for _ in 0..1000 {
            let u = g.utterance();
            assert!(u.words.len() + 1 <= 32, "too long: {:?}", u.words);
        }
    }

    #[test]
    fn pinned_first_utterance_seed42() {
        // Mirror contract with python/compile/data.py (test_data_parity).
        let mut g = Generator::new(42);
        let u = g.utterance();
        let joined = u.words.join(" ");
        let expected_ti = {
            let mut r = SplitMix64::new(42);
            r.below(templates().len() as u64) as usize
        };
        assert_eq!(u.intent, templates()[expected_ti].intent);
        assert!(!joined.is_empty());
    }
}
