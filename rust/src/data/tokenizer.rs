//! Deterministic vocabulary + encoder for the synthetic ATIS grammar.
//!
//! The vocabulary is derived from the grammar's word lists and template
//! literals in a fixed order (mirror contract with
//! `python/compile/data.py`): ids 0/1/2 are PAD/CLS/UNK, the rest are the
//! grammar words sorted lexicographically, capped at [`VOCAB_CAP`].

use super::grammar::{templates, Part, Utterance, WordList};
use crate::config::ModelConfig;
use std::collections::BTreeMap;

/// Paper Table II embedding rows (vocab size 1000).
pub const VOCAB_CAP: usize = 1000;

/// Word -> id mapping.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub word_to_id: BTreeMap<String, i32>,
    pub pad_id: i32,
    pub cls_id: i32,
    pub unk_id: i32,
}

impl Tokenizer {
    /// Build the canonical vocabulary from the grammar.
    pub fn build(cfg: &ModelConfig) -> Tokenizer {
        let mut words: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for tpl in templates() {
            for part in &tpl.parts {
                match part {
                    Part::Lit(w) => {
                        words.insert((*w).to_string());
                    }
                    Part::Hole(list, _) => {
                        for w in list_words(*list) {
                            for piece in w.split(' ') {
                                words.insert(piece.to_string());
                            }
                        }
                    }
                }
            }
        }
        let mut word_to_id = BTreeMap::new();
        let mut next = 3i32; // after PAD/CLS/UNK
        for w in words {
            if (next as usize) >= cfg.vocab.min(VOCAB_CAP) {
                break;
            }
            word_to_id.insert(w, next);
            next += 1;
        }
        Tokenizer {
            word_to_id,
            pad_id: cfg.pad_id,
            cls_id: cfg.cls_id,
            unk_id: cfg.unk_id,
        }
    }

    pub fn vocab_used(&self) -> usize {
        self.word_to_id.len() + 3
    }

    pub fn id(&self, word: &str) -> i32 {
        *self.word_to_id.get(word).unwrap_or(&self.unk_id)
    }

    /// Encode an utterance to fixed-length (CLS + words, PAD-filled);
    /// CLS and PAD carry the O slot label (0).
    pub fn encode(&self, utt: &Utterance, cfg: &ModelConfig) -> super::Example {
        let mut tokens = vec![self.pad_id; cfg.seq_len];
        let mut slots = vec![0i32; cfg.seq_len];
        tokens[0] = self.cls_id;
        for (i, (w, &l)) in utt.words.iter().zip(&utt.labels).enumerate() {
            let pos = i + 1;
            if pos >= cfg.seq_len {
                break;
            }
            tokens[pos] = self.id(w);
            slots[pos] = l as i32;
        }
        super::Example { tokens, intent: utt.intent as i32, slots }
    }
}

fn list_words(list: WordList) -> &'static [&'static str] {
    list.words()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::grammar::Generator;

    #[test]
    fn vocab_fits_paper_table() {
        let t = Tokenizer::build(&ModelConfig::paper(2));
        assert!(t.vocab_used() <= VOCAB_CAP, "vocab {} > 1000", t.vocab_used());
        assert!(t.vocab_used() > 100, "vocab suspiciously small");
    }

    #[test]
    fn no_unk_for_grammar_words() {
        let cfg = ModelConfig::paper(2);
        let t = Tokenizer::build(&cfg);
        let mut g = Generator::new(5);
        for _ in 0..300 {
            let u = g.utterance();
            for w in &u.words {
                assert_ne!(t.id(w), t.unk_id, "grammar word '{w}' not in vocab");
            }
        }
    }

    #[test]
    fn encode_shapes_and_alignment() {
        let cfg = ModelConfig::paper(2);
        let t = Tokenizer::build(&cfg);
        let mut g = Generator::new(6);
        let u = g.utterance();
        let ex = t.encode(&u, &cfg);
        assert_eq!(ex.tokens[0], cfg.cls_id);
        assert_eq!(ex.slots[0], 0);
        for (i, w) in u.words.iter().enumerate().take(cfg.seq_len - 1) {
            assert_eq!(ex.tokens[i + 1], t.id(w));
            assert_eq!(ex.slots[i + 1], u.labels[i] as i32);
        }
    }

    #[test]
    fn ids_are_stable() {
        let cfg = ModelConfig::paper(2);
        let a = Tokenizer::build(&cfg);
        let b = Tokenizer::build(&cfg);
        assert_eq!(a.word_to_id, b.word_to_id);
    }
}
