//! Synthetic ATIS-like data substrate.
//!
//! The real ATIS corpus (LDC93S4B) is license-gated, so the library ships
//! a seeded template-grammar generator that mimics its structure: airline
//! flight-booking utterances with joint **intent classification** (26
//! classes) and **slot filling** (BIO labels over ~20 slot types, padded
//! to the paper's 129-label head).  The generator is deterministic
//! (SplitMix64) and mirrored in `python/compile/data.py`; the parity test
//! pins the first utterances on both sides.

pub mod grammar;
pub mod tokenizer;

pub use grammar::{Generator, Utterance, INTENTS, SLOT_TYPES};
pub use tokenizer::{Tokenizer, VOCAB_CAP};

use crate::config::ModelConfig;

/// One encoded training example, fixed-length per the model config.
#[derive(Debug, Clone)]
pub struct Example {
    /// Token ids, `[CLS]` first, PAD-filled to seq_len.
    pub tokens: Vec<i32>,
    /// Intent class id.
    pub intent: i32,
    /// Slot label ids aligned with `tokens` (O at CLS, O at PAD —
    /// PAD positions are masked by the loss).
    pub slots: Vec<i32>,
}

/// An encoded dataset split.
#[derive(Debug)]
pub struct Dataset {
    pub examples: Vec<Example>,
    pub tokenizer: Tokenizer,
}

impl Dataset {
    /// Generate `n` utterances with the seeded grammar and encode them.
    pub fn synth(cfg: &ModelConfig, seed: u64, n: usize) -> Dataset {
        let tokenizer = Tokenizer::build(cfg);
        let mut gen = Generator::new(seed);
        let examples = (0..n)
            .map(|_| {
                let utt = gen.utterance();
                tokenizer.encode(&utt, cfg)
            })
            .collect();
        Dataset { examples, tokenizer }
    }

    /// The paper's train/test sizes (ATIS: 4478 train / 893 test).
    pub fn paper_splits(cfg: &ModelConfig, seed: u64) -> (Dataset, Dataset) {
        let train = Dataset::synth(cfg, seed, 4478);
        let test = Dataset::synth(cfg, seed.wrapping_add(0xA71_5), 893);
        (train, test)
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::paper(2)
    }

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synth(&cfg(), 7, 10);
        let b = Dataset::synth(&cfg(), 7, 10);
        for (x, y) in a.examples.iter().zip(&b.examples) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.intent, y.intent);
            assert_eq!(x.slots, y.slots);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = Dataset::synth(&cfg(), 7, 50);
        let b = Dataset::synth(&cfg(), 8, 50);
        assert!(a.examples.iter().zip(&b.examples).any(|(x, y)| x.tokens != y.tokens));
    }

    #[test]
    fn examples_well_formed() {
        let cfg = cfg();
        let d = Dataset::synth(&cfg, 3, 200);
        for ex in &d.examples {
            assert_eq!(ex.tokens.len(), cfg.seq_len);
            assert_eq!(ex.slots.len(), cfg.seq_len);
            assert_eq!(ex.tokens[0], cfg.cls_id);
            assert!((0..cfg.n_intents as i32).contains(&ex.intent));
            for (&t, &s) in ex.tokens.iter().zip(&ex.slots) {
                assert!((0..cfg.vocab as i32).contains(&t));
                assert!((0..cfg.n_slots as i32).contains(&s));
                if t == cfg.pad_id {
                    assert_eq!(s, 0, "PAD must carry O label");
                }
            }
        }
    }

    #[test]
    fn covers_many_intents() {
        let d = Dataset::synth(&cfg(), 5, 500);
        let mut seen = std::collections::BTreeSet::new();
        for ex in &d.examples {
            seen.insert(ex.intent);
        }
        assert!(seen.len() >= 10, "only {} intents exercised", seen.len());
    }

    #[test]
    fn paper_split_sizes() {
        let (train, test) = Dataset::paper_splits(&cfg(), 1);
        assert_eq!(train.len(), 4478);
        assert_eq!(test.len(), 893);
    }
}
