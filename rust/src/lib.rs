//! # tt-trainer
//!
//! Rust coordinator for **tensor-compressed transformer training**, a
//! reproduction of *"Ultra Memory-Efficient On-FPGA Training of
//! Transformers via Tensor-Compressed Optimization"* (Tian et al., 2025).
//!
//! The stack has three layers:
//!
//! * **L1 (Pallas, python, build-time)** — the bidirectional tensor-train
//!   (BTT) contraction kernels (`python/compile/kernels/`).
//! * **L2 (JAX, python, build-time)** — the tensorized transformer
//!   forward/backward and the fused SGD train step, AOT-lowered to HLO
//!   text (`make artifacts`).
//! * **L3 (this crate, run-time)** — loads the HLO artifacts via PJRT
//!   ([`runtime`]), owns the training loop ([`coordinator`]), the
//!   synthetic ATIS data substrate ([`data`]), the TT/TTM tensor algebra
//!   ([`tensor`]), the paper's analytic cost model ([`costmodel`]) and
//!   the FPGA accelerator simulator ([`fpga`]) that regenerates the
//!   paper's hardware tables and figures.
//!
//! Python never runs on the training path: after `make artifacts` the
//! binary is self-contained.

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod fpga;
pub mod inference;
pub mod runtime;
pub mod tensor;
pub mod util;
