//! # tt-trainer
//!
//! Rust coordinator for **tensor-compressed transformer training**, a
//! reproduction of *"Ultra Memory-Efficient On-FPGA Training of
//! Transformers via Tensor-Compressed Optimization"* (Tian et al., 2025).
//!
//! The stack has three layers:
//!
//! * **L1 (Pallas, python, build-time)** — the bidirectional tensor-train
//!   (BTT) contraction kernels (`python/compile/kernels/`).
//! * **L2 (JAX, python, build-time)** — the tensorized transformer
//!   forward/backward and the fused SGD train step, AOT-lowered to HLO
//!   text (`make artifacts`).
//! * **L3 (this crate, run-time)** — owns the training loop
//!   ([`coordinator`]), the synthetic ATIS data substrate ([`data`]),
//!   the TT/TTM tensor algebra ([`tensor`]), the paper's analytic cost
//!   model ([`costmodel`]) and the FPGA accelerator simulator ([`fpga`])
//!   that regenerates the paper's hardware tables and figures.
//!
//! ## Training backends
//!
//! The coordinator drives any [`coordinator::TrainBackend`]:
//!
//! * [`runtime::Engine`] (**`pjrt` feature**) executes the fused
//!   FP/BP/PU HLO artifact via PJRT — the L1/L2 build products.
//! * [`train::NativeTrainer`] (**default**) trains entirely in rust:
//!   hand-derived backward through the BTT contraction (gradients of
//!   the TT cores via the merged Z1/Z3 chain states), attention /
//!   LayerNorm / GELU VJPs, the joint intent+slot cross-entropy, and a
//!   pluggable parameter update — no XLA, no Python, no artifacts.
//!   Backward FLOPs/memory carry the same [`tensor::ContractionStats`]
//!   instrumentation as the forward engines and validate against the
//!   cost model's Eqs. 18-21 ([`costmodel::LinearShape::btt_bwd_muls`]).
//!
//! ## The PU stage
//!
//! The paper's parameter-update stage keeps gradients *and* optimizer
//! state on chip in the same compressed TT/TTM-core layout as the
//! weights — the [`optim`] subsystem reproduces that: an
//! [`optim::Optimizer`] trait with SGD / momentum / Adam / AdamW rules
//! whose per-parameter state buffers mirror the core shapes exactly
//! (0x / 1x / 2x the compressed parameter count), a mini-batch path
//! where the contraction K dimension carries `B * S` tokens, and an
//! [`optim::StateFootprint`] report that [`costmodel`] and
//! [`fpga::resources`] charge against the U50 BRAM/URAM budget right
//! next to the cores and the Eq. 21 activation caches.
//!
//! After `make artifacts` the binary is self-contained with either
//! backend; with the native backend it is self-contained from a bare
//! `cargo build` — the paper's end-to-end on-device training claim is
//! reproducible without a Python/XLA toolchain anywhere.

// The tensor kernels and backward passes are index arithmetic by
// nature; explicit indices document the contraction layouts better than
// iterator chains would.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::type_complexity)]

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod fpga;
pub mod inference;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
