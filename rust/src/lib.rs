//! # tt-trainer
//!
//! Rust coordinator for **tensor-compressed transformer training**, a
//! reproduction of *"Ultra Memory-Efficient On-FPGA Training of
//! Transformers via Tensor-Compressed Optimization"* (Tian et al., 2025).
//!
//! The stack has three layers:
//!
//! * **L1 (Pallas, python, build-time)** — the bidirectional tensor-train
//!   (BTT) contraction kernels (`python/compile/kernels/`).
//! * **L2 (JAX, python, build-time)** — the tensorized transformer
//!   forward/backward and the fused SGD train step, AOT-lowered to HLO
//!   text (`make artifacts`).
//! * **L3 (this crate, run-time)** — owns the training loop
//!   ([`coordinator`]), the synthetic ATIS data substrate ([`data`]),
//!   the TT/TTM tensor algebra ([`tensor`]), the paper's analytic cost
//!   model ([`costmodel`]) and the FPGA accelerator simulator ([`fpga`])
//!   that regenerates the paper's hardware tables and figures.
//!
//! ## Training backends
//!
//! The coordinator drives any [`coordinator::TrainBackend`]:
//!
//! * [`runtime::Engine`] (**`pjrt` feature**) executes the fused
//!   FP/BP/PU HLO artifact via PJRT — the L1/L2 build products.
//! * [`train::NativeTrainer`] (**default**) trains entirely in rust:
//!   hand-derived backward through the BTT contraction (gradients of
//!   the TT cores via the merged Z1/Z3 chain states), attention /
//!   LayerNorm / GELU VJPs, the joint intent+slot cross-entropy, and a
//!   pluggable parameter update — no XLA, no Python, no artifacts.
//!   Backward FLOPs/memory carry the same [`tensor::ContractionStats`]
//!   instrumentation as the forward engines and validate against the
//!   cost model's Eqs. 18-21 ([`costmodel::LinearShape::btt_bwd_muls`]).
//!
//! ## The PU stage
//!
//! The paper's parameter-update stage keeps gradients *and* optimizer
//! state on chip in the same compressed TT/TTM-core layout as the
//! weights — the [`optim`] subsystem reproduces that: an
//! [`optim::Optimizer`] trait with SGD / momentum / Adam / AdamW rules
//! whose per-parameter state buffers mirror the core shapes exactly
//! (0x / 1x / 2x the compressed parameter count), a mini-batch path
//! where the contraction K dimension carries `B * S` tokens, and an
//! [`optim::StateFootprint`] report that [`costmodel`] and
//! [`fpga::resources`] charge against the U50 BRAM/URAM budget right
//! next to the cores and the Eq. 21 activation caches.
//!
//! ## Compute schedule & performance
//!
//! The native training hot path executes the paper's scheduling tricks
//! rather than only modeling them ([`fpga::schedule`] keeps the
//! analytic Fig. 9/10 counterparts, now linked to the executed path):
//!
//! * **Fused QKV** — Q/K/V projections share their input-side TT cores
//!   (tied at init, kept in lockstep by the PU stage), so one right
//!   merge and one `Z2 = X Z1^T` serve all three projections in both
//!   forward and backward ([`train::forward_qkv_fused`]).  Contraction
//!   multiplies drop from `3 (L + R + K r_d (M + N))` to
//!   `3L + R + K r_d (3M + N)`
//!   ([`costmodel::LinearShape::btt_fwd_qkv_muls`], the Fig. 9
//!   companion of Eq. 20; `btt_qkv_memory` is the Eq. 21 analog), about
//!   a third of the QKV forward work at the Table II shape.  **Note
//!   this is a weight-tying parameterization change**, not only a
//!   schedule change: the paper's Fig. 9 shares kernel units across
//!   independent Q/K/V weights, whereas the executed fusion ties the
//!   input-side cores (slightly lower capacity, additionally fewer
//!   parameters and 1x optimizer state for the tied cores).  Untied
//!   checkpoints — including PJRT-exported ones — keep the paper's
//!   independent parameterization and automatically fall back to
//!   separate forwards per layer, and
//!   `train::NativeTrainModel::random_init_untied` initializes a fresh
//!   model in that parameterization (same RNG stream as the tied
//!   init), so loss trajectories stay comparable to independent-QKV
//!   baselines when that is what an experiment needs.
//! * **Batched attention** — the whole `(B, heads, S, S)` score block
//!   runs in three `bmm*` launches on the persistent worker pool
//!   ([`tensor::ops::multi_head_attention_batched`]); the pad mask is
//!   an additive `-inf` bias, so pad columns never branch yet get
//!   exact-zero probability and gradient.  No per-example sub-tensors
//!   are materialized — head packing slices the K-stacked projection
//!   buffers by offset.
//! * **Fused elementwise lanes** — with
//!   [`engine::ComputePath::fused_elementwise`] (on by default) the
//!   bias-add, residual-add, LayerNorm and GELU surrounding each
//!   TT-apply run inside the apply's output loop instead of as
//!   separate whole-tensor passes: the TT linears produce bias-free
//!   raw outputs (`forward_ckpt_raw` / `MergedLinear::apply_raw`) and
//!   [`train::blocks::bias_residual_layer_norm_fwd`],
//!   [`tensor::ops::bias_gelu`] and the two-summand VJP
//!   [`train::blocks::layer_norm_vjp2`] consume them element-by-element,
//!   so the `bias+residual` / `bias+preactivation` intermediates and
//!   the materialized `dY_a + dY_b` gradient sum never round-trip
//!   through memory.  The fused lanes execute the *identical* scalar
//!   order as the unfused chain (and share one
//!   [`tensor::ops::gelu_scalar`] / [`tensor::ops::gelu_grad_scalar`]
//!   definition with it), and the elementwise chain stays pure f32 at
//!   every storage precision, so fused-vs-unfused outputs, gradients
//!   and whole Adam trajectories are **bitwise identical at every
//!   `Precision`** (pinned in `train::model` tests).
//! * **SIMD microkernels** — the innermost matmul/bmm loops are
//!   fixed-width register-blocked tiles (`chunks_exact`, unrolled
//!   accumulators) the autovectorizer lifts to packed FMAs, with a
//!   fixed accumulation order that keeps the documented
//!   bitwise-deterministic band split ([`tensor::dense`]).
//! * **Memoized TTM lookups** — embedding chains are contracted once
//!   per unique token id per batch (pad tokens dominate ATIS rows) in
//!   both forward and VJP.
//!
//! `cargo bench --offline -- native-train` measures the fused/batched
//! path against the pre-fusion looped schedule in the same run and
//! records both in `BENCH_native_train.json` (uploaded as a CI
//! artifact).  `cargo bench --offline -- matrix` (and the
//! `bench-matrix` CLI command) runs the full [`benchgrid`] —
//! {f32, bf16, f16, int8} x {fused, looped} x {cache, recompute} —
//! recording per-cell tokens/sec, the traced FP/BP/PU stage split and
//! the measured at-rest bytes into `BENCH_matrix.json`; CI gates on the
//! fused-bf16 cell staying faster than the unfused-f32 baseline and on
//! the int8 cell's `param_bytes` staying at or below 0.27x f32.
//!
//! ## Precision
//!
//! The native trainer runs a **mixed-precision storage path**
//! ([`tensor::Precision`]: `f32` / `bf16` / `f16` / `int8`; CLI
//! `--precision bf16`) in the spirit of the paper's low-precision
//! predecessor (arXiv:2104.03420): storage happens at the selected
//! width, compute always accumulates in f32.
//!
//! * **Storage width** — everything at rest lives *physically* at the
//!   selected width, `u16`-packed under bf16/f16: the TT-linear Eq. 21
//!   activation caches ([`train::TTLinear::forward_prec`], via
//!   [`tensor::PackedTensor`]), the optimizer moments
//!   ([`tensor::PackedVec`]), and — since the packed-parameter
//!   tentpole — the parameters themselves: TT cores in
//!   [`tensor::PackedTTMatrix`] (TT linears, fused QKV), TTM embedding
//!   cores and the positional/head tensors in [`tensor::PackedTensor`],
//!   LayerNorm vectors and biases in [`tensor::PackedVec`], and the
//!   merged Z1/Z3 inference factors inside `engine::MergedLinear`.
//!   Packing is **lossless** because every store site rounds on store
//!   (chain states before each next fold, cores by the PU stage and
//!   once on entry by `NativeTrainModel::set_precision`, merged factors
//!   by the merge chains), so the at-rest value is always exactly
//!   representable and `pack(widen(x)) == x` bitwise.
//!   `NativeTrainModel::param_bytes` / `NativeEngine::param_bytes` sum
//!   the *measured* packed buffers (the `param_bytes` trace gauge
//!   samples the same sum), pinned exactly half the f32 figure in
//!   `rust/tests/packed_params.rs`; the width-parameterized accounting
//!   ([`fpga::resources::report_with_optim_prec`], `fpga::bram::*_at`)
//!   charges the same 16 bits into the U50 budget.
//! * **Block-scaled int8** — `Precision::Int8` drops the at-rest width
//!   to **1 byte/element plus one f32 scale per 64-element block**
//!   ([`tensor::ScaledBlockVec`] / [`tensor::ScaledBlockTensor`],
//!   `tensor::INT8_BLOCK`): ~0.266x the f32 bytes for parameters,
//!   Eq. 21 caches and optimizer moments alike (the `fpga` report and
//!   `costmodel` formulas charge the scale sidecar explicitly).  The
//!   per-block scale is `amax / 127` snapped to bf16 precision, so
//!   every `code * scale` product is exact in f32 and
//!   dequantize-requantize is a bitwise fixed point — the same
//!   round-on-store contract as the 16-bit formats, with the rounding
//!   unit being the 64-element block instead of the scalar.  Block
//!   boundaries are fixed (element index / 64 over the flat buffer), so
//!   quantization is deterministic and thread-count independent; note
//!   that because the block — not the scalar — is the rounding unit, an
//!   activation row's stored bits depend on its whole `(K, ·)` buffer,
//!   so int8 bitwise contracts hold per identical batch shape (the
//!   per-request batch-invariance the serving suite pins for f32/bf16
//!   is deliberately not an int8 contract).  Under
//!   int8 the Adam-family second moment is stored in the **sqrt
//!   domain** (`optim::moment2_sqrt_domain`), which keeps a block's
//!   numerator and denominator flushing to zero together instead of
//!   leaving a live numerator over a flushed denominator.
//! * **Accumulation width** — every contraction widens on load (exact
//!   for both 16-bit formats and for int8 codes times bf16-snapped
//!   scales) and runs the unchanged f32 microkernels
//!   ([`tensor::dense`]); results round to the storage width only on
//!   store, with **round-to-nearest-even** ([`tensor::precision`]).
//! * **Loss scaling / overflow guard** — f16's narrow exponent (and
//!   int8's narrow code range) can overflow a bad batch into inf/NaN
//!   gradients.  Every PU stage below f32 is guarded
//!   ([`train::NativeTrainModel::apply_grads_guarded`], also on the
//!   replica lead): a non-finite loss or gradient skips the step
//!   (parameters and moments untouched), backs off the dynamic
//!   [`optim::LossScaler`] (power-of-two halving, doubling after 2000
//!   good steps), and counts `train_steps_skipped_nonfinite` in the
//!   trace.  With f32 gradient accumulation the power-of-two
//!   multiply/divide pair is a bitwise identity, so the scaler drives
//!   the detect-skip-backoff protocol rather than an actual rescale —
//!   finite steps stay bitwise unchanged.
//! * **Determinism contract** — the conversions are pure integer bit
//!   manipulation, so the kernels' bitwise-deterministic band split
//!   becomes a per-precision guarantee: same inputs + same precision =
//!   same bits, any thread count.  `Precision::F32` is bitwise the
//!   legacy full-precision path.
//! * **Checkpointing** — optimizer moments (and the Adam step count)
//!   serialize into the npy checkpoint set as name-verified
//!   `optim.state.*` entries, and the loss-scaler state rides along as
//!   `optim.loss_scale` once it moves off its default, so `--optimizer
//!   adam` training resumes exactly — including the overflow-guard
//!   posture; parameter-only checkpoints (e.g. PJRT exports) still load
//!   and start the PU state fresh.
//!
//! The `rust/tests/precision_parity.rs` suite bounds the bf16 loss
//! trajectory against f32 over 24 native training steps and
//! finite-difference-checks gradients through the rounding round-trip;
//! `BENCH_native_train.json` records fp32-vs-bf16 steps/sec, tokens/sec
//! and on-chip bytes (`bf16_vs_f32_speedup_b8` summary).
//!
//! ## Memory vs recompute (gradient checkpointing)
//!
//! The Eq. 21 activation caches carry a second memory axis besides
//! precision: a gradient-checkpointing policy
//! ([`train::CheckpointPolicy`]: `CacheAll` / `Recompute` /
//! `PerLayer(..)`; CLI `--checkpoint cache|recompute`).
//!
//! * **Policy semantics** — under `Recompute`, every TT linear (and
//!   the TTM embedding chain) stores only its *input*; the merge-chain
//!   states and `Z2` are dropped after the forward and rebuilt by the
//!   BP stage immediately before the gradient unroll
//!   ([`train::TTLinear::forward_ckpt`] /
//!   [`train::forward_qkv_fused_ckpt`]).  The at-rest Eq. 21 cache of
//!   a recomputed layer is **zero bytes**; the rebuild costs
//!   [`costmodel::LinearShape::btt_recompute_muls`] extra multiplies
//!   (one forward minus the output apply — a fully recomputed layer
//!   trains at under 4x forward multiplies instead of the cached 3x).
//!   `PerLayer` picks the mode per encoder block for intermediate
//!   points on the memory/FLOP curve.
//! * **Determinism contract** — the rebuilt states go through the
//!   *identical* deterministic fold order
//!   (`TTMatrix::merge_{left,right}_chain_prec`) and the identical
//!   round-on-store precision as the cached ones, from the same stored
//!   input and the same (not-yet-updated) cores, so recompute-vs-cached
//!   gradients are **bitwise identical at f32** and reproduce the
//!   rounded cached states exactly at bf16/f16.  Whole Adam
//!   trajectories are bitwise policy-independent at f32
//!   (`rust/tests/checkpointing.rs`).
//! * **Accounting** — `TTLinearCache::stored_bytes` /
//!   `QkvFusedCache::stored_bytes` are the single source of truth: the
//!   U50 report's [`fpga::resources::ResourceReport::eq21_cache_bytes`]
//!   is property-tested equal to the summed live caches
//!   ([`train::NativeTrainModel::measure_eq21_cache_bytes`]) on the
//!   default fused-QKV schedule, the one the report models (an
//!   untied/looped model stores three separate QKV caches per layer
//!   and measures higher).  The report charges the at-rest cache into
//!   the URAM BP stash per policy, so recompute's saving is real
//!   block-level demand, not a side annotation.  At the
//!   paper shape the report's at-rest Eq. 21 cache is ~0.93 MB (2-ENC)
//!   to ~2.6 MB (6-ENC) at f32 — halved by bf16, and eliminated by
//!   `Recompute`, which frees ~70 URAM blocks of BP-stash demand at
//!   6-ENC/f32 in the U50 model (asserted in `fpga::resources` tests).
//!   bf16 storage x recompute composes freely — the paper's full
//!   memory story.
//! * **Resume** — the policy is a trainer setting, not checkpoint
//!   state: it is applied before `--init-ckpt` loads, survives
//!   `load_checkpoint`, and composes with `--optimizer adam` resume; a
//!   checkpoint written under either policy resumes bitwise under the
//!   other at f32.
//!
//! ## Serving
//!
//! The batched `(B, S)` forward is deduplicated into a shared
//! inference-capable engine ([`engine::NativeEngine`]) consumed by
//! training evaluation ([`train::NativeTrainModel::eval`], pinned
//! bitwise equal), the historical deployment name
//! ([`inference::NativeModel`], now an alias) and a
//! continuous-batching serving layer ([`serve`]):
//!
//! * **Scheduler semantics** — one executor thread over per-bucket
//!   FIFO queues; a bucket fires when it reaches
//!   [`serve::ServeConfig::max_batch`] requests or its oldest request
//!   has waited [`serve::ServeConfig::max_wait`]; among ready buckets
//!   the oldest head wins, and shutdown drains everything queued.
//! * **Bucketing policy** — trailing pads are trimmed and the
//!   effective length rounds up to the next multiple of
//!   [`serve::ServeConfig::bucket`] (capped at `seq_len`); a bucket's
//!   requests pad to that length and run as one dense `(B, S')` block,
//!   so the `bmm*` kernels never see ragged shapes.  Trimming is
//!   value-preserving: pad keys carry exact-zero attention probability
//!   and every other op is per-row.
//! * **Backpressure contract** — admission is bounded by
//!   [`serve::ServeConfig::queue_cap`]; a submit beyond it is rejected
//!   immediately with [`serve::SubmitError::QueueFull`] (explicit
//!   reject, not OOM), while every *accepted* request is answered —
//!   served, failed with its batch's error, or drained at shutdown.
//! * **Determinism guarantee** — a request's bucket length is a pure
//!   function of its effective length and the blocked kernels
//!   accumulate per output row, so predictions are **bitwise
//!   identical** whether a request is served alone, in a full bucket,
//!   or interleaved with other lengths — across `Precision`
//!   f32/bf16/f16 and both `ComputePath`s (`rust/tests/serving.rs`).
//!
//! `cargo run --release -- serve-bench` (and `cargo bench --offline --
//! serve`) drives a multi-threaded closed-loop load generator
//! ([`serve::loadgen`]) over {no-batching, continuous batching} x
//! concurrency {1, 8} and records p50/p99 latency and saturation
//! throughput per scenario into `BENCH_serve.json` (a CI artifact next
//! to `BENCH_native_train.json`).  [`costmodel`] carries the matching
//! analytic entry: batched inference at `(B, S)` is the Eq. 20 forward
//! *without* the Eq. 21 cache charge
//! ([`costmodel::LinearShape::btt_serve_muls`], surfaced by the CLI
//! `cost-model` command).
//!
//! ## Data parallelism
//!
//! The compression story makes gradient exchange nearly free — a full
//! compressed-core gradient set is kilobytes-to-megabytes — so the
//! crate scales *across* batch shards with [`replica::ReplicaGroup`]
//! (`--replicas N` on the `train` command): N [`train::NativeTrainModel`]
//! replicas on N threads, each running the pure
//! [`train::NativeTrainModel::forward_backward`] over its shard, one
//! optimizer step on the reduced gradients, then a parameter
//! broadcast.
//!
//! * **Sharding rule** — replica `r` of `N` takes global examples
//!   `r, r + N, r + 2N, …` (stride-`N`); a batch smaller than `N`
//!   (e.g. an epoch's partial tail) is dropped by the coordinator's
//!   existing tail rule via `supports_batch`.
//! * **Reduction order** — shard-mean gradients are buffered whole
//!   (they are tiny by construction) and reduced as
//!   `g = Σ_r (b_r/B)·g_r` in ascending replica index with f32
//!   arithmetic, per slot, element by element
//!   ([`replica::allreduce_fixed_order`]); thread completion order
//!   cannot affect the result.
//! * **Determinism contract** — R=1 is **bitwise identical** to the
//!   plain single-model trainer (the weight-1 scale is skipped);
//!   same R ⇒ bitwise-identical trajectories across runs; different R
//!   re-associates the batch mean and agrees within the usual
//!   ~1e-5-class float tolerance (`rust/tests/replicas.rs`).
//! * **Exchange-volume math** — with `G` gradient bytes per replica,
//!   the in-process exchange buffers `(N−1)·G` in and `(N−1)·P`
//!   parameter bytes back; a ring all-reduce over real links would
//!   move `2(N−1)/N·G` per device
//!   ([`costmodel::ring_allreduce_bytes`], tabulated by
//!   `costmodel::sweeps::replica_exchange_table`).  Optimizer state is
//!   never exchanged and lives **once**, on the lead replica
//!   ([`fpga::resources::ReplicaBudget`] charges it to device 0 only).
//!
//! `cargo bench --offline -- replicas` (and the `bench-replicas` CLI
//! command) records tokens/sec at R ∈ {1, 2, 4} into
//! `BENCH_replicas.json`, with the R=4 / R=1 speedup gated in CI on
//! multi-core runners.  The matmul worker pool width is independently
//! controllable with `--threads` (see [`tensor`] module docs on
//! replica × pool oversubscription).
//!
//! ## Observability
//!
//! The paper's headline claims are *per-stage* numbers — FP/BP/PU
//! latency breakdowns and a <6 MB BRAM / 22.5 MB URAM on-chip budget —
//! so the crate carries a zero-dependency tracing + metrics subsystem
//! ([`trace`]) that measures at runtime what [`costmodel`] and
//! [`fpga::resources`] predict:
//!
//! * **Span taxonomy** — deterministic span trees named after the
//!   paper's stages: `train`-category `fp.*`/`bp.*`/`pu.*` spans per
//!   layer, `ttlinear`-category `merge_left`/`merge_right`/`apply`
//!   contraction spans inside each projection, `pool`-category `job`
//!   spans on the `tt-matmul-{i}` worker threads, an
//!   `engine`-category `forward` span per shared-engine block, and
//!   `serve`-category `admit` → `queue` → `batch_execute` → `respond`
//!   spans through the scheduler.  Disabled cost is a single relaxed
//!   atomic load per site (bound self-tested in
//!   `rust/tests/tracing.rs`), and instrumentation never touches
//!   computed values, so traced and untraced runs are bitwise
//!   identical.
//! * **Byte gauges → U50 budget** — at each stage boundary the trainer
//!   publishes `eq21_cache_bytes` (the measured live-cache sum, the
//!   quantity [`fpga::resources::ResourceReport::eq21_cache_bytes`]
//!   charges into the URAM BP stash), `optim_state_bytes` (the PU
//!   moments charged next to the cores) and `param_bytes` (packed
//!   cores + dense biases at the storage width) — so the BRAM/URAM
//!   budget tables become runtime-asserted invariants
//!   (`rust/tests/tracing.rs` pins gauge == measured == analytic
//!   across {f32, bf16} × {cache, recompute}).  The serving layer
//!   publishes queue depth and a batch-size histogram.
//! * **Exporters** — `--trace <path>` on `train`/`serve-bench` writes
//!   Chrome trace-event JSON ([`trace::chrome`], Perfetto-loadable,
//!   per-thread lanes showing pool fan-out and executor batching); the
//!   `trace-report` CLI command prints the measured FP/BP/PU
//!   percentage split next to the cost model's prediction
//!   ([`trace::report`]); and
//!   [`serve::ServerHandle::prometheus_snapshot`] renders the live
//!   serving counters in Prometheus text format ([`trace::prom`]).
//!
//! Step-level latency statistics ride along:
//! [`coordinator::Metrics`] keeps per-step execute-time samples and
//! surfaces p50/p95 step time in the CLI summary, and
//! [`serve::ServeStats`] carries per-bucket served/batch counts, the
//! queue-depth high-watermark and p50/p95/p99 request latency — all
//! through the one shared [`coordinator::metrics::percentile`] helper.
//!
//! After `make artifacts` the binary is self-contained with either
//! backend; with the native backend it is self-contained from a bare
//! `cargo build` — the paper's end-to-end on-device training claim is
//! reproducible without a Python/XLA toolchain anywhere.

// The tensor kernels and backward passes are index arithmetic by
// nature; explicit indices document the contraction layouts better than
// iterator chains would.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::type_complexity)]

pub mod benchgrid;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod engine;
pub mod fpga;
pub mod inference;
pub mod optim;
pub mod replica;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod util;
