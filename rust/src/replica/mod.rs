//! Deterministic data-parallel training: N model replicas, one
//! optimizer step.
//!
//! The paper's compression argument makes data parallelism unusually
//! cheap: the *entire* trainable state of a 6-ENC model lives in a few
//! MB of TT/TTM cores, so a full gradient exchange per step — the
//! classic data-parallel bottleneck — is kilobytes-to-megabytes, not
//! gigabytes.  [`ReplicaGroup`] exploits that: it runs N
//! [`NativeTrainModel`] replicas on N threads, each computing
//! forward + backward over its slice of the global batch, buffers each
//! replica's complete compressed-core gradient set
//! ([`crate::train::GradMap`]), reduces them in a **fixed order**, and
//! applies **one** optimizer step to the lead model before
//! broadcasting the updated parameters back out.
//!
//! # Sharding rule
//!
//! A global batch of `B` examples is split by stride: replica `r` of
//! `N` takes examples `r, r + N, r + 2N, …` (so shard sizes differ by
//! at most one, and shard membership depends only on `(B, N)`).  The
//! coordinator's partial-tail drop rule composes through
//! [`TrainBackend::supports_batch`]: a tail smaller than `N` cannot
//! give every replica work and is dropped, exactly like a tail the
//! PJRT backend cannot execute.
//!
//! # Reduction order and the determinism contract
//!
//! Each replica computes a *shard-mean* gradient.  The global
//! batch-mean is recovered as the weighted sum
//! `g = Σ_r (b_r / B) · g_r`, accumulated in **ascending replica
//! index** with f32 arithmetic, per optimizer slot, element by element
//! ([`allreduce_fixed_order`]).  Thread completion order never touches
//! the result — gradients are buffered per replica and reduced only
//! after all shards finished.  Consequences, pinned by
//! `rust/tests/replicas.rs`:
//!
//! * **R = 1 is bitwise-identical to [`NativeTrainModel`]**: the
//!   single shard has weight `b_0 / B = 1`, the scale multiply is
//!   skipped, and the reduced map is byte-for-byte the plain backward's.
//! * **Same R ⇒ bitwise-identical trajectory** across runs: sharding,
//!   reduction order and the single PU stage are all deterministic.
//! * **Different R ⇒ same trajectory within tolerance**: the weighted
//!   sum re-associates the batch-mean reduction (the same ~1e-5-class
//!   effect as reordering example summation, documented for the
//!   mini-batch reduction contract in [`crate::optim::mean_accumulate`]).
//!
//! # Exchange volume
//!
//! With `G = 4·Σ|gradient slots|` bytes per replica (f32 on the wire),
//! the buffered in-process exchange moves `(N−1)·G` into the reducer
//! and `(N−1)·P` parameter bytes back out.  A ring all-reduce over
//! real links would move `2(N−1)/N · G` per device
//! ([`crate::costmodel::ring_allreduce_bytes`]); both figures are
//! published as gauges (`allreduce_grad_bytes`, `allreduce_ring_bytes`)
//! and tabulated by `costmodel::sweeps::replica_exchange_table`.
//! Optimizer state is **never** exchanged and never replicated — the
//! moments live once, on the lead model (see
//! [`crate::optim::StateFootprint`]).
//!
//! Each replica thread is named `replica-{r}`, so every span recorded
//! inside a shard's backward lands in its own per-replica lane in the
//! Chrome trace; the reduce/apply/broadcast phases carry the
//! `allreduce` category on the coordinating thread.

use crate::config::ModelConfig;
use crate::coordinator::backend::{StepOutput, TrainBackend};
use crate::tensor::ContractionStats;
use crate::trace;
use crate::train::model::GradMap;
use crate::train::{NativeTrainModel, NativeTrainer};
use anyhow::{anyhow, Result};
use std::path::Path;
use std::time::Instant;

/// N-replica data-parallel trainer over one [`NativeTrainer`].
///
/// The lead trainer owns the optimizer state and the checkpoint
/// format; followers are parameter mirrors that only ever run the pure
/// `forward_backward`.  See the module docs for the sharding /
/// reduction / determinism contract.
pub struct ReplicaGroup {
    lead: NativeTrainer,
    followers: Vec<NativeTrainModel>,
    /// Merged instrumentation of the most recent step: contraction
    /// counts summed over all replicas, peak intermediate taken as the
    /// max (replicas run concurrently, so peaks coexist).
    pub last_stats: ContractionStats,
}

impl ReplicaGroup {
    /// Wrap `lead` into a group of `replicas` total models.  Followers
    /// are built as exact parameter mirrors (same packed bits, compute
    /// path, precision and checkpoint policy) with **no optimizer
    /// state of their own** — they never step.
    pub fn new(lead: NativeTrainer, replicas: usize) -> Result<ReplicaGroup> {
        if replicas == 0 {
            return Err(anyhow!("replica group needs at least 1 replica"));
        }
        let mut followers = Vec::with_capacity(replicas - 1);
        for _ in 1..replicas {
            let mut m = NativeTrainModel::from_params(&lead.model.cfg, &lead.model.to_params())?;
            m.compute_path = lead.model.compute_path;
            m.checkpoint = lead.model.checkpoint.clone();
            // Exact packed-bit mirror (from_params round-trips through
            // f32; copying the packed tensors removes even that).
            m.copy_params_from(&lead.model);
            followers.push(m);
        }
        Ok(ReplicaGroup { lead, followers, last_stats: ContractionStats::default() })
    }

    /// Total replica count (lead + followers).
    pub fn replicas(&self) -> usize {
        1 + self.followers.len()
    }

    /// Direct access to the lead trainer (owner of optimizer state and
    /// checkpoints).
    pub fn lead(&self) -> &NativeTrainer {
        &self.lead
    }

    /// Optimizer-state bytes of the whole group — the lead's figure,
    /// because followers hold none (the no-double-charge contract).
    pub fn allocated_state_bytes(&self) -> u64 {
        self.lead.model.optim.allocated_state_bytes()
    }

    /// Optimizer-state elements across all *followers* — zero by
    /// construction; exposed so tests can assert the no-double-charge
    /// contract directly.
    pub fn follower_state_elems(&self) -> u64 {
        self.followers.iter().map(|m| m.optim.allocated_state_elems()).sum()
    }

    /// One data-parallel training step over a global `(B, S)` batch:
    /// shard by stride, run N concurrent backwards, reduce in fixed
    /// order, apply one optimizer step on the lead, broadcast.
    /// Returns the global batch-mean loss and the merged stats.
    pub fn replica_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<(f32, ContractionStats)> {
        let rn = self.replicas();
        let s = self.lead.model.cfg.seq_len;
        let b = intent.len();
        if b < rn || tokens.len() != b * s || slots.len() != b * s {
            return Err(anyhow!(
                "replica_step: need (B, {s}) tokens/slots and B >= {rn} intents, \
                 got {} / {} / {b}",
                tokens.len(),
                slots.len()
            ));
        }
        let shards: Vec<_> = (0..rn).map(|r| shard_examples(tokens, intent, slots, s, r, rn)).collect();

        // ---- N concurrent shard backwards (pure; `&model`) ----------
        let models: Vec<&NativeTrainModel> = std::iter::once(&self.lead.model)
            .chain(self.followers.iter())
            .collect();
        let mut shard_results: Vec<(usize, usize, f32, GradMap, ContractionStats)> =
            std::thread::scope(|scope| -> Result<Vec<_>> {
                let mut handles = Vec::with_capacity(rn);
                for (r, (model, (tok, int, sl))) in models.iter().zip(&shards).enumerate() {
                    let handle = std::thread::Builder::new()
                        .name(format!("replica-{r}"))
                        .spawn_scoped(scope, move || -> Result<_> {
                            let (loss, grads, stats) = model.forward_backward(tok, int, sl)?;
                            Ok((r, int.len(), loss, grads, stats))
                        })
                        .map_err(|e| anyhow!("failed to spawn replica thread {r}: {e}"))?;
                    handles.push(handle);
                }
                handles
                    .into_iter()
                    .map(|h| h.join().map_err(|_| anyhow!("replica thread panicked"))?)
                    .collect()
            })?;
        shard_results.sort_by_key(|(r, ..)| *r);

        // Merged stats: work adds up across replicas; peaks coexist.
        let mut stats = ContractionStats::default();
        for (_, _, _, _, st) in &shard_results {
            stats.muls += st.muls;
            stats.stored_intermediate_elems += st.stored_intermediate_elems;
            stats.steps += st.steps;
            stats.peak_intermediate_elems = stats.peak_intermediate_elems.max(st.peak_intermediate_elems);
        }

        // Global batch-mean loss, same fixed ascending order (and the
        // same skip-the-multiply-at-weight-1 rule) as the gradients.
        let scale0 = shard_results[0].1 as f32 / b as f32;
        let mut loss = if scale0 == 1.0 {
            shard_results[0].2
        } else {
            shard_results[0].2 * scale0
        };
        for (_, br, l, _, _) in &shard_results[1..] {
            loss += l * (*br as f32 / b as f32);
        }

        // ---- Fixed-order all-reduce in the compressed core layout ---
        let t0 = Instant::now();
        let reduced = {
            let _sp = trace::span("allreduce", "reduce.cores");
            let shards_in: Vec<(usize, usize, GradMap)> = shard_results
                .into_iter()
                .map(|(r, br, _, g, _)| (r, br, g))
                .collect();
            if trace::enabled() {
                let grad_bytes: u64 =
                    shards_in[0].2.values().map(|g| 4 * g.len() as u64).sum();
                trace::gauge_set("allreduce_grad_bytes", grad_bytes);
                trace::gauge_set(
                    "allreduce_ring_bytes",
                    crate::costmodel::ring_allreduce_bytes(grad_bytes, rn),
                );
            }
            allreduce_fixed_order(shards_in)?
        };
        if trace::enabled() {
            trace::gauge_set("allreduce_micros", t0.elapsed().as_micros() as u64);
        }

        // ---- One PU stage on the lead, then broadcast ---------------
        // The guarded apply scans the reduced map (a non-finite shard
        // gradient survives the weighted sum as non-finite) and the
        // global loss; an overflow step is skipped on the lead, backs
        // off the loss scale, and leaves every replica untouched — so
        // no broadcast is needed and the group stays bitwise in sync.
        let applied = {
            let _sp = trace::span("allreduce", "apply.reduced");
            self.lead.model.apply_grads_guarded(loss, &reduced, lr)?
        };
        if applied {
            let _sp = trace::span("allreduce", "broadcast.params");
            let lead = &self.lead.model;
            for f in self.followers.iter_mut() {
                f.copy_params_from(lead);
            }
        }
        self.lead.invalidate_eval_cache();
        if trace::enabled() {
            trace::gauge_set(
                "optim_state_bytes",
                self.lead.model.optim.allocated_state_bytes(),
            );
            trace::counter_add("train_steps_total", 1);
        }
        Ok((loss, stats))
    }
}

/// Validate a `(replicas, global batch)` pairing **before** training
/// starts.  Every replica must get at least one example per step
/// ([`ReplicaGroup::supports_batch`]); when the global batch is smaller
/// than the replica count, the coordinator's partial-tail rule drops
/// *every* batch and the run silently "trains" zero steps.  The CLI
/// calls this at parse time so the misconfiguration errors loudly up
/// front instead.
pub fn validate_replica_batch(replicas: usize, global_batch: usize) -> Result<()> {
    if replicas == 0 {
        return Err(anyhow!("--replicas must be at least 1"));
    }
    if global_batch < replicas {
        return Err(anyhow!(
            "--replicas {replicas} with global batch {global_batch}: every step's \
             batch is smaller than the replica count, so the partial-tail drop \
             rule would discard every batch and the run would train zero steps. \
             Lower --replicas to at most {global_batch} or raise --batch."
        ));
    }
    Ok(())
}

/// Strided shard `r` of `rn`: examples `r, r + rn, r + 2·rn, …` of a
/// `(B, S)` batch.  Returns owned `(tokens, intents, slots)` slices in
/// global example order (ascending), so each shard's own batch-mean is
/// deterministic.
fn shard_examples(
    tokens: &[i32],
    intent: &[i32],
    slots: &[i32],
    s: usize,
    r: usize,
    rn: usize,
) -> (Vec<i32>, Vec<i32>, Vec<i32>) {
    let b = intent.len();
    let mut tok = Vec::new();
    let mut int = Vec::new();
    let mut sl = Vec::new();
    for e in (r..b).step_by(rn) {
        tok.extend_from_slice(&tokens[e * s..(e + 1) * s]);
        int.push(intent[e]);
        sl.extend_from_slice(&slots[e * s..(e + 1) * s]);
    }
    (tok, int, sl)
}

/// Reduce per-replica shard-mean gradient maps into the global
/// batch-mean map, **independent of input order**: shards are sorted
/// by replica index, then accumulated ascending with f32 arithmetic —
/// `g = Σ_r (b_r / B) · g_r`, slot by slot, element by element.
///
/// Each entry is `(replica index, shard batch size, shard-mean
/// gradients)`.  The accumulator is *initialized from* replica 0's
/// scaled contribution rather than zeros, and a weight of exactly 1
/// skips the multiply — so a single shard passes through
/// byte-for-byte (R=1 bitwise parity, including signed zeros and
/// NaN payloads).
pub fn allreduce_fixed_order(mut shards: Vec<(usize, usize, GradMap)>) -> Result<GradMap> {
    if shards.is_empty() {
        return Err(anyhow!("allreduce: no shards"));
    }
    shards.sort_by_key(|(r, ..)| *r);
    for w in shards.windows(2) {
        if w[0].0 == w[1].0 {
            return Err(anyhow!("allreduce: duplicate replica index {}", w[0].0));
        }
    }
    let total: usize = shards.iter().map(|(_, br, _)| *br).sum();
    if total == 0 {
        return Err(anyhow!("allreduce: zero total batch"));
    }
    let mut it = shards.into_iter();
    let (_, b0, mut acc) = it.next().expect("non-empty checked above");
    let scale0 = b0 as f32 / total as f32;
    if scale0 != 1.0 {
        for g in acc.values_mut() {
            for v in g.iter_mut() {
                *v *= scale0;
            }
        }
    }
    for (r, br, gmap) in it {
        if gmap.len() != acc.len() {
            return Err(anyhow!(
                "allreduce: replica {r} has {} gradient slots, expected {}",
                gmap.len(),
                acc.len()
            ));
        }
        let scale = br as f32 / total as f32;
        // BTreeMap iteration is sorted by key, so zipping walks both
        // maps in the same (deterministic) slot order.
        for ((name_a, a), (name_b, gb)) in acc.iter_mut().zip(gmap.iter()) {
            if name_a != name_b {
                return Err(anyhow!(
                    "allreduce: replica {r} slot '{name_b}' does not match '{name_a}'"
                ));
            }
            if a.len() != gb.len() {
                return Err(anyhow!(
                    "allreduce: replica {r} slot '{name_a}' has {} elements, expected {}",
                    gb.len(),
                    a.len()
                ));
            }
            for (av, &bv) in a.iter_mut().zip(gb.iter()) {
                *av += scale * bv;
            }
        }
    }
    Ok(acc)
}

impl TrainBackend for ReplicaGroup {
    fn backend_name(&self) -> &'static str {
        "native-replicas"
    }

    fn config(&self) -> &ModelConfig {
        &self.lead.model.cfg
    }

    /// Every replica must receive at least one example; smaller
    /// batches (e.g. the epoch's partial tail) are dropped by the
    /// coordinator's existing tail rule.
    fn supports_batch(&self, batch: usize) -> bool {
        batch >= self.replicas()
    }

    fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        let _sp = trace::span("step", "train_step");
        let (loss, stats) = self.replica_step(tokens, intent, slots, lr)?;
        self.last_stats = stats;
        Ok(StepOutput {
            loss,
            execute_secs: t0.elapsed().as_secs_f64(),
            host_secs: 0.0,
        })
    }

    fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.lead.eval(tokens)
    }

    /// Checkpoints are the lead's (parameters + optimizer state):
    /// followers are always byte-identical mirrors after a step, so
    /// one copy of the parameters is the whole group's state.
    fn save_checkpoint(&self, dir: &Path) -> Result<()> {
        self.lead.save_checkpoint(dir)
    }

    fn load_checkpoint(&mut self, dir: &Path) -> Result<()> {
        self.lead.load_checkpoint(dir)?;
        let lead = &self.lead.model;
        for f in self.followers.iter_mut() {
            f.compute_path = lead.compute_path;
            f.checkpoint = lead.checkpoint.clone();
            f.copy_params_from(lead);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(entries: &[(&str, &[f32])]) -> GradMap {
        entries.iter().map(|(k, v)| (k.to_string(), v.to_vec())).collect()
    }

    #[test]
    fn strided_sharding_partitions_the_batch() {
        let s = 2usize;
        let b = 7usize;
        let tokens: Vec<i32> = (0..(b * s) as i32).collect();
        let intent: Vec<i32> = (100..100 + b as i32).collect();
        let slots: Vec<i32> = (200..200 + (b * s) as i32).collect();
        for rn in 1..=4 {
            let mut seen = vec![false; b];
            let mut total = 0usize;
            for r in 0..rn {
                let (tok, int, sl) = shard_examples(&tokens, &intent, &slots, s, r, rn);
                assert_eq!(tok.len(), int.len() * s);
                assert_eq!(sl.len(), int.len() * s);
                for (i, &iv) in int.iter().enumerate() {
                    let e = (iv - 100) as usize;
                    assert_eq!(e % rn, r, "example {e} on wrong shard");
                    assert!(!seen[e], "example {e} sharded twice");
                    seen[e] = true;
                    // Rows travel with their example, in order.
                    assert_eq!(&tok[i * s..(i + 1) * s], &tokens[e * s..(e + 1) * s]);
                    assert_eq!(&sl[i * s..(i + 1) * s], &slots[e * s..(e + 1) * s]);
                }
                total += int.len();
            }
            assert_eq!(total, b, "R={rn}: shards must partition the batch");
        }
    }

    #[test]
    fn allreduce_is_input_order_independent() {
        let a = map(&[("p", &[1.0, 2.0]), ("q", &[0.5])]);
        let c = map(&[("p", &[3.0, -2.0]), ("q", &[1.5])]);
        let d = map(&[("p", &[-1.0, 8.0]), ("q", &[4.0])]);
        let fwd = allreduce_fixed_order(vec![
            (0, 2, a.clone()),
            (1, 2, c.clone()),
            (2, 1, d.clone()),
        ])
        .unwrap();
        // Same shards handed over in "completion order" — bitwise equal.
        let rev = allreduce_fixed_order(vec![(2, 1, d), (0, 2, a), (1, 2, c)]).unwrap();
        assert_eq!(fwd, rev);
        // Weighted by shard size: p[0] = (2*1 + 2*3 + 1*-1)/5.
        assert_eq!(fwd["p"][0], (2.0 * 1.0 + 2.0 * 3.0 - 1.0) / 5.0);
    }

    #[test]
    fn single_shard_passes_through_bitwise() {
        // Signed zero survives: scaling by a computed 1.0 is skipped.
        let g = map(&[("p", &[-0.0f32, 1.25, f32::MIN_POSITIVE])]);
        let out = allreduce_fixed_order(vec![(0, 3, g.clone())]).unwrap();
        for (a, b) in out["p"].iter().zip(g["p"].iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn allreduce_rejects_malformed_shards() {
        let g = map(&[("p", &[1.0])]);
        assert!(allreduce_fixed_order(vec![]).is_err(), "empty accepted");
        assert!(
            allreduce_fixed_order(vec![(0, 1, g.clone()), (0, 1, g.clone())]).is_err(),
            "duplicate replica index accepted"
        );
        assert!(
            allreduce_fixed_order(vec![(0, 0, g.clone())]).is_err(),
            "zero total batch accepted"
        );
        let other = map(&[("z", &[1.0])]);
        assert!(
            allreduce_fixed_order(vec![(0, 1, g.clone()), (1, 1, other)]).is_err(),
            "mismatched slot names accepted"
        );
        let short = map(&[("p", &[1.0, 2.0])]);
        assert!(
            allreduce_fixed_order(vec![(0, 1, g), (1, 1, short)]).is_err(),
            "mismatched slot lengths accepted"
        );
    }
}
