//! Parameter sweeps regenerating the paper's Fig. 6 and Fig. 7, plus
//! the PU-stage optimizer-state column.
//!
//! Fig. 6: computation and memory of MM / TTM / TT / BTT at the Table II
//! attention shape, seq len 32.
//! Fig. 7 (top): reduction ratios vs sequence length 8..512 at rank 12.
//! Fig. 7 (bottom): reduction ratios vs TT rank 1..48 at seq len 32.
//! [`optimizer_state_table`]: whole-model optimizer-state memory per
//! update rule, compressed vs dense-equivalent.

use super::{compare_all, CostRow, LinearShape};
use crate::config::ModelConfig;
use crate::optim::{OptimKind, StateFootprint};
use crate::tensor::Precision;

/// One sweep point: the independent variable plus all method rows.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub x: u64,
    pub rows: Vec<CostRow>,
}

/// Fig. 7 (top): sequence-length sweep at fixed rank.
pub fn seq_len_sweep(rank: usize, seq_lens: &[u64]) -> Vec<SweepPoint> {
    let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], rank);
    seq_lens
        .iter()
        .map(|&k| SweepPoint { x: k, rows: compare_all(&shape, k) })
        .collect()
}

/// Fig. 7 (bottom): rank sweep at fixed sequence length.
pub fn rank_sweep(seq_len: u64, ranks: &[usize]) -> Vec<SweepPoint> {
    ranks
        .iter()
        .map(|&r| {
            let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], r);
            SweepPoint { x: r as u64, rows: compare_all(&shape, seq_len) }
        })
        .collect()
}

/// The paper's sweep grids.
pub fn paper_seq_lens() -> Vec<u64> {
    vec![8, 16, 32, 64, 128, 256, 512]
}

pub fn paper_ranks() -> Vec<usize> {
    vec![1, 2, 4, 8, 12, 16, 24, 32, 48]
}

/// Render a sweep as an aligned text table (one line per x, one column
/// pair per method) — the bench harness prints these as the paper's
/// figure series.
pub fn render_sweep(points: &[SweepPoint], x_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{x_name:>8} | {:>14} {:>14} {:>14} {:>14} | {:>12} {:>12} {:>12}\n",
        "MM muls", "TTM muls", "TT muls", "BTT muls", "TTM mem-red", "TT mem-red", "BTT mem-red"
    ));
    for p in points {
        let get = |name: &str| p.rows.iter().find(|r| r.method == name).unwrap();
        out.push_str(&format!(
            "{:>8} | {:>14} {:>14} {:>14} {:>14} | {:>12.2} {:>12.2} {:>12.2}\n",
            p.x,
            get("MM").fwd_muls,
            get("TTM").fwd_muls,
            get("TT").fwd_muls,
            get("BTT").fwd_muls,
            get("TTM").memory_reduction,
            get("TT").memory_reduction,
            get("BTT").memory_reduction,
        ));
    }
    out
}

/// PU-stage optimizer-state column for a whole model at fp32 storage:
/// per update rule, the state multiplier, the compressed state size,
/// and what the same rule would cost on the uncompressed model — the
/// paper's on-chip-optimizer story in one table.
pub fn optimizer_state_table(cfg: &ModelConfig) -> String {
    optimizer_state_table_prec(cfg, Precision::F32)
}

/// [`optimizer_state_table`] at a storage [`Precision`] — the
/// per-precision sweep row of the mixed-precision path (element counts
/// unchanged, bytes halved for bf16/f16).
pub fn optimizer_state_table_prec(cfg: &ModelConfig, precision: Precision) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>11} {:>14} {:>12} {:>14}\n",
        "optimizer",
        "state/param",
        "state elems",
        format!("{} MB", precision.name()),
        "dense-equiv MB"
    ));
    for kind in OptimKind::all() {
        let fp = StateFootprint::for_model_prec(cfg, kind, precision);
        let dense_mb = (kind.state_multiplier() * cfg.dense_equivalent_params()) as f64
            * precision.bytes() as f64
            / 1e6;
        out.push_str(&format!(
            "{:<10} {:>10}x {:>14} {:>12.3} {:>14.1}\n",
            kind.name(),
            kind.state_multiplier(),
            fp.state_elems,
            fp.state_mb(),
            dense_mb
        ));
    }
    out
}

/// Data-parallel gradient-exchange sweep: for N ∈ {1, 2, 4, 8}
/// replicas, the per-replica compressed-core gradient payload
/// ([`super::core_grad_bytes`]) and the per-device ring vs root naive
/// all-reduce traffic.  The closing note pins the optimizer-state
/// contract: moments live **once** on the lead regardless of N (see
/// [`crate::optim::StateFootprint`]), so scale-out multiplies exchange
/// traffic but never PU-stage state.
pub fn replica_exchange_table(cfg: &ModelConfig, precision: Precision) -> String {
    let g = super::core_grad_bytes(cfg, precision);
    let kb = |b: u64| b as f64 / 1e3;
    let mut out = String::new();
    out.push_str(&format!(
        "{:>8} {:>14} {:>14} {:>14}\n",
        "replicas",
        format!("grad {} KB", precision.name()),
        "ring KB/dev",
        "naive KB@root"
    ));
    for n in [1usize, 2, 4, 8] {
        out.push_str(&format!(
            "{:>8} {:>14.2} {:>14.2} {:>14.2}\n",
            n,
            kb(g),
            kb(super::ring_allreduce_bytes(g, n)),
            kb(super::naive_allreduce_bytes(g, n)),
        ));
    }
    out.push_str("optimizer state: lives once on the lead at every N (not N copies)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 7 (top) qualitative shape: BTT's advantage over TT grows with
    /// sequence length.
    #[test]
    fn btt_advantage_grows_with_seq_len() {
        let pts = seq_len_sweep(12, &paper_seq_lens());
        let advantage: Vec<f64> = pts
            .iter()
            .map(|p| {
                let tt = p.rows.iter().find(|r| r.method == "TT").unwrap().fwd_muls as f64;
                let btt = p.rows.iter().find(|r| r.method == "BTT").unwrap().fwd_muls as f64;
                tt / btt
            })
            .collect();
        for w in advantage.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "advantage not monotone: {advantage:?}");
        }
    }

    /// Fig. 7 (bottom) qualitative shape: all tensor methods' reduction
    /// ratios degrade as rank grows, but BTT stays the best.
    #[test]
    fn reduction_degrades_with_rank_btt_best() {
        let pts = rank_sweep(32, &paper_ranks());
        let mut last_btt = f64::INFINITY;
        for p in &pts {
            let btt = p.rows.iter().find(|r| r.method == "BTT").unwrap();
            let tt = p.rows.iter().find(|r| r.method == "TT").unwrap();
            let ttm = p.rows.iter().find(|r| r.method == "TTM").unwrap();
            assert!(btt.compute_reduction <= last_btt + 1e-9);
            assert!(btt.compute_reduction >= tt.compute_reduction - 1e-9);
            assert!(btt.compute_reduction >= ttm.compute_reduction - 1e-9);
            last_btt = btt.compute_reduction;
        }
    }

    #[test]
    fn render_has_all_points() {
        let pts = seq_len_sweep(12, &[8, 16]);
        let s = render_sweep(&pts, "seq");
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    fn optimizer_state_table_covers_all_rules() {
        let s = optimizer_state_table(&ModelConfig::paper(2));
        assert_eq!(s.lines().count(), 5, "header + 4 optimizer rows");
        for kind in OptimKind::all() {
            assert!(s.contains(kind.name()), "missing row for {:?}", kind);
        }
        // Adam state on the compressed 2-ENC model stays well under a
        // single MB while the dense equivalent would be ~73 MB.
        let adam = StateFootprint::for_model(&ModelConfig::paper(2), OptimKind::Adam);
        assert!(adam.state_mb() < 3.0, "compressed Adam state {} MB", adam.state_mb());
    }

    #[test]
    fn replica_exchange_table_shape_and_math() {
        let cfg = ModelConfig::paper(2);
        let s = replica_exchange_table(&cfg, Precision::F32);
        assert_eq!(s.lines().count(), 6, "header + 4 replica rows + state note");
        assert!(s.contains("lives once"), "state-lives-once note missing");
        let g = super::super::core_grad_bytes(&cfg, Precision::F32);
        assert_eq!(g, cfg.tensor_params() as u64 * 4);
        // Ring: 0 at N=1, 2(N-1)/N·G otherwise; naive: (N-1)·G.
        assert_eq!(super::super::ring_allreduce_bytes(g, 1), 0);
        assert_eq!(super::super::ring_allreduce_bytes(g, 2), g);
        assert_eq!(super::super::ring_allreduce_bytes(g, 4), g * 2 * 3 / 4);
        assert_eq!(super::super::naive_allreduce_bytes(g, 1), 0);
        assert_eq!(super::super::naive_allreduce_bytes(g, 4), g * 3);
        // Ring beats naive for every N > 2 and the payload itself is
        // compressed-core tiny (well under a megabyte at fp32).
        assert!(super::super::ring_allreduce_bytes(g, 4) < super::super::naive_allreduce_bytes(g, 4));
        assert!(g < 4_000_000, "compressed-core grad set unexpectedly large: {g} bytes");
        // Half-width wire precision halves the payload exactly.
        assert_eq!(super::super::core_grad_bytes(&cfg, Precision::Bf16) * 2, g);
    }

    #[test]
    fn per_precision_state_table_halves_the_bytes() {
        let cfg = ModelConfig::paper(2);
        let bf16 = optimizer_state_table_prec(&cfg, Precision::Bf16);
        assert_eq!(bf16.lines().count(), 5, "header + 4 optimizer rows");
        assert!(bf16.contains("bf16 MB"), "precision missing from header");
        let f = StateFootprint::for_model_prec(&cfg, OptimKind::Adam, Precision::F32);
        let b = StateFootprint::for_model_prec(&cfg, OptimKind::Adam, Precision::Bf16);
        assert_eq!(b.state_elems, f.state_elems);
        assert!((2.0 * b.state_mb() - f.state_mb()).abs() < 1e-9);
    }
}
