//! Analytic computing/memory cost model (paper Sec. IV, Eqs. 18-21,
//! Table I) for the four linear-layer execution schemes:
//!
//! * **MM** — dense matrix-matrix multiplication,
//! * **TTM** — tensor-train-matrix right-to-left contraction,
//! * **TT** — tensor-train right-to-left contraction (prior accelerators),
//! * **BTT** — the paper's bidirectional tensor-train contraction.
//!
//! The TT/BTT formulas are validated *exactly* against the instrumented
//! contraction engines in [`crate::tensor::tt`] (see tests) — the model
//! is executable arithmetic, not transcription.

pub mod sweeps;

/// Shape of one tensorized linear layer: `y = W x`, `W (M, N)`,
/// `M = prod(m_modes)`, `N = prod(n_modes)`, plus the TT rank tuple
/// `(r_0, ..., r_2d)` with `r_0 = r_2d = 1`.
#[derive(Debug, Clone)]
pub struct LinearShape {
    pub m_modes: Vec<usize>,
    pub n_modes: Vec<usize>,
    pub ranks: Vec<usize>,
}

impl LinearShape {
    /// Uniform-rank constructor.
    pub fn uniform(m_modes: &[usize], n_modes: &[usize], rank: usize) -> LinearShape {
        let d2 = m_modes.len() + n_modes.len();
        let mut ranks = vec![rank; d2 + 1];
        ranks[0] = 1;
        ranks[d2] = 1;
        LinearShape {
            m_modes: m_modes.to_vec(),
            n_modes: n_modes.to_vec(),
            ranks,
        }
    }

    /// The paper's Table II attention/FFN/classifier layer.
    pub fn paper() -> LinearShape {
        LinearShape::uniform(&[12, 8, 8], &[8, 8, 12], 12)
    }

    pub fn d(&self) -> usize {
        self.m_modes.len()
    }

    pub fn m(&self) -> u64 {
        self.m_modes.iter().map(|&x| x as u64).product()
    }

    pub fn n(&self) -> u64 {
        self.n_modes.iter().map(|&x| x as u64).product()
    }

    /// TT parameter count (the "Weight" column of Table I).
    pub fn tt_params(&self) -> u64 {
        let modes: Vec<u64> = self
            .m_modes
            .iter()
            .chain(&self.n_modes)
            .map(|&x| x as u64)
            .collect();
        modes
            .iter()
            .enumerate()
            .map(|(k, &m)| self.ranks[k] as u64 * m * self.ranks[k + 1] as u64)
            .sum()
    }

    /// TTM parameter count for the same (M, N) matrix: cores
    /// (r_{k-1}, m_k, n_k, r_k), pairing m_k with n_k (d cores).
    pub fn ttm_params(&self) -> u64 {
        let d = self.d();
        // TTM rank tuple: interior = max interior TT rank for a fair
        // same-rank comparison (the paper sweeps a single scalar r).
        let r = self.interior_rank();
        (0..d)
            .map(|k| {
                let rp = if k == 0 { 1 } else { r };
                let rk = if k == d - 1 { 1 } else { r };
                rp * self.m_modes[k] as u64 * self.n_modes[k] as u64 * rk
            })
            .sum()
    }

    fn interior_rank(&self) -> u64 {
        self.ranks[1..self.ranks.len() - 1]
            .iter()
            .copied()
            .max()
            .unwrap_or(1) as u64
    }

    // -- MM ----------------------------------------------------------------

    /// Dense forward multiplies: K M N.
    pub fn mm_muls(&self, k: u64) -> u64 {
        k * self.m() * self.n()
    }

    /// Dense weight memory (elements).
    pub fn mm_weight(&self) -> u64 {
        self.m() * self.n()
    }

    // -- TT right-to-left (paper Eq. 18 / 19) --------------------------------

    /// Eq. 18: forward multiplies of the right-to-left TT contraction.
    pub fn tt_rl_muls(&self, k_dim: u64) -> u64 {
        let d = self.d();
        let r = |i: usize| self.ranks[i] as u64;
        let mut total = 0u64;
        for k in 0..d {
            // input side: r_{2d-k-1} r_{2d-k} prod_{i=1}^{d-k} n_i
            let prod_n: u64 = self.n_modes[..d - k].iter().map(|&x| x as u64).product();
            total += r(2 * d - k - 1) * r(2 * d - k) * prod_n;
            // output side: r_{d-k-1} r_{d-k} prod_{i=d-k}^{d} m_i
            let prod_m: u64 = self.m_modes[d - k - 1..].iter().map(|&x| x as u64).product();
            total += r(d - k - 1) * r(d - k) * prod_m;
        }
        k_dim * total
    }

    /// Eq. 19: intermediate activation memory (elements) stored by the
    /// right-to-left TT contraction (2d-1 intermediates, all carrying K).
    pub fn tt_rl_memory(&self, k_dim: u64) -> u64 {
        let d = self.d();
        let r = |i: usize| self.ranks[i] as u64;
        let mut total = r(d); // K r_d middle intermediate
        for k in 0..d.saturating_sub(1) {
            let prod_n: u64 = self.n_modes[..d - k - 1].iter().map(|&x| x as u64).product();
            total += r(2 * d - k - 1) * prod_n;
            let prod_m: u64 = self.m_modes[d - k - 1..].iter().map(|&x| x as u64).product();
            total += r(d - k - 1) * prod_m;
        }
        k_dim * total
    }

    // -- BTT (paper Eq. 20 / 21) ---------------------------------------------

    /// K-independent multiplies of the **left (output-side) merge
    /// chain** `G_1..G_d -> Z3` — the `m`-sum of Eq. 20, split out so
    /// the fused-QKV expression below can charge it per projection.
    pub fn btt_left_merge_muls(&self) -> u64 {
        let d = self.d();
        let r = |i: usize| self.ranks[i] as u64;
        (0..d.saturating_sub(1))
            .map(|k| {
                let prod_m: u64 = self.m_modes[..k + 2].iter().map(|&x| x as u64).product();
                r(k + 1) * r(k + 2) * prod_m
            })
            .sum()
    }

    /// K-independent multiplies of the **right (input-side) merge
    /// chain** `G_2d..G_{d+1} -> Z1` — the `n`-sum of Eq. 20, shared
    /// across Q/K/V by the fused path.
    pub fn btt_right_merge_muls(&self) -> u64 {
        let d = self.d();
        let r = |i: usize| self.ranks[i] as u64;
        (0..d.saturating_sub(1))
            .map(|k| {
                let prod_n: u64 = self.n_modes[d - k - 2..].iter().map(|&x| x as u64).product();
                r(2 * d - k - 1) * r(2 * d - k - 2) * prod_n
            })
            .sum()
    }

    /// Stored elements of the left merge chain (the `m`-terms of
    /// Eq. 21; the first chain state is a reshaped core and excluded).
    pub fn btt_left_chain_elems(&self) -> u64 {
        let d = self.d();
        let r = |i: usize| self.ranks[i] as u64;
        (0..d.saturating_sub(1))
            .map(|k| {
                let prod_m: u64 = self.m_modes[..k + 2].iter().map(|&x| x as u64).product();
                r(k + 1) * prod_m
            })
            .sum()
    }

    /// Stored elements of the right merge chain (the `n`-terms of
    /// Eq. 21).
    pub fn btt_right_chain_elems(&self) -> u64 {
        let d = self.d();
        let r = |i: usize| self.ranks[i] as u64;
        (0..d.saturating_sub(1))
            .map(|k| {
                let prod_n: u64 = self.n_modes[d - k - 2..].iter().map(|&x| x as u64).product();
                r(2 * d - k - 2) * prod_n
            })
            .sum()
    }

    /// Eq. 20: forward multiplies of the bidirectional contraction —
    /// both merges plus the two K-dependent applies.
    pub fn btt_muls(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        self.btt_left_merge_muls()
            + self.btt_right_merge_muls()
            + k_dim * r_d * (self.m() + self.n())
    }

    /// Eq. 21: intermediate memory (elements) of the BTT contraction —
    /// only the final Z2 term carries K.
    pub fn btt_memory(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        self.btt_left_chain_elems() + self.btt_right_chain_elems() + k_dim * r_d
    }

    // -- Fused QKV (Fig. 9 rescheduling, executed) ---------------------------

    /// Forward multiplies of the **fused QKV** pass (three projections
    /// with tied input-side cores, `crate::train::layers::
    /// forward_qkv_fused`).  The companion of Eq. 20 for the fused
    /// schedule: the right merge and the K-wide `Z2 = X Z1^T` are
    /// charged **once**, the left merges and output applies three
    /// times —
    ///
    /// ```text
    /// C_qkv = 3 C_left + C_right + K r_d (N + 3 M)
    /// ```
    ///
    /// vs `3 (C_left + C_right + K r_d (N + M))` for three separate
    /// forwards: strictly fewer for every K >= 1.
    pub fn btt_fwd_qkv_muls(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        3 * self.btt_left_merge_muls()
            + self.btt_right_merge_muls()
            + k_dim * r_d * (self.n() + 3 * self.m())
    }

    /// BP-stage multiplies of the fused QKV pass: exactly 2x the fused
    /// forward (the input-side gradient flows through one summed dZ2,
    /// so dZ1/dX and the right-chain unroll are also charged once).
    pub fn btt_qkv_bwd_muls(&self, k_dim: u64) -> u64 {
        2 * self.btt_fwd_qkv_muls(k_dim)
    }

    /// Eq. 21 companion for the fused QKV pass: three left chains, one
    /// shared right chain, one shared K-carrying Z2.
    ///
    /// ```text
    /// M_qkv = 3 M_left + M_right + K r_d
    /// ```
    pub fn btt_qkv_memory(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        3 * self.btt_left_chain_elems() + self.btt_right_chain_elems() + k_dim * r_d
    }

    // -- TTM right-to-left (Table I row 2, generalized) ----------------------

    /// Forward multiplies of a TTM-format linear layer contracted
    /// right-to-left: step k (from d down to 1) contracts over
    /// (n_k, r_k) and introduces m_k.
    pub fn ttm_muls(&self, k_dim: u64) -> u64 {
        let d = self.d();
        let r = self.interior_rank();
        let rk = |i: usize| -> u64 {
            if i == 0 || i == d {
                1
            } else {
                r
            }
        };
        let mut total = 0u64;
        for k in (1..=d).rev() {
            let prod_n: u64 = self.n_modes[..k - 1].iter().map(|&x| x as u64).product();
            let prod_m: u64 = self.m_modes[k..].iter().map(|&x| x as u64).product();
            total += prod_n
                * prod_m
                * self.m_modes[k - 1] as u64
                * self.n_modes[k - 1] as u64
                * rk(k - 1)
                * rk(k);
        }
        k_dim * total
    }

    /// Intermediate activation memory of the TTM contraction (d-1
    /// intermediates, each carrying K and a full mixed n/m prefix).
    pub fn ttm_memory(&self, k_dim: u64) -> u64 {
        let d = self.d();
        let r = self.interior_rank();
        let mut total = 0u64;
        for k in (1..d).rev() {
            let prod_n: u64 = self.n_modes[..k].iter().map(|&x| x as u64).product();
            let prod_m: u64 = self.m_modes[k..].iter().map(|&x| x as u64).product();
            total += prod_n * prod_m * r;
        }
        k_dim * total
    }

    /// BP-stage multiplies of one BTT linear layer.
    ///
    /// The hand-derived backward (see `crate::train::layers`) runs
    /// exactly twice Eq. 20: the four K-wide products (`dZ3 = dY^T Z2`,
    /// `dZ2 = dY Z3`, `dZ1 = dZ2^T X`, `dX = dZ2 Z1`) cost
    /// `2 K r_d (M + N)` — twice the forward apply — and unrolling each
    /// merge-chain step costs two products of the forward step's size
    /// (the core gradient and the carried state gradient).  Together
    /// with Eq. 20 this realizes the paper's FP+BP ~ 3x forward rule
    /// ([`LinearShape::training_factor`]).
    pub fn btt_bwd_muls(&self, k_dim: u64) -> u64 {
        2 * self.btt_muls(k_dim)
    }

    /// Activation elements a training step stores for the BP stage of
    /// one BTT layer: the merge-chain intermediates plus Z2 — exactly
    /// the Eq. 21 forward intermediate memory (the input X is an
    /// upstream activation, accounted by the producing layer).
    pub fn btt_training_cache_elems(&self, k_dim: u64) -> u64 {
        self.btt_memory(k_dim)
    }

    /// Training FLOPs ~ 3x forward multiplies (paper Sec. IV-A).
    pub fn training_factor() -> u64 {
        3
    }

    /// PU-stage optimizer-state elements for this layer: state mirrors
    /// the compressed parameters (cores + bias), `state_multiplier`
    /// copies (0 for SGD, 1 for momentum, 2 for Adam/AdamW — see
    /// `crate::optim::OptimKind::state_multiplier`).  K-independent:
    /// unlike the Eq. 21 caches, optimizer state never carries the
    /// sequence dimension.
    pub fn optimizer_state_elems(&self, state_multiplier: u64) -> u64 {
        state_multiplier * (self.tt_params() + self.m())
    }

    // -- Gradient checkpointing (recompute the Eq. 21 cache in the BP stage) -

    /// FLOP delta of the `Recompute` checkpoint policy for one BTT
    /// layer — the compute side of the Eq. 20/21 memory/FLOP trade.
    /// Before unrolling the chains, the BP stage re-runs both merges
    /// and `Z2 = X Z1^T` from the stored layer input, but **never** the
    /// output apply `Y = Z2 Z3^T` (only the intermediates feed the
    /// gradient contractions):
    ///
    /// ```text
    /// C_re = C_left + C_right + K r_d N   <   C_fwd (Eq. 20)
    /// ```
    ///
    /// so a fully recomputed layer trains at `(3 + C_re/C_fwd) < 4`
    /// times the forward multiplies instead of the cached-path 3x
    /// ([`LinearShape::training_factor`]).
    pub fn btt_recompute_muls(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        self.btt_left_merge_muls() + self.btt_right_merge_muls() + k_dim * r_d * self.n()
    }

    /// Recompute-FLOP delta of the fused QKV pass (companion of
    /// [`LinearShape::btt_fwd_qkv_muls`]): the shared right merge and
    /// `Z2` are rebuilt once, the three left merges per projection, and
    /// none of the three output applies —
    ///
    /// ```text
    /// C_qkv_re = 3 C_left + C_right + K r_d N
    /// ```
    pub fn btt_qkv_recompute_muls(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        3 * self.btt_left_merge_muls() + self.btt_right_merge_muls() + k_dim * r_d * self.n()
    }

    // -- Per-precision byte accounting (mixed-precision storage path) --------

    /// Eq. 21 intermediate memory in **bytes** at a storage precision —
    /// element counts are precision-independent, the bytes halve for
    /// the 16-bit formats and drop to ~1/4 (1 code byte + 1/16 scale
    /// byte per element) for block-scaled int8
    /// ([`crate::tensor::Precision::storage_bytes`]).
    pub fn btt_memory_bytes(&self, k_dim: u64, precision: crate::tensor::Precision) -> u64 {
        precision.storage_bytes(self.btt_memory(k_dim))
    }

    /// Fused-QKV Eq. 21 cache in bytes at a storage precision.
    pub fn btt_qkv_memory_bytes(&self, k_dim: u64, precision: crate::tensor::Precision) -> u64 {
        precision.storage_bytes(self.btt_qkv_memory(k_dim))
    }

    /// Eq. 21 bytes one BTT layer holds **at rest** between FP and BP
    /// under a checkpointing mode: the cached path stores the full
    /// chain + Z2 ([`LinearShape::btt_memory_bytes`]); the recompute
    /// path stores nothing beyond the layer input (itself accounted to
    /// the producing layer), trading the bytes for
    /// [`LinearShape::btt_recompute_muls`].  The BP stage transiently
    /// rebuilds one layer's chain + Z2 at a time, so the *peak* live
    /// intra-layer set under recompute is a single `btt_memory_bytes`,
    /// never the sum over layers.
    pub fn btt_memory_bytes_checkpointed(
        &self,
        k_dim: u64,
        precision: crate::tensor::Precision,
        recompute: bool,
    ) -> u64 {
        if recompute {
            0
        } else {
            self.btt_memory_bytes(k_dim, precision)
        }
    }

    /// Fused-QKV counterpart of
    /// [`LinearShape::btt_memory_bytes_checkpointed`].
    pub fn btt_qkv_memory_bytes_checkpointed(
        &self,
        k_dim: u64,
        precision: crate::tensor::Precision,
        recompute: bool,
    ) -> u64 {
        if recompute {
            0
        } else {
            self.btt_qkv_memory_bytes(k_dim, precision)
        }
    }

    /// PU-stage optimizer-state bytes at a storage precision, charged
    /// per moment buffer (`state_multiplier` contiguous buffers of the
    /// per-moment element count) so the int8 per-block scale sidecar is
    /// counted the way the slots allocate it.
    pub fn optimizer_state_bytes(
        &self,
        state_multiplier: u64,
        precision: crate::tensor::Precision,
    ) -> u64 {
        state_multiplier * precision.storage_bytes(self.optimizer_state_elems(1))
    }

    // -- Batched serving (shared engine, merged factors at rest) -------------

    /// Forward multiplies of one BTT linear in the **serving** engine
    /// at contraction width `k = B * S`: only the two K-wide applies
    /// `Z2 = X Z1^T`, `Y = Z2 Z3^T`.  The merge chains are folded
    /// *once* at engine construction (`crate::engine::MergedLinear`)
    /// and amortize over every request, so unlike training (Eq. 20)
    /// they are not charged per batch:
    ///
    /// ```text
    /// C_serve = K r_d (M + N)  =  C_fwd - C_left - C_right
    /// ```
    pub fn btt_serve_muls(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        k_dim * r_d * (self.m() + self.n())
    }

    /// Serving multiplies of the fused QKV projections (tied input
    /// cores): one shared `Z2`, three output applies —
    ///
    /// ```text
    /// C_serve_qkv = K r_d (N + 3 M)
    /// ```
    pub fn btt_serve_qkv_muls(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        k_dim * r_d * (self.n() + 3 * self.m())
    }

    /// At-rest weight memory of the serving engine's merged factors for
    /// one linear: `Z3 (M, r_d)` + `Z1 (r_d, N)` elements.  This is the
    /// inference analog of the weight column — the raw cores are not
    /// kept after merging.
    pub fn merged_factor_elems(&self) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        r_d * (self.m() + self.n())
    }

    /// Transient per-batch memory of one serving forward: only the
    /// K-carrying `Z2 (K, r_d)` — the Eq. 21 chain-state charge
    /// training pays for the BP stage does **not** apply to inference:
    ///
    /// ```text
    /// M_serve = K r_d  =  M_fwd (Eq. 21) - M_left - M_right
    /// ```
    pub fn btt_serve_transient_elems(&self, k_dim: u64) -> u64 {
        let r_d = self.ranks[self.d()] as u64;
        k_dim * r_d
    }

    /// Serving transient bytes at a storage precision (the engine
    /// rounds `Z2` on store at half precisions, mirroring training).
    pub fn btt_serve_transient_bytes(
        &self,
        k_dim: u64,
        precision: crate::tensor::Precision,
    ) -> u64 {
        precision.storage_bytes(self.btt_serve_transient_elems(k_dim))
    }
}

// -- Data-parallel gradient exchange (compressed-core all-reduce) -----------

/// Bytes of one replica's complete compressed-core gradient set at a
/// wire precision: every trainable scalar
/// ([`crate::config::ModelConfig::tensor_params`] — TT/TTM cores,
/// biases, LayerNorm vectors, heads, positional table) times the
/// element width.  This is the per-replica unit `G` of the exchange —
/// tiny by construction, which is the paper's compression argument
/// applied to scale-out.  Upper bound for the fused-QKV schedule: the
/// tied input-side cores travel **once** in the actual
/// [`crate::train::GradMap`], so the realized exchange is slightly
/// smaller than this untied count (the measured figure is published as
/// the `allreduce_grad_bytes` gauge).
pub fn core_grad_bytes(cfg: &crate::config::ModelConfig, prec: crate::tensor::Precision) -> u64 {
    prec.storage_bytes(cfg.tensor_params() as u64)
}

/// Per-device traffic of a ring all-reduce over `n` devices:
/// `2 (n−1)/n · grad_bytes` (reduce-scatter + all-gather, each moving
/// `(n−1)/n` of the buffer).  Zero for a single device.
pub fn ring_allreduce_bytes(grad_bytes: u64, n: usize) -> u64 {
    if n <= 1 {
        0
    } else {
        grad_bytes * 2 * (n as u64 - 1) / n as u64
    }
}

/// Root traffic of the naive gather-then-broadcast reduction: the root
/// receives `(n−1)` full gradient buffers (and broadcasts `(n−1)`
/// parameter copies back).  The in-process [`crate::replica`] exchange
/// has this shape — affordable precisely because `grad_bytes` is
/// compressed-core sized.
pub fn naive_allreduce_bytes(grad_bytes: u64, n: usize) -> u64 {
    grad_bytes * (n as u64).saturating_sub(1)
}

/// One row of a Fig. 6-style comparison.
#[derive(Debug, Clone)]
pub struct CostRow {
    pub method: &'static str,
    pub fwd_muls: u64,
    /// Intermediate-activation elements (Eqs. 19/21; 0 for MM).
    pub memory_elems: u64,
    /// Weight (+bias) elements.
    pub weight_elems: u64,
    /// Total intra-layer memory = weights + bias + intermediates — the
    /// quantity behind the paper's Fig. 6 bars (its 22.67x MM/BTT example
    /// reproduces only with weights included).
    pub total_memory: u64,
    /// Reduction ratios vs MM (compute, memory), the paper's y-axes.
    pub compute_reduction: f64,
    pub memory_reduction: f64,
}

/// Compare all four schemes at a given K (Fig. 6).
pub fn compare_all(shape: &LinearShape, k_dim: u64) -> Vec<CostRow> {
    let bias = shape.m();
    let mm_muls = shape.mm_muls(k_dim);
    let mm_total = shape.mm_weight() + bias; // dense: no intermediates
    let rows = [
        ("MM", mm_muls, 0, shape.mm_weight() + bias),
        (
            "TTM",
            shape.ttm_muls(k_dim),
            shape.ttm_memory(k_dim),
            shape.ttm_params() + bias,
        ),
        (
            "TT",
            shape.tt_rl_muls(k_dim),
            shape.tt_rl_memory(k_dim),
            shape.tt_params() + bias,
        ),
        (
            "BTT",
            shape.btt_muls(k_dim),
            shape.btt_memory(k_dim),
            shape.tt_params() + bias,
        ),
    ];
    rows.iter()
        .map(|&(method, muls, mem, weight)| CostRow {
            method,
            fwd_muls: muls,
            memory_elems: mem,
            weight_elems: weight,
            total_memory: weight + mem,
            compute_reduction: mm_muls as f64 / muls as f64,
            memory_reduction: mm_total as f64 / (weight + mem) as f64,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Tensor, TTMatrix};
    use crate::util::prop;
    use crate::util::rng::SplitMix64;

    /// The analytic model must match the instrumented contraction engine
    /// *exactly* — multiplies and stored intermediates.
    #[test]
    fn eq18_eq19_match_instrumented_rl() {
        prop::check(31, 20, |rng| {
            let d = 2 + rng.below(2) as usize; // d in {2, 3}
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(5) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(5) as usize).collect();
            let rank = 1 + rng.below(6) as usize;
            let k_dim = 1 + rng.below(24) as usize;
            let tt = TTMatrix::randn(&m_modes, &n_modes, rank, 0.1, rng);
            let shape = LinearShape {
                m_modes: m_modes.clone(),
                n_modes: n_modes.clone(),
                ranks: tt.ranks.clone(),
            };
            let x = Tensor::randn(&[tt.n(), k_dim], 1.0, rng);
            let (_, stats) = tt.matmul_right_to_left(&x).unwrap();
            assert_eq!(stats.muls, shape.tt_rl_muls(k_dim as u64), "Eq.18 mismatch");
            assert_eq!(
                stats.stored_intermediate_elems,
                shape.tt_rl_memory(k_dim as u64),
                "Eq.19 mismatch"
            );
        });
    }

    #[test]
    fn eq20_eq21_match_instrumented_btt() {
        prop::check(32, 20, |rng| {
            let d = 2 + rng.below(2) as usize;
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(5) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(5) as usize).collect();
            let rank = 1 + rng.below(6) as usize;
            let k_dim = 1 + rng.below(24) as usize;
            let tt = TTMatrix::randn(&m_modes, &n_modes, rank, 0.1, rng);
            let shape = LinearShape {
                m_modes,
                n_modes,
                ranks: tt.ranks.clone(),
            };
            let x = Tensor::randn(&[tt.n(), k_dim], 1.0, rng);
            let (_, stats) = tt.matmul_btt(&x).unwrap();
            assert_eq!(stats.muls, shape.btt_muls(k_dim as u64), "Eq.20 mismatch");
            assert_eq!(
                stats.stored_intermediate_elems,
                shape.btt_memory(k_dim as u64),
                "Eq.21 mismatch"
            );
        });
    }

    /// Paper Sec. IV-B example: BTT vs MM is ~22.5x compute and ~22.7x
    /// memory at the Table II attention shape with seq len 32.
    #[test]
    fn fig6_paper_example_ratios() {
        let shape = LinearShape::uniform(&[8, 8, 12], &[12, 8, 8], 12);
        let k = 32;
        let mm = shape.mm_muls(k) as f64;
        let btt = shape.btt_muls(k) as f64;
        let compute_ratio = mm / btt;
        assert!(
            (compute_ratio - 22.5).abs() < 1.5,
            "compute ratio {compute_ratio:.2} (paper: 22.51x)"
        );
        // Memory: paper's 22.67x reproduces with weights + bias +
        // Eq. 21 intermediates on both sides.
        let mm_total = (shape.mm_weight() + shape.m()) as f64;
        let btt_total = (shape.tt_params() + shape.m() + shape.btt_memory(k)) as f64;
        let mem_ratio = mm_total / btt_total;
        assert!(
            (mem_ratio - 22.67).abs() < 1.0,
            "memory ratio {mem_ratio:.2} (paper: 22.67x)"
        );
        // BTT vs right-to-left TT: the paper reports 1.49x compute and
        // 2.31x memory; our exact Eq. 18-21 arithmetic gives ~1.9x / ~3.3x
        // (at least the claimed factors — see EXPERIMENTS.md note).
        let tt_total = (shape.tt_params() + shape.m() + shape.tt_rl_memory(k)) as f64;
        assert!(shape.tt_rl_muls(k) as f64 / btt >= 1.49);
        assert!(tt_total / btt_total >= 2.31);
    }

    /// BTT strictly beats right-to-left TT whenever K exceeds the modes
    /// (the paper's Sec. IV-B claim), property-tested.
    #[test]
    fn btt_beats_rl_for_large_k() {
        prop::check(33, 30, |rng| {
            let d = 2 + rng.below(2) as usize;
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(8) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(8) as usize).collect();
            let rank = 1 + rng.below(8) as usize;
            let shape = LinearShape::uniform(&m_modes, &n_modes, rank);
            let max_mode = *m_modes.iter().chain(&n_modes).max().unwrap() as u64;
            let k = max_mode * (2 + rng.below(16));
            assert!(shape.btt_muls(k) <= shape.tt_rl_muls(k));
            assert!(shape.btt_memory(k) <= shape.tt_rl_memory(k));
        });
    }

    #[test]
    fn merge_split_reassembles_eq20_eq21() {
        // The left/right split must reassemble exactly into Eq. 20/21.
        prop::check(34, 20, |rng| {
            let d = 2 + rng.below(2) as usize;
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(5) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(5) as usize).collect();
            let rank = 1 + rng.below(6) as usize;
            let k = 1 + rng.below(24) as u64;
            let shape = LinearShape::uniform(&m_modes, &n_modes, rank);
            let r_d = shape.ranks[shape.d()] as u64;
            assert_eq!(
                shape.btt_muls(k),
                shape.btt_left_merge_muls()
                    + shape.btt_right_merge_muls()
                    + k * r_d * (shape.m() + shape.n())
            );
            assert_eq!(
                shape.btt_memory(k),
                shape.btt_left_chain_elems() + shape.btt_right_chain_elems() + k * r_d
            );
        });
    }

    #[test]
    fn fused_qkv_strictly_cheaper_than_three_forwards() {
        // The fused-QKV expression saves two right merges and two
        // K-wide Z2 products vs three separate forwards, for every
        // shape and every K >= 1.
        prop::check(35, 30, |rng| {
            let d = 1 + rng.below(3) as usize; // d in {1, 2, 3}
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(6) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(6) as usize).collect();
            let rank = 1 + rng.below(8) as usize;
            let k = 1 + rng.below(64) as u64;
            let shape = LinearShape::uniform(&m_modes, &n_modes, rank);
            let r_d = shape.ranks[shape.d()] as u64;
            assert!(shape.btt_fwd_qkv_muls(k) < 3 * shape.btt_muls(k));
            assert!(shape.btt_qkv_memory(k) < 3 * shape.btt_memory(k));
            // Exactly the claimed saving: 2 right merges + 2 K r_d N.
            assert_eq!(
                3 * shape.btt_muls(k) - shape.btt_fwd_qkv_muls(k),
                2 * shape.btt_right_merge_muls() + 2 * k * r_d * shape.n()
            );
            // And BP stays the 2x rule (3x training factor overall).
            assert_eq!(shape.btt_qkv_bwd_muls(k), 2 * shape.btt_fwd_qkv_muls(k));
        });
    }

    #[test]
    fn fused_qkv_paper_shape_saving() {
        // At the Table II shape and seq len 32 the fused schedule drops
        // about a third of the QKV forward multiplies.
        let shape = LinearShape::paper();
        let sep = 3 * shape.btt_muls(32);
        let fused = shape.btt_fwd_qkv_muls(32);
        let saving = (sep - fused) as f64 / sep as f64;
        assert!(
            (0.25..0.45).contains(&saving),
            "fused saves {saving:.2} of 3x separate (expected ~1/3)"
        );
    }

    #[test]
    fn backward_formulas_close_the_training_factor() {
        // FP (Eq. 20) + BP (2x Eq. 20) == the paper's 3x training rule.
        let shape = LinearShape::paper();
        for k in [1u64, 8, 32, 128] {
            assert_eq!(
                shape.btt_muls(k) + shape.btt_bwd_muls(k),
                LinearShape::training_factor() * shape.btt_muls(k)
            );
            assert_eq!(shape.btt_training_cache_elems(k), shape.btt_memory(k));
        }
    }

    #[test]
    fn optimizer_state_is_k_independent_and_scales_with_multiplier() {
        let shape = LinearShape::paper();
        let params = shape.tt_params() + shape.m();
        assert_eq!(shape.optimizer_state_elems(0), 0);
        assert_eq!(shape.optimizer_state_elems(1), params);
        assert_eq!(shape.optimizer_state_elems(2), 2 * params);
        // Dense-equivalent Adam state would be 2 M N; compressed state
        // keeps the full compression ratio.
        assert!(shape.optimizer_state_elems(2) < 2 * shape.mm_weight() / 20);
    }

    #[test]
    fn recompute_flop_delta_is_strictly_below_one_forward() {
        // The recompute pass skips the output apply, so C_re < C_fwd
        // for every shape and K, and a fully recomputed layer trains
        // strictly under 4x forward multiplies.
        prop::check(36, 30, |rng| {
            let d = 1 + rng.below(3) as usize;
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(6) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(6) as usize).collect();
            let rank = 1 + rng.below(8) as usize;
            let k = 1 + rng.below(64) as u64;
            let shape = LinearShape::uniform(&m_modes, &n_modes, rank);
            let r_d = shape.ranks[shape.d()] as u64;
            // Exactly the forward minus the K-wide output apply.
            assert_eq!(
                shape.btt_recompute_muls(k),
                shape.btt_muls(k) - k * r_d * shape.m()
            );
            assert!(shape.btt_recompute_muls(k) < shape.btt_muls(k));
            assert!(
                shape.btt_muls(k) + shape.btt_bwd_muls(k) + shape.btt_recompute_muls(k)
                    < 4 * shape.btt_muls(k)
            );
            // Fused QKV: forward minus the three output applies.
            assert_eq!(
                shape.btt_qkv_recompute_muls(k),
                shape.btt_fwd_qkv_muls(k) - 3 * k * r_d * shape.m()
            );
        });
    }

    #[test]
    fn checkpointed_bytes_drop_to_zero_at_rest() {
        use crate::tensor::Precision;
        let shape = LinearShape::paper();
        for k in [1u64, 8, 32] {
            for prec in Precision::all() {
                assert_eq!(
                    shape.btt_memory_bytes_checkpointed(k, prec, false),
                    shape.btt_memory_bytes(k, prec)
                );
                assert_eq!(shape.btt_memory_bytes_checkpointed(k, prec, true), 0);
                assert_eq!(
                    shape.btt_qkv_memory_bytes_checkpointed(k, prec, false),
                    shape.btt_qkv_memory_bytes(k, prec)
                );
                assert_eq!(shape.btt_qkv_memory_bytes_checkpointed(k, prec, true), 0);
            }
        }
    }

    #[test]
    fn half_precision_byte_accounting_halves_every_row() {
        use crate::tensor::Precision;
        let shape = LinearShape::paper();
        for k in [1u64, 8, 32] {
            for prec in [Precision::Bf16, Precision::F16] {
                assert_eq!(
                    2 * shape.btt_memory_bytes(k, prec),
                    shape.btt_memory_bytes(k, Precision::F32)
                );
                assert_eq!(
                    2 * shape.btt_qkv_memory_bytes(k, prec),
                    shape.btt_qkv_memory_bytes(k, Precision::F32)
                );
                assert_eq!(
                    2 * shape.optimizer_state_bytes(2, prec),
                    shape.optimizer_state_bytes(2, Precision::F32)
                );
            }
        }
        assert_eq!(
            shape.btt_memory_bytes(32, Precision::F32),
            4 * shape.btt_memory(32)
        );
    }

    #[test]
    fn serving_entries_are_the_forward_minus_the_amortized_merges() {
        // The serving engine folds the merge chains once at load, so
        // per-batch compute is exactly Eq. 20 minus both merges, and
        // the per-batch transient is exactly Eq. 21 minus both chains.
        prop::check(37, 30, |rng| {
            let d = 1 + rng.below(3) as usize;
            let m_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(6) as usize).collect();
            let n_modes: Vec<usize> = (0..d).map(|_| 2 + rng.below(6) as usize).collect();
            let rank = 1 + rng.below(8) as usize;
            let k = 1 + rng.below(64) as u64;
            let shape = LinearShape::uniform(&m_modes, &n_modes, rank);
            let r_d = shape.ranks[shape.d()] as u64;
            assert_eq!(
                shape.btt_serve_muls(k),
                shape.btt_muls(k) - shape.btt_left_merge_muls() - shape.btt_right_merge_muls()
            );
            assert_eq!(
                shape.btt_serve_qkv_muls(k),
                shape.btt_fwd_qkv_muls(k)
                    - 3 * shape.btt_left_merge_muls()
                    - shape.btt_right_merge_muls()
            );
            assert_eq!(
                shape.btt_serve_transient_elems(k),
                shape.btt_memory(k)
                    - shape.btt_left_chain_elems()
                    - shape.btt_right_chain_elems()
            );
            // Fused QKV serving shares Z2: saves exactly 2 K r_d N vs
            // three separate applies.
            assert_eq!(
                3 * shape.btt_serve_muls(k) - shape.btt_serve_qkv_muls(k),
                2 * k * r_d * shape.n()
            );
            assert_eq!(shape.merged_factor_elems(), r_d * (shape.m() + shape.n()));
        });
    }

    #[test]
    fn serving_bytes_follow_precision() {
        use crate::tensor::Precision;
        let shape = LinearShape::paper();
        for k in [1u64, 8, 32] {
            for prec in [Precision::Bf16, Precision::F16] {
                assert_eq!(
                    2 * shape.btt_serve_transient_bytes(k, prec),
                    shape.btt_serve_transient_bytes(k, Precision::F32)
                );
            }
            assert_eq!(
                shape.btt_serve_transient_bytes(k, Precision::F32),
                4 * shape.btt_serve_transient_elems(k)
            );
        }
    }

    #[test]
    fn compare_all_orders_btt_best() {
        let rows = compare_all(&LinearShape::paper(), 32);
        let btt = rows.iter().find(|r| r.method == "BTT").unwrap();
        for r in &rows {
            assert!(btt.fwd_muls <= r.fwd_muls, "BTT not best vs {}", r.method);
        }
    }
}
