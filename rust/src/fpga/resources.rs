//! Fabric resource occupancy model (paper Table IV).
//!
//! The paper's RTL reports show DSP/LUT/FF *constant* across model sizes
//! (the same kernel set is instantiated regardless of layer count) while
//! BRAM shifts to URAM as layer count grows (HLS moves the grouped
//! inter-layer activation arrays to URAM).  This module reproduces that
//! structure from components:
//!
//! * compute kernels (fixed set -> fixed DSP/LUT/FF),
//! * TT/TTM parameter storage (BRAM, from [`super::bram`]),
//! * activation/gradient buffers (BRAM or URAM by size threshold).
//!
//! Constants are calibrated to the paper's Table IV within tolerance
//! (tests); the *trends* (what grows, what does not) are structural.

use super::bram::{self, Strategy};
use crate::config::{ModelConfig, U50};

/// Utilization of one fabric resource.
#[derive(Debug, Clone, Copy)]
pub struct Util {
    pub used: usize,
    pub available: usize,
}

impl Util {
    pub fn pct(&self) -> f64 {
        100.0 * self.used as f64 / self.available as f64
    }
}

/// Full resource report for one model configuration.
#[derive(Debug, Clone)]
pub struct ResourceReport {
    pub n_layers: usize,
    pub dsp: Util,
    pub lut: Util,
    pub ff: Util,
    pub bram: Util,
    pub uram: Util,
    pub dynamic_power_w: f64,
    pub static_power_w: f64,
}

impl ResourceReport {
    pub fn total_power_w(&self) -> f64 {
        self.dynamic_power_w + self.static_power_w
    }

    /// On-chip memory in MB (BRAM + URAM actually occupied).
    pub fn onchip_memory_mb(&self) -> f64 {
        (self.bram.used * U50::BRAM_BITS + self.uram.used * U50::URAM_BITS) as f64 / 8.0 / 1e6
    }
}

/// Compute-kernel DSP/LUT/FF costs (fixed across model sizes).
///
/// Breakdown calibrated to the paper's 2396 DSP / 565k LUT / 475k FF
/// totals: rank-parallel contraction kernels dominate DSP; control,
/// AXI/stream glue and the nonlinear function lanes dominate LUT.
struct KernelCosts;

impl KernelCosts {
    // (dsp, lut, ff) per kernel instance.
    const MUL0: (usize, usize, usize) = (60, 14_000, 12_000); // x2 units
    const MUL1: (usize, usize, usize) = (384, 52_000, 46_000);
    const MUL2: (usize, usize, usize) = (384, 52_000, 46_000);
    const MUL3: (usize, usize, usize) = (384, 52_000, 46_000);
    const MM_ATTN: (usize, usize, usize) = (768, 120_000, 98_000);
    const SOFTMAX: (usize, usize, usize) = (96, 48_000, 40_000);
    const GELU: (usize, usize, usize) = (64, 36_000, 30_000);
    const LAYERNORM: (usize, usize, usize) = (96, 44_000, 38_000);
    const LOOKUP: (usize, usize, usize) = (60, 22_000, 18_000);
    const CONTROL: (usize, usize, usize) = (40, 111_000, 89_000);

    fn total() -> (usize, usize, usize) {
        let parts = [
            (Self::MUL0, 2usize),
            (Self::MUL1, 1),
            (Self::MUL2, 1),
            (Self::MUL3, 1),
            (Self::MM_ATTN, 1),
            (Self::SOFTMAX, 1),
            (Self::GELU, 1),
            (Self::LAYERNORM, 1),
            (Self::LOOKUP, 1),
            (Self::CONTROL, 1),
        ];
        let mut acc = (0, 0, 0);
        for ((d, l, f), n) in parts {
            acc.0 += d * n;
            acc.1 += l * n;
            acc.2 += f * n;
        }
        acc
    }
}

/// Activation / gradient buffer words needed on-chip per model
/// (double-buffered current-layer activations + BTT intermediates +
/// attention scratch), plus inter-layer activation stash that scales
/// with depth (spilled to URAM; beyond the URAM high-water mark the
/// coordinator streams to HBM, Sec. V-A).
fn activation_words(cfg: &ModelConfig) -> (usize, usize) {
    let k = cfg.batch * cfg.seq_len;
    let h = cfg.d_hid;
    // Current-layer working set (BRAM side): x, q, k, v, attn, ffn
    // hidden and their gradients, double-buffered.
    let working = 8 * k * h * 2;
    // BTT intermediates per linear: Z1, Z3, Z2 (+ grads).
    let r = cfg.tt_rank;
    let btt = 2 * (r * h * 2 + r * k);
    // Inter-layer stash for BP: one activation set per encoder layer
    // (the part the paper moves to URAM as L grows).
    let stash = cfg.n_layers * 6 * k * h;
    (working + btt, stash)
}

/// Build the Table IV row for a model configuration.
pub fn report(cfg: &ModelConfig) -> ResourceReport {
    let (dsp, lut, ff) = KernelCosts::total();

    // Parameter storage in BRAM via the grouped-reshape allocator.
    let cores = bram::paper_core_set(cfg.n_layers, cfg.tt_rank);
    let group_k = bram::paper_group_k(cfg.tt_m.len(), cfg.n_layers);
    let alloc = bram::allocate(&cores, Strategy::ReshapeGrouped, group_k);

    // Activation working set: BRAM; deep-layer stash: URAM.
    let (work_words, stash_words) = activation_words(cfg);
    let work_bram = (work_words * 32).div_ceil(U50::BRAM_BITS);
    let stash_uram = (stash_words * 32).div_ceil(U50::URAM_BITS);

    // Biases, LN params, head weights: small, BRAM.
    let small_words = cfg.n_layers * 10 * cfg.d_hid
        + (cfg.n_intents + cfg.n_slots) * (cfg.d_hid + 1)
        + cfg.seq_len * cfg.d_hid;
    let small_bram = (small_words * 32).div_ceil(U50::BRAM_BITS);

    // HLS pragma overhead: fixed partitioned control FIFOs etc.  As L
    // grows the synthesizer retargets the largest activation arrays from
    // BRAM to URAM (the paper's observed BRAM-down / URAM-up trend):
    // model it by moving the working set to URAM when the stash exceeds
    // the small-URAM threshold.
    let fifo_bram = 620; // fixed stream/FIFO + pipeline buffers
    let mut bram_used = alloc.total_blocks + work_bram + small_bram + fifo_bram;
    let mut uram_used = stash_uram + 64; // fixed URAM floor (I/O staging)
    if cfg.n_layers >= 6 {
        // Deep configs: HLS moves the double-buffered working set to URAM.
        bram_used -= work_bram;
        uram_used += (work_words * 32).div_ceil(U50::URAM_BITS) + work_bram / 2;
    }

    // Dynamic power: calibrated linear model in active compute + memory.
    let dynamic = 20.55 + 0.07 * cfg.n_layers as f64;

    ResourceReport {
        n_layers: cfg.n_layers,
        dsp: Util { used: dsp, available: U50::DSP },
        lut: Util { used: lut, available: U50::LUT },
        ff: Util { used: ff, available: U50::FF },
        bram: Util { used: bram_used.min(U50::BRAM_BLOCKS), available: U50::BRAM_BLOCKS },
        uram: Util { used: uram_used.min(U50::URAM_BLOCKS), available: U50::URAM_BLOCKS },
        dynamic_power_w: dynamic,
        static_power_w: U50::STATIC_POWER_W,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_lut_ff_constant_across_sizes() {
        let r2 = report(&ModelConfig::paper(2));
        let r6 = report(&ModelConfig::paper(6));
        assert_eq!(r2.dsp.used, r6.dsp.used);
        assert_eq!(r2.lut.used, r6.lut.used);
        assert_eq!(r2.ff.used, r6.ff.used);
    }

    #[test]
    fn matches_table4_within_tolerance() {
        // Paper Table IV: DSP 2396 (40%), LUT 565-579k, FF 475-499k,
        // BRAM 1216/1163/1089, URAM 114/128/374, power ~26.7-27.1 W.
        let paper = [
            (2usize, 1216usize, 114usize, 26.68),
            (4, 1163, 128, 26.82),
            (6, 1089, 374, 27.06),
        ];
        for (layers, bram_blocks, uram_blocks, power) in paper {
            let r = report(&ModelConfig::paper(layers));
            assert!((r.dsp.used as f64 - 2396.0).abs() / 2396.0 < 0.05, "dsp {}", r.dsp.used);
            assert!((r.lut.used as f64 - 572_000.0).abs() / 572_000.0 < 0.10);
            assert!((r.ff.used as f64 - 485_000.0).abs() / 485_000.0 < 0.10);
            let bram_rel = (r.bram.used as f64 - bram_blocks as f64).abs() / (bram_blocks as f64);
            assert!(bram_rel < 0.30, "L{layers} bram {} vs paper {bram_blocks}", r.bram.used);
            let uram_rel = (r.uram.used as f64 - uram_blocks as f64).abs() / (uram_blocks as f64);
            assert!(uram_rel < 0.45, "L{layers} uram {} vs paper {uram_blocks}", r.uram.used);
            assert!((r.total_power_w() - power).abs() < 1.0);
        }
    }

    #[test]
    fn trend_bram_down_uram_up_with_depth() {
        let r2 = report(&ModelConfig::paper(2));
        let r6 = report(&ModelConfig::paper(6));
        assert!(r6.bram.used < r2.bram.used, "BRAM should drop at L6");
        assert!(r6.uram.used > r2.uram.used, "URAM should grow with L");
    }

    #[test]
    fn fits_the_device() {
        for layers in [2usize, 4, 6] {
            let r = report(&ModelConfig::paper(layers));
            assert!(r.dsp.used <= r.dsp.available);
            assert!(r.lut.used <= r.lut.available);
            assert!(r.bram.used <= r.bram.available);
            assert!(r.uram.used <= r.uram.available);
        }
    }

    #[test]
    fn onchip_memory_under_budget() {
        // Paper abstract: < 6 MB BRAM + 22.5 MB URAM budget; Table V
        // reports 17.2-34.5 MB computing memory.
        let r = report(&ModelConfig::paper(2));
        let mb = r.onchip_memory_mb();
        assert!(mb < 28.4, "on-chip memory {mb:.1} MB over budget");
    }
}
