//! Fabric resource occupancy model (paper Table IV).
//!
//! The paper's RTL reports show DSP/LUT/FF *constant* across model sizes
//! (the same kernel set is instantiated regardless of layer count) while
//! BRAM shifts to URAM as layer count grows (HLS moves the grouped
//! inter-layer activation arrays to URAM).  This module reproduces that
//! structure from components:
//!
//! * compute kernels (fixed set -> fixed DSP/LUT/FF),
//! * TT/TTM parameter storage (BRAM, from [`super::bram`]),
//! * activation/gradient buffers (BRAM or URAM by size threshold).
//!
//! Constants are calibrated to the paper's Table IV within tolerance
//! (tests); the *trends* (what grows, what does not) are structural.

use super::bram::{self, Strategy};
use crate::config::{ModelConfig, U50};
use crate::costmodel::LinearShape;
use crate::optim::OptimKind;
use crate::tensor::Precision;
use crate::train::{CheckpointMode, CheckpointPolicy};

/// Utilization of one fabric resource.
#[derive(Debug, Clone, Copy)]
pub struct Util {
    pub used: usize,
    pub available: usize,
}

impl Util {
    pub fn pct(&self) -> f64 {
        100.0 * self.used as f64 / self.available as f64
    }
}

/// Full resource report for one model configuration.
#[derive(Debug, Clone)]
pub struct ResourceReport {
    pub n_layers: usize,
    pub dsp: Util,
    pub lut: Util,
    pub ff: Util,
    pub bram: Util,
    pub uram: Util,
    pub dynamic_power_w: f64,
    pub static_power_w: f64,
    /// PU-stage update rule this report was sized for.
    pub optim_kind: OptimKind,
    /// BRAM blocks holding optimizer state (0 when it spilled to URAM).
    pub optim_state_bram: usize,
    /// URAM blocks holding optimizer state.
    pub optim_state_uram: usize,
    /// Unclamped BRAM demand — unlike `bram.used` (display, clamped to
    /// the device), this may exceed the budget and is what feasibility
    /// checks must look at.
    pub bram_required: usize,
    /// Unclamped URAM demand (see `bram_required`).
    pub uram_required: usize,
    /// Storage precision this report was sized for (cores, Eq. 21
    /// caches, activations and optimizer state all at this width).
    pub precision: Precision,
    /// Gradient-checkpointing policy this report was sized for.
    pub checkpoint: CheckpointPolicy,
    /// At-rest Eq. 21 training-cache bytes of the executed (fused-QKV)
    /// schedule at `precision` under `checkpoint` — exactly half the
    /// f32 figure for bf16/f16, and reduced (to 0 for every recomputed
    /// layer) under the `Recompute` policy.  For a model on the
    /// **default fused-QKV schedule** (tied input cores) this equals
    /// the sum of the live caches' `stored_bytes()`
    /// ([`crate::train::NativeTrainModel::measure_eq21_cache_bytes`]),
    /// which the checkpointing test suite pins as the single source of
    /// truth; the separate/looped QKV schedule stores three full
    /// per-projection caches per layer and measures higher than this
    /// fused-schedule figure.
    pub eq21_cache_bytes: u64,
    /// Optimizer-state bytes at rest at `precision` (core share + dense
    /// share), before block rounding.
    pub optim_state_bytes: u64,
}

impl ResourceReport {
    pub fn total_power_w(&self) -> f64 {
        self.dynamic_power_w + self.static_power_w
    }

    /// On-chip memory in MB (BRAM + URAM actually occupied).
    pub fn onchip_memory_mb(&self) -> f64 {
        (self.bram.used * U50::BRAM_BITS + self.uram.used * U50::URAM_BITS) as f64 / 8.0 / 1e6
    }

    /// Optimizer-state share of the on-chip memory, in MB.
    pub fn optim_state_mb(&self) -> f64 {
        (self.optim_state_bram * U50::BRAM_BITS + self.optim_state_uram * U50::URAM_BITS) as f64
            / 8.0
            / 1e6
    }
}

/// Compute-kernel DSP/LUT/FF costs (fixed across model sizes).
///
/// Breakdown calibrated to the paper's 2396 DSP / 565k LUT / 475k FF
/// totals: rank-parallel contraction kernels dominate DSP; control,
/// AXI/stream glue and the nonlinear function lanes dominate LUT.
struct KernelCosts;

impl KernelCosts {
    // (dsp, lut, ff) per kernel instance.
    const MUL0: (usize, usize, usize) = (60, 14_000, 12_000); // x2 units
    const MUL1: (usize, usize, usize) = (384, 52_000, 46_000);
    const MUL2: (usize, usize, usize) = (384, 52_000, 46_000);
    const MUL3: (usize, usize, usize) = (384, 52_000, 46_000);
    const MM_ATTN: (usize, usize, usize) = (768, 120_000, 98_000);
    const SOFTMAX: (usize, usize, usize) = (96, 48_000, 40_000);
    const GELU: (usize, usize, usize) = (64, 36_000, 30_000);
    const LAYERNORM: (usize, usize, usize) = (96, 44_000, 38_000);
    const LOOKUP: (usize, usize, usize) = (60, 22_000, 18_000);
    const CONTROL: (usize, usize, usize) = (40, 111_000, 89_000);

    fn total() -> (usize, usize, usize) {
        let parts = [
            (Self::MUL0, 2usize),
            (Self::MUL1, 1),
            (Self::MUL2, 1),
            (Self::MUL3, 1),
            (Self::MM_ATTN, 1),
            (Self::SOFTMAX, 1),
            (Self::GELU, 1),
            (Self::LAYERNORM, 1),
            (Self::LOOKUP, 1),
            (Self::CONTROL, 1),
        ];
        let mut acc = (0, 0, 0);
        for ((d, l, f), n) in parts {
            acc.0 += d * n;
            acc.1 += l * n;
            acc.2 += f * n;
        }
        acc
    }
}

/// Activation / gradient buffer words needed on-chip per model
/// (double-buffered current-layer activations + BTT intermediates +
/// attention scratch), plus inter-layer activation stash that scales
/// with depth (spilled to URAM; beyond the URAM high-water mark the
/// coordinator streams to HBM, Sec. V-A).
fn activation_words(cfg: &ModelConfig) -> (usize, usize) {
    let k = cfg.batch * cfg.seq_len;
    let h = cfg.d_hid;
    // Current-layer working set (BRAM side): x, q, k, v, attn, ffn
    // hidden and their gradients, double-buffered.
    let working = 8 * k * h * 2;
    // BTT intermediates per linear: Z1, Z3, Z2 (+ grads).
    let r = cfg.tt_rank;
    let btt = 2 * (r * h * 2 + r * k);
    // Inter-layer stash for BP: one activation set per encoder layer
    // (the part the paper moves to URAM as L grows).
    let stash = cfg.n_layers * 6 * k * h;
    (working + btt, stash)
}

/// Build the Table IV row for a model configuration (PU stage = plain
/// SGD, the paper's setting: no optimizer state on chip).
pub fn report(cfg: &ModelConfig) -> ResourceReport {
    report_with_optim(cfg, OptimKind::Sgd)
}

/// Table IV row with the PU stage's optimizer state charged against the
/// on-chip budget at the fp32 storage width.
pub fn report_with_optim(cfg: &ModelConfig, optim: OptimKind) -> ResourceReport {
    report_with_optim_prec(cfg, optim, Precision::F32)
}

/// Table IV row with the PU stage's optimizer state charged against the
/// on-chip budget at the given storage [`Precision`].  State mirrors
/// the compressed parameter layout (`crate::optim::StateFootprint`):
/// the TT/TTM-core share goes through the same grouped-reshape BRAM
/// allocator as the cores themselves — width-parameterized, so 16-bit
/// storage halves the word width everywhere — and the dense share
/// (LN/bias/pos/head tensors) is word-packed; when the parameter BRAM
/// plus state no longer fits the 1344-block budget, the state spills to
/// URAM (like the deep-config activation stash).
pub fn report_with_optim_prec(
    cfg: &ModelConfig,
    optim: OptimKind,
    precision: Precision,
) -> ResourceReport {
    report_for_policy(cfg, optim, precision, &CheckpointPolicy::CacheAll)
}

/// At-rest Eq. 21 cache bytes of the executed (fused-QKV) schedule
/// under a checkpointing policy: per encoder block one fused QKV cache
/// plus wo/w1/w2 where the block caches, 0 where it recomputes; plus
/// the pooler per the policy's aux stance.  This is the analytic
/// mirror of summing `stored_bytes()` over the native trainer's live
/// caches on the default fused-QKV schedule —
/// `rust/tests/checkpointing.rs` pins the two equal so the formula
/// cannot drift from the executed path.  (An untied/looped model runs
/// three separate QKV forwards and stores more; this report always
/// models the fused schedule, like the rest of the Table IV row.)
pub fn eq21_cache_bytes_for_policy(
    shape: &LinearShape,
    n_layers: usize,
    k_dim: u64,
    precision: Precision,
    policy: &CheckpointPolicy,
) -> u64 {
    let layer_bytes = |recompute: bool| {
        shape.btt_qkv_memory_bytes_checkpointed(k_dim, precision, recompute)
            + 3 * shape.btt_memory_bytes_checkpointed(k_dim, precision, recompute)
    };
    (0..n_layers)
        .map(|li| layer_bytes(policy.layer_mode(li) == CheckpointMode::Recompute))
        .sum::<u64>()
        + shape.btt_memory_bytes_checkpointed(
            k_dim,
            precision,
            policy.aux_mode() == CheckpointMode::Recompute,
        )
}

/// [`report_with_optim_prec`] under a gradient-checkpointing policy.
/// The at-rest Eq. 21 caches are charged into the URAM BP stash per
/// policy: `CacheAll` carries every layer's cache (the paper's
/// schedule; Table IV trends and tolerances still hold — see
/// `matches_table4_within_tolerance`), while `Recompute` drops a
/// recomputed layer's cache from the stash (the chains are rebuilt
/// transiently inside the per-layer working set, which this model
/// already charges), shrinking the depth-scaling URAM demand by
/// exactly the dropped cache bytes.
///
/// **Data parallelism does not multiply the optimizer-state charge.**
/// One report describes one device; under N-replica training
/// ([`crate::replica::ReplicaGroup`]) the PU stage — and hence the
/// moment buffers this report charges — runs only on the lead device.
/// Follower devices size as the same report with
/// [`OptimKind::Sgd`] (zero state); [`replica_budget`] builds exactly
/// that pair of views and charges the gradient exchange buffer
/// explicitly instead.
pub fn report_for_policy(
    cfg: &ModelConfig,
    optim: OptimKind,
    precision: Precision,
    policy: &CheckpointPolicy,
) -> ResourceReport {
    let (dsp, lut, ff) = KernelCosts::total();
    let elem_bits = precision.bits();

    // Block-scaled int8 stores carry one f32 scale per
    // [`crate::tensor::INT8_BLOCK`]-element block alongside the codes
    // (mirroring [`Precision::storage_bytes`]); the sidecar is charged
    // to the same memory class as the store it describes.  Wider
    // precisions carry no sidecar, so this is zero there.
    let scale_bits = |store_bits: usize| -> usize {
        if precision == Precision::Int8 {
            32 * (store_bits / 8).div_ceil(crate::tensor::INT8_BLOCK)
        } else {
            0
        }
    };

    // Parameter storage in BRAM via the grouped-reshape allocator at
    // the storage element width.
    let cores = bram::paper_core_set(cfg.n_layers, cfg.tt_rank);
    let group_k = bram::paper_group_k(cfg.tt_m.len(), cfg.n_layers);
    let alloc = bram::allocate_at(&cores, Strategy::ReshapeGrouped, group_k, elem_bits);

    // Eq. 21 training-cache bytes of the executed (fused-QKV) schedule
    // at this policy, and the bytes the policy saves vs CacheAll — the
    // gradient-checkpointing memory win.
    let shape = LinearShape {
        m_modes: cfg.tt_m.clone(),
        n_modes: cfg.tt_n.clone(),
        ranks: cfg.tt_ranks(),
    };
    let k_dim = (cfg.batch * cfg.seq_len) as u64;
    let eq21_cache_bytes =
        eq21_cache_bytes_for_policy(&shape, cfg.n_layers, k_dim, precision, policy);

    // Activation working set: BRAM; deep-layer BP stash: URAM.  The
    // stash holds the inter-layer activation sets (`6 K H` words per
    // encoder, always resident for BP) **plus** the at-rest Eq. 21
    // chain caches of every layer that keeps its cache under the
    // policy — recomputed layers drop theirs (rebuilt transiently in
    // the per-layer working set, already charged above), so the URAM
    // demand honestly shrinks by exactly the dropped cache bytes.
    let (work_words, stash_words) = activation_words(cfg);
    let work_bram = (work_words * elem_bits).div_ceil(U50::BRAM_BITS);
    let stash_store_bits = stash_words * elem_bits;
    let stash_bits =
        stash_store_bits + scale_bits(stash_store_bits) + 8 * eq21_cache_bytes as usize;
    let stash_uram = stash_bits.div_ceil(U50::URAM_BITS);

    // Biases, LN params, head weights: small, BRAM.
    let small_words = cfg.n_layers * 10 * cfg.d_hid
        + (cfg.n_intents + cfg.n_slots) * (cfg.d_hid + 1)
        + cfg.seq_len * cfg.d_hid;
    let small_store_bits = small_words * elem_bits;
    let small_bram = (small_store_bits + scale_bits(small_store_bits)).div_ceil(U50::BRAM_BITS);

    // HLS pragma overhead: fixed partitioned control FIFOs etc.  As L
    // grows the synthesizer retargets the largest activation arrays from
    // BRAM to URAM (the paper's observed BRAM-down / URAM-up trend):
    // model it by moving the working set to URAM when the stash exceeds
    // the small-URAM threshold.
    let fifo_bram = 620; // fixed stream/FIFO + pipeline buffers
    let param_scale_bram = scale_bits(alloc.total_bits).div_ceil(U50::BRAM_BITS);
    let mut bram_used =
        alloc.total_blocks + param_scale_bram + work_bram + small_bram + fifo_bram;
    let mut uram_used = stash_uram + 64; // fixed URAM floor (I/O staging)
    if cfg.n_layers >= 6 {
        // Deep configs: HLS moves the double-buffered working set to URAM.
        bram_used -= work_bram;
        uram_used += (work_words * elem_bits).div_ceil(U50::URAM_BITS) + work_bram / 2;
    }

    // PU-stage optimizer state in the compressed layout: the TT/TTM-core
    // share through the grouped allocator, the dense share word-packed.
    let mult = optim.state_multiplier();
    let state_cores = bram::optimizer_state_core_set(cfg.n_layers, cfg.tt_rank, mult);
    let state_alloc = bram::allocate_at(&state_cores, Strategy::ReshapeGrouped, group_k, elem_bits);
    let dense_state_words = mult * small_words;
    let dense_state_store_bits = dense_state_words * elem_bits;
    let dense_state_bits = dense_state_store_bits + scale_bits(dense_state_store_bits);
    let state_bram_blocks = state_alloc.total_blocks
        + scale_bits(state_alloc.total_bits).div_ceil(U50::BRAM_BITS)
        + dense_state_bits.div_ceil(U50::BRAM_BITS);
    let state_bits = state_alloc.total_bits + scale_bits(state_alloc.total_bits) + dense_state_bits;
    let (optim_state_bram, optim_state_uram) =
        if mult == 0 {
            (0, 0)
        } else if bram_used + state_bram_blocks <= U50::BRAM_BLOCKS {
            (state_bram_blocks, 0)
        } else {
            (0, state_bits.div_ceil(U50::URAM_BITS))
        };
    bram_used += optim_state_bram;
    uram_used += optim_state_uram;

    let optim_state_bytes = state_bits as u64 / 8;

    // Dynamic power: calibrated linear model in active compute + memory.
    let dynamic = 20.55 + 0.07 * cfg.n_layers as f64;

    ResourceReport {
        n_layers: cfg.n_layers,
        dsp: Util { used: dsp, available: U50::DSP },
        lut: Util { used: lut, available: U50::LUT },
        ff: Util { used: ff, available: U50::FF },
        bram: Util { used: bram_used.min(U50::BRAM_BLOCKS), available: U50::BRAM_BLOCKS },
        uram: Util { used: uram_used.min(U50::URAM_BLOCKS), available: U50::URAM_BLOCKS },
        dynamic_power_w: dynamic,
        static_power_w: U50::STATIC_POWER_W,
        optim_kind: optim,
        optim_state_bram,
        optim_state_uram,
        bram_required: bram_used,
        uram_required: uram_used,
        precision,
        checkpoint: policy.clone(),
        eq21_cache_bytes,
        optim_state_bytes,
    }
}

/// Per-device budget view of an N-replica data-parallel deployment.
///
/// Device 0 (the lead) runs FP + BP + the only PU stage, so it carries
/// the optimizer state; devices 1..N run FP + BP only and are sized
/// with zero optimizer state ([`OptimKind::Sgd`] report).  Every device
/// additionally holds one **gradient exchange buffer** — a second copy
/// of the compressed-core gradient set it ships into the fixed-order
/// all-reduce ([`crate::costmodel::core_grad_bytes`]) — which this view
/// charges explicitly rather than hiding inside the activation stash.
#[derive(Debug, Clone)]
pub struct ReplicaBudget {
    pub replicas: usize,
    /// Lead device: full report including the optimizer state.
    pub device0: ResourceReport,
    /// Follower devices (identical to each other): no optimizer state.
    pub device_n: ResourceReport,
    /// Per-device gradient exchange buffer, bytes (0 when `replicas == 1`
    /// — a single device reduces nothing and reuses the grads in place).
    pub exchange_buffer_bytes: u64,
    /// URAM blocks the exchange buffer rounds up to on each device.
    pub exchange_uram_blocks: usize,
}

impl ReplicaBudget {
    /// Total URAM demand of a device including its exchange buffer.
    pub fn uram_demand(&self, device: usize) -> usize {
        let base = if device == 0 {
            self.device0.uram_required
        } else {
            self.device_n.uram_required
        };
        base + self.exchange_uram_blocks
    }
}

/// Build the per-device budget pair for an N-replica deployment at a
/// storage precision and checkpointing policy.  The optimizer state is
/// charged once — on `device0` only — mirroring the runtime contract
/// ([`crate::optim::StateFootprint`], [`crate::replica::ReplicaGroup`]);
/// followers get the zero-state ([`OptimKind::Sgd`]) sizing.
pub fn replica_budget(
    cfg: &ModelConfig,
    optim: OptimKind,
    precision: Precision,
    policy: &CheckpointPolicy,
    replicas: usize,
) -> ReplicaBudget {
    let device0 = report_for_policy(cfg, optim, precision, policy);
    let device_n = report_for_policy(cfg, OptimKind::Sgd, precision, policy);
    let exchange_buffer_bytes = if replicas > 1 {
        crate::costmodel::core_grad_bytes(cfg, precision)
    } else {
        0
    };
    let exchange_uram_blocks = (8 * exchange_buffer_bytes as usize).div_ceil(U50::URAM_BITS);
    ReplicaBudget {
        replicas: replicas.max(1),
        device0,
        device_n,
        exchange_buffer_bytes,
        exchange_uram_blocks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_lut_ff_constant_across_sizes() {
        let r2 = report(&ModelConfig::paper(2));
        let r6 = report(&ModelConfig::paper(6));
        assert_eq!(r2.dsp.used, r6.dsp.used);
        assert_eq!(r2.lut.used, r6.lut.used);
        assert_eq!(r2.ff.used, r6.ff.used);
    }

    #[test]
    fn matches_table4_within_tolerance() {
        // Paper Table IV: DSP 2396 (40%), LUT 565-579k, FF 475-499k,
        // BRAM 1216/1163/1089, URAM 114/128/374, power ~26.7-27.1 W.
        let paper = [
            (2usize, 1216usize, 114usize, 26.68),
            (4, 1163, 128, 26.82),
            (6, 1089, 374, 27.06),
        ];
        for (layers, bram_blocks, uram_blocks, power) in paper {
            let r = report(&ModelConfig::paper(layers));
            assert!((r.dsp.used as f64 - 2396.0).abs() / 2396.0 < 0.05, "dsp {}", r.dsp.used);
            assert!((r.lut.used as f64 - 572_000.0).abs() / 572_000.0 < 0.10);
            assert!((r.ff.used as f64 - 485_000.0).abs() / 485_000.0 < 0.10);
            let bram_rel = (r.bram.used as f64 - bram_blocks as f64).abs() / (bram_blocks as f64);
            assert!(bram_rel < 0.30, "L{layers} bram {} vs paper {bram_blocks}", r.bram.used);
            let uram_rel = (r.uram.used as f64 - uram_blocks as f64).abs() / (uram_blocks as f64);
            assert!(uram_rel < 0.45, "L{layers} uram {} vs paper {uram_blocks}", r.uram.used);
            assert!((r.total_power_w() - power).abs() < 1.0);
        }
    }

    #[test]
    fn trend_bram_down_uram_up_with_depth() {
        let r2 = report(&ModelConfig::paper(2));
        let r6 = report(&ModelConfig::paper(6));
        assert!(r6.bram.used < r2.bram.used, "BRAM should drop at L6");
        assert!(r6.uram.used > r2.uram.used, "URAM should grow with L");
    }

    #[test]
    fn fits_the_device() {
        for layers in [2usize, 4, 6] {
            let r = report(&ModelConfig::paper(layers));
            assert!(r.dsp.used <= r.dsp.available);
            assert!(r.lut.used <= r.lut.available);
            assert!(r.bram.used <= r.bram.available);
            assert!(r.uram.used <= r.uram.available);
        }
    }

    #[test]
    fn optimizer_state_fits_the_device_for_every_rule() {
        // Acceptance: the BRAM/URAM report carries an optimizer-state
        // row and that state stays within the U50 budget for all four
        // update rules at every paper depth.  Checked on the *unclamped*
        // demand fields (`bram_required`/`uram_required`), not the
        // display-clamped Util — the seed's calibrated base model
        // already oversubscribes BRAM slightly at L4 (the paper's HLS
        // moves arrays to URAM more aggressively than our threshold
        // model), so the meaningful guarantees are: state never worsens
        // BRAM demand unless it genuinely fits, and total URAM demand
        // including state stays within the 640-block budget.
        for layers in [2usize, 4, 6] {
            let base = report(&ModelConfig::paper(layers));
            for kind in OptimKind::all() {
                let r = report_with_optim(&ModelConfig::paper(layers), kind);
                assert!(
                    r.uram_required <= r.uram.available,
                    "L{layers} {kind:?} URAM demand {} over budget",
                    r.uram_required
                );
                if r.optim_state_bram > 0 {
                    // State was placed in BRAM => the whole BRAM plan fits.
                    assert!(
                        r.bram_required <= r.bram.available,
                        "L{layers} {kind:?} BRAM demand {} over budget with on-BRAM state",
                        r.bram_required
                    );
                } else {
                    // State spilled to URAM (or is empty): BRAM demand
                    // is exactly the SGD baseline, never worse.
                    assert_eq!(
                        r.bram_required, base.bram_required,
                        "L{layers} {kind:?} state changed BRAM demand despite spilling"
                    );
                }
                let state_blocks = r.optim_state_bram + r.optim_state_uram;
                if kind.state_multiplier() == 0 {
                    assert_eq!(state_blocks, 0, "SGD keeps no optimizer state");
                } else {
                    assert!(state_blocks > 0, "L{layers} {kind:?} state row missing");
                    // Compressed-space state stays small: the Adam pair
                    // of moments on the deepest model is a few MB, far
                    // under the 22.5 MB URAM budget on its own.
                    assert!(
                        r.optim_state_mb() < 6.0,
                        "L{layers} {kind:?} state {:.1} MB",
                        r.optim_state_mb()
                    );
                }
            }
        }
    }

    #[test]
    fn adam_state_exceeds_momentum_state() {
        let cfg = ModelConfig::paper(4);
        let mom = report_with_optim(&cfg, OptimKind::Momentum);
        let adam = report_with_optim(&cfg, OptimKind::Adam);
        let blocks = |r: &ResourceReport| r.optim_state_bram * U50::BRAM_BITS
            + r.optim_state_uram * U50::URAM_BITS;
        assert!(blocks(&adam) > blocks(&mom), "2x state must outweigh 1x");
        // AdamW keeps the same two moments as Adam.
        let adamw = report_with_optim(&cfg, OptimKind::AdamW);
        assert_eq!(blocks(&adam), blocks(&adamw));
    }

    #[test]
    fn bf16_halves_adam_state_and_eq21_cache_bytes() {
        // Acceptance: under the bf16 storage path the U50 report
        // charges the Adam moments and the Eq. 21 caches at exactly
        // half the f32 bytes, and total demand never grows.
        for layers in [2usize, 4, 6] {
            let cfg = ModelConfig::paper(layers);
            let f = report_with_optim_prec(&cfg, OptimKind::Adam, Precision::F32);
            assert_eq!(f.precision, Precision::F32);
            for prec in [Precision::Bf16, Precision::F16] {
                let h = report_with_optim_prec(&cfg, OptimKind::Adam, prec);
                assert_eq!(2 * h.eq21_cache_bytes, f.eq21_cache_bytes, "L{layers} {prec:?}");
                assert_eq!(2 * h.optim_state_bytes, f.optim_state_bytes, "L{layers} {prec:?}");
                assert!(h.eq21_cache_bytes > 0 && h.optim_state_bytes > 0);
            }
        }
    }

    #[test]
    fn int8_report_lands_at_quarter_class_bytes_on_the_deep_config() {
        // Acceptance gate: block-scaled int8 (1 code byte + one f32
        // scale per 64 elements = 1.0625 B/elem) must keep both at-rest
        // figures at or below 0.27x their f32 size on the 6-ENC paper
        // config, and the scale sidecar must be charged rather than
        // hidden (strictly above a pure 0.25x quarter).
        let cfg = ModelConfig::paper(6);
        let f = report_with_optim_prec(&cfg, OptimKind::Adam, Precision::F32);
        let q = report_with_optim_prec(&cfg, OptimKind::Adam, Precision::Int8);
        assert!(q.eq21_cache_bytes > 0 && q.optim_state_bytes > 0);
        for (name, int8, f32b) in [
            ("eq21_cache_bytes", q.eq21_cache_bytes, f.eq21_cache_bytes),
            ("optim_state_bytes", q.optim_state_bytes, f.optim_state_bytes),
        ] {
            let ratio = int8 as f64 / f32b as f64;
            assert!(
                (0.25..=0.27).contains(&ratio),
                "{name}: int8 {int8} vs f32 {f32b} (ratio {ratio:.4})"
            );
        }
        assert!(4 * q.optim_state_bytes > f.optim_state_bytes, "scale sidecar uncharged");
        assert!(q.uram_required <= q.uram.available);
        // Base plan (state placement may legitimately differ) shrinks.
        assert!(q.bram_required - q.optim_state_bram <= f.bram_required - f.optim_state_bram);
        assert!(q.uram_required - q.optim_state_uram <= f.uram_required - f.optim_state_uram);
    }

    #[test]
    fn half_precision_never_increases_onchip_demand() {
        // Compare the placement-independent demand: the base plan
        // (everything except the state, whose BRAM-vs-URAM placement
        // may legitimately differ between widths) and the state bytes.
        for layers in [2usize, 4, 6] {
            let cfg = ModelConfig::paper(layers);
            for kind in OptimKind::all() {
                let f = report_with_optim_prec(&cfg, kind, Precision::F32);
                let h = report_with_optim_prec(&cfg, kind, Precision::Bf16);
                assert!(
                    h.bram_required - h.optim_state_bram <= f.bram_required - f.optim_state_bram,
                    "L{layers} {kind:?}: bf16 base BRAM demand grew"
                );
                assert!(
                    h.uram_required - h.optim_state_uram <= f.uram_required - f.optim_state_uram,
                    "L{layers} {kind:?}: bf16 base URAM demand grew"
                );
                assert!(h.optim_state_bytes <= f.optim_state_bytes);
                assert!(h.uram_required <= h.uram.available, "L{layers} {kind:?}");
            }
        }
    }

    #[test]
    fn recompute_policy_shrinks_eq21_and_fits_a_smaller_uram_budget() {
        // Acceptance: the Recompute policy reduces the reported Eq. 21
        // cache bytes (to 0: every layer recomputes) and the URAM
        // demand drops by (at least) the saved cache blocks — at L6 the
        // recompute plan fits a U50 budget the CacheAll plan needs the
        // saved blocks of.  CacheAll itself must stay bitwise the
        // calibrated baseline.
        for prec in [Precision::F32, Precision::Bf16] {
            let cfg = ModelConfig::paper(6);
            let ca = report_for_policy(&cfg, OptimKind::Adam, prec, &CheckpointPolicy::CacheAll);
            let base = report_with_optim_prec(&cfg, OptimKind::Adam, prec);
            assert_eq!(ca.bram_required, base.bram_required, "CacheAll shifted the baseline");
            assert_eq!(ca.uram_required, base.uram_required);
            assert_eq!(ca.eq21_cache_bytes, base.eq21_cache_bytes);
            let re = report_for_policy(&cfg, OptimKind::Adam, prec, &CheckpointPolicy::Recompute);
            assert_eq!(re.eq21_cache_bytes, 0, "full recompute retains no Eq. 21 cache");
            assert!(ca.eq21_cache_bytes > 0);
            // URAM demand drops by at least floor(saved_bits / URAM) - 1
            // (block-rounding slack), and the smaller plan still fits.
            let saved_blocks = (8 * ca.eq21_cache_bytes as usize) / U50::URAM_BITS;
            assert!(saved_blocks >= 1, "{prec:?}: saved cache under one URAM block");
            assert!(
                re.uram_required + saved_blocks <= ca.uram_required + 1,
                "{prec:?}: URAM dropped {} -> {} but {} blocks were saved",
                ca.uram_required,
                re.uram_required,
                saved_blocks
            );
            assert!(re.uram_required < ca.uram_required);
            assert!(re.uram_required <= re.uram.available);
            assert!(re.bram_required <= ca.bram_required);
        }
    }

    #[test]
    fn per_layer_policy_interpolates_between_the_extremes() {
        let cfg = ModelConfig::paper(4);
        let ca = report_for_policy(
            &cfg,
            OptimKind::Adam,
            Precision::F32,
            &CheckpointPolicy::CacheAll,
        );
        let re = report_for_policy(
            &cfg,
            OptimKind::Adam,
            Precision::F32,
            &CheckpointPolicy::Recompute,
        );
        let half = CheckpointPolicy::PerLayer(vec![
            CheckpointMode::Recompute,
            CheckpointMode::Recompute,
            CheckpointMode::CacheAll,
            CheckpointMode::CacheAll,
        ]);
        let mid = report_for_policy(&cfg, OptimKind::Adam, Precision::F32, &half);
        assert!(re.eq21_cache_bytes < mid.eq21_cache_bytes);
        assert!(mid.eq21_cache_bytes < ca.eq21_cache_bytes);
        assert!(mid.uram_required <= ca.uram_required);
        assert!(re.uram_required <= mid.uram_required);
        // Out-of-range blocks (and the pooler) default to cached.
        let short = CheckpointPolicy::PerLayer(vec![CheckpointMode::Recompute]);
        let shallow = report_for_policy(&cfg, OptimKind::Adam, Precision::F32, &short);
        assert!(shallow.eq21_cache_bytes > mid.eq21_cache_bytes);
        assert!(shallow.eq21_cache_bytes < ca.eq21_cache_bytes);
    }

    #[test]
    fn replica_budget_charges_state_once_and_exchange_explicitly() {
        // Acceptance (no-double-charge): the N-replica budget carries the
        // optimizer state only on device 0; followers size as the
        // zero-state report, at every N.
        let cfg = ModelConfig::paper(2);
        let policy = CheckpointPolicy::CacheAll;
        let solo = report_for_policy(&cfg, OptimKind::Adam, Precision::F32, &policy);
        for n in [1usize, 2, 4] {
            let b = replica_budget(&cfg, OptimKind::Adam, Precision::F32, &policy, n);
            assert_eq!(b.replicas, n);
            assert_eq!(b.device0.optim_state_bytes, solo.optim_state_bytes);
            assert_eq!(b.device_n.optim_state_bytes, 0, "N={n}: follower charged state");
            assert_eq!(b.device_n.optim_state_bram + b.device_n.optim_state_uram, 0);
            if n == 1 {
                assert_eq!(b.exchange_buffer_bytes, 0, "R=1 reduces nothing");
                assert_eq!(b.exchange_uram_blocks, 0);
            } else {
                assert_eq!(
                    b.exchange_buffer_bytes,
                    crate::costmodel::core_grad_bytes(&cfg, Precision::F32)
                );
                assert!(b.exchange_uram_blocks >= 1);
                // Exchange buffer is compressed-core sized: it fits a
                // handful of URAM blocks, and both device views still
                // fit the U50 including it.
                assert!(b.exchange_uram_blocks < 16, "{} blocks", b.exchange_uram_blocks);
                assert!(b.uram_demand(0) <= b.device0.uram.available);
                assert!(b.uram_demand(1) <= b.device_n.uram.available);
                assert!(b.uram_demand(1) <= b.uram_demand(0) + b.exchange_uram_blocks);
            }
        }
    }

    #[test]
    fn onchip_memory_under_budget() {
        // Paper abstract: < 6 MB BRAM + 22.5 MB URAM budget; Table V
        // reports 17.2-34.5 MB computing memory.
        let r = report(&ModelConfig::paper(2));
        let mb = r.onchip_memory_mb();
        assert!(mb < 28.4, "on-chip memory {mb:.1} MB over budget");
    }
}
