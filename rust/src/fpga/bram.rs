//! BRAM allocation model (paper Sec. V-C, Eqs. 22-25).
//!
//! Each BRAM 36K block stores `C = 36864` bits and can be configured as
//! `W x D` with `W` in {1, 2, 4, 9, 18, 36, 72} and `D = C / W`.  A data
//! array of logical width `w_bits` and depth `depth` occupies
//! `n_w x n_d` blocks.  Storing each small TT core in its own block
//! wastes most of the depth; the paper's *tensor grouping* concatenates
//! `K` data-independent cores (across encoder layers and contraction
//! directions) along the depth dimension to amortize it.

use crate::config::U50;

/// Legal BRAM36 width configurations (bits).
pub const WIDTHS: [usize; 7] = [1, 2, 4, 9, 18, 36, 72];

/// Default element width: the paper's fp32 words.  The allocator itself
/// is precision-parameterized (`*_at` variants take the element width in
/// bits, e.g. 16 for the bf16/f16 storage path — see
/// [`crate::tensor::Precision::bits`]); the historical entry points
/// below fix the width to this fp32 default.
pub const BW: usize = 32;

/// Allocation strategies from the paper (Sec. V-C + Fig. 12 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// HLS array partitioning: `r` physical banks, one per rank lane.
    PartitionDefault,
    /// HLS array reshaping: rank lanes packed into wide words.
    ReshapeDefault,
    /// Partitioning + tensor grouping of K cores along depth.
    PartitionGrouped,
    /// Reshaping + tensor grouping — the paper's final scheme.
    ReshapeGrouped,
}

impl Strategy {
    pub fn all() -> [Strategy; 4] {
        [
            Strategy::PartitionDefault,
            Strategy::ReshapeDefault,
            Strategy::PartitionGrouped,
            Strategy::ReshapeGrouped,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::PartitionDefault => "partition/default",
            Strategy::ReshapeDefault => "reshape/default",
            Strategy::PartitionGrouped => "partition/grouped",
            Strategy::ReshapeGrouped => "reshape/grouped",
        }
    }

    pub fn grouped(&self) -> bool {
        matches!(self, Strategy::PartitionGrouped | Strategy::ReshapeGrouped)
    }
}

/// One logical array to place: a TT core (or a group of cores) exposed to
/// `r`-way rank-parallel reads.
#[derive(Debug, Clone, Copy)]
pub struct CoreArray {
    /// Rank lanes that must be readable in parallel.
    pub r: usize,
    /// Elements per lane (core elements / r).
    pub depth: usize,
}

/// Blocks used by one array under (strategy, W) at the fp32 element
/// width; Eqs. 22-25.
pub fn blocks_for(array: CoreArray, group_k: usize, strategy: Strategy, w: usize) -> usize {
    blocks_for_width(array, group_k, strategy, w, BW)
}

/// Blocks used by one array under (strategy, W) for elements of
/// `elem_bits` bits — Eqs. 22-25 generalized to the mixed-precision
/// storage path (16-bit elements halve every `n_w` term, never
/// increasing the block count).
pub fn blocks_for_width(
    array: CoreArray,
    group_k: usize,
    strategy: Strategy,
    w: usize,
    elem_bits: usize,
) -> usize {
    let d = U50::BRAM_BITS / w;
    let depth = array.depth * group_k; // grouping concatenates along depth
    let (n_w, n_d) = if matches!(
        strategy,
        Strategy::PartitionDefault | Strategy::PartitionGrouped
    ) {
        // Eq. 22/24: one bank per rank lane, each B_w bits wide.
        (array.r * elem_bits.div_ceil(w), depth.div_ceil(d))
    } else {
        // Eq. 23/25: lanes packed into one B_w * r wide word.
        ((elem_bits * array.r).div_ceil(w), depth.div_ceil(d))
    };
    n_w * n_d
}

/// Best width configuration for an array at the fp32 element width: the
/// paper's optimization `min_W F(theta, beta)` over the legal widths.
pub fn best_width(array: CoreArray, group_k: usize, strategy: Strategy) -> (usize, usize) {
    best_width_at(array, group_k, strategy, BW)
}

/// [`best_width`] optimizing over the *real* element width of the
/// stored format rather than the hard-coded fp32 word.
pub fn best_width_at(
    array: CoreArray,
    group_k: usize,
    strategy: Strategy,
    elem_bits: usize,
) -> (usize, usize) {
    WIDTHS
        .iter()
        .map(|&w| (w, blocks_for_width(array, group_k, strategy, w, elem_bits)))
        .min_by_key(|&(_, blocks)| blocks)
        .unwrap()
}

/// Allocation result for a whole model's TT cores.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub strategy: Strategy,
    pub total_blocks: usize,
    /// Ideal block count ignoring per-block granularity (N_min).
    pub ideal_blocks: f64,
    /// Utilization efficiency eta = N_min / N_total (paper Sec. V-C).
    pub efficiency: f64,
    pub total_bits: usize,
}

/// The paper's grouping factor: `K = (d-1) * L` cores concatenated
/// (across encoder layers and contraction directions).
pub fn paper_group_k(d: usize, n_layers: usize) -> usize {
    ((d - 1) * n_layers).max(1)
}

/// Allocate a set of identical-shaped core arrays at the fp32 element
/// width.
///
/// `cores`: (array, count) pairs — e.g. the 2d cores of each of the 6
/// linear layers across L encoders.  `group_k` applies to every array
/// kind (cores are grouped only with same-shape peers, conservatively).
pub fn allocate(cores: &[(CoreArray, usize)], strategy: Strategy, group_k: usize) -> Allocation {
    allocate_at(cores, strategy, group_k, BW)
}

/// [`allocate`] for elements of `elem_bits` bits — the mixed-precision
/// storage path places 16-bit cores/state through the same grouped
/// allocator at half the bits per element.
pub fn allocate_at(
    cores: &[(CoreArray, usize)],
    strategy: Strategy,
    group_k: usize,
    elem_bits: usize,
) -> Allocation {
    let mut total_blocks = 0usize;
    let mut total_bits = 0usize;
    for &(array, count) in cores {
        let bits = array.r * array.depth * elem_bits * count;
        total_bits += bits;
        if strategy.grouped() {
            let k = group_k.min(count).max(1);
            // Last group may be smaller; model it exactly.
            let full = count / k;
            let rem = count - full * k;
            let (_, blocks_full) = best_width_at(array, k, strategy, elem_bits);
            total_blocks += full * blocks_full;
            if rem > 0 {
                let (_, blocks_rem) = best_width_at(array, rem, strategy, elem_bits);
                total_blocks += blocks_rem;
            }
        } else {
            let (_, blocks) = best_width_at(array, 1, strategy, elem_bits);
            total_blocks += count * blocks;
        }
    }
    let ideal_blocks = total_bits as f64 / U50::BRAM_BITS as f64;
    Allocation {
        strategy,
        total_blocks,
        ideal_blocks,
        efficiency: ideal_blocks / total_blocks.max(1) as f64,
        total_bits,
    }
}

/// The TT-core array population of the paper's model at a given layer
/// count and rank (Table II shapes): 6 TT linear layers per encoder plus
/// the classifier, each with 2d cores, plus the 3 TTM embedding cores.
pub fn paper_core_set(n_layers: usize, rank: usize) -> Vec<(CoreArray, usize)> {
    let n_linear = 6 * n_layers + 1;
    // Cores of a (12,8,8)x(8,8,12) TT linear at uniform rank r:
    // boundary cores (1, 12, r) and (r, 12, 1) -> depth 12, lanes r;
    // interior cores (r, 8, r) -> depth 8r.
    vec![
        // 2 boundary cores per linear.
        (CoreArray { r: rank, depth: 12 }, 2 * n_linear),
        // 4 interior cores per linear.
        (CoreArray { r: rank, depth: 8 * rank }, 4 * n_linear),
        // TTM embedding cores (rank 30): (1,12,10,30), (30,8,10,30), (30,8,10,1).
        (CoreArray { r: 30, depth: 120 }, 1),
        (CoreArray { r: 30, depth: 800 }, 1),
        (CoreArray { r: 30, depth: 80 }, 1),
    ]
}

/// Optimizer-state arrays for the same model: state lives in the same
/// compressed TT/TTM-core layout as the parameters (the paper's PU
/// stage keeps all optimizer information on chip), so each state copy
/// is one more array of every core shape — `multiplier` copies per core
/// (0 for SGD, 1 for momentum, 2 for Adam/AdamW; see
/// `crate::optim::OptimKind::state_multiplier`).
pub fn optimizer_state_core_set(
    n_layers: usize,
    rank: usize,
    multiplier: usize,
) -> Vec<(CoreArray, usize)> {
    if multiplier == 0 {
        return Vec::new();
    }
    paper_core_set(n_layers, rank)
        .into_iter()
        .map(|(array, count)| (array, count * multiplier))
        .collect()
}

/// Fig. 12 / Fig. 14 driver: efficiency of each strategy for a model.
pub fn strategy_comparison(n_layers: usize, rank: usize) -> Vec<Allocation> {
    let cores = paper_core_set(n_layers, rank);
    let k = paper_group_k(3, n_layers);
    Strategy::all()
        .iter()
        .map(|&s| allocate(&cores, s, k))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn single_small_core_wastes_blocks_ungrouped() {
        // A (12, 8, 12) interior core: 1152 elems = 36864 bits = exactly
        // one ideal block, but rank-parallel partitioning needs 12.
        let core = CoreArray { r: 12, depth: 96 };
        let (_, blocks) = best_width(core, 1, Strategy::PartitionDefault);
        assert_eq!(blocks, 12);
        // Reshaping packs 12 lanes * 32 bits = 384-bit words: ceil(384/72)=6.
        let (_, blocks) = best_width(core, 1, Strategy::ReshapeDefault);
        assert_eq!(blocks, 6);
    }

    #[test]
    fn grouping_improves_efficiency_paper_range() {
        // Paper Fig. 12: grouped strategies are 3.9x-8.4x more efficient.
        for n_layers in [2usize, 4, 6] {
            let allocs = strategy_comparison(n_layers, 12);
            let part_def = allocs[0].efficiency;
            let resh_grp = allocs[3].efficiency;
            let gain = resh_grp / part_def;
            assert!(
                (2.0..=12.0).contains(&gain),
                "L{n_layers}: gain {gain:.1} outside plausible paper range"
            );
            assert!(allocs[3].total_blocks <= allocs[0].total_blocks);
        }
    }

    #[test]
    fn efficiency_at_most_one() {
        prop::check(41, 40, |rng| {
            let core = CoreArray {
                r: 1 + rng.below(32) as usize,
                depth: 1 + rng.below(2048) as usize,
            };
            let count = 1 + rng.below(48) as usize;
            let k = 1 + rng.below(12) as usize;
            for s in Strategy::all() {
                let a = allocate(&[(core, count)], s, k);
                assert!(a.efficiency <= 1.0 + 1e-9, "{s:?}: eta {}", a.efficiency);
                assert!(a.total_blocks >= 1);
            }
        });
    }

    #[test]
    fn grouped_never_worse_than_ungrouped() {
        prop::check(42, 40, |rng| {
            let core = CoreArray {
                r: 1 + rng.below(16) as usize,
                depth: 1 + rng.below(512) as usize,
            };
            let count = 1 + rng.below(64) as usize;
            let k = 1 + rng.below(16) as usize;
            let ungrouped = allocate(&[(core, count)], Strategy::ReshapeDefault, 1);
            let grouped = allocate(&[(core, count)], Strategy::ReshapeGrouped, k);
            assert!(
                grouped.total_blocks <= ungrouped.total_blocks,
                "grouping increased blocks: {} > {}",
                grouped.total_blocks,
                ungrouped.total_blocks
            );
        });
    }

    #[test]
    fn optimizer_state_scales_like_the_cores() {
        // Adam state (2x) holds exactly twice the bits of the parameter
        // cores, and the grouped allocator places it in at most 2x the
        // blocks plus per-array rounding.
        let params = allocate(&paper_core_set(2, 12), Strategy::ReshapeGrouped, 3);
        let adam = allocate(&optimizer_state_core_set(2, 12, 2), Strategy::ReshapeGrouped, 3);
        assert_eq!(adam.total_bits, 2 * params.total_bits);
        assert!(adam.total_blocks <= 2 * params.total_blocks + 16);
        assert!(optimizer_state_core_set(2, 12, 0).is_empty(), "SGD keeps no state");
    }

    #[test]
    fn halving_the_element_width_never_increases_blocks() {
        // The mixed-precision guarantee behind the bf16/f16 storage
        // path: for every array shape, count, grouping factor, strategy
        // and legal BRAM width, 16-bit elements never need more blocks
        // than 32-bit elements — and the total bits halve exactly.
        prop::check(44, 40, |rng| {
            let core = CoreArray {
                r: 1 + rng.below(24) as usize,
                depth: 1 + rng.below(1024) as usize,
            };
            let count = 1 + rng.below(48) as usize;
            let k = 1 + rng.below(12) as usize;
            for s in Strategy::all() {
                for &w in &WIDTHS {
                    assert!(
                        blocks_for_width(core, k, s, w, 16) <= blocks_for_width(core, k, s, w, 32),
                        "{s:?} W={w}: halving the element width increased blocks"
                    );
                }
                let full = allocate_at(&[(core, count)], s, k, 32);
                let half = allocate_at(&[(core, count)], s, k, 16);
                assert!(
                    half.total_blocks <= full.total_blocks,
                    "{s:?}: 16-bit allocation {} > 32-bit {}",
                    half.total_blocks,
                    full.total_blocks
                );
                assert_eq!(2 * half.total_bits, full.total_bits);
            }
        });
    }

    #[test]
    fn fp32_wrappers_match_the_width_parameterized_allocator() {
        let core = CoreArray { r: 12, depth: 96 };
        for s in Strategy::all() {
            assert_eq!(best_width(core, 3, s), best_width_at(core, 3, s, BW));
            assert_eq!(
                allocate(&[(core, 13)], s, 3).total_blocks,
                allocate_at(&[(core, 13)], s, 3, BW).total_blocks
            );
        }
    }

    #[test]
    fn fits_u50_bram_budget() {
        // The paper stores all compressed parameters on-chip: the grouped
        // allocation must fit the U50's 1344 BRAM blocks.
        let allocs = strategy_comparison(6, 12);
        assert!(
            allocs[3].total_blocks < crate::config::U50::BRAM_BLOCKS,
            "grouped allocation {} blocks exceeds U50",
            allocs[3].total_blocks
        );
    }
}
