//! FPGA accelerator simulator.
//!
//! We have no Alveo U50 + Vitis HLS toolchain, so the paper's hardware
//! contribution is reproduced as an analytic/discrete-event simulator
//! (DESIGN.md substitution table).  The paper's memory-management and
//! scheduling results are arithmetic over block sizes and dataflow DAGs,
//! which a simulator evaluates exactly:
//!
//! * [`bram`] — BRAM 36K block model, array partitioning vs reshaping,
//!   and the tensor-grouping allocator (paper Eqs. 22-25, Figs. 11/12/14).
//! * [`schedule`] — kernel-timeline simulator for the BTT dataflow:
//!   MUL0-MUL3 kernels, naive vs rescheduled attention (Fig. 9), unfused
//!   vs fused backprop (Fig. 10), and per-epoch latency (Table V).
//! * [`resources`] — DSP/LUT/FF/BRAM/URAM occupancy model (Table IV).
//! * [`energy`] — power integration and the GPU-vs-FPGA comparison
//!   (Table V, Figs. 1 and 15).

pub mod bram;
pub mod energy;
pub mod resources;
pub mod schedule;
