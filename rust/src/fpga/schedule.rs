//! Kernel-timeline scheduling simulator (paper Sec. V-B, Figs. 9/10) and
//! the per-epoch latency model behind Table V.
//!
//! The paper's dataflow is a DAG of contraction kernels:
//!
//! * `MUL0` — K-independent core merges (G pairs -> Z1 / Z3),
//! * `MUL1` — `Z2 = X Z1^T` (K-dependent),
//! * `MUL2` — `Y = Z2 Z3^T` (fwd) or `dZ3 = dY Z2` (bp),
//! * `MUL3` — core-gradient contraction + parameter update,
//! * `MM`   — attention score/apply matrix multiplies,
//! * `NL`   — softmax / GELU / LayerNorm / tanh lanes,
//! * `LKP`  — TTM embedding lookup.
//!
//! A list scheduler with per-kernel unit counts reproduces the paper's
//! two scheduling results: task rescheduling keeps the naive QKV makespan
//! with 2 instead of 6 MUL0 units (Fig. 9), and operation fusion shrinks
//! the BP intermediate buffer from O(n1*n2*r) to O(r) (Fig. 10).
//!
//! The Fig. 9 analysis is no longer simulation-only: the native
//! trainer's fused QKV path (`crate::train::layers::forward_qkv_fused`)
//! executes the [`qkv_fused_tasks`] DAG — one shared right merge + one
//! shared MUL1 — and its mul counts are charged by
//! [`crate::costmodel::LinearShape::btt_fwd_qkv_muls`], so the analytic
//! makespans here and the executed [`crate::tensor::ContractionStats`]
//! describe the same schedule.

use crate::config::{ModelConfig, U50};
use crate::costmodel::LinearShape;
use std::collections::BTreeMap;

/// Kernel classes with dedicated compute units on the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Kernel {
    Mul0,
    Mul1,
    Mul2,
    Mul3,
    Mm,
    Nl,
    Lkp,
}

/// One schedulable task.
#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    pub kernel: Kernel,
    pub cycles: u64,
    /// Indices of tasks that must finish first.
    pub deps: Vec<usize>,
}

/// Available unit counts per kernel class.
#[derive(Debug, Clone)]
pub struct Units(pub BTreeMap<Kernel, usize>);

impl Units {
    pub fn new(pairs: &[(Kernel, usize)]) -> Units {
        Units(pairs.iter().copied().collect())
    }

    fn count(&self, k: Kernel) -> usize {
        *self.0.get(&k).unwrap_or(&1)
    }
}

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub makespan: u64,
    /// (start, end) per task, same order as input.
    pub spans: Vec<(u64, u64)>,
}

/// List-schedule the task DAG under the unit constraints (longest-path
/// priority, non-preemptive).
pub fn simulate(tasks: &[Task], units: &Units) -> Schedule {
    let n = tasks.len();
    // Critical-path priority: longest downstream chain first.
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, t) in tasks.iter().enumerate() {
        for &d in &t.deps {
            succs[d].push(i);
        }
    }
    let mut rank = vec![0u64; n];
    for i in (0..n).rev() {
        rank[i] = tasks[i].cycles
            + succs[i].iter().map(|&s| rank[s]).max().unwrap_or(0);
    }

    let mut done_at = vec![u64::MAX; n];
    let mut remaining_deps: Vec<usize> = tasks.iter().map(|t| t.deps.len()).collect();
    let mut ready: Vec<usize> = (0..n).filter(|&i| remaining_deps[i] == 0).collect();
    // Per-kernel busy-until times, one slot per unit.
    let mut unit_free: BTreeMap<Kernel, Vec<u64>> = BTreeMap::new();
    for (&k, &c) in &units.0 {
        unit_free.insert(k, vec![0; c.max(1)]);
    }
    let mut scheduled = 0usize;
    let mut spans = vec![(0u64, 0u64); n];
    while scheduled < n {
        // Pick the ready task with the highest critical-path rank.
        ready.sort_by_key(|&i| std::cmp::Reverse(rank[i]));
        let mut progressed = false;
        let mut next_ready: Vec<usize> = Vec::new();
        for &i in &ready {
            let t = &tasks[i];
            let slots = unit_free
                .entry(t.kernel)
                .or_insert_with(|| vec![0; units.count(t.kernel).max(1)]);
            // Earliest a unit frees up and all deps are done.
            let dep_done = t.deps.iter().map(|&d| done_at[d]).max().unwrap_or(0);
            let (slot_idx, &slot_time) = slots
                .iter()
                .enumerate()
                .min_by_key(|&(_, &t0)| t0)
                .unwrap();
            let start = dep_done.max(slot_time);
            let end = start + t.cycles;
            slots[slot_idx] = end;
            done_at[i] = end;
            spans[i] = (start, end);
            scheduled += 1;
            progressed = true;
            for &s in &succs[i] {
                remaining_deps[s] -= 1;
                if remaining_deps[s] == 0 {
                    next_ready.push(s);
                }
            }
        }
        assert!(progressed, "scheduler stuck (cyclic deps?)");
        ready = next_ready;
    }
    Schedule {
        makespan: spans.iter().map(|&(_, e)| e).max().unwrap_or(0),
        spans,
    }
}

// ---------------------------------------------------------------------------
// Fig. 9: QKV forward — naive parallel vs rescheduled
// ---------------------------------------------------------------------------

/// Cycle cost of kernels at the paper shape, derived from mul counts at
/// `lanes`-way (rank-parallel) MACs.
fn mul0_cycles(shape: &LinearShape, lanes: u64) -> u64 {
    // Both merges happen on MUL0 units; cost of one chain (larger of the
    // two sides, they are symmetric at the paper shape).
    (shape.btt_muls(0) / 2).div_ceil(lanes)
}

fn mul12_cycles(shape: &LinearShape, k: u64, lanes: u64) -> (u64, u64) {
    let r_d = shape.ranks[shape.d()] as u64;
    let mul1 = (k * r_d * shape.n()).div_ceil(lanes);
    let mul2 = (k * r_d * shape.m()).div_ceil(lanes);
    (mul1, mul2)
}

/// Build the QKV forward DAG (paper Fig. 9).  With `rescheduled = false`
/// every linear's two MUL0 merges are issued at time zero (6 units needed
/// for full speed); with `rescheduled = true` the same tasks exist but
/// non-urgent merges are *expected* to wait for a shared unit — the test
/// is that 2 units suffice for the same makespan.
pub fn qkv_tasks(shape: &LinearShape, k: u64, lanes: u64) -> Vec<Task> {
    let m0 = mul0_cycles(shape, lanes);
    let (m1, m2) = mul12_cycles(shape, k, lanes);
    let mut tasks = Vec::new();
    for (qi, name) in ["q", "k", "v"].iter().enumerate() {
        let base = qi * 4;
        tasks.push(Task {
            name: format!("{name}.mul0.left"),
            kernel: Kernel::Mul0,
            cycles: m0,
            deps: vec![],
        });
        tasks.push(Task {
            name: format!("{name}.mul0.right"),
            kernel: Kernel::Mul0,
            cycles: m0,
            deps: vec![],
        });
        tasks.push(Task {
            name: format!("{name}.mul1"),
            kernel: Kernel::Mul1,
            cycles: m1,
            deps: vec![base + 1],
        });
        tasks.push(Task {
            name: format!("{name}.mul2"),
            kernel: Kernel::Mul2,
            cycles: m2,
            deps: vec![base, base + 2],
        });
    }
    tasks
}

/// Fig. 9 result: (naive makespan w/ 6 MUL0 units, rescheduled makespan
/// w/ 2 MUL0 units).
pub fn fig9_compare(shape: &LinearShape, k: u64, lanes: u64) -> (u64, u64) {
    let tasks = qkv_tasks(shape, k, lanes);
    let naive = simulate(
        &tasks,
        &Units::new(&[(Kernel::Mul0, 6), (Kernel::Mul1, 1), (Kernel::Mul2, 1)]),
    );
    let resched = simulate(
        &tasks,
        &Units::new(&[(Kernel::Mul0, 2), (Kernel::Mul1, 1), (Kernel::Mul2, 1)]),
    );
    (naive.makespan, resched.makespan)
}

/// The **fused QKV** task DAG — the schedule the native trainer
/// actually executes (`crate::train::layers::forward_qkv_fused`, tied
/// input-side cores): ONE shared right merge feeds ONE MUL1
/// (`Z2 = X Z1^T`), which fans out into the three per-projection MUL2
/// applies; only the three left merges remain per-projection.  Where
/// Fig. 9's rescheduling keeps the naive makespan with fewer units,
/// fusion removes two of the six MUL0 tasks and two of the three MUL1
/// tasks outright — the same work reduction
/// `LinearShape::btt_fwd_qkv_muls` charges in the cost model.
pub fn qkv_fused_tasks(shape: &LinearShape, k: u64, lanes: u64) -> Vec<Task> {
    let m0 = mul0_cycles(shape, lanes);
    let (m1, m2) = mul12_cycles(shape, k, lanes);
    let mut tasks = vec![
        Task {
            name: "qkv.mul0.right(shared)".into(),
            kernel: Kernel::Mul0,
            cycles: m0,
            deps: vec![],
        },
        Task {
            name: "qkv.mul1(shared)".into(),
            kernel: Kernel::Mul1,
            cycles: m1,
            deps: vec![0],
        },
    ];
    for name in ["q", "k", "v"] {
        let left = tasks.len();
        tasks.push(Task {
            name: format!("{name}.mul0.left"),
            kernel: Kernel::Mul0,
            cycles: m0,
            deps: vec![],
        });
        tasks.push(Task {
            name: format!("{name}.mul2"),
            kernel: Kernel::Mul2,
            cycles: m2,
            deps: vec![left, 1],
        });
    }
    tasks
}

/// Fused-QKV makespan under the same 2-MUL0-unit budget as the
/// rescheduled Fig. 9 run.
pub fn fig9_fused_makespan(shape: &LinearShape, k: u64, lanes: u64) -> u64 {
    simulate(
        &qkv_fused_tasks(shape, k, lanes),
        &Units::new(&[(Kernel::Mul0, 2), (Kernel::Mul1, 1), (Kernel::Mul2, 1)]),
    )
    .makespan
}

// ---------------------------------------------------------------------------
// Fig. 10: fused vs unfused BP buffer
// ---------------------------------------------------------------------------

/// Peak intermediate-buffer elements in the core-gradient path
/// (`MUL2 -> MUL3`): the unfused schedule materializes the whole
/// dZ3' = dY Z2 block before MUL3 consumes it; the fused schedule streams
/// `n_1 * n_2` fine-grained slices through an O(r) buffer.
pub fn fig10_buffer_elems(shape: &LinearShape, fused: bool) -> u64 {
    let r = shape.ranks[shape.d()] as u64;
    if fused {
        r
    } else {
        let n1 = shape.n_modes[0] as u64;
        let n2 = shape.n_modes.get(1).copied().unwrap_or(1) as u64;
        n1 * n2 * r
    }
}

// ---------------------------------------------------------------------------
// Per-epoch latency model (Table V)
// ---------------------------------------------------------------------------

/// Per-sample training-cycle model for the whole transformer.
///
/// Each kernel class has its own MAC-lane width: TT contraction kernels
/// parallelize over the rank index (`tt_lanes = r = 12`, Sec. V-C), the
/// TTM lookup over its rank 30, while the dense attention/classifier MM
/// kernel uses a wide DSP array, and the nonlinear lanes are narrow.
/// Training costs ~3x the forward pass (Sec. IV-A).  Calibrated against
/// the paper's measured latencies in tests (within 20%).
#[derive(Debug, Clone)]
pub struct CycleModel {
    pub cfg: ModelConfig,
    /// Rank-parallel lanes of the TT contraction kernels.
    pub lanes: u64,
    /// Dense-MM kernel lanes (attention scores/apply, task heads).
    pub mm_lanes: u64,
    /// TTM lookup lanes (embedding rank).
    pub lkp_lanes: u64,
    /// Nonlinear function lanes (softmax/GELU/LN/tanh).
    pub nl_lanes: u64,
    /// Contraction order for TT linears: true = BTT, false = right-to-left.
    pub btt: bool,
}

impl CycleModel {
    pub fn paper(n_layers: usize) -> CycleModel {
        CycleModel {
            cfg: ModelConfig::paper(n_layers),
            lanes: 12,
            mm_lanes: 64,
            lkp_lanes: 30,
            nl_lanes: 8,
            btt: true,
        }
    }

    fn linear_shape(&self) -> LinearShape {
        LinearShape::uniform(&self.cfg.tt_m, &self.cfg.tt_n, self.cfg.tt_rank)
    }

    /// Forward multiplies of one TT linear at this model's K.
    fn tt_linear_muls(&self) -> u64 {
        let k = (self.cfg.batch * self.cfg.seq_len) as u64;
        let shape = self.linear_shape();
        if self.btt {
            shape.btt_muls(k)
        } else {
            shape.tt_rl_muls(k)
        }
    }

    /// Per-kernel-class training multiplies for one sample
    /// (FP + BP + PU ~ 3x FP): `(tt, mm, lookup, nl)`.
    pub fn muls_per_sample(&self) -> (u64, u64, u64, u64) {
        let cfg = &self.cfg;
        let k = (cfg.batch * cfg.seq_len) as u64;
        let h = cfg.d_hid as u64;
        let s = cfg.seq_len as u64;
        let heads = cfg.n_heads as u64;
        let dh = (cfg.d_hid / cfg.n_heads) as u64;
        let tt_lin = self.tt_linear_muls();
        // TT kernels: 6 linears per encoder + the classifier layer.
        let tt = (6 * cfg.n_layers as u64 + 1) * tt_lin;
        // Dense MM kernels: attention scores/apply + task heads.
        let attn_mm = 2 * heads * s * s * dh;
        let mm = cfg.n_layers as u64 * attn_mm
            + k * h * cfg.n_slots as u64
            + h * cfg.n_intents as u64;
        // Embedding lookup: rank-chain per token.
        let r_e = cfg.ttm_rank as u64;
        let m = &cfg.ttm_hid_modes;
        let lookup = k
            * ((m[0] as u64) * r_e * r_e
                + (m[0] * m[1]) as u64 * r_e * r_e
                + (m[0] * m[1] * m[2]) as u64 * r_e);
        // Nonlinearities (softmax, GELU, LN, tanh): ~20 ops/elem/layer.
        let nl = cfg.n_layers as u64 * 20 * k * h;
        (3 * tt, 3 * mm, 3 * lookup, 3 * nl)
    }

    /// Cycles per sample under the per-kernel lane widths.
    pub fn cycles_per_sample(&self) -> u64 {
        let (tt, mm, lookup, nl) = self.muls_per_sample();
        tt.div_ceil(self.lanes)
            + mm.div_ceil(self.mm_lanes)
            + lookup.div_ceil(self.lkp_lanes)
            + nl.div_ceil(self.nl_lanes)
    }

    /// Latency for one epoch of `samples` at the U50 clock (seconds).
    pub fn epoch_latency_secs(&self, samples: u64) -> f64 {
        (self.cycles_per_sample() * samples) as f64 / U50::CLOCK_HZ
    }
}

/// ATIS training-set size used for per-epoch numbers (Hemphill et al.).
pub const ATIS_TRAIN_SAMPLES: u64 = 4478;

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_shape() -> LinearShape {
        LinearShape::paper()
    }

    #[test]
    fn fig9_rescheduling_halves_units_same_makespan() {
        let (naive, resched) = fig9_compare(&paper_shape(), 32, 12);
        assert_eq!(
            naive, resched,
            "rescheduled (2 MUL0 units) must match naive (6 units)"
        );
    }

    #[test]
    fn fused_qkv_dag_is_smaller_and_no_slower() {
        // The executed fused schedule (train::layers::forward_qkv_fused)
        // drops 2 of 6 MUL0 and 2 of 3 MUL1 tasks and must not lose any
        // latency vs the rescheduled separate-QKV DAG on the same units.
        let shape = paper_shape();
        let (_, resched) = fig9_compare(&shape, 32, 12);
        let fused = fig9_fused_makespan(&shape, 32, 12);
        assert!(fused <= resched, "fused {fused} slower than rescheduled {resched}");
        let tasks = qkv_fused_tasks(&shape, 32, 12);
        assert_eq!(tasks.iter().filter(|t| t.kernel == Kernel::Mul0).count(), 4);
        assert_eq!(tasks.iter().filter(|t| t.kernel == Kernel::Mul1).count(), 1);
        // Total scheduled work drops by exactly the two elided right
        // merges and two elided MUL1 products.
        let work = |ts: &[Task]| ts.iter().map(|t| t.cycles).sum::<u64>();
        let sep = qkv_tasks(&shape, 32, 12);
        assert!(work(&tasks) < work(&sep));
    }

    #[test]
    fn fig9_one_unit_is_slower() {
        let tasks = qkv_tasks(&paper_shape(), 32, 12);
        let two = simulate(
            &tasks,
            &Units::new(&[(Kernel::Mul0, 2), (Kernel::Mul1, 1), (Kernel::Mul2, 1)]),
        );
        let one = simulate(
            &tasks,
            &Units::new(&[(Kernel::Mul0, 1), (Kernel::Mul1, 1), (Kernel::Mul2, 1)]),
        );
        assert!(one.makespan >= two.makespan);
    }

    #[test]
    fn fig10_fusion_shrinks_buffer_to_rank() {
        let s = paper_shape();
        assert_eq!(fig10_buffer_elems(&s, true), 12);
        assert_eq!(fig10_buffer_elems(&s, false), 8 * 8 * 12);
        // The paper's claim: fusion removes the O(n1 n2) factor entirely.
        assert_eq!(
            fig10_buffer_elems(&s, false) / fig10_buffer_elems(&s, true),
            64
        );
    }

    #[test]
    fn table5_fpga_latency_within_20pct() {
        // Paper Table V FPGA-BTT: 191 / 335 / 482 s per epoch (L2/L4/L6).
        for (layers, paper_secs) in [(2usize, 191.0), (4, 335.0), (6, 482.0)] {
            let m = CycleModel::paper(layers);
            let ours = m.epoch_latency_secs(ATIS_TRAIN_SAMPLES);
            let rel = (ours - paper_secs).abs() / paper_secs;
            assert!(
                rel < 0.20,
                "L{layers}: {ours:.0}s vs paper {paper_secs}s ({:.0}% off)",
                rel * 100.0
            );
        }
    }

    #[test]
    fn btt_faster_than_rl_in_cycles() {
        for layers in [2usize, 4, 6] {
            let mut m = CycleModel::paper(layers);
            let btt = m.cycles_per_sample();
            m.btt = false;
            let rl = m.cycles_per_sample();
            assert!(btt < rl, "L{layers}: BTT {btt} !< RL {rl}");
        }
    }

    #[test]
    fn scheduler_respects_deps() {
        let tasks = vec![
            Task { name: "a".into(), kernel: Kernel::Mul0, cycles: 10, deps: vec![] },
            Task { name: "b".into(), kernel: Kernel::Mul0, cycles: 5, deps: vec![0] },
            Task { name: "c".into(), kernel: Kernel::Mul1, cycles: 7, deps: vec![1] },
        ];
        let s = simulate(&tasks, &Units::new(&[(Kernel::Mul0, 1), (Kernel::Mul1, 1)]));
        assert!(s.spans[1].0 >= s.spans[0].1);
        assert!(s.spans[2].0 >= s.spans[1].1);
        assert_eq!(s.makespan, 22);
    }
}
