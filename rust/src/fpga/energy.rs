//! Energy / memory comparison vs GPU training (paper Table V, Figs. 1
//! and 15).
//!
//! GPU-side numbers are the paper's measured RTX 3090 constants
//! ([`crate::config::Rtx3090`]) — we have no 3090, so they serve as the
//! fixed reference side of every ratio (DESIGN.md substitution table).
//! FPGA-side numbers come from our simulator: latency from
//! [`super::schedule::CycleModel`], power and memory from
//! [`super::resources::report`].

use super::resources;
use super::schedule::{CycleModel, ATIS_TRAIN_SAMPLES};
use crate::config::{ModelConfig, Rtx3090};

/// One Table V row.
#[derive(Debug, Clone)]
pub struct TableVRow {
    pub setting: String,
    pub platform: &'static str,
    pub latency_per_epoch_s: f64,
    pub power_w: f64,
    pub computing_memory_mb: f64,
    pub memory_ratio_vs_fpga: f64,
    pub energy_per_epoch_kj: f64,
    pub energy_ratio_vs_fpga: f64,
}

/// Analytic "reserved" GPU memory estimate (Fig. 15 blue bars): model +
/// gradients + live activations + CUDA workspace, no framework overhead.
pub fn gpu_reserved_memory_mb(cfg: &ModelConfig, compressed: bool) -> f64 {
    let params = if compressed {
        cfg.tensor_params()
    } else {
        cfg.dense_equivalent_params()
    } as f64;
    let k = (cfg.batch * cfg.seq_len) as f64;
    // Stored activations per layer for BP (+ TT intermediates when
    // compressed, Eq. 19/21 already folded into the 8x working factor).
    let acts = cfg.n_layers as f64 * 8.0 * k * cfg.d_hid as f64;
    let workspace_mb = 42.0; // cuBLAS/cuDNN workspace floor
    (2.0 * params + acts) * 4.0 / 1e6 + workspace_mb
}

/// The FPGA side of Table V for one layer count.
pub fn fpga_row(n_layers: usize) -> TableVRow {
    let cfg = ModelConfig::paper(n_layers);
    let model = CycleModel::paper(n_layers);
    let rep = resources::report(&cfg);
    let latency = model.epoch_latency_secs(ATIS_TRAIN_SAMPLES);
    let power = rep.total_power_w();
    TableVRow {
        setting: format!("L{n_layers}-S32-FP32"),
        platform: "FPGA-BTT (ours)",
        latency_per_epoch_s: latency,
        power_w: power,
        computing_memory_mb: rep.onchip_memory_mb(),
        memory_ratio_vs_fpga: 1.0,
        energy_per_epoch_kj: latency * power / 1e3,
        energy_ratio_vs_fpga: 1.0,
    }
}

/// Assemble the full Table V (4 platforms x 3 model sizes).
pub fn table_v() -> Vec<TableVRow> {
    let mut rows = Vec::new();
    for (i, &layers) in [2usize, 4, 6].iter().enumerate() {
        let fpga = fpga_row(layers);
        let gpu_rows = [
            ("GPU-Matrix", Rtx3090::MATRIX[i]),
            ("GPU-TT", Rtx3090::TT[i]),
            ("GPU-BTT", Rtx3090::BTT[i]),
        ];
        for (platform, (l, lat, pow, mem)) in gpu_rows {
            debug_assert_eq!(l, layers);
            let energy = lat * pow / 1e3;
            rows.push(TableVRow {
                setting: format!("L{layers}-S32-FP32"),
                platform,
                latency_per_epoch_s: lat,
                power_w: pow,
                computing_memory_mb: mem,
                memory_ratio_vs_fpga: mem / fpga.computing_memory_mb,
                energy_per_epoch_kj: energy,
                energy_ratio_vs_fpga: energy / fpga.energy_per_epoch_kj,
            });
        }
        rows.push(fpga);
    }
    rows
}

/// Fig. 1 summary: per model size, (GPU-TT memory, FPGA memory,
/// GPU-TT energy, FPGA energy).
#[derive(Debug, Clone)]
pub struct Fig1Point {
    pub n_layers: usize,
    pub gpu_tt_memory_mb: f64,
    pub fpga_memory_mb: f64,
    pub gpu_tt_energy_kj: f64,
    pub fpga_energy_kj: f64,
}

pub fn fig1() -> Vec<Fig1Point> {
    [2usize, 4, 6]
        .iter()
        .enumerate()
        .map(|(i, &layers)| {
            let fpga = fpga_row(layers);
            let (_, lat, pow, mem) = Rtx3090::TT[i];
            Fig1Point {
                n_layers: layers,
                gpu_tt_memory_mb: mem,
                fpga_memory_mb: fpga.computing_memory_mb,
                gpu_tt_energy_kj: lat * pow / 1e3,
                fpga_energy_kj: fpga.energy_per_epoch_kj,
            }
        })
        .collect()
}

/// Fig. 15: GPU total vs reserved vs FPGA computing memory.
#[derive(Debug, Clone)]
pub struct Fig15Point {
    pub n_layers: usize,
    pub gpu_total_mb: f64,
    pub gpu_reserved_matrix_mb: f64,
    pub gpu_reserved_btt_mb: f64,
    pub fpga_mb: f64,
}

pub fn fig15() -> Vec<Fig15Point> {
    [2usize, 4, 6]
        .iter()
        .enumerate()
        .map(|(i, &layers)| {
            let cfg = ModelConfig::paper(layers);
            Fig15Point {
                n_layers: layers,
                gpu_total_mb: Rtx3090::BTT[i].3,
                gpu_reserved_matrix_mb: gpu_reserved_memory_mb(&cfg, false),
                gpu_reserved_btt_mb: gpu_reserved_memory_mb(&cfg, true),
                fpga_mb: fpga_row(layers).computing_memory_mb,
            }
        })
        .collect()
}

/// Render Table V as aligned text (the bench harness output).
pub fn render_table_v(rows: &[TableVRow]) -> String {
    let mut out = String::from(
        "setting      | platform          | lat/epoch(s) | power(W) | mem(MB) | mem-ratio | kJ/epoch | kJ-ratio\n",
    );
    for r in rows {
        out.push_str(&format!(
            "{:<12} | {:<17} | {:>12.0} | {:>8.2} | {:>7.1} | {:>9.1} | {:>8.1} | {:>8.2}\n",
            r.setting,
            r.platform,
            r.latency_per_epoch_s,
            r.power_w,
            r.computing_memory_mb,
            r.memory_ratio_vs_fpga,
            r.energy_per_epoch_kj,
            r.energy_ratio_vs_fpga,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fpga_beats_gpu_tt_energy_by_over_3x() {
        // Paper: "over 3.6x and 3.4x lower energy than TT and BTT on GPU".
        for row in table_v() {
            if row.platform == "GPU-TT" {
                assert!(
                    row.energy_ratio_vs_fpga > 3.0,
                    "{}: TT energy ratio {:.2}",
                    row.setting,
                    row.energy_ratio_vs_fpga
                );
            }
            if row.platform == "GPU-BTT" {
                assert!(row.energy_ratio_vs_fpga > 2.8);
            }
        }
    }

    #[test]
    fn fpga_beats_gpu_matrix_energy() {
        // Paper: ~1.3x lower energy even vs optimized dense GPU training.
        for row in table_v() {
            if row.platform == "GPU-Matrix" {
                assert!(
                    row.energy_ratio_vs_fpga > 1.0,
                    "{}: matrix energy ratio {:.2}",
                    row.setting,
                    row.energy_ratio_vs_fpga
                );
            }
        }
    }

    #[test]
    fn memory_reduction_at_least_20x() {
        // Paper Table V: 20.7x - 51.4x memory ratios vs GPU.
        for row in table_v() {
            if row.platform != "FPGA-BTT (ours)" {
                assert!(
                    row.memory_ratio_vs_fpga > 20.0,
                    "{} {}: {:.1}x",
                    row.setting,
                    row.platform,
                    row.memory_ratio_vs_fpga
                );
            }
        }
    }

    #[test]
    fn fig15_reserved_ordering() {
        // Paper Sec. VI-D1: BTT reserved < matrix reserved on GPU
        // (2.3x-4.2x), and FPGA < BTT reserved (1.5x-2.7x more reduction).
        for p in fig15() {
            assert!(p.gpu_reserved_btt_mb < p.gpu_reserved_matrix_mb);
            assert!(p.fpga_mb < p.gpu_reserved_btt_mb);
            let vs_matrix = p.gpu_reserved_matrix_mb / p.gpu_reserved_btt_mb;
            assert!(
                (1.5..=6.0).contains(&vs_matrix),
                "L{}: reserved reduction {vs_matrix:.1}",
                p.n_layers
            );
        }
    }

    #[test]
    fn fig1_fpga_lower_on_both_axes() {
        for p in fig1() {
            assert!(p.fpga_memory_mb < p.gpu_tt_memory_mb);
            assert!(p.fpga_energy_kj < p.gpu_tt_energy_kj);
        }
    }

    #[test]
    fn energy_kj_close_to_paper() {
        // Paper FPGA energy: 5.1 / 9.0 / 13.0 kJ per epoch.
        for (layers, paper_kj) in [(2usize, 5.1), (4, 9.0), (6, 13.0)] {
            let row = fpga_row(layers);
            let rel = (row.energy_per_epoch_kj - paper_kj).abs() / paper_kj;
            assert!(
                rel < 0.25,
                "L{layers}: {:.1} kJ vs paper {paper_kj} ({:.0}%)",
                row.energy_per_epoch_kj,
                rel * 100.0
            );
        }
    }
}
