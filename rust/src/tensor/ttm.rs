//! Tensor-train-matrix (TTM) embedding table: lookup and reconstruction
//! (paper Sec. III-C, Eqs. 8/17).

use super::dense::Tensor;
use super::precision::{PackedTensor, Precision};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, Result};

/// A (vocab, hidden) embedding table in TTM format.  Core k has shape
/// (r_{k-1}, m_k, n_k, r_k) with m = hidden modes, n = vocab modes.
///
/// Cores are stored **at rest** as [`PackedTensor`]s: genuinely
/// `u16`-packed under the half precisions (so the table's measured
/// bytes halve), a plain f32 buffer otherwise.  Per-token lookups
/// widen only the sliced elements ([`PackedTensor::get`]), never a
/// whole core.
#[derive(Debug, Clone)]
pub struct TTMEmbedding {
    pub cores: Vec<PackedTensor>,
    pub hid_modes: Vec<usize>,
    pub vocab_modes: Vec<usize>,
    pub ranks: Vec<usize>,
}

impl TTMEmbedding {
    pub fn vocab(&self) -> usize {
        self.vocab_modes.iter().product()
    }

    pub fn hidden(&self) -> usize {
        self.hid_modes.iter().product()
    }

    pub fn param_count(&self) -> usize {
        self.cores.iter().map(PackedTensor::numel).sum()
    }

    /// **Measured** bytes at rest: the sum of the actual core buffer
    /// sizes at their stored precision.
    pub fn bytes(&self) -> u64 {
        self.cores.iter().map(PackedTensor::bytes).sum()
    }

    /// Re-store every core at `prec` (bitwise lossless for values
    /// already representable there).
    pub fn set_precision(&mut self, prec: Precision) {
        for core in &mut self.cores {
            core.set_precision(prec);
        }
    }

    pub fn randn(
        hid_modes: &[usize],
        vocab_modes: &[usize],
        rank: usize,
        target_std: f32,
        rng: &mut SplitMix64,
    ) -> TTMEmbedding {
        let d = hid_modes.len();
        let mut ranks = vec![rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        let rank_paths: f64 = ranks[1..d].iter().map(|&r| r as f64).product();
        let sigma = ((target_std as f64).powi(2) / rank_paths).powf(1.0 / (2.0 * d as f64));
        let cores = (0..d)
            .map(|k| {
                PackedTensor::pack_owned(
                    Tensor::randn(
                        &[ranks[k], hid_modes[k], vocab_modes[k], ranks[k + 1]],
                        sigma as f32,
                        rng,
                    ),
                    Precision::F32,
                )
            })
            .collect();
        TTMEmbedding {
            cores,
            hid_modes: hid_modes.to_vec(),
            vocab_modes: vocab_modes.to_vec(),
            ranks,
        }
    }

    /// Mixed-radix digits of a token id over the vocab modes
    /// (most-significant first) — must match `python/compile/tt_layers.py`.
    pub fn token_digits(&self, token: usize) -> Vec<usize> {
        let mut digits = vec![0usize; self.vocab_modes.len()];
        let mut rem = token;
        for (k, &base) in self.vocab_modes.iter().enumerate().rev() {
            digits[k] = rem % base;
            rem /= base;
        }
        digits
    }

    /// Embedding lookup for one token (paper Eq. 17): chain the selected
    /// 2-D slices over the rank indices.
    pub fn lookup(&self, token: usize) -> Result<Tensor> {
        if token >= self.vocab() {
            return Err(anyhow!("token {token} out of vocab {}", self.vocab()));
        }
        let digits = self.token_digits(token);
        // Start: slice of core 0 at j_0: (m_0, r_1)  (r_0 == 1).
        let mut acc = self.slice(0, digits[0])?; // (m_0 * 1, r_1) viewed (m_acc, r)
        let mut m_acc = self.hid_modes[0];
        for k in 1..self.cores.len() {
            let sl = self.slice(k, digits[k])?; // (r_{k-1}, m_k * r_k)
            let rk = self.ranks[k + 1];
            let mk = self.hid_modes[k];
            // acc (m_acc, r_{k-1}) x sl (r_{k-1}, m_k * r_k)
            acc = acc.matmul(&sl)?.reshape(&[m_acc * mk, rk])?;
            m_acc *= mk;
        }
        acc.reshape(&[self.hidden()])
    }

    /// Embedding lookup that also returns the chain states
    /// `A_0..A_{d-1}` (`A_{d-1}` reshapes to the returned row) — the
    /// activations the backward pass reuses.
    pub fn lookup_cached(&self, token: usize) -> Result<(Tensor, Vec<Tensor>)> {
        self.lookup_cached_prec(token, Precision::F32)
    }

    /// [`TTMEmbedding::lookup_cached`] with mixed-precision storage:
    /// every chain state is **rounded on store** (round-to-nearest-even
    /// to `prec`) and the next fold consumes the rounded value — the
    /// same contract as `TTMatrix::merge_left_chain_prec`, so the chain
    /// the backward pass reads is exactly the chain the forward
    /// computed through.  `Precision::F32` is bitwise the
    /// full-precision lookup.
    pub fn lookup_cached_prec(
        &self,
        token: usize,
        prec: Precision,
    ) -> Result<(Tensor, Vec<Tensor>)> {
        if token >= self.vocab() {
            return Err(anyhow!("token {token} out of vocab {}", self.vocab()));
        }
        let digits = self.token_digits(token);
        let mut states = vec![prec.round_tensor_owned(self.slice(0, digits[0])?)];
        let mut m_acc = self.hid_modes[0];
        for k in 1..self.cores.len() {
            let sl = self.slice(k, digits[k])?;
            let rk = self.ranks[k + 1];
            let mk = self.hid_modes[k];
            let next = {
                let prev = states.last().expect("nonempty");
                prev.matmul(&sl)?.reshape(&[m_acc * mk, rk])?
            };
            states.push(prec.round_tensor_owned(next));
            m_acc *= mk;
        }
        let row = states.last().expect("nonempty").reshape(&[self.hidden()])?;
        Ok((row, states))
    }

    /// Backward of [`TTMEmbedding::lookup_cached`]: scatter-add the core
    /// gradients for `d_row` (d hidden,) into `grads` (one tensor per
    /// core, same shapes as [`TTMEmbedding::cores`]).
    pub fn lookup_vjp(
        &self,
        token: usize,
        states: &[Tensor],
        d_row: &[f32],
        grads: &mut [Tensor],
    ) -> Result<()> {
        let d = self.cores.len();
        if grads.len() != d || states.len() != d || d_row.len() != self.hidden() {
            return Err(anyhow!("lookup_vjp: inconsistent cache/grads for token {token}"));
        }
        let digits = self.token_digits(token);
        // d_state starts as the row gradient viewed as A_{d-1}'s shape.
        let mut d_state = Tensor::from_vec(d_row.to_vec(), &states[d - 1].shape)?;
        for k in (1..d).rev() {
            let prev = &states[k - 1]; // (m_prev, r_k)
            let m_prev = prev.shape[0];
            let mk = self.hid_modes[k];
            let rk = self.ranks[k + 1];
            let dflat = d_state.reshape(&[m_prev, mk * rk])?;
            // Gradient of the sliced core: A_{k-1}^T dA_k.
            let d_slice = prev.t()?.matmul(&dflat)?; // (r_k, mk * rk)
            self.scatter_slice_grad(k, digits[k], &d_slice, &mut grads[k])?;
            // Pull the gradient through to the previous chain state.
            let sl = self.slice(k, digits[k])?; // (r_k, mk * rk)
            d_state = dflat.matmul(&sl.t()?)?; // (m_prev, r_k)
        }
        self.scatter_slice_grad(0, digits[0], &d_state, &mut grads[0])?;
        Ok(())
    }

    /// Add a sliced-core gradient back into the full core gradient at
    /// vocab digit `j` (inverse indexing of [`TTMEmbedding::slice`]).
    fn scatter_slice_grad(
        &self,
        k: usize,
        j: usize,
        d_slice: &Tensor,
        grad: &mut Tensor,
    ) -> Result<()> {
        let core = &self.cores[k];
        let shape = core.shape();
        let (rp, mk, nk, rk) = (shape[0], shape[1], shape[2], shape[3]);
        if grad.shape.as_slice() != shape {
            return Err(anyhow!("grad shape {:?} != core {:?}", grad.shape, shape));
        }
        if k == 0 {
            for a in 0..mk {
                for b in 0..rk {
                    grad.data[(a * nk + j) * rk + b] += d_slice.data[a * rk + b];
                }
            }
        } else {
            for r in 0..rp {
                for a in 0..mk {
                    for b in 0..rk {
                        grad.data[((r * mk + a) * nk + j) * rk + b] +=
                            d_slice.data[r * mk * rk + a * rk + b];
                    }
                }
            }
        }
        Ok(())
    }

    /// Core k sliced at vocab digit j: (r_{k-1}, m_k * r_k) matrix
    /// ordered so the chain matmul in `lookup` is contiguous.
    fn slice(&self, k: usize, j: usize) -> Result<Tensor> {
        let core = &self.cores[k];
        let shape = core.shape();
        let (rp, mk, nk, rk) = (shape[0], shape[1], shape[2], shape[3]);
        if j >= nk {
            return Err(anyhow!("digit {j} out of mode {nk}"));
        }
        if k == 0 {
            // (1, m_0, n_0, r_1) -> (m_0, r_1)
            let mut out = Tensor::zeros(&[mk, rk]);
            for a in 0..mk {
                for b in 0..rk {
                    out.data[a * rk + b] = core.get((a * nk + j) * rk + b);
                }
            }
            Ok(out)
        } else {
            // (r_{k-1}, m_k, n_k, r_k) -> (r_{k-1}, m_k * r_k)
            let mut out = Tensor::zeros(&[rp, mk * rk]);
            for r in 0..rp {
                for a in 0..mk {
                    for b in 0..rk {
                        out.data[r * mk * rk + a * rk + b] =
                            core.get(((r * mk + a) * nk + j) * rk + b);
                    }
                }
            }
            Ok(out)
        }
    }

    /// Reconstruct the dense (vocab, hidden) table.
    pub fn to_dense(&self) -> Result<Tensor> {
        let v = self.vocab();
        let h = self.hidden();
        let mut out = Tensor::zeros(&[v, h]);
        for t in 0..v {
            let row = self.lookup(t)?;
            out.data[t * h..(t + 1) * h].copy_from_slice(&row.data);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_roundtrip() {
        let mut rng = SplitMix64::new(20);
        let e = TTMEmbedding::randn(&[4, 4, 3], &[3, 3, 3], 4, 0.02, &mut rng);
        for t in [0usize, 1, 13, 26] {
            let d = e.token_digits(t);
            let back = d.iter().fold(0usize, |acc, &x| acc * 3 + x);
            assert_eq!(back, t);
        }
    }

    #[test]
    fn lookup_matches_dense() {
        let mut rng = SplitMix64::new(21);
        let e = TTMEmbedding::randn(&[4, 4, 3], &[3, 3, 3], 4, 0.5, &mut rng);
        let dense = e.to_dense().unwrap();
        assert_eq!(dense.shape, vec![27, 48]);
        for t in [0usize, 5, 26] {
            let row = e.lookup(t).unwrap();
            for h in 0..48 {
                assert!((row.data[h] - dense.at2(t, h)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn lookup_cached_matches_lookup() {
        let mut rng = SplitMix64::new(23);
        let e = TTMEmbedding::randn(&[4, 4, 3], &[3, 3, 3], 4, 0.5, &mut rng);
        for t in [0usize, 7, 19, 26] {
            let (row, states) = e.lookup_cached(t).unwrap();
            assert_eq!(row, e.lookup(t).unwrap());
            assert_eq!(states.len(), e.cores.len());
        }
    }

    #[test]
    fn lookup_vjp_matches_finite_difference() {
        let mut rng = SplitMix64::new(24);
        let mut e = TTMEmbedding::randn(&[3, 2], &[2, 3], 3, 0.5, &mut rng);
        let token = 4usize;
        let h = e.hidden();
        let d_row: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
        let (_, states) = e.lookup_cached(token).unwrap();
        let mut grads: Vec<Tensor> =
            e.cores.iter().map(|c| Tensor::zeros(c.shape())).collect();
        e.lookup_vjp(token, &states, &d_row, &mut grads).unwrap();
        // loss(w) = <d_row, lookup(token)> — central differences on every
        // core entry must match the scattered analytic gradient.
        let eps = 1e-2f32;
        for k in 0..e.cores.len() {
            for idx in 0..e.cores[k].numel() {
                let orig = e.cores[k].get(idx);
                e.cores[k].update_in_place(|d| d[idx] = orig + eps);
                let up: f32 =
                    e.lookup(token).unwrap().data.iter().zip(&d_row).map(|(a, b)| a * b).sum();
                e.cores[k].update_in_place(|d| d[idx] = orig - eps);
                let dn: f32 =
                    e.lookup(token).unwrap().data.iter().zip(&d_row).map(|(a, b)| a * b).sum();
                e.cores[k].update_in_place(|d| d[idx] = orig);
                let fd = (up - dn) / (2.0 * eps);
                let an = grads[k].data[idx];
                assert!(
                    (fd - an).abs() < 1e-3 * (1.0 + an.abs()),
                    "core {k}[{idx}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn paper_config_param_count() {
        let mut rng = SplitMix64::new(22);
        let e = TTMEmbedding::randn(&[12, 8, 8], &[10, 10, 10], 30, 0.02, &mut rng);
        // (1*12*10*30) + (30*8*10*30) + (30*8*10*1) = 3600 + 72000 + 2400
        assert_eq!(e.param_count(), 78_000);
        assert_eq!(e.vocab(), 1000);
        assert_eq!(e.hidden(), 768);
        // vs dense 768,000: ~9.8x compression of the embedding table.
        assert!(e.vocab() * e.hidden() / e.param_count() >= 9);
    }
}
