//! Dense row-major f32 tensor: the substrate under the TT/TTM algebra.
//!
//! Deliberately minimal — shapes, reshape, matmul, transpose, SVD — just
//! what tensor-train decomposition and the contraction engines need.

use anyhow::{anyhow, Result};

/// Dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {shape:?} wants {n} elements, got {}", data.len()));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Standard-normal init scaled by `std`, from the library RNG.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::SplitMix64) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape (same element count), returning a view-copy.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(anyhow!("cannot reshape {:?} -> {shape:?}", self.shape));
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// 2-D element access.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Matrix product `self (m,k) @ other (k,n)`.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.ndim() != 2 || other.ndim() != 2 || self.shape[1] != other.shape[0] {
            return Err(anyhow!("matmul shape mismatch {:?} x {:?}", self.shape, other.shape));
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        let mut out = vec![0.0f32; m * n];
        // ikj loop order: streams `other` rows, vectorizes the j loop.
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::from_vec(out, &[m, n])
    }

    /// 2-D transpose.
    pub fn t(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            return Err(anyhow!("t() needs a matrix, got {:?}", self.shape));
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(out, &[n, m])
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Thin SVD of a 2-D tensor via one-sided Jacobi rotation on the smaller
/// side; returns `(u (m,r), s (r,), vt (r,n))` with `r = min(m, n)`,
/// singular values descending.
///
/// Accuracy is ample for TT-SVD at the paper's scale (small unfolding
/// side <= r * mode <= 144); verified against reconstruction in tests.
pub fn svd(a: &Tensor) -> Result<(Tensor, Vec<f32>, Tensor)> {
    if a.ndim() != 2 {
        return Err(anyhow!("svd needs a matrix"));
    }
    let (m, n) = (a.shape[0], a.shape[1]);
    if m <= n {
        // Work on rows: B = A A^T (m x m), eigendecompose, U = eigvecs,
        // V^T = S^{-1} U^T A.
        let (u, s) = sym_eig_psd(&gram_rows(a))?;
        let mut vt = Tensor::zeros(&[m, n]);
        let ut_a = u.t()?.matmul(a)?; // (m, n)
        let mut svals = vec![0.0f32; m];
        for i in 0..m {
            let sv = s[i].max(0.0).sqrt();
            svals[i] = sv;
            let inv = if sv > 1e-12 { 1.0 / sv } else { 0.0 };
            for j in 0..n {
                vt.data[i * n + j] = ut_a.data[i * n + j] * inv;
            }
        }
        Ok((u, svals, vt))
    } else {
        // Transpose route: svd(A^T) = (V, S, U^T).
        let (v, s, ut) = svd(&a.t()?)?;
        Ok((ut.t()?, s, v.t()?))
    }
}

/// `A A^T` for row-gram (m x m).
fn gram_rows(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut g = Tensor::zeros(&[m, m]);
    for i in 0..m {
        for j in i..m {
            let mut acc = 0.0f32;
            for p in 0..n {
                acc += a.data[i * n + p] * a.data[j * n + p];
            }
            g.data[i * m + j] = acc;
            g.data[j * m + i] = acc;
        }
    }
    g
}

/// Symmetric PSD eigendecomposition via cyclic Jacobi; returns
/// `(eigvecs (n,n) column-major-by-column, eigvals desc)`.
fn sym_eig_psd(a: &Tensor) -> Result<(Tensor, Vec<f32>)> {
    let n = a.shape[0];
    let mut m = a.data.clone(); // working copy, row-major (n,n)
    let mut v = vec![0.0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |i: usize, j: usize| i * n + j;
    for _sweep in 0..60 {
        let mut off = 0.0f32;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[idx(p, q)] * m[idx(p, q)];
            }
        }
        if off.sqrt() < 1e-10 * (1.0 + m.iter().map(|x| x.abs()).fold(0.0, f32::max)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-20 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = 0.5 * (aqq - app).atan2(2.0 * apq).mul_add(-1.0, std::f32::consts::FRAC_PI_2) / 2.0;
                // Standard Jacobi rotation angle:
                let phi = 0.5 * (2.0 * apq).atan2(app - aqq);
                let _ = theta;
                let (s, c) = phi.sin_cos();
                for k in 0..n {
                    let akp = m[idx(k, p)];
                    let akq = m[idx(k, q)];
                    m[idx(k, p)] = c * akp + s * akq;
                    m[idx(k, q)] = -s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[idx(p, k)];
                    let aqk = m[idx(q, k)];
                    m[idx(p, k)] = c * apk + s * aqk;
                    m[idx(q, k)] = -s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp + s * vkq;
                    v[idx(k, q)] = -s * vkp + c * vkq;
                }
            }
        }
    }
    // Sort by descending eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f32> = (0..n).map(|i| m[idx(i, i)]).collect();
    order.sort_by(|&a, &b| evals[b].partial_cmp(&evals[a]).unwrap());
    let mut u = Tensor::zeros(&[n, n]);
    let mut s = vec![0.0f32; n];
    for (new, &old) in order.iter().enumerate() {
        s[new] = evals[old];
        for k in 0..n {
            u.data[k * n + new] = v[idx(k, old)];
        }
    }
    Ok((u, s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let mut eye = Tensor::zeros(&[2, 2]);
        eye.data[0] = 1.0;
        eye.data[3] = 1.0;
        assert_eq!(a.matmul(&eye).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let b = Tensor::from_vec(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(a.t().unwrap().t().unwrap(), a);
    }

    #[test]
    fn svd_reconstructs() {
        let mut rng = SplitMix64::new(2);
        for &(m, n) in &[(6usize, 9usize), (9, 6), (4, 4), (1, 5), (12, 40)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let (u, s, vt) = svd(&a).unwrap();
            let r = m.min(n);
            assert_eq!(u.shape, vec![m, r]);
            assert_eq!(vt.shape, vec![r, n]);
            // Reconstruct U diag(S) V^T.
            let mut usv = Tensor::zeros(&[m, n]);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..r {
                        acc += u.data[i * r + k] * s[k] * vt.data[k * n + j];
                    }
                    usv.data[i * n + j] = acc;
                }
            }
            let err = usv.max_abs_diff(&a) / (1.0 + a.norm());
            assert!(err < 1e-3, "({m},{n}) err {err}");
        }
    }

    #[test]
    fn svd_singular_values_sorted() {
        let mut rng = SplitMix64::new(3);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let (_, s, _) = svd(&a).unwrap();
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }
}
