//! Dense row-major f32 tensor: the substrate under the TT/TTM algebra.
//!
//! Deliberately minimal — shapes, reshape, matmul, transpose, SVD — just
//! what tensor-train decomposition, the contraction engines and the
//! native training path need.
//!
//! ## Matmul kernels
//!
//! All products run through cache-blocked, **register-blocked
//! microkernels** built for the autovectorizer: the innermost updates
//! are fixed-width tiles (`axpy4`, `dot4`) expressed over
//! `chunks_exact` slices with unrolled accumulators, so the compiler
//! lifts them to SIMD lanes without any `unsafe` intrinsics.  Large
//! products are split row-wise across a **lazily-initialized persistent
//! worker pool** (spawned once per process, fed through a shared queue —
//! no per-call thread spawn on the hot path; the calling thread works
//! the first band while the pool works the rest).  Each output element's
//! accumulation order is *fixed by the kernel shape alone* (ascending
//! k-blocks, four-term quads within a block), never by the dispatch
//! decision: the k-blocking and the row split both preserve it, so
//! results are **bitwise identical** regardless of size or thread
//! count — parity tests and checkpoint determinism do not depend on
//! problem size or core count.
//!
//! The batched variants ([`Tensor::bmm`], [`Tensor::bmm_nt`],
//! [`Tensor::bmm_tn`]) contract stacks of matrices (batch-major 3-D
//! tensors) and parallelize over the batch — the shape of per-head
//! attention in both the forward and backward pass.
//!
//! ## Pool width and replica oversubscription
//!
//! The pool defaults to one compute thread per available core (the
//! caller plus `cores − 1` workers) and is **process-global**: under
//! data-parallel training ([`crate::replica`]) all N replica threads
//! share this one pool, so peak demand is `N + workers` runnable
//! threads — oversubscribed by design, since shards rarely hit their
//! parallel sections simultaneously and the OS scheduler time-slices
//! the rest.  For reproducible benchmarking (or to bound CPU use),
//! [`configure_worker_threads`] (CLI `--threads N`) pins the *total*
//! compute-thread width before the pool spawns; `--threads 1` makes
//! every matmul serial on its calling thread, which under `--replicas
//! N` degrades gracefully to pure batch-level parallelism.
//! Oversubscription (or any width) never affects results: dispatch
//! shape is chosen by problem size alone and accumulation order is
//! fixed by the kernel, so outputs stay bitwise identical.
//!
//! ## Mixed precision
//!
//! These kernels are the **f32 accumulation** half of the
//! mixed-precision contract ([`crate::tensor::precision`]): buffers
//! stored at bf16/f16 are widened to f32 on load (exactly), every
//! product accumulates in these f32 microkernels unchanged, and results
//! are rounded to the storage width only when stored
//! (round-to-nearest-even).  The bitwise-determinism guarantee above is
//! therefore a *per-precision* guarantee — the kernels themselves never
//! see a half-width operand.

use anyhow::{anyhow, Result};
use std::sync::{Condvar, Mutex, OnceLock};

/// Multiply-accumulate count above which `matmul` switches to the
/// thread-parallel path (handing bands to the pool still costs a queue
/// round-trip; below this the serial kernel wins).
const PAR_MULS_THRESHOLD: usize = 1 << 20;

/// k-dimension block of the inner kernel: 64 rows of the right operand
/// (<= 64 * 4 * n bytes) stay hot in L1/L2 while an output row is built.
const BLOCK_K: usize = 64;

/// Contraction-side unroll of the microkernels: four left-operand
/// scalars (and their four right-operand rows) are folded per pass.
const UNROLL_K: usize = 4;

/// Output-side tile of [`axpy4`]: wide enough for two 4-lane (or one
/// 8-lane) SIMD register per update, fixed so the compiler unrolls it.
const TILE_N: usize = 8;

// ---------------------------------------------------------------------------
// SIMD-friendly microkernels
//
// Plain safe Rust; the fixed-width tiles below are what the
// autovectorizer needs to emit packed FMAs.  Accumulation order is part
// of the kernel contract (see the module docs): `axpy4` folds its four
// terms left-to-right into the existing output, `dot4` keeps four
// independent lane accumulators and reduces them pairwise at the end —
// both fully deterministic and independent of dispatch.
// ---------------------------------------------------------------------------

/// `o[j] += a[0] b0[j] + a[1] b1[j] + a[2] b2[j] + a[3] b3[j]` over the
/// full row, tiled `TILE_N` wide with a scalar tail.
#[inline]
fn axpy4(o: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
    let n = o.len();
    let main = n - n % TILE_N;
    let (o_main, o_tail) = o.split_at_mut(main);
    for (t, ot) in o_main.chunks_exact_mut(TILE_N).enumerate() {
        let off = t * TILE_N;
        let c0 = &b0[off..off + TILE_N];
        let c1 = &b1[off..off + TILE_N];
        let c2 = &b2[off..off + TILE_N];
        let c3 = &b3[off..off + TILE_N];
        for l in 0..TILE_N {
            ot[l] += a[0] * c0[l] + a[1] * c1[l] + a[2] * c2[l] + a[3] * c3[l];
        }
    }
    for (l, ov) in o_tail.iter_mut().enumerate() {
        let j = main + l;
        *ov += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
    }
}

/// Single-row update `o[j] += a * b[j]` (the `UNROLL_K` remainder path).
#[inline]
fn axpy1(o: &mut [f32], a: f32, b: &[f32]) {
    for (ov, &bv) in o.iter_mut().zip(b) {
        *ov += a * bv;
    }
}

/// Dot product with four independent lane accumulators (`chunks_exact`
/// quads), reduced pairwise — the inner kernel of [`Tensor::bmm_nt`].
#[inline]
fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut qa = a.chunks_exact(4);
    let mut qb = b.chunks_exact(4);
    for (ca, cb) in (&mut qa).zip(&mut qb) {
        for l in 0..4 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in qa.remainder().iter().zip(qb.remainder()) {
        tail += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

// ---------------------------------------------------------------------------
// Persistent worker pool
//
// Threads cost ~10us each to launch; the old per-call `thread::scope`
// paid that on every large matmul.  The pool spawns its workers once
// (first parallel product) and feeds them through a shared LIFO queue;
// a per-dispatch latch blocks the caller until its jobs drain, which is
// also what makes the short-lived borrows in each job sound.
// ---------------------------------------------------------------------------

/// A queued unit of work.  Jobs are erased to `'static` at dispatch; the
/// dispatching call guarantees their real borrows outlive execution by
/// blocking on the latch before returning.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    queue: Mutex<Vec<Job>>,
    work_ready: Condvar,
    /// Worker threads parked on `work_ready` (0 on single-core hosts —
    /// the caller then runs everything inline).
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// Requested pool width (`0` = auto: available cores).  Consulted once,
/// when the pool lazily initializes; see [`configure_worker_threads`].
static REQUESTED_THREADS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Set the matmul worker-pool width to `threads` total compute threads
/// (`0` restores the default: every available core).  The calling
/// thread always works the first band itself, so `threads = n` spawns
/// `n - 1` workers and `threads = 1` is the fully serial kernel.
///
/// Must be called **before** the first large matmul of the process —
/// the pool spawns lazily exactly once and its width is then fixed; a
/// late call is a loud no-op (`stderr` warning) rather than a silent
/// reconfiguration.  Determinism is unaffected either way: results are
/// bitwise identical at any width (see the module docs).
pub fn configure_worker_threads(threads: usize) {
    REQUESTED_THREADS.store(threads, std::sync::atomic::Ordering::SeqCst);
    if let Some(p) = POOL.get() {
        if threads != 0 && threads.saturating_sub(1) != p.workers {
            eprintln!(
                "warning: matmul pool already running with {} worker(s); \
                 --threads {threads} ignored (set it before the first large matmul)",
                p.workers
            );
        }
    }
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let requested = REQUESTED_THREADS.load(std::sync::atomic::Ordering::SeqCst);
        let threads = if requested > 0 {
            requested
        } else {
            std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
        };
        let workers = threads.saturating_sub(1);
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("tt-matmul-{i}"))
                .spawn(worker_loop)
                .expect("spawning matmul worker");
        }
        Pool { queue: Mutex::new(Vec::new()), work_ready: Condvar::new(), workers }
    })
}

fn worker_loop() {
    // Workers racing into `pool()` during initialization block on the
    // OnceLock until the initializer (on the first caller) completes.
    let p = pool();
    let mut guard = p.queue.lock().unwrap();
    loop {
        if let Some(job) = guard.pop() {
            drop(guard);
            job();
            guard = p.queue.lock().unwrap();
        } else {
            guard = p.work_ready.wait(guard).unwrap();
        }
    }
}

/// Completion latch for one dispatch: counts outstanding jobs and
/// records whether any of them panicked.
struct Latch {
    state: Mutex<(usize, bool)>,
    all_done: Condvar,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch { state: Mutex::new((jobs, false)), all_done: Condvar::new() }
    }

    fn finish(&self, panicked: bool) {
        let mut g = self.state.lock().unwrap();
        g.0 -= 1;
        g.1 |= panicked;
        if g.0 == 0 {
            self.all_done.notify_all();
        }
    }

    /// Block until every job finished; returns whether any panicked.
    fn wait(&self) -> bool {
        let mut g = self.state.lock().unwrap();
        while g.0 > 0 {
            g = self.all_done.wait(g).unwrap();
        }
        g.1
    }
}

/// Blocked `ikj` kernel over a contiguous band of output rows.
///
/// `out` holds rows `row0..row0 + out.len() / n` of the product.  The
/// accumulation order over `p` is ascending k-blocks, [`UNROLL_K`]-wide
/// [`axpy4`] quads within a block (scalar tail last) — fixed by the
/// kernel, independent of band split and thread count.  All-zero quads
/// of the left operand are skipped (exact: adding a `0.0 * x` term is
/// the identity the skip elides).
fn matmul_rows(a: &[f32], b: &[f32], out: &mut [f32], row0: usize, k: usize, n: usize) {
    for (i, orow) in out.chunks_mut(n).enumerate() {
        let arow = &a[(row0 + i) * k..(row0 + i + 1) * k];
        let mut p0 = 0;
        while p0 < k {
            let p1 = (p0 + BLOCK_K).min(k);
            let mut quads = arow[p0..p1].chunks_exact(UNROLL_K);
            let mut p = p0;
            for q in quads.by_ref() {
                let av = [q[0], q[1], q[2], q[3]];
                if av != [0.0; 4] {
                    axpy4(
                        orow,
                        av,
                        &b[p * n..(p + 1) * n],
                        &b[(p + 1) * n..(p + 2) * n],
                        &b[(p + 2) * n..(p + 3) * n],
                        &b[(p + 3) * n..(p + 4) * n],
                    );
                }
                p += UNROLL_K;
            }
            for &av in quads.remainder() {
                if av != 0.0 {
                    axpy1(orow, av, &b[p * n..(p + 1) * n]);
                }
                p += 1;
            }
            p0 = p1;
        }
    }
}

/// Run `f(batch_index, out_chunk)` for every `stride`-sized chunk of
/// `out`, optionally fanning the chunks out across the persistent
/// worker pool.  Each chunk is computed wholly within one band, so the
/// band split never changes any element's accumulation order.
fn for_each_chunk<F>(out: &mut [f32], stride: usize, parallel: bool, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if stride == 0 || out.is_empty() {
        return;
    }
    let chunks = out.len() / stride;
    let lanes = if parallel { pool().workers + 1 } else { 1 };
    if lanes < 2 || chunks < 2 {
        for (i, chunk) in out.chunks_mut(stride).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let per_worker = chunks.div_ceil(lanes.min(chunks));
    let mut bands: Vec<(usize, &mut [f32])> =
        out.chunks_mut(per_worker * stride).enumerate().collect();
    let latch = Latch::new(bands.len() - 1);
    {
        let p = pool();
        let mut queue = p.queue.lock().unwrap();
        for (w, band) in bands.drain(1..) {
            let f_ref = &f;
            let latch_ref = &latch;
            let job = move || {
                // One span per pool job on the tt-matmul-{i} lane
                // (inert single atomic load when tracing is off).
                let _sp = crate::trace::span("pool", "job");
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    for (j, chunk) in band.chunks_mut(stride).enumerate() {
                        f_ref(w * per_worker + j, chunk);
                    }
                }));
                latch_ref.finish(result.is_err());
            };
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(job);
            // SAFETY: lifetime erasure only.  The borrows inside `job`
            // (`f`, `latch`, the band of `out`) stay valid until
            // `latch.wait()` below returns, and `finish` runs even when
            // the job panics (catch_unwind), so `wait` cannot miss a
            // job and this function cannot return while any job still
            // holds a borrow.
            let job: Job = unsafe { std::mem::transmute(job) };
            queue.push(job);
        }
        p.work_ready.notify_all();
    }
    // Band 0 runs on the calling thread while the pool works the rest.
    let band0 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let (_, band) = bands.pop().expect("band 0");
        for (j, chunk) in band.chunks_mut(stride).enumerate() {
            f(j, chunk);
        }
    }));
    let worker_panicked = latch.wait();
    if let Err(payload) = band0 {
        std::panic::resume_unwind(payload);
    }
    if worker_panicked {
        panic!("matmul worker panicked");
    }
}

/// Dense row-major tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {shape:?} wants {n} elements, got {}", data.len()));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Standard-normal init scaled by `std`, from the library RNG.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::rng::SplitMix64) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape (same element count), returning a view-copy.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(anyhow!("cannot reshape {:?} -> {shape:?}", self.shape));
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// 2-D element access.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Matrix product `self (m,k) @ other (k,n)`.
    ///
    /// Dispatches between the serial and thread-parallel blocked kernel
    /// by problem size; the result is bitwise identical either way (see
    /// the module docs).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.ndim() != 2 || other.ndim() != 2 || self.shape[1] != other.shape[0] {
            return Err(anyhow!("matmul shape mismatch {:?} x {:?}", self.shape, other.shape));
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let n = other.shape[1];
        let mut out = vec![0.0f32; m * n];
        if k > 0 {
            let parallel = m.saturating_mul(k).saturating_mul(n) >= PAR_MULS_THRESHOLD;
            for_each_chunk(&mut out, n, parallel, |row, orow| {
                matmul_rows(&self.data, &other.data, orow, row, k, n);
            });
        }
        Tensor::from_vec(out, &[m, n])
    }

    /// Batched matrix product: `self (B,m,k) @ other (B,k,n) -> (B,m,n)`,
    /// parallel over the batch for large products.
    pub fn bmm(&self, other: &Tensor) -> Result<Tensor> {
        let (b, m, k, n) = bmm_dims(self, other, 1)?;
        let mut out = vec![0.0f32; b * m * n];
        if k > 0 {
            let parallel = (b * m).saturating_mul(k).saturating_mul(n) >= PAR_MULS_THRESHOLD;
            for_each_chunk(&mut out, m * n, parallel, |i, chunk| {
                matmul_rows(&self.data[i * m * k..], &other.data[i * k * n..], chunk, 0, k, n);
            });
        }
        Tensor::from_vec(out, &[b, m, n])
    }

    /// Batched product with the second operand transposed:
    /// `self (B,m,k) @ other (B,n,k)^T -> (B,m,n)` — the attention
    /// `Q K^T` shape, contracted without materializing the transpose.
    pub fn bmm_nt(&self, other: &Tensor) -> Result<Tensor> {
        let (b, m, k, n) = bmm_dims(self, other, 2)?;
        let mut out = vec![0.0f32; b * m * n];
        let parallel = (b * m).saturating_mul(k).saturating_mul(n) >= PAR_MULS_THRESHOLD;
        for_each_chunk(&mut out, m * n, parallel, |i, chunk| {
            let a = &self.data[i * m * k..(i + 1) * m * k];
            let bb = &other.data[i * n * k..(i + 1) * n * k];
            for (ii, orow) in chunk.chunks_mut(n).enumerate() {
                let arow = &a[ii * k..(ii + 1) * k];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = dot4(arow, &bb[j * k..(j + 1) * k]);
                }
            }
        });
        Tensor::from_vec(out, &[b, m, n])
    }

    /// Batched product with the first operand transposed:
    /// `self (B,k,m)^T @ other (B,k,n) -> (B,m,n)` — the attention
    /// backward shapes (`P^T dCtx`, `dS^T Q`).
    pub fn bmm_tn(&self, other: &Tensor) -> Result<Tensor> {
        let (b, m, k, n) = bmm_dims(self, other, 3)?;
        let mut out = vec![0.0f32; b * m * n];
        if k > 0 {
            let parallel = (b * m).saturating_mul(k).saturating_mul(n) >= PAR_MULS_THRESHOLD;
            for_each_chunk(&mut out, m * n, parallel, |i, chunk| {
                let a = &self.data[i * k * m..(i + 1) * k * m];
                let bb = &other.data[i * k * n..(i + 1) * k * n];
                // Contraction rows four at a time: the left scalars for
                // output row `ii` are a strided gather (stride m), the
                // four right rows are contiguous — same axpy4 microkernel
                // and quad accumulation order as `matmul_rows`.
                let k_main = k - k % UNROLL_K;
                for p in (0..k_main).step_by(UNROLL_K) {
                    let (b0, b1, b2, b3) = (
                        &bb[p * n..(p + 1) * n],
                        &bb[(p + 1) * n..(p + 2) * n],
                        &bb[(p + 2) * n..(p + 3) * n],
                        &bb[(p + 3) * n..(p + 4) * n],
                    );
                    for ii in 0..m {
                        let av = [
                            a[p * m + ii],
                            a[(p + 1) * m + ii],
                            a[(p + 2) * m + ii],
                            a[(p + 3) * m + ii],
                        ];
                        if av != [0.0; 4] {
                            axpy4(&mut chunk[ii * n..(ii + 1) * n], av, b0, b1, b2, b3);
                        }
                    }
                }
                for p in k_main..k {
                    let arow = &a[p * m..(p + 1) * m];
                    let brow = &bb[p * n..(p + 1) * n];
                    for (ii, &av) in arow.iter().enumerate() {
                        if av != 0.0 {
                            axpy1(&mut chunk[ii * n..(ii + 1) * n], av, brow);
                        }
                    }
                }
            });
        }
        Tensor::from_vec(out, &[b, m, n])
    }

    /// 2-D transpose.
    pub fn t(&self) -> Result<Tensor> {
        if self.ndim() != 2 {
            return Err(anyhow!("t() needs a matrix, got {:?}", self.shape));
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::from_vec(out, &[n, m])
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Validate batched-matmul operands and return `(batch, m, k, n)`.
///
/// `variant`: 1 = `a b`, 2 = `a b^T`, 3 = `a^T b` (per-batch transposes).
fn bmm_dims(a: &Tensor, b: &Tensor, variant: u8) -> Result<(usize, usize, usize, usize)> {
    if a.ndim() != 3 || b.ndim() != 3 || a.shape[0] != b.shape[0] {
        return Err(anyhow!("bmm needs (B,_,_) x (B,_,_), got {:?} x {:?}", a.shape, b.shape));
    }
    let (m, k, kb, n) = match variant {
        1 => (a.shape[1], a.shape[2], b.shape[1], b.shape[2]),
        2 => (a.shape[1], a.shape[2], b.shape[2], b.shape[1]),
        _ => (a.shape[2], a.shape[1], b.shape[1], b.shape[2]),
    };
    if k != kb {
        return Err(anyhow!("bmm contraction mismatch {:?} x {:?}", a.shape, b.shape));
    }
    Ok((a.shape[0], m, k, n))
}

/// Thin SVD of a 2-D tensor via one-sided Jacobi rotation on the smaller
/// side; returns `(u (m,r), s (r,), vt (r,n))` with `r = min(m, n)`,
/// singular values descending.
///
/// Accuracy is ample for TT-SVD at the paper's scale (small unfolding
/// side <= r * mode <= 144); verified against reconstruction in tests.
pub fn svd(a: &Tensor) -> Result<(Tensor, Vec<f32>, Tensor)> {
    if a.ndim() != 2 {
        return Err(anyhow!("svd needs a matrix"));
    }
    let (m, n) = (a.shape[0], a.shape[1]);
    if m <= n {
        // Work on rows: B = A A^T (m x m), eigendecompose, U = eigvecs,
        // V^T = S^{-1} U^T A.
        let (u, s) = sym_eig_psd(&gram_rows(a))?;
        let mut vt = Tensor::zeros(&[m, n]);
        let ut_a = u.t()?.matmul(a)?; // (m, n)
        let mut svals = vec![0.0f32; m];
        for i in 0..m {
            let sv = s[i].max(0.0).sqrt();
            svals[i] = sv;
            let inv = if sv > 1e-12 { 1.0 / sv } else { 0.0 };
            for j in 0..n {
                vt.data[i * n + j] = ut_a.data[i * n + j] * inv;
            }
        }
        Ok((u, svals, vt))
    } else {
        // Transpose route: svd(A^T) = (V, S, U^T).
        let (v, s, ut) = svd(&a.t()?)?;
        Ok((ut.t()?, s, v.t()?))
    }
}

/// `A A^T` for row-gram (m x m).
fn gram_rows(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    let mut g = Tensor::zeros(&[m, m]);
    for i in 0..m {
        for j in i..m {
            let mut acc = 0.0f32;
            for p in 0..n {
                acc += a.data[i * n + p] * a.data[j * n + p];
            }
            g.data[i * m + j] = acc;
            g.data[j * m + i] = acc;
        }
    }
    g
}

/// Symmetric PSD eigendecomposition via cyclic Jacobi; returns
/// `(eigvecs (n,n) column-major-by-column, eigvals desc)`.
fn sym_eig_psd(a: &Tensor) -> Result<(Tensor, Vec<f32>)> {
    let n = a.shape[0];
    let mut m = a.data.clone(); // working copy, row-major (n,n)
    let mut v = vec![0.0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |i: usize, j: usize| i * n + j;
    for _sweep in 0..60 {
        let mut off = 0.0f32;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[idx(p, q)] * m[idx(p, q)];
            }
        }
        if off.sqrt() < 1e-10 * (1.0 + m.iter().map(|x| x.abs()).fold(0.0, f32::max)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-20 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                // Standard Jacobi rotation angle:
                let phi = 0.5 * (2.0 * apq).atan2(app - aqq);
                let (s, c) = phi.sin_cos();
                for k in 0..n {
                    let akp = m[idx(k, p)];
                    let akq = m[idx(k, q)];
                    m[idx(k, p)] = c * akp + s * akq;
                    m[idx(k, q)] = -s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[idx(p, k)];
                    let aqk = m[idx(q, k)];
                    m[idx(p, k)] = c * apk + s * aqk;
                    m[idx(q, k)] = -s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp + s * vkq;
                    v[idx(k, q)] = -s * vkp + c * vkq;
                }
            }
        }
    }
    // Sort by descending eigenvalue.
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f32> = (0..n).map(|i| m[idx(i, i)]).collect();
    order.sort_by(|&a, &b| evals[b].partial_cmp(&evals[a]).unwrap());
    let mut u = Tensor::zeros(&[n, n]);
    let mut s = vec![0.0f32; n];
    for (new, &old) in order.iter().enumerate() {
        s[new] = evals[old];
        for k in 0..n {
            u.data[k * n + new] = v[idx(k, old)];
        }
    }
    Ok((u, s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let mut eye = Tensor::zeros(&[2, 2]);
        eye.data[0] = 1.0;
        eye.data[3] = 1.0;
        assert_eq!(a.matmul(&eye).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let b = Tensor::from_vec(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    /// Reference triple-loop product (jik order — deliberately a
    /// *different* accumulation order than the kernel).
    fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape[0], a.shape[1]);
        let n = b.shape[1];
        let mut out = Tensor::zeros(&[m, n]);
        for j in 0..n {
            for i in 0..m {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.data[i * k + p] as f64 * b.data[p * n + j] as f64;
                }
                out.data[i * n + j] = acc as f32;
            }
        }
        out
    }

    #[test]
    fn parallel_path_matches_naive() {
        // 150*80*120 = 1.44M muls: crosses PAR_MULS_THRESHOLD, so this
        // exercises the threaded blocked kernel.
        let mut rng = SplitMix64::new(9);
        let a = Tensor::randn(&[150, 80], 1.0, &mut rng);
        let b = Tensor::randn(&[80, 120], 1.0, &mut rng);
        assert!(150 * 80 * 120 >= super::PAR_MULS_THRESHOLD);
        let c = a.matmul(&b).unwrap();
        let reference = matmul_naive(&a, &b);
        let scale = reference.norm() / (reference.numel() as f32).sqrt();
        assert!(c.max_abs_diff(&reference) < 1e-4 * (1.0 + scale));
    }

    #[test]
    fn microkernel_handles_ragged_tile_sizes() {
        // Dimensions chosen to exercise every remainder path of the
        // register-blocked kernels: k % UNROLL_K != 0, n % TILE_N != 0,
        // and a k crossing the BLOCK_K boundary with a tail.
        let mut rng = SplitMix64::new(21);
        for &(m, k, n) in &[(3usize, 5usize, 7usize), (4, 66, 9), (1, 131, 13), (7, 4, 1)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = a.matmul(&b).unwrap();
            let reference = matmul_naive(&a, &b);
            let scale = reference.norm() / (reference.numel() as f32).sqrt();
            assert!(
                c.max_abs_diff(&reference) < 1e-4 * (1.0 + scale),
                "({m},{k},{n}) diverges from f64 reference"
            );
        }
    }

    #[test]
    fn microkernel_zero_quad_skip_is_exact() {
        // Rows with embedded all-zero quads must produce the same result
        // as the dense reference (the skip only elides exact identities).
        let mut rng = SplitMix64::new(22);
        let mut a = Tensor::randn(&[2, 12], 1.0, &mut rng);
        for j in 4..8 {
            a.data[j] = 0.0; // zero quad in row 0
        }
        let b = Tensor::randn(&[12, 10], 1.0, &mut rng);
        let c = a.matmul(&b).unwrap();
        let reference = matmul_naive(&a, &b);
        assert!(c.max_abs_diff(&reference) < 1e-5);
    }

    #[test]
    fn matmul_is_deterministic_across_dispatch() {
        // Same inputs -> bitwise-equal output on repeated runs (the
        // thread split must not change accumulation order).
        let mut rng = SplitMix64::new(10);
        let a = Tensor::randn(&[130, 90], 1.0, &mut rng);
        let b = Tensor::randn(&[90, 110], 1.0, &mut rng);
        let c1 = a.matmul(&b).unwrap();
        let c2 = a.matmul(&b).unwrap();
        assert_eq!(c1.data, c2.data);
    }

    #[test]
    fn pool_survives_concurrent_callers_and_stays_deterministic() {
        // Several user threads hammering the shared persistent pool at
        // once: every product must match the single-threaded result
        // bitwise (bands are independent; the queue only schedules).
        let mut rng = SplitMix64::new(13);
        let a = Tensor::randn(&[140, 90], 1.0, &mut rng);
        let b = Tensor::randn(&[90, 100], 1.0, &mut rng);
        assert!(140 * 90 * 100 >= super::PAR_MULS_THRESHOLD);
        let want = a.matmul(&b).unwrap();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let (a, b, want) = (&a, &b, &want);
                scope.spawn(move || {
                    for _ in 0..8 {
                        assert_eq!(a.matmul(b).unwrap().data, want.data);
                    }
                });
            }
        });
    }

    #[test]
    fn bmm_matches_per_batch_matmul() {
        let mut rng = SplitMix64::new(11);
        let a = Tensor::randn(&[3, 5, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[3, 7, 4], 1.0, &mut rng);
        let c = a.bmm(&b).unwrap();
        assert_eq!(c.shape, vec![3, 5, 4]);
        for i in 0..3 {
            let ai = Tensor::from_vec(a.data[i * 35..(i + 1) * 35].to_vec(), &[5, 7]).unwrap();
            let bi = Tensor::from_vec(b.data[i * 28..(i + 1) * 28].to_vec(), &[7, 4]).unwrap();
            let ci = ai.matmul(&bi).unwrap();
            assert_eq!(&c.data[i * 20..(i + 1) * 20], &ci.data[..]);
        }
    }

    #[test]
    fn bmm_nt_and_tn_match_explicit_transposes() {
        let mut rng = SplitMix64::new(12);
        let a = Tensor::randn(&[2, 6, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 4, 5], 1.0, &mut rng);
        let nt = a.bmm_nt(&b).unwrap(); // (2, 6, 4)
        let at = Tensor::randn(&[2, 5, 6], 1.0, &mut rng);
        let bt = Tensor::randn(&[2, 5, 4], 1.0, &mut rng);
        let tn = at.bmm_tn(&bt).unwrap(); // (2, 6, 4)
        assert_eq!(nt.shape, vec![2, 6, 4]);
        assert_eq!(tn.shape, vec![2, 6, 4]);
        for i in 0..2 {
            let ai = Tensor::from_vec(a.data[i * 30..(i + 1) * 30].to_vec(), &[6, 5]).unwrap();
            let bi = Tensor::from_vec(b.data[i * 20..(i + 1) * 20].to_vec(), &[4, 5]).unwrap();
            let expect = ai.matmul(&bi.t().unwrap()).unwrap();
            assert!(
                Tensor::from_vec(nt.data[i * 24..(i + 1) * 24].to_vec(), &[6, 4])
                    .unwrap()
                    .max_abs_diff(&expect)
                    < 1e-5
            );
            let ati = Tensor::from_vec(at.data[i * 30..(i + 1) * 30].to_vec(), &[5, 6]).unwrap();
            let bti = Tensor::from_vec(bt.data[i * 20..(i + 1) * 20].to_vec(), &[5, 4]).unwrap();
            let expect = ati.t().unwrap().matmul(&bti).unwrap();
            assert!(
                Tensor::from_vec(tn.data[i * 24..(i + 1) * 24].to_vec(), &[6, 4])
                    .unwrap()
                    .max_abs_diff(&expect)
                    < 1e-5
            );
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = SplitMix64::new(1);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        assert_eq!(a.t().unwrap().t().unwrap(), a);
    }

    #[test]
    fn svd_reconstructs() {
        let mut rng = SplitMix64::new(2);
        for &(m, n) in &[(6usize, 9usize), (9, 6), (4, 4), (1, 5), (12, 40)] {
            let a = Tensor::randn(&[m, n], 1.0, &mut rng);
            let (u, s, vt) = svd(&a).unwrap();
            let r = m.min(n);
            assert_eq!(u.shape, vec![m, r]);
            assert_eq!(vt.shape, vec![r, n]);
            // Reconstruct U diag(S) V^T.
            let mut usv = Tensor::zeros(&[m, n]);
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..r {
                        acc += u.data[i * r + k] * s[k] * vt.data[k * n + j];
                    }
                    usv.data[i * n + j] = acc;
                }
            }
            let err = usv.max_abs_diff(&a) / (1.0 + a.norm());
            assert!(err < 1e-3, "({m},{n}) err {err}");
        }
    }

    #[test]
    fn svd_singular_values_sorted() {
        let mut rng = SplitMix64::new(3);
        let a = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let (_, s, _) = svd(&a).unwrap();
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
    }
}
