//! TT / TTM tensor algebra substrate.
//!
//! The paper assumes a tensor-train toolbox (decomposition, contraction,
//! reconstruction); this module provides it natively in rust so the
//! coordinator, cost model and FPGA simulator can reason about tensor
//! shapes and contraction schedules without touching python:
//!
//! * [`dense`] — row-major dense tensors + Jacobi SVD.
//! * [`tt`] — TT matrices (paper Eq. 7): TT-SVD (`from_dense`), both
//!   contraction orders with instrumentation (validates Eqs. 18-21).
//! * [`ttm`] — TTM embedding tables (paper Eq. 8/17).
//! * [`precision`] — the mixed-precision storage substrate
//!   (f32/bf16/f16 plus block-scaled int8, deterministic
//!   round-to-nearest-even, genuinely packed sub-f32 buffers; compute
//!   always accumulates in f32).

pub mod dense;
pub mod ops;
pub mod precision;
pub mod tt;
pub mod ttm;

pub use dense::{configure_worker_threads, svd, Tensor};
pub use precision::{
    PackedTensor, PackedVec, Precision, ScaledBlockTensor, ScaledBlockVec, INT8_BLOCK,
};
pub use tt::{ContractionStats, PackedTTMatrix, TTMatrix};
pub use ttm::TTMEmbedding;
