//! TT / TTM tensor algebra substrate.
//!
//! The paper assumes a tensor-train toolbox (decomposition, contraction,
//! reconstruction); this module provides it natively in rust so the
//! coordinator, cost model and FPGA simulator can reason about tensor
//! shapes and contraction schedules without touching python:
//!
//! * [`dense`] — row-major dense tensors + Jacobi SVD.
//! * [`tt`] — TT matrices (paper Eq. 7): TT-SVD (`from_dense`), both
//!   contraction orders with instrumentation (validates Eqs. 18-21).
//! * [`ttm`] — TTM embedding tables (paper Eq. 8/17).

pub mod dense;
pub mod ops;
pub mod tt;
pub mod ttm;

pub use dense::{svd, Tensor};
pub use tt::{ContractionStats, TTMatrix};
pub use ttm::TTMEmbedding;
