//! Elementwise / normalization ops on dense tensors — the nonlinear
//! kernels of the paper's accelerator (softmax, GELU, LayerNorm, tanh;
//! Fig. 8's "NL" units) plus the shared multi-head-attention block.
//!
//! Both the inference engine ([`crate::inference`]) and the native
//! training path ([`crate::train`]) run their forward passes through
//! these functions; training additionally keeps the attention
//! probabilities returned by [`multi_head_attention`] for the backward
//! pass.

use super::dense::Tensor;
use anyhow::{anyhow, Result};

/// Row-wise softmax over the last axis of a 2-D tensor, with an optional
/// key mask (0.0 entries are excluded, as in masked attention).
pub fn softmax_rows(x: &Tensor, mask: Option<&[f32]>) -> Tensor {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mut maxv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            let keep = mask.map(|m| m[j] > 0.5).unwrap_or(true);
            if keep && v > maxv {
                maxv = v;
            }
        }
        let mut sum = 0.0f32;
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for (j, &v) in row.iter().enumerate() {
            let keep = mask.map(|m| m[j] > 0.5).unwrap_or(true);
            if keep {
                let e = (v - maxv).exp();
                orow[j] = e;
                sum += e;
            }
        }
        if sum > 0.0 {
            for v in orow.iter_mut() {
                *v /= sum;
            }
        }
    }
    out
}

/// GELU (tanh approximation, matching `jax.nn.gelu`'s default).
pub fn gelu(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        let x = *v;
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        *v = 0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh());
    }
    out
}

/// Row-wise LayerNorm over the last axis: `(x - mu) / sqrt(var + eps) * g + b`.
pub fn layer_norm(x: &Tensor, g: &[f32], b: &[f32], eps: f32) -> Tensor {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    debug_assert_eq!(g.len(), cols);
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for j in 0..cols {
            orow[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
    out
}

/// Elementwise tanh.
pub fn tanh(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        *v = v.tanh();
    }
    out
}

/// `a + b` elementwise.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (o, &v) in out.data.iter_mut().zip(&b.data) {
        *o += v;
    }
    out
}

/// Add a row vector to every row of a 2-D tensor.
pub fn add_row(a: &Tensor, row: &[f32]) -> Tensor {
    let (rows, cols) = (a.shape[0], a.shape[1]);
    debug_assert_eq!(row.len(), cols);
    let mut out = a.clone();
    for i in 0..rows {
        for j in 0..cols {
            out.data[i * cols + j] += row[j];
        }
    }
    out
}

/// Split `(S, H)` row-major activations into head-major `(heads, S, dh)`.
pub fn pack_heads(x: &Tensor, n_heads: usize) -> Result<Tensor> {
    if x.ndim() != 2 || x.shape[1] % n_heads != 0 {
        return Err(anyhow!("pack_heads: bad shape {:?} for {n_heads} heads", x.shape));
    }
    let (s, h) = (x.shape[0], x.shape[1]);
    let dh = h / n_heads;
    let mut out = Tensor::zeros(&[n_heads, s, dh]);
    for head in 0..n_heads {
        for i in 0..s {
            let src = &x.data[i * h + head * dh..i * h + (head + 1) * dh];
            out.data[(head * s + i) * dh..(head * s + i + 1) * dh].copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Inverse of [`pack_heads`]: `(heads, S, dh)` back to `(S, H)`.
pub fn unpack_heads(x: &Tensor) -> Result<Tensor> {
    if x.ndim() != 3 {
        return Err(anyhow!("unpack_heads: need (heads, S, dh), got {:?}", x.shape));
    }
    let (n_heads, s, dh) = (x.shape[0], x.shape[1], x.shape[2]);
    let h = n_heads * dh;
    let mut out = Tensor::zeros(&[s, h]);
    for head in 0..n_heads {
        for i in 0..s {
            let src = &x.data[(head * s + i) * dh..(head * s + i + 1) * dh];
            out.data[i * h + head * dh..i * h + (head + 1) * dh].copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Masked multi-head self-attention on `(S, H)` activations (the
/// accelerator's MM + softmax path, paper Fig. 8).
///
/// Returns the context `(S, H)` and the per-head attention
/// probabilities `(heads, S, S)` — the latter is exactly what the
/// backward pass must keep, and is discarded by inference.
pub fn multi_head_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &[f32],
    n_heads: usize,
) -> Result<(Tensor, Tensor)> {
    let (s, h) = (q.shape[0], q.shape[1]);
    if k.shape != q.shape || v.shape != q.shape || mask.len() != s {
        return Err(anyhow!("attention shape mismatch q {:?} mask {}", q.shape, mask.len()));
    }
    let dh = h / n_heads;
    let qh = pack_heads(q, n_heads)?;
    let kh = pack_heads(k, n_heads)?;
    let vh = pack_heads(v, n_heads)?;
    let mut scores = qh.bmm_nt(&kh)?; // (heads, S, S)
    let scale = 1.0 / (dh as f32).sqrt();
    for x in scores.data.iter_mut() {
        *x *= scale;
    }
    let probs = softmax_rows(&scores.reshape(&[n_heads * s, s])?, Some(mask))
        .reshape(&[n_heads, s, s])?;
    let ctx = probs.bmm(&vh)?; // (heads, S, dh)
    Ok((unpack_heads(&ctx)?, probs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0], &[2, 3]).unwrap();
        let s = softmax_rows(&x, None);
        for i in 0..2 {
            let sum: f32 = s.data[i * 3..(i + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // monotone: larger logit, larger prob
        assert!(s.at2(0, 2) > s.at2(0, 1));
    }

    #[test]
    fn softmax_mask_zeroes_padding() {
        let x = Tensor::from_vec(vec![5.0, 1.0, 9.0], &[1, 3]).unwrap();
        let s = softmax_rows(&x, Some(&[1.0, 1.0, 0.0]));
        assert_eq!(s.at2(0, 2), 0.0);
        assert!((s.data.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn gelu_fixed_points() {
        let x = Tensor::from_vec(vec![0.0, 10.0, -10.0], &[1, 3]).unwrap();
        let g = gelu(&x);
        assert_eq!(g.data[0], 0.0);
        assert!((g.data[1] - 10.0).abs() < 1e-3);
        assert!(g.data[2].abs() < 1e-3);
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[1, 4]).unwrap();
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = layer_norm(&x, &g, &b, 1e-5);
        let mu: f32 = y.data.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-5);
        let var: f32 = y.data.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-2);
    }

    #[test]
    fn tanh_range() {
        let x = Tensor::from_vec(vec![-100.0, 0.0, 100.0], &[1, 3]).unwrap();
        let y = tanh(&x);
        assert_eq!(y.data, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn pack_unpack_heads_roundtrip() {
        let mut rng = SplitMix64::new(41);
        let x = Tensor::randn(&[5, 12], 1.0, &mut rng);
        let packed = pack_heads(&x, 3).unwrap();
        assert_eq!(packed.shape, vec![3, 5, 4]);
        assert_eq!(unpack_heads(&packed).unwrap(), x);
    }

    #[test]
    fn attention_probs_rows_sum_to_one_and_respect_mask() {
        let mut rng = SplitMix64::new(42);
        let (s, h, heads) = (6, 8, 2);
        let q = Tensor::randn(&[s, h], 1.0, &mut rng);
        let k = Tensor::randn(&[s, h], 1.0, &mut rng);
        let v = Tensor::randn(&[s, h], 1.0, &mut rng);
        let mask = [1.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let (ctx, probs) = multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
        assert_eq!(ctx.shape, vec![s, h]);
        assert_eq!(probs.shape, vec![heads, s, s]);
        for row in probs.data.chunks(s) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            assert_eq!(row[4], 0.0);
            assert_eq!(row[5], 0.0);
        }
    }
}
