//! Elementwise / normalization ops on dense tensors — the nonlinear
//! kernels of the paper's accelerator (softmax, GELU, LayerNorm, tanh;
//! Fig. 8's "NL" units) plus the shared multi-head-attention block.
//!
//! Both the inference engine ([`crate::inference`]) and the native
//! training path ([`crate::train`]) run their forward passes through
//! these functions; training additionally keeps the attention
//! probabilities returned by [`multi_head_attention`] /
//! [`multi_head_attention_batched`] for the backward pass.
//!
//! The batched attention contracts the whole `(B, heads, S, S)` score
//! block through the `bmm*` kernels (persistent worker pool) in three
//! launches; the pad mask is applied as an **additive `-inf` bias**, so
//! pad columns never branch inside the kernels yet still receive an
//! exact-zero probability.  The single-example
//! [`multi_head_attention`] is the `B = 1` view of the same code path.

use super::dense::Tensor;
use anyhow::{anyhow, Result};

/// Row-wise softmax over the last axis of a 2-D tensor, with an optional
/// key mask (0.0 entries are excluded, as in masked attention).
pub fn softmax_rows(x: &Tensor, mask: Option<&[f32]>) -> Tensor {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mut maxv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            let keep = mask.map(|m| m[j] > 0.5).unwrap_or(true);
            if keep && v > maxv {
                maxv = v;
            }
        }
        let mut sum = 0.0f32;
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for (j, &v) in row.iter().enumerate() {
            let keep = mask.map(|m| m[j] > 0.5).unwrap_or(true);
            if keep {
                let e = (v - maxv).exp();
                orow[j] = e;
                sum += e;
            }
        }
        if sum > 0.0 {
            for v in orow.iter_mut() {
                *v /= sum;
            }
        }
    }
    out
}

/// Scalar GELU (tanh approximation, matching `jax.nn.gelu`'s default)
/// — the **single definition** of the approximation: the dense
/// [`gelu`], the fused [`bias_gelu`] lane and the VJP derivative
/// ([`gelu_grad_scalar`], used by `train::blocks::gelu_vjp`) all call
/// it, so the forward and its derivative can never drift apart.
#[inline]
pub fn gelu_scalar(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

/// Derivative of [`gelu_scalar`] at `x` (same tanh approximation,
/// expressions kept verbatim so existing fixed points don't move).
#[inline]
pub fn gelu_grad_scalar(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    let u = c * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    let du = c * (1.0 + 3.0 * 0.044715 * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// GELU (tanh approximation) over a whole tensor.
pub fn gelu(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        *v = gelu_scalar(*v);
    }
    out
}

/// Fused bias row-add + GELU on a raw (bias-free) TT-apply output
/// `y (K, M)`: one elementwise pass computes `h = y + bias` and
/// `gelu(h)` together, so the pre-activation never makes a separate
/// round trip through memory before the nonlinearity reads it.
/// Bitwise identical to [`add_row`] followed by [`gelu`] (identical
/// scalar order per element).  `h` is returned alongside because the
/// GELU VJP consumes the pre-activation.
pub fn bias_gelu(y: &Tensor, bias: &[f32]) -> (Tensor, Tensor) {
    let (rows, cols) = (y.shape[0], y.shape[1]);
    debug_assert_eq!(bias.len(), cols);
    let mut h = Tensor::zeros(&[rows, cols]);
    let mut g = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        for j in 0..cols {
            let hv = y.data[i * cols + j] + bias[j];
            h.data[i * cols + j] = hv;
            g.data[i * cols + j] = gelu_scalar(hv);
        }
    }
    (h, g)
}

/// Row-wise LayerNorm over the last axis: `(x - mu) / sqrt(var + eps) * g + b`.
pub fn layer_norm(x: &Tensor, g: &[f32], b: &[f32], eps: f32) -> Tensor {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    debug_assert_eq!(g.len(), cols);
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for j in 0..cols {
            orow[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
    out
}

/// Elementwise tanh.
pub fn tanh(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        *v = v.tanh();
    }
    out
}

/// `a + b` elementwise.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (o, &v) in out.data.iter_mut().zip(&b.data) {
        *o += v;
    }
    out
}

/// Add a row vector to every row of a 2-D tensor.
pub fn add_row(a: &Tensor, row: &[f32]) -> Tensor {
    let (rows, cols) = (a.shape[0], a.shape[1]);
    debug_assert_eq!(row.len(), cols);
    let mut out = a.clone();
    for i in 0..rows {
        for j in 0..cols {
            out.data[i * cols + j] += row[j];
        }
    }
    out
}

/// Split `(S, H)` row-major activations into head-major `(heads, S, dh)`.
pub fn pack_heads(x: &Tensor, n_heads: usize) -> Result<Tensor> {
    if x.ndim() != 2 || x.shape[1] % n_heads != 0 {
        return Err(anyhow!("pack_heads: bad shape {:?} for {n_heads} heads", x.shape));
    }
    let (s, h) = (x.shape[0], x.shape[1]);
    let dh = h / n_heads;
    let mut out = Tensor::zeros(&[n_heads, s, dh]);
    for head in 0..n_heads {
        for i in 0..s {
            let src = &x.data[i * h + head * dh..i * h + (head + 1) * dh];
            out.data[(head * s + i) * dh..(head * s + i + 1) * dh].copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Inverse of [`pack_heads`]: `(heads, S, dh)` back to `(S, H)`.
pub fn unpack_heads(x: &Tensor) -> Result<Tensor> {
    if x.ndim() != 3 {
        return Err(anyhow!("unpack_heads: need (heads, S, dh), got {:?}", x.shape));
    }
    let (n_heads, s, dh) = (x.shape[0], x.shape[1], x.shape[2]);
    let h = n_heads * dh;
    let mut out = Tensor::zeros(&[s, h]);
    for head in 0..n_heads {
        for i in 0..s {
            let src = &x.data[(head * s + i) * dh..(head * s + i + 1) * dh];
            out.data[i * h + head * dh..i * h + (head + 1) * dh].copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Contraction-lane width of the head packing: the per-head dimension
/// is zero-padded up to a multiple of this, matching the `dot4`/`axpy4`
/// quad width of the `bmm*` microkernels so the attention contractions
/// never fall into the ragged-tail scalar path.  Padding lanes are
/// exact zeros: `x + 0.0 * 0.0` is the identity, so padded and unpadded
/// head dims produce the same attention up to accumulation grouping,
/// and the B=1 view stays bitwise identical to the batched path (both
/// run the same padded kernels).
pub const HEAD_LANE: usize = 4;

/// Per-head dimension after SIMD-lane padding.
#[inline]
fn padded_dh(dh: usize) -> usize {
    dh.div_ceil(HEAD_LANE) * HEAD_LANE
}

/// Batched head split: `(B*S, H)` row-major activations to head-major
/// `(B*heads, S, dh_pad)` with the per-head dim zero-padded to a
/// multiple of [`HEAD_LANE`], slicing the K-stacked buffer directly by
/// offset (no per-example sub-tensors are materialized).
pub fn pack_heads_batched(x: &Tensor, batch: usize, n_heads: usize) -> Result<Tensor> {
    if x.ndim() != 2 || batch == 0 || x.shape[0] % batch != 0 || x.shape[1] % n_heads != 0 {
        return Err(anyhow!(
            "pack_heads_batched: bad shape {:?} for batch {batch} x {n_heads} heads",
            x.shape
        ));
    }
    let (s, h) = (x.shape[0] / batch, x.shape[1]);
    let dh = h / n_heads;
    let dhp = padded_dh(dh);
    let mut out = Tensor::zeros(&[batch * n_heads, s, dhp]);
    for e in 0..batch {
        for head in 0..n_heads {
            for i in 0..s {
                let src = &x.data[(e * s + i) * h + head * dh..(e * s + i) * h + (head + 1) * dh];
                let dst = ((e * n_heads + head) * s + i) * dhp;
                out.data[dst..dst + dh].copy_from_slice(src);
            }
        }
    }
    Ok(out)
}

/// Inverse of [`pack_heads_batched`]: `(B*heads, S, dh_pad)` back to
/// `(B*S, H)` for the true hidden width `h`, dropping the zero padding
/// lanes.
pub fn unpack_heads_batched(x: &Tensor, batch: usize, h: usize) -> Result<Tensor> {
    if x.ndim() != 3 || batch == 0 || x.shape[0] % batch != 0 {
        return Err(anyhow!(
            "unpack_heads_batched: need (B*heads, S, dh_pad), got {:?} at batch {batch}",
            x.shape
        ));
    }
    let (n_heads, s, dhp) = (x.shape[0] / batch, x.shape[1], x.shape[2]);
    if n_heads == 0 || h % n_heads != 0 || padded_dh(h / n_heads) != dhp {
        return Err(anyhow!(
            "unpack_heads_batched: hidden {h} over {n_heads} heads does not pad to {dhp} lanes"
        ));
    }
    let dh = h / n_heads;
    let mut out = Tensor::zeros(&[batch * s, h]);
    for e in 0..batch {
        for head in 0..n_heads {
            for i in 0..s {
                let src = ((e * n_heads + head) * s + i) * dhp;
                let dst = (e * s + i) * h + head * dh;
                out.data[dst..dst + dh].copy_from_slice(&x.data[src..src + dh]);
            }
        }
    }
    Ok(out)
}

/// Gather the per-example CLS rows (position 0 of each example) out of
/// a `(B*S, H)` K-stacked block into `(B, H)` — the intent head's
/// input, shared by the training forward and the inference engine.
pub fn cls_rows(x: &Tensor, batch: usize, seq: usize) -> Result<Tensor> {
    if x.ndim() != 2 || x.shape[0] != batch * seq {
        return Err(anyhow!(
            "cls_rows: expected ({} * {}, H), got {:?}",
            batch,
            seq,
            x.shape
        ));
    }
    let h = x.shape[1];
    let mut out = Tensor::zeros(&[batch, h]);
    for e in 0..batch {
        out.data[e * h..(e + 1) * h].copy_from_slice(&x.data[e * seq * h..e * seq * h + h]);
    }
    Ok(out)
}

/// Key mask (1.0 = keep, 0.0 = pad) to the additive score bias the
/// batched attention consumes: `0.0` for valid keys, `-inf` for pads.
/// Adding `-inf` drives the padded scores' `exp` to an exact `0.0`, so
/// pad columns never branch in the softmax and receive exactly zero
/// probability — the same semantics as the exclusion mask of
/// [`softmax_rows`].
pub fn attention_bias_from_mask(mask: &[f32]) -> Vec<f32> {
    mask.iter()
        .map(|&m| if m > 0.5 { 0.0 } else { f32::NEG_INFINITY })
        .collect()
}

/// Row-wise softmax over rows that may contain `-inf` entries (from the
/// additive attention bias): branch-free over columns — `exp(-inf)`
/// underflows to an exact 0.0 — with an all-masked-row guard (such a
/// row stays all-zero, matching [`softmax_rows`] on a fully-excluded
/// mask).
fn softmax_rows_biased(x: &mut Tensor, cols: usize) {
    for row in x.data.chunks_mut(cols) {
        let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        if maxv == f32::NEG_INFINITY {
            for v in row.iter_mut() {
                *v = 0.0;
            }
            continue;
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - maxv).exp();
            sum += *v;
        }
        if sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

/// Batched masked multi-head self-attention over a `(B*S, H)` block of
/// K-stacked activations — the whole mini-batch's attention in three
/// `bmm` launches on the persistent worker pool instead of `B`
/// per-example calls.
///
/// `bias` is the `(B*S,)` additive key bias from
/// [`attention_bias_from_mask`]; pad columns carry `-inf` and therefore
/// never branch inside the kernels.  Returns the context `(B*S, H)` and
/// the probabilities `(B*heads, S, S)` — exactly what
/// [`crate::train::blocks::multi_head_attention_vjp_batched`] consumes.
pub fn multi_head_attention_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    bias: &[f32],
    n_heads: usize,
    batch: usize,
) -> Result<(Tensor, Tensor)> {
    if k.shape != q.shape || v.shape != q.shape || bias.len() != q.shape[0] {
        return Err(anyhow!(
            "attention shape mismatch q {:?} bias {}",
            q.shape,
            bias.len()
        ));
    }
    let qh = pack_heads_batched(q, batch, n_heads)?;
    let kh = pack_heads_batched(k, batch, n_heads)?;
    let vh = pack_heads_batched(v, batch, n_heads)?;
    let s = qh.shape[1];
    // The softmax scale uses the *true* head dim; the packed buffers are
    // zero-padded to the SIMD lane width and the padding contributes
    // exact zeros to every contraction.
    let dh = q.shape[1] / n_heads;
    let mut scores = qh.bmm_nt(&kh)?; // (B*heads, S, S)
    let scale = 1.0 / (dh as f32).sqrt();
    for (bh, mat) in scores.data.chunks_mut(s * s).enumerate() {
        let ebias = &bias[(bh / n_heads) * s..(bh / n_heads + 1) * s];
        for row in mat.chunks_mut(s) {
            for (x, &b) in row.iter_mut().zip(ebias) {
                *x = *x * scale + b;
            }
        }
    }
    softmax_rows_biased(&mut scores, s);
    let probs = scores;
    let ctx = probs.bmm(&vh)?; // (B*heads, S, dh_pad)
    Ok((unpack_heads_batched(&ctx, batch, q.shape[1])?, probs))
}

/// Masked multi-head self-attention on `(S, H)` activations (the
/// accelerator's MM + softmax path, paper Fig. 8) — the single-example
/// view of [`multi_head_attention_batched`], kept for inference and the
/// looped reference schedule.
///
/// Returns the context `(S, H)` and the per-head attention
/// probabilities `(heads, S, S)` — the latter is exactly what the
/// backward pass must keep, and is discarded by inference.
pub fn multi_head_attention(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &[f32],
    n_heads: usize,
) -> Result<(Tensor, Tensor)> {
    let s = q.shape[0];
    if k.shape != q.shape || v.shape != q.shape || mask.len() != s {
        return Err(anyhow!("attention shape mismatch q {:?} mask {}", q.shape, mask.len()));
    }
    let bias = attention_bias_from_mask(mask);
    multi_head_attention_batched(q, k, v, &bias, n_heads, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0], &[2, 3]).unwrap();
        let s = softmax_rows(&x, None);
        for i in 0..2 {
            let sum: f32 = s.data[i * 3..(i + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // monotone: larger logit, larger prob
        assert!(s.at2(0, 2) > s.at2(0, 1));
    }

    #[test]
    fn softmax_mask_zeroes_padding() {
        let x = Tensor::from_vec(vec![5.0, 1.0, 9.0], &[1, 3]).unwrap();
        let s = softmax_rows(&x, Some(&[1.0, 1.0, 0.0]));
        assert_eq!(s.at2(0, 2), 0.0);
        assert!((s.data.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn gelu_fixed_points() {
        let x = Tensor::from_vec(vec![0.0, 10.0, -10.0], &[1, 3]).unwrap();
        let g = gelu(&x);
        assert_eq!(g.data[0], 0.0);
        assert!((g.data[1] - 10.0).abs() < 1e-3);
        assert!(g.data[2].abs() < 1e-3);
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[1, 4]).unwrap();
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = layer_norm(&x, &g, &b, 1e-5);
        let mu: f32 = y.data.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-5);
        let var: f32 = y.data.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-2);
    }

    #[test]
    fn tanh_range() {
        let x = Tensor::from_vec(vec![-100.0, 0.0, 100.0], &[1, 3]).unwrap();
        let y = tanh(&x);
        assert_eq!(y.data, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn pack_unpack_heads_roundtrip() {
        let mut rng = SplitMix64::new(41);
        let x = Tensor::randn(&[5, 12], 1.0, &mut rng);
        let packed = pack_heads(&x, 3).unwrap();
        assert_eq!(packed.shape, vec![3, 5, 4]);
        assert_eq!(unpack_heads(&packed).unwrap(), x);
    }

    #[test]
    fn pack_heads_batched_roundtrip_and_b1_equivalence() {
        let mut rng = SplitMix64::new(43);
        let x = Tensor::randn(&[2 * 5, 12], 1.0, &mut rng); // B=2, S=5, H=12
        let packed = pack_heads_batched(&x, 2, 3).unwrap();
        assert_eq!(packed.shape, vec![6, 5, 4]); // dh=4 is already lane-aligned
        assert_eq!(unpack_heads_batched(&packed, 2, 12).unwrap(), x);
        // batch = 1 degenerates to the single-example pack (aligned dh).
        let x1 = Tensor::randn(&[5, 12], 1.0, &mut rng);
        assert_eq!(
            pack_heads_batched(&x1, 1, 3).unwrap(),
            pack_heads(&x1, 3).unwrap()
        );
    }

    #[test]
    fn ragged_head_dim_pads_to_lane_width_and_roundtrips() {
        // dh = 5 pads to 8: zero lanes, exact roundtrip.
        let mut rng = SplitMix64::new(46);
        let x = Tensor::randn(&[2 * 3, 10], 1.0, &mut rng); // B=2, S=3, heads=2, dh=5
        let packed = pack_heads_batched(&x, 2, 2).unwrap();
        assert_eq!(packed.shape, vec![4, 3, 8]);
        for row in packed.data.chunks(8) {
            assert_eq!(&row[5..], &[0.0; 3], "padding lanes must be exact zeros");
        }
        assert_eq!(unpack_heads_batched(&packed, 2, 10).unwrap(), x);
        // A hidden width whose padded head dim mismatches the packed
        // lanes is a loud error, not a silent misread.
        assert!(unpack_heads_batched(&packed, 2, 20).is_err());
        assert!(unpack_heads_batched(&packed, 2, 11).is_err());
    }

    #[test]
    fn padded_attention_matches_explicit_per_head_reference() {
        // dh = 5 (not a multiple of the lane width): the padded batched
        // attention must match an explicit per-head dense reference.
        let mut rng = SplitMix64::new(47);
        let (s, h, heads) = (4usize, 10usize, 2usize);
        let q = Tensor::randn(&[s, h], 0.8, &mut rng);
        let k = Tensor::randn(&[s, h], 0.8, &mut rng);
        let v = Tensor::randn(&[s, h], 0.8, &mut rng);
        let mask = [1.0, 1.0, 1.0, 0.0];
        let (ctx, probs) = multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
        let dh = h / heads;
        let scale = 1.0 / (dh as f32).sqrt();
        for head in 0..heads {
            for i in 0..s {
                // Reference probabilities from unpadded dot products.
                let mut scores = vec![f32::NEG_INFINITY; s];
                for j in 0..s {
                    if mask[j] == 0.0 {
                        continue;
                    }
                    let mut dot = 0.0f32;
                    for l in 0..dh {
                        dot += q.at2(i, head * dh + l) * k.at2(j, head * dh + l);
                    }
                    scores[j] = dot * scale;
                }
                let maxv = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> =
                    scores.iter().map(|&x| if x.is_finite() { (x - maxv).exp() } else { 0.0 }).collect();
                let sum: f32 = exps.iter().sum();
                for j in 0..s {
                    let want = exps[j] / sum;
                    let got = probs.data[(head * s + i) * s + j];
                    assert!((got - want).abs() < 1e-5, "prob[{head},{i},{j}]: {got} vs {want}");
                }
                for l in 0..dh {
                    let mut want = 0.0f32;
                    for j in 0..s {
                        want += exps[j] / sum * v.at2(j, head * dh + l);
                    }
                    let got = ctx.at2(i, head * dh + l);
                    assert!((got - want).abs() < 1e-5, "ctx[{head},{i},{l}]: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn batched_attention_matches_per_example_on_ragged_masks() {
        // Two examples with different pad counts: the batched kernel
        // must reproduce the per-example reference bitwise (same bmm
        // microkernels, additive -inf bias == exclusion mask).
        let mut rng = SplitMix64::new(44);
        let (b, s, h, heads) = (2usize, 6usize, 8usize, 2usize);
        let q = Tensor::randn(&[b * s, h], 1.0, &mut rng);
        let k = Tensor::randn(&[b * s, h], 1.0, &mut rng);
        let v = Tensor::randn(&[b * s, h], 1.0, &mut rng);
        let mask = [1.0, 1.0, 1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.0];
        let bias = attention_bias_from_mask(&mask);
        let (ctx, probs) = multi_head_attention_batched(&q, &k, &v, &bias, heads, b).unwrap();
        assert_eq!(ctx.shape, vec![b * s, h]);
        assert_eq!(probs.shape, vec![b * heads, s, s]);
        for e in 0..b {
            let slice = |t: &Tensor| {
                Tensor::from_vec(t.data[e * s * h..(e + 1) * s * h].to_vec(), &[s, h]).unwrap()
            };
            let (ctx_e, probs_e) = multi_head_attention(
                &slice(&q),
                &slice(&k),
                &slice(&v),
                &mask[e * s..(e + 1) * s],
                heads,
            )
            .unwrap();
            assert_eq!(&ctx.data[e * s * h..(e + 1) * s * h], &ctx_e.data[..]);
            assert_eq!(
                &probs.data[e * heads * s * s..(e + 1) * heads * s * s],
                &probs_e.data[..]
            );
        }
        // Pad columns carry exactly zero probability in every row.
        for (bh, mat) in probs.data.chunks(s * s).enumerate() {
            let e = bh / heads;
            for row in mat.chunks(s) {
                for (j, &p) in row.iter().enumerate() {
                    if mask[e * s + j] == 0.0 {
                        assert_eq!(p, 0.0);
                    }
                }
                assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fully_masked_example_yields_zero_probs_not_nan() {
        let mut rng = SplitMix64::new(45);
        let (s, h, heads) = (4usize, 8usize, 2usize);
        let q = Tensor::randn(&[s, h], 1.0, &mut rng);
        let kk = Tensor::randn(&[s, h], 1.0, &mut rng);
        let v = Tensor::randn(&[s, h], 1.0, &mut rng);
        let bias = attention_bias_from_mask(&[0.0; 4]);
        let (ctx, probs) = multi_head_attention_batched(&q, &kk, &v, &bias, heads, 1).unwrap();
        assert!(probs.data.iter().all(|&p| p == 0.0));
        assert!(ctx.data.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn attention_probs_rows_sum_to_one_and_respect_mask() {
        let mut rng = SplitMix64::new(42);
        let (s, h, heads) = (6, 8, 2);
        let q = Tensor::randn(&[s, h], 1.0, &mut rng);
        let k = Tensor::randn(&[s, h], 1.0, &mut rng);
        let v = Tensor::randn(&[s, h], 1.0, &mut rng);
        let mask = [1.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let (ctx, probs) = multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
        assert_eq!(ctx.shape, vec![s, h]);
        assert_eq!(probs.shape, vec![heads, s, s]);
        for row in probs.data.chunks(s) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            assert_eq!(row[4], 0.0);
            assert_eq!(row[5], 0.0);
        }
    }
}
