//! Elementwise / normalization ops on dense tensors — the nonlinear
//! kernels of the paper's accelerator (softmax, GELU, LayerNorm, tanh;
//! Fig. 8's "NL" units), implemented natively for the rust inference
//! engine ([`crate::inference`]).

use super::dense::Tensor;

/// Row-wise softmax over the last axis of a 2-D tensor, with an optional
/// key mask (0.0 entries are excluded, as in masked attention).
pub fn softmax_rows(x: &Tensor, mask: Option<&[f32]>) -> Tensor {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mut maxv = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            let keep = mask.map(|m| m[j] > 0.5).unwrap_or(true);
            if keep && v > maxv {
                maxv = v;
            }
        }
        let mut sum = 0.0f32;
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for (j, &v) in row.iter().enumerate() {
            let keep = mask.map(|m| m[j] > 0.5).unwrap_or(true);
            if keep {
                let e = (v - maxv).exp();
                orow[j] = e;
                sum += e;
            }
        }
        if sum > 0.0 {
            for v in orow.iter_mut() {
                *v /= sum;
            }
        }
    }
    out
}

/// GELU (tanh approximation, matching `jax.nn.gelu`'s default).
pub fn gelu(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        let x = *v;
        let c = (2.0f32 / std::f32::consts::PI).sqrt();
        *v = 0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh());
    }
    out
}

/// Row-wise LayerNorm over the last axis: `(x - mu) / sqrt(var + eps) * g + b`.
pub fn layer_norm(x: &Tensor, g: &[f32], b: &[f32], eps: f32) -> Tensor {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    debug_assert_eq!(g.len(), cols);
    let mut out = Tensor::zeros(&[rows, cols]);
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = &mut out.data[i * cols..(i + 1) * cols];
        for j in 0..cols {
            orow[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
    out
}

/// Elementwise tanh.
pub fn tanh(x: &Tensor) -> Tensor {
    let mut out = x.clone();
    for v in out.data.iter_mut() {
        *v = v.tanh();
    }
    out
}

/// `a + b` elementwise.
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    debug_assert_eq!(a.shape, b.shape);
    let mut out = a.clone();
    for (o, &v) in out.data.iter_mut().zip(&b.data) {
        *o += v;
    }
    out
}

/// Add a row vector to every row of a 2-D tensor.
pub fn add_row(a: &Tensor, row: &[f32]) -> Tensor {
    let (rows, cols) = (a.shape[0], a.shape[1]);
    debug_assert_eq!(row.len(), cols);
    let mut out = a.clone();
    for i in 0..rows {
        for j in 0..cols {
            out.data[i * cols + j] += row[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0], &[2, 3]).unwrap();
        let s = softmax_rows(&x, None);
        for i in 0..2 {
            let sum: f32 = s.data[i * 3..(i + 1) * 3].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        // monotone: larger logit, larger prob
        assert!(s.at2(0, 2) > s.at2(0, 1));
    }

    #[test]
    fn softmax_mask_zeroes_padding() {
        let x = Tensor::from_vec(vec![5.0, 1.0, 9.0], &[1, 3]).unwrap();
        let s = softmax_rows(&x, Some(&[1.0, 1.0, 0.0]));
        assert_eq!(s.at2(0, 2), 0.0);
        assert!((s.data.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn gelu_fixed_points() {
        let x = Tensor::from_vec(vec![0.0, 10.0, -10.0], &[1, 3]).unwrap();
        let g = gelu(&x);
        assert_eq!(g.data[0], 0.0);
        assert!((g.data[1] - 10.0).abs() < 1e-3);
        assert!(g.data[2].abs() < 1e-3);
    }

    #[test]
    fn layer_norm_normalizes() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[1, 4]).unwrap();
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = layer_norm(&x, &g, &b, 1e-5);
        let mu: f32 = y.data.iter().sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-5);
        let var: f32 = y.data.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((var - 1.0).abs() < 1e-2);
    }

    #[test]
    fn tanh_range() {
        let x = Tensor::from_vec(vec![-100.0, 0.0, 100.0], &[1, 3]).unwrap();
        let y = tanh(&x);
        assert_eq!(y.data, vec![-1.0, 0.0, 1.0]);
    }
}
