//! Tensor-train matrix algebra: decomposition, reconstruction, and both
//! contraction orders (right-to-left and the paper's bidirectional BTT).
//!
//! The contraction engines are *instrumented*: they count multiplies and
//! track peak intermediate memory, so the analytic cost model
//! ([`crate::costmodel`], paper Eqs. 18-21) is validated against executed
//! counts instead of being trusted on paper.

use super::dense::{svd, Tensor};
use super::precision::{PackedTensor, Precision};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, Result};
use std::borrow::Cow;

/// A (M, N) matrix in TT format: `2d` order-3 cores, the first `d`
/// carrying output modes `m_i`, the last `d` input modes `n_i`
/// (paper Eq. 7).
#[derive(Debug, Clone, PartialEq)]
pub struct TTMatrix {
    /// Core k has shape (ranks[k], modes[k], ranks[k+1]).
    pub cores: Vec<Tensor>,
    pub m_modes: Vec<usize>,
    pub n_modes: Vec<usize>,
    pub ranks: Vec<usize>,
}

/// Instrumentation record from a contraction run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ContractionStats {
    /// Scalar multiplications executed.
    pub muls: u64,
    /// Peak *live intermediate* tensor size in elements (excluding
    /// inputs/outputs).
    pub peak_intermediate_elems: u64,
    /// Sum of all intermediate tensor sizes (elements) — what training
    /// must store for reuse in backprop.
    pub stored_intermediate_elems: u64,
    /// Number of contraction steps.
    pub steps: u32,
}

impl ContractionStats {
    /// Record one contraction step.
    ///
    /// The accounting rule is uniform across every engine: a step's
    /// product counts toward `stored_intermediate_elems` (and the peak)
    /// **iff it is an intermediate** — i.e. anything except the tensor
    /// the contraction ultimately returns.  The backward pass must keep
    /// exactly these tensors, so the stored count is also the training
    /// activation cache (validated against Eqs. 19/21 in
    /// [`crate::costmodel`]).
    pub fn record_step(&mut self, muls: u64, product_elems: u64, is_intermediate: bool) {
        self.muls += muls;
        self.steps += 1;
        if is_intermediate {
            self.stored_intermediate_elems += product_elems;
            self.peak_intermediate_elems = self.peak_intermediate_elems.max(product_elems);
        }
    }

}

impl TTMatrix {
    /// Number of output rows M = prod(m_modes).
    pub fn m(&self) -> usize {
        self.m_modes.iter().product()
    }

    /// Number of input cols N = prod(n_modes).
    pub fn n(&self) -> usize {
        self.n_modes.iter().product()
    }

    pub fn d(&self) -> usize {
        self.m_modes.len()
    }

    /// Total scalars across cores.
    pub fn param_count(&self) -> usize {
        self.cores.iter().map(Tensor::numel).sum()
    }

    /// Random TT matrix with the given modes and uniform interior rank,
    /// scaled so the reconstructed dense matrix has ~`target_std`.
    pub fn randn(
        m_modes: &[usize],
        n_modes: &[usize],
        rank: usize,
        target_std: f32,
        rng: &mut SplitMix64,
    ) -> TTMatrix {
        let modes: Vec<usize> = m_modes.iter().chain(n_modes).copied().collect();
        let d2 = modes.len();
        let mut ranks = vec![rank; d2 + 1];
        ranks[0] = 1;
        ranks[d2] = 1;
        let rank_paths: f64 = ranks[1..d2].iter().map(|&r| r as f64).product();
        let sigma = ((target_std as f64).powi(2) / rank_paths).powf(1.0 / (2.0 * d2 as f64));
        let cores = (0..d2)
            .map(|k| Tensor::randn(&[ranks[k], modes[k], ranks[k + 1]], sigma as f32, rng))
            .collect();
        TTMatrix {
            cores,
            m_modes: m_modes.to_vec(),
            n_modes: n_modes.to_vec(),
            ranks,
        }
    }

    /// TT-SVD decomposition (Oseledets 2011) of a dense (M, N) matrix with
    /// rank cap `max_rank`.
    ///
    /// The matrix is reshaped to the order-2d tensor with *interleaved
    /// pairing*: index layout (m_1..m_d, n_1..n_d) following Eq. 7.
    pub fn from_dense(
        w: &Tensor,
        m_modes: &[usize],
        n_modes: &[usize],
        max_rank: usize,
    ) -> Result<TTMatrix> {
        if w.ndim() != 2 {
            return Err(anyhow!("from_dense needs a matrix"));
        }
        let m: usize = m_modes.iter().product();
        let n: usize = n_modes.iter().product();
        if w.shape != [m, n] {
            return Err(anyhow!("shape {:?} != modes ({m}, {n})", w.shape));
        }
        // Reorder (M, N) -> tensor with modes (m_1..m_d, n_1..n_d): the
        // row index factors as m-digits, the col index as n-digits; the
        // natural row-major order of (m_1..m_d, n_1..n_d) needs an
        // explicit permutation of the (row, col) layout.
        let modes: Vec<usize> = m_modes.iter().chain(n_modes).copied().collect();
        let d2 = modes.len();
        let mut t = vec![0.0f32; m * n];
        // For each (row, col), compute the position in the mode-major
        // layout.  Row digits are the first d modes, col digits the rest.
        let mut strides = vec![1usize; d2];
        for k in (0..d2 - 1).rev() {
            strides[k] = strides[k + 1] * modes[k + 1];
        }
        for row in 0..m {
            // decompose row into m-digits (most significant first)
            for col in 0..n {
                let mut pos = 0usize;
                let mut r = row;
                for (k, &mk) in m_modes.iter().enumerate().rev() {
                    pos += (r % mk) * strides[k];
                    r /= mk;
                }
                let mut c = col;
                for (k, &nk) in n_modes.iter().enumerate().rev() {
                    pos += (c % nk) * strides[m_modes.len() + k];
                    c /= nk;
                }
                t[pos] = w.data[row * n + col];
            }
        }
        // Sequential TT-SVD over the mode-major tensor.
        let mut cores = Vec::with_capacity(d2);
        let mut ranks = vec![1usize; d2 + 1];
        let mut rest = Tensor::from_vec(t, &[modes[0], m * n / modes[0]])?;
        for k in 0..d2 - 1 {
            let rows = ranks[k] * modes[k];
            let cols = rest.numel() / rows;
            let mat = rest.reshape(&[rows, cols])?;
            let (u, s, vt) = svd(&mat)?;
            // Truncate to max_rank, dropping near-zero singular values.
            let full = s.len();
            let mut r = full.min(max_rank);
            while r > 1 && s[r - 1] < 1e-7 * s[0].max(1e-30) {
                r -= 1;
            }
            ranks[k + 1] = r;
            // Core k = U[:, :r] reshaped (ranks[k], modes[k], r).
            let mut core = Tensor::zeros(&[ranks[k], modes[k], r]);
            for i in 0..rows {
                for j in 0..r {
                    core.data[i * r + j] = u.data[i * full + j];
                }
            }
            cores.push(core);
            // rest = diag(S[:r]) V^T[:r, :]
            let mut next = Tensor::zeros(&[r, cols]);
            for i in 0..r {
                for j in 0..cols {
                    next.data[i * cols + j] = s[i] * vt.data[i * cols + j];
                }
            }
            rest = next;
        }
        ranks[d2] = 1;
        let last = rest.reshape(&[ranks[d2 - 1], modes[d2 - 1], 1])?;
        cores.push(last);
        Ok(TTMatrix {
            cores,
            m_modes: m_modes.to_vec(),
            n_modes: n_modes.to_vec(),
            ranks,
        })
    }

    /// Reconstruct the dense (M, N) matrix (inverse of `from_dense`).
    pub fn to_dense(&self) -> Result<Tensor> {
        let d = self.d();
        let z3 = self.merge_left()?; // (M, r_d)
        let z1 = self.merge_right()?; // (r_d, N)
        let _ = d;
        z3.matmul(&z1)
    }

    /// Merge the output-mode cores into Z3 (M, r_d) — paper kernel MUL0.
    pub fn merge_left(&self) -> Result<Tensor> {
        Ok(self.merge_left_chain()?.pop().expect("d >= 1"))
    }

    /// Merge the input-mode cores into Z1 (r_d, N) — paper kernel MUL0.
    pub fn merge_right(&self) -> Result<Tensor> {
        Ok(self.merge_right_chain()?.pop().expect("d >= 1"))
    }

    /// Every state of the left-merge chain: `L_0` is core 0 reshaped to
    /// (m_1, r_1); `L_k` folds core `k` in; the last state is Z3
    /// (M, r_d).  The backward pass consumes the full chain — state
    /// `L_{k-1}` is the left operand of the step that produced `L_k`.
    pub fn merge_left_chain(&self) -> Result<Vec<Tensor>> {
        self.merge_left_chain_prec(Precision::F32)
    }

    /// [`TTMatrix::merge_left_chain`] with mixed-precision storage:
    /// every chain state is **rounded on store** (round-to-nearest-even
    /// to `prec`) and the next fold consumes the rounded value, so the
    /// chain the backward pass reads is exactly the chain the forward
    /// computed through.  `Precision::F32` is bitwise the full-precision
    /// chain.  (Products themselves accumulate in f32 — widen-on-load.)
    pub fn merge_left_chain_prec(&self, prec: Precision) -> Result<Vec<Tensor>> {
        let d = self.d();
        let first = self.cores[0].reshape(&[self.m_modes[0], self.ranks[1]])?;
        let mut states = vec![prec.round_tensor_owned(first)];
        for k in 1..d {
            let g = &self.cores[k];
            let (rp, mk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            let next = {
                let prev = states.last().expect("nonempty");
                prev.matmul(&g.reshape(&[rp, mk * rk])?)?
                    .reshape(&[prev.shape[0] * mk, rk])?
            };
            states.push(prec.round_tensor_owned(next));
        }
        Ok(states)
    }

    /// Every state of the right-merge chain: `R_0` is core 2d-1 reshaped
    /// to (r_{2d-1}, n_d); `R_j` folds core `2d-1-j` in; the last state
    /// is Z1 (r_d, N).
    pub fn merge_right_chain(&self) -> Result<Vec<Tensor>> {
        self.merge_right_chain_prec(Precision::F32)
    }

    /// [`TTMatrix::merge_right_chain`] with round-on-store storage
    /// precision (see [`TTMatrix::merge_left_chain_prec`]).
    pub fn merge_right_chain_prec(&self, prec: Precision) -> Result<Vec<Tensor>> {
        let d = self.d();
        let d2 = 2 * d;
        let last = &self.cores[d2 - 1];
        let first = last.reshape(&[last.shape[0], last.shape[1]])?;
        let mut states = vec![prec.round_tensor_owned(first)];
        for k in (d..d2 - 1).rev() {
            let g = &self.cores[k];
            let (rp, nk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            let next = {
                let prev = states.last().expect("nonempty");
                g.reshape(&[rp * nk, rk])?
                    .matmul(prev)?
                    .reshape(&[rp, nk * prev.shape[1]])?
            };
            states.push(prec.round_tensor_owned(next));
        }
        Ok(states)
    }

    /// `Y = W X` with X (N, K) via **right-to-left** contraction (the
    /// sequential order of prior accelerators, paper Sec. IV-A).
    ///
    /// Every step carries the K dimension, exactly as Eq. 18/19 model.
    pub fn matmul_right_to_left(&self, x: &Tensor) -> Result<(Tensor, ContractionStats)> {
        let d = self.d();
        let d2 = 2 * d;
        let n = self.n();
        if x.ndim() != 2 || x.shape[0] != n {
            return Err(anyhow!("x must be ({n}, K), got {:?}", x.shape));
        }
        let k_dim = x.shape[1];
        let mut stats = ContractionStats::default();
        // State: tensor of shape (r_k, prod-of-remaining-n, K) flattened to
        // 2-D (r_k * remaining_n, K); contract cores d2-1 down to d (input
        // side), then cores d-1 down to 0 (output side, building up M).
        //
        // Input side: cur has shape (n_1..n_j, r_j-ish, K).  We keep it as
        // (rows, K) and peel one n-mode per step.
        let mut cur = x.clone(); // (n_1*...*n_d, K) with r = 1 implicit
        let mut r_cur = 1usize;
        let mut n_left: usize = n;
        for k in (d..d2).rev() {
            let g = &self.cores[k]; // (r_{k-1}, n_k, r_k)
            let (rp, nk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            debug_assert_eq!(rk, r_cur);
            // cur: (n_left * r_cur... actually (n_1..n_k) x (r_k * K)) —
            // reshape cur (n_left, r_cur * K) -> split off n_k:
            // cur2 (n_left/nk, nk, r_cur, K); contract over (nk, r_cur)
            // with g (rp, nk, rk=r_cur) -> (n_left/nk, rp, K).
            let rows = n_left / nk;
            let cur3 = cur.reshape(&[rows, nk * r_cur, k_dim])?;
            let mut next = Tensor::zeros(&[rows, rp, k_dim]);
            for a in 0..rows {
                for b in 0..rp {
                    for c in 0..k_dim {
                        let mut acc = 0.0f32;
                        for e in 0..nk {
                            for f in 0..r_cur {
                                let xi = a * nk * r_cur * k_dim + (e * r_cur + f) * k_dim + c;
                                acc += cur3.data[xi] * g.data[b * nk * r_cur + e * r_cur + f];
                            }
                        }
                        next.data[a * rp * k_dim + b * k_dim + c] = acc;
                    }
                }
            }
            // Every input-side product is an intermediate: even the last
            // one (the (r_d, K) middle state) is consumed by the output
            // side, not returned.
            stats.record_step(
                (rows * rp * k_dim * nk * r_cur) as u64,
                (rows * rp * k_dim) as u64,
                true,
            );
            cur = next.reshape(&[rows * rp, k_dim])?;
            r_cur = rp;
            n_left = rows;
        }
        // Now cur is (r_d, K) (n fully consumed).  Output side: build M up
        // by contracting cores d-1 .. 0: cur (m_{k+1}..m_d prod, r_k, K).
        let mut m_built = 1usize;
        for k in (0..d).rev() {
            let g = &self.cores[k]; // (r_{k-1}, m_k, r_k)
            let (rp, mk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            debug_assert_eq!(rk, r_cur);
            // cur: (m_built, r_cur, K) ; g: (rp, mk, r_cur)
            // next: (mk, m_built, rp, K) -> flattened ((mk*m_built)*rp, K)
            let cur3 = cur.reshape(&[m_built, r_cur, k_dim])?;
            let mut next = Tensor::zeros(&[mk, m_built, rp, k_dim]);
            for a in 0..mk {
                for b in 0..m_built {
                    for c in 0..rp {
                        for e in 0..k_dim {
                            let mut acc = 0.0f32;
                            for f in 0..r_cur {
                                acc += g.data[c * mk * r_cur + a * r_cur + f]
                                    * cur3.data[b * r_cur * k_dim + f * k_dim + e];
                            }
                            next.data[((a * m_built + b) * rp + c) * k_dim + e] = acc;
                        }
                    }
                }
            }
            // Output-side products are intermediates except the k == 0
            // step, whose product is the returned Y itself.
            stats.record_step(
                (mk * m_built * rp * k_dim * r_cur) as u64,
                (mk * m_built * rp * k_dim) as u64,
                k > 0,
            );
            m_built *= mk;
            r_cur = rp;
            cur = next.reshape(&[m_built * rp, k_dim])?;
        }
        debug_assert_eq!(r_cur, 1);
        let y = cur.reshape(&[self.m(), k_dim])?;
        self.debug_check_stats(&stats, k_dim, false);
        Ok((y, stats))
    }

    /// Debug-build invariant: executed counts must equal the analytic
    /// cost model (Eqs. 18/19 for right-to-left, Eqs. 20/21 for BTT).
    fn debug_check_stats(&self, stats: &ContractionStats, k_dim: usize, btt: bool) {
        if !cfg!(debug_assertions) {
            return;
        }
        let shape = crate::costmodel::LinearShape {
            m_modes: self.m_modes.clone(),
            n_modes: self.n_modes.clone(),
            ranks: self.ranks.clone(),
        };
        let (muls, mem) = if btt {
            (shape.btt_muls(k_dim as u64), shape.btt_memory(k_dim as u64))
        } else {
            (shape.tt_rl_muls(k_dim as u64), shape.tt_rl_memory(k_dim as u64))
        };
        debug_assert_eq!(stats.muls, muls, "executed muls diverge from cost model");
        debug_assert_eq!(
            stats.stored_intermediate_elems, mem,
            "stored intermediates diverge from cost model"
        );
    }

    /// Record the K-independent merge-chain costs (the first terms of
    /// Eqs. 20/21) into `stats` — the single accounting source shared
    /// by [`TTMatrix::matmul_btt`] and the training layer's
    /// instrumented forward (`crate::train::layers`).
    pub fn record_merge_stats(&self, stats: &mut ContractionStats) {
        self.record_merge_left_stats(stats);
        self.record_merge_right_stats(stats);
    }

    /// Left (output-side) merge costs only: `G_1..G_d -> Z3`.  Split out
    /// so the fused QKV layer (`crate::train::layers::forward_qkv_fused`)
    /// can charge the three per-projection left merges while charging
    /// the shared right merge **once** — the Fig. 9 rescheduling
    /// realized in accounting as well as in compute.
    pub fn record_merge_left_stats(&self, stats: &mut ContractionStats) {
        let d = self.d();
        // muls per step: (m_1..m_k) r_{k-1} m_k r_k.
        let mut m_acc = self.m_modes[0];
        for k in 1..d {
            let g = &self.cores[k];
            let (rp, mk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            let muls = (m_acc * rp * mk * rk) as u64;
            m_acc *= mk;
            stats.record_step(muls, (m_acc * rk) as u64, true);
        }
    }

    /// Right (input-side) merge costs only: `G_{2d}..G_{d+1} -> Z1`,
    /// symmetric to [`TTMatrix::record_merge_left_stats`].
    pub fn record_merge_right_stats(&self, stats: &mut ContractionStats) {
        let d = self.d();
        let d2 = 2 * d;
        let mut n_acc = self.cores[d2 - 1].shape[1];
        for k in (d..d2 - 1).rev() {
            let g = &self.cores[k];
            let (rp, nk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            let muls = (rp * nk * rk * n_acc) as u64;
            n_acc *= nk;
            stats.record_step(muls, (rp * n_acc) as u64, true);
        }
    }

    /// `Y = W X` with X (N, K) via the paper's **bidirectional** (BTT)
    /// contraction: merge both core chains K-independently, then apply
    /// two K-dependent matmuls (Fig. 5 bottom).
    pub fn matmul_btt(&self, x: &Tensor) -> Result<(Tensor, ContractionStats)> {
        let d = self.d();
        let n = self.n();
        let m = self.m();
        if x.ndim() != 2 || x.shape[0] != n {
            return Err(anyhow!("x must be ({n}, K), got {:?}", x.shape));
        }
        let k_dim = x.shape[1];
        let r_d = self.ranks[d];
        let mut stats = ContractionStats::default();

        // Merges: Z3 (M, r_d) and Z1 (r_d, N), costed by the shared
        // accounting helper.
        self.record_merge_stats(&mut stats);
        let z3 = self.merge_left()?;
        let z1 = self.merge_right()?;
        // Apply: Z2 = Z1 X (r_d, K); Y = Z3 Z2 (M, K).  These are the only
        // K-dependent steps (the last term of Eqs. 20-21).  Z2 is an
        // intermediate; Y is the returned output.
        let z2 = z1.matmul(x)?;
        stats.record_step((r_d * n * k_dim) as u64, (r_d * k_dim) as u64, true);
        let y = z3.matmul(&z2)?;
        stats.record_step((m * r_d * k_dim) as u64, (m * k_dim) as u64, false);
        self.debug_check_stats(&stats, k_dim, true);
        Ok((y, stats))
    }
}

/// A [`TTMatrix`] **at rest** in storage precision.
///
/// The f32 variant keeps the working representation — [`view`] is a
/// zero-copy borrow, so the default full-precision hot path is
/// untouched.  The sub-f32 variants store every core genuinely packed
/// ([`PackedTensor`] per core: `u16` for bf16/f16, block-scaled `i8`
/// codes for int8) and widen exactly on load, so the cores' at-rest
/// bytes *measurably* shrink instead of just being accounted as
/// shrunk.
///
/// The precision contract that makes this lossless: the optimizer
/// rounds parameters on store (`ModelOptim::step` — per-scalar RNE for
/// the half formats, blockwise quantization over each core's flat
/// buffer for int8), so every value a reduced-precision model holds at
/// rest is a fixed point of the store rounding — `pack` then `widen`
/// reproduces it bitwise, and [`update`]'s widen/mutate/repack round
/// trip is exact.
///
/// [`view`]: PackedTTMatrix::view
/// [`update`]: PackedTTMatrix::update
#[derive(Debug, Clone, PartialEq)]
pub enum PackedTTMatrix {
    F32(TTMatrix),
    Half {
        prec: Precision,
        m_modes: Vec<usize>,
        n_modes: Vec<usize>,
        ranks: Vec<usize>,
        cores: Vec<PackedTensor>,
    },
}

impl PackedTTMatrix {
    /// Pack a TT matrix, consuming it (move — no copy — for f32).
    /// Values not representable at `prec` are rounded on store.
    pub fn pack_owned(tt: TTMatrix, precision: Precision) -> PackedTTMatrix {
        match precision {
            Precision::F32 => PackedTTMatrix::F32(tt),
            p => PackedTTMatrix::Half {
                prec: p,
                m_modes: tt.m_modes,
                n_modes: tt.n_modes,
                ranks: tt.ranks,
                cores: tt
                    .cores
                    .into_iter()
                    .map(|c| PackedTensor::pack_owned(c, p))
                    .collect(),
            },
        }
    }

    /// The stored TT matrix as f32: a zero-copy borrow for f32 storage,
    /// an exact widening of every core for the half formats.
    pub fn view(&self) -> Cow<'_, TTMatrix> {
        match self {
            PackedTTMatrix::F32(tt) => Cow::Borrowed(tt),
            PackedTTMatrix::Half { m_modes, n_modes, ranks, cores, .. } => {
                Cow::Owned(TTMatrix {
                    cores: cores.iter().map(PackedTensor::unpack).collect(),
                    m_modes: m_modes.clone(),
                    n_modes: n_modes.clone(),
                    ranks: ranks.clone(),
                })
            }
        }
    }

    /// Run one update over the cores as a widened f32 [`TTMatrix`]:
    /// in place for f32, widen/mutate/repack for the half formats
    /// (lossless when the mutation stores rounded values, which the
    /// optimizer guarantees).
    pub fn update(&mut self, f: impl FnOnce(&mut TTMatrix)) {
        match self {
            PackedTTMatrix::F32(tt) => f(tt),
            PackedTTMatrix::Half { prec, m_modes, n_modes, ranks, cores } => {
                let mut tt = TTMatrix {
                    cores: cores.iter().map(PackedTensor::unpack).collect(),
                    m_modes: m_modes.clone(),
                    n_modes: n_modes.clone(),
                    ranks: ranks.clone(),
                };
                f(&mut tt);
                *cores = tt
                    .cores
                    .into_iter()
                    .map(|c| PackedTensor::pack_owned(c, *prec))
                    .collect();
            }
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            PackedTTMatrix::F32(_) => Precision::F32,
            PackedTTMatrix::Half { prec, .. } => *prec,
        }
    }

    /// Total scalars across cores.
    pub fn param_count(&self) -> usize {
        match self {
            PackedTTMatrix::F32(tt) => tt.param_count(),
            PackedTTMatrix::Half { cores, .. } => cores.iter().map(PackedTensor::numel).sum(),
        }
    }

    /// **Measured** bytes at rest: the sum of the actual core buffer
    /// sizes, not an analytic figure.
    pub fn bytes(&self) -> u64 {
        match self {
            PackedTTMatrix::F32(tt) => {
                tt.cores.iter().map(|c| c.data.len() as u64 * 4).sum()
            }
            PackedTTMatrix::Half { cores, .. } => cores.iter().map(PackedTensor::bytes).sum(),
        }
    }

    /// Re-store at a (possibly different) precision.  Values already
    /// representable at `prec` survive bitwise.
    pub fn set_precision(&mut self, prec: Precision) {
        if self.precision() != prec {
            let tt = self.view().into_owned();
            *self = PackedTTMatrix::pack_owned(tt, prec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_tt(rng: &mut SplitMix64) -> TTMatrix {
        TTMatrix::randn(&[12, 8, 8], &[8, 8, 12], 12, 0.03, rng)
    }

    #[test]
    fn btt_equals_right_to_left_equals_dense() {
        let mut rng = SplitMix64::new(11);
        let tt = TTMatrix::randn(&[4, 3], &[3, 4], 3, 0.5, &mut rng);
        let x = Tensor::randn(&[12, 5], 1.0, &mut rng);
        let w = tt.to_dense().unwrap();
        let y_dense = w.matmul(&x).unwrap();
        let (y_rl, _) = tt.matmul_right_to_left(&x).unwrap();
        let (y_btt, _) = tt.matmul_btt(&x).unwrap();
        assert!(y_rl.max_abs_diff(&y_dense) < 1e-4, "rl vs dense");
        assert!(y_btt.max_abs_diff(&y_dense) < 1e-4, "btt vs dense");
    }

    #[test]
    fn paper_config_contraction_equivalence() {
        let mut rng = SplitMix64::new(12);
        let tt = paper_tt(&mut rng);
        // K = 32 (the paper's seq len): BTT wins only when K exceeds the
        // tensor modes (Sec. IV-B), which is the regime the paper targets.
        let x = Tensor::randn(&[768, 32], 1.0, &mut rng);
        let (y_rl, s_rl) = tt.matmul_right_to_left(&x).unwrap();
        let (y_btt, s_btt) = tt.matmul_btt(&x).unwrap();
        let scale = y_rl.norm() / (y_rl.numel() as f32).sqrt();
        assert!(y_rl.max_abs_diff(&y_btt) < 5e-4 * (1.0 + scale));
        // The paper's claim: BTT uses strictly fewer muls and less
        // intermediate memory when K > modes.
        assert!(s_btt.muls < s_rl.muls, "{} !< {}", s_btt.muls, s_rl.muls);
        assert!(s_btt.peak_intermediate_elems < s_rl.peak_intermediate_elems);
        // And fewer sequential stages: d+1 vs 2d (merges run in parallel).
        assert_eq!(s_rl.steps, 6);
    }

    #[test]
    fn tt_svd_roundtrip_exact_rank() {
        let mut rng = SplitMix64::new(13);
        // Build a TT matrix, densify, re-decompose with the same rank cap:
        // reconstruction must match (TT-SVD is exact at sufficient rank).
        let tt = TTMatrix::randn(&[4, 3], &[3, 4], 3, 0.5, &mut rng);
        let w = tt.to_dense().unwrap();
        let tt2 = TTMatrix::from_dense(&w, &[4, 3], &[3, 4], 16).unwrap();
        let w2 = tt2.to_dense().unwrap();
        let rel = w2.max_abs_diff(&w) / (1.0 + w.norm());
        assert!(rel < 1e-4, "roundtrip err {rel}");
    }

    #[test]
    fn tt_svd_truncation_reduces_params() {
        let mut rng = SplitMix64::new(14);
        let w = Tensor::randn(&[24, 24], 1.0, &mut rng);
        let full = TTMatrix::from_dense(&w, &[6, 4], &[4, 6], 64).unwrap();
        let trunc = TTMatrix::from_dense(&w, &[6, 4], &[4, 6], 3).unwrap();
        assert!(trunc.param_count() < full.param_count());
        assert!(trunc.param_count() < w.numel());
    }

    #[test]
    fn merge_shapes() {
        let mut rng = SplitMix64::new(15);
        let tt = paper_tt(&mut rng);
        assert_eq!(tt.merge_left().unwrap().shape, vec![768, 12]);
        assert_eq!(tt.merge_right().unwrap().shape, vec![12, 768]);
    }

    #[test]
    fn packed_tt_f32_is_zero_copy_and_half_halves_measured_bytes() {
        let mut rng = SplitMix64::new(16);
        let tt = TTMatrix::randn(&[4, 3], &[3, 4], 3, 0.5, &mut rng);
        let elems = tt.param_count() as u64;
        let p32 = PackedTTMatrix::pack_owned(tt.clone(), Precision::F32);
        assert!(matches!(p32.view(), Cow::Borrowed(_)), "f32 view must be zero-copy");
        assert_eq!(p32.bytes(), elems * 4);
        for prec in [Precision::Bf16, Precision::F16] {
            let p = PackedTTMatrix::pack_owned(tt.clone(), prec);
            assert_eq!(p.bytes(), elems * 2, "{prec:?}: measured bytes not halved");
            assert_eq!(p.param_count(), elems as usize);
            // The widened view is the rounded matrix, and re-packing a
            // rounded matrix is bitwise lossless.
            let v = p.view().into_owned();
            for (core, orig) in v.cores.iter().zip(&tt.cores) {
                for (a, &b) in core.data.iter().zip(&orig.data) {
                    assert_eq!(a.to_bits(), prec.round(b).to_bits());
                }
            }
            assert_eq!(PackedTTMatrix::pack_owned(v.clone(), prec).view().into_owned(), v);
        }
    }

    #[test]
    fn packed_tt_update_is_lossless_for_rounded_stores() {
        let mut rng = SplitMix64::new(17);
        let tt = TTMatrix::randn(&[4, 3], &[3, 4], 3, 0.5, &mut rng);
        for prec in Precision::all() {
            let mut p = PackedTTMatrix::pack_owned(tt.clone(), prec);
            let before = p.view().into_owned();
            // An optimizer-style update: mutate, then round on store
            // (per-scalar for the half formats, blockwise per core
            // buffer for int8 — the same boundaries packing uses).
            p.update(|m| {
                for core in &mut m.cores {
                    for x in core.data.iter_mut() {
                        *x *= 0.5;
                    }
                    prec.round_slice_in_place(&mut core.data);
                }
            });
            let after = p.view().into_owned();
            for (core, was) in after.cores.iter().zip(&before.cores) {
                let mut want = was.data.clone();
                for x in want.iter_mut() {
                    *x *= 0.5;
                }
                prec.round_slice_in_place(&mut want);
                for (a, b) in core.data.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{prec:?}: update drifted");
                }
            }
            // set_precision round trip through f32 keeps the bits.
            let snap = p.clone();
            p.set_precision(Precision::F32);
            p.set_precision(prec);
            assert_eq!(p.view().into_owned(), snap.view().into_owned());
        }
    }
}
