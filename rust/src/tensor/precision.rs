//! Mixed-precision storage substrate: `f32` / `bf16` / `f16` /
//! block-scaled `int8` element formats with **deterministic
//! round-to-nearest-even** conversion and packed sub-f32 buffers.
//!
//! The contract of the whole mixed-precision path lives here:
//!
//! * **Storage** happens at [`Precision`] width — TT/TTM cores, the
//!   Eq. 21 activation caches ([`PackedTensor`], genuinely `u16`-packed
//!   for the half formats, `i8`-coded with per-block scales for int8)
//!   and the optimizer moments.
//! * **Compute** always accumulates in `f32`: packed buffers are
//!   widened on load (`bf16 -> f32` is exact; `f16 -> f32` is exact;
//!   int8 `code * scale` is an exact f32 product — see below), the
//!   [`crate::tensor::dense`] microkernels run unchanged, and the
//!   result is rounded **once, on store**, with round-to-nearest-even.
//! * **Determinism**: the conversions are pure integer bit
//!   manipulation (and, for int8, fixed block boundaries + a scale
//!   derived by a fixed formula), so the kernels'
//!   bitwise-deterministic band-split guarantee becomes a
//!   *per-precision* guarantee — same inputs, same precision, same
//!   bits, regardless of thread count.
//!
//! **Block-scaled int8** ([`Precision::Int8`], [`ScaledBlockVec`] /
//! [`ScaledBlockTensor`]) stores one `i8` code per element plus one
//! `f32` scale per [`INT8_BLOCK`]-element block (blocks are fixed
//! windows of the flat buffer, starting at index 0).  The scale is
//! `amax / 127` *snapped to bf16 precision* (still stored as f32):
//! with an 8-bit-mantissa scale and codes in `[-127, 127]` every
//! `code * scale` product is exact in f32, so dequantize -> requantize
//! is a **bitwise fixed point** — repacking stored values reproduces
//! the same codes, the same scales and the same widened values.  That
//! idempotence is what lets int8 checkpoints round-trip through f32
//! `ParamMap`s and the serving engine bitwise, exactly like the half
//! formats' `pack(round(x)) == pack(x)` contract.  An all-zero (or
//! subnormal-below-scale-floor) block stores scale 0 and codes 0.
//!
//! On the U50 this is the next 2x (half formats) and then ~4x (int8:
//! 1 byte/element + 4/64 bytes of scale = 0.2656x f32) of on-chip
//! memory and bandwidth: the Adam moment pair, the Eq. 21 caches and
//! the core arrays all shrink (see
//! `crate::fpga::resources::report_with_optim_prec` and the
//! width-parameterized BRAM allocator in `crate::fpga::bram`).

use super::dense::Tensor;
use anyhow::{anyhow, Result};
use std::borrow::Cow;

/// Element storage format of the mixed-precision path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// IEEE-754 binary32 — the default full-precision path.
    F32,
    /// bfloat16: f32's 8-bit exponent, 7-bit mantissa.  Same dynamic
    /// range as f32, ~2-3 significant decimal digits.
    Bf16,
    /// IEEE-754 binary16: 5-bit exponent, 10-bit mantissa.  More
    /// mantissa than bf16 but overflows beyond 65504.
    F16,
    /// Block-scaled int8: one `i8` code in `[-127, 127]` per element
    /// plus one f32 scale (bf16-snapped `amax/127`) per
    /// [`INT8_BLOCK`]-element block.  1 byte/element + 1/16 byte of
    /// scale amortized.
    Int8,
}

impl Precision {
    pub fn all() -> [Precision; 4] {
        [Precision::F32, Precision::Bf16, Precision::F16, Precision::Int8]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
        }
    }

    /// Parse a CLI / manifest spelling.
    pub fn parse(s: &str) -> Result<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(Precision::F32),
            "bf16" | "bfloat16" => Ok(Precision::Bf16),
            "f16" | "fp16" | "half" | "float16" => Ok(Precision::F16),
            "int8" | "i8" => Ok(Precision::Int8),
            other => Err(anyhow!("unknown precision '{other}' (f32|bf16|f16|int8)")),
        }
    }

    /// Bytes per stored element (excluding the int8 per-block scale —
    /// see [`Precision::storage_bytes`] for the at-rest total).
    pub fn bytes(&self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::Bf16 | Precision::F16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// Bits per stored element (the BRAM word width of this format).
    pub fn bits(&self) -> usize {
        match self {
            Precision::F32 => 32,
            Precision::Bf16 | Precision::F16 => 16,
            Precision::Int8 => 8,
        }
    }

    /// Total at-rest bytes of `elems` stored elements — the single
    /// source of truth every byte-accounting formula charges.  For
    /// int8 this includes the per-block f32 scales
    /// (`elems + 4 * ceil(elems / 64)` = 1.0625 bytes/element);
    /// for the other formats it is simply `elems * bytes()`.
    pub fn storage_bytes(&self, elems: u64) -> u64 {
        match self {
            Precision::Int8 => {
                elems + INT8_SCALE_BYTES * elems.div_ceil(INT8_BLOCK as u64)
            }
            p => elems * p.bytes(),
        }
    }

    pub fn is_half(&self) -> bool {
        !matches!(self, Precision::F32)
    }

    /// Storage round-trip of one value: round to this precision
    /// (round-to-nearest-even) and widen back to f32.  Identity for
    /// [`Precision::F32`] **and** [`Precision::Int8`] — int8 rounding
    /// is a property of a whole block (the scale is shared), so a
    /// single scalar has no int8 rounding; the block-aware store point
    /// is [`Precision::round_slice_in_place`].  Idempotent for every
    /// format.
    #[inline]
    pub fn round(&self, x: f32) -> f32 {
        match self {
            Precision::F32 | Precision::Int8 => x,
            Precision::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
            Precision::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
        }
    }

    /// Round a whole buffer in place (no-op for f32) — the
    /// "round-on-store" half of the compute contract.  For int8 this
    /// is the blockwise quantize/dequantize round trip over fixed
    /// 64-element windows of the slice (idempotent: requantizing a
    /// rounded buffer reproduces it bitwise).
    pub fn round_slice_in_place(&self, xs: &mut [f32]) {
        match self {
            Precision::F32 => {}
            Precision::Bf16 | Precision::F16 => {
                for x in xs.iter_mut() {
                    *x = self.round(*x);
                }
            }
            Precision::Int8 => {
                for block in xs.chunks_mut(INT8_BLOCK) {
                    int8_round_block_in_place(block);
                }
            }
        }
    }

    /// Rounded copy of a tensor (clones for f32).
    pub fn round_tensor(&self, t: &Tensor) -> Tensor {
        self.round_tensor_owned(t.clone())
    }

    /// Round an owned tensor on store — zero-cost move for f32.
    pub fn round_tensor_owned(&self, mut t: Tensor) -> Tensor {
        self.round_slice_in_place(&mut t.data);
        t
    }

    /// Quantize one value to this format's 16 stored bits.  Only
    /// meaningful for the half formats (shared by [`PackedTensor`] and
    /// the optimizer's packed state buffers).
    #[inline]
    pub(crate) fn quantize_bits(&self, x: f32) -> u16 {
        match self {
            Precision::Bf16 => f32_to_bf16_bits(x),
            Precision::F16 => f32_to_f16_bits(x),
            Precision::F32 | Precision::Int8 => {
                unreachable!("only the half formats pack to 16 bits")
            }
        }
    }

    /// Widen one stored 16-bit element back to f32 (exact).
    #[inline]
    pub(crate) fn widen_bits(&self, bits: u16) -> f32 {
        match self {
            Precision::Bf16 => bf16_bits_to_f32(bits),
            Precision::F16 => f16_bits_to_f32(bits),
            Precision::F32 | Precision::Int8 => {
                unreachable!("only the half formats pack to 16 bits")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// bf16 conversion — round-to-nearest-even on the dropped 16 bits.
// ---------------------------------------------------------------------------

/// f32 -> bf16 bits, round-to-nearest-even.  Overflow past the largest
/// finite bf16 carries into the exponent and yields the correct signed
/// infinity; NaN stays NaN (quieted, sign preserved).
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep the sign, force a quiet-NaN payload bit so truncation
        // cannot silently produce infinity.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lower = bits & 0x0000_FFFF;
    let upper = (bits >> 16) as u16;
    let halfway = 0x0000_8000;
    if lower > halfway || (lower == halfway && (upper & 1) == 1) {
        upper.wrapping_add(1)
    } else {
        upper
    }
}

/// bf16 bits -> f32 (exact: bf16 is a prefix of f32).
#[inline]
pub fn bf16_bits_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

// ---------------------------------------------------------------------------
// f16 conversion — round-to-nearest-even with subnormal and
// overflow-to-infinity handling.
// ---------------------------------------------------------------------------

/// f32 -> IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mut man = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: preserve infiniteness; quiet NaNs keep their top
        // payload bits.
        return if man != 0 {
            sign | 0x7E00 | ((man >> 13) as u16 & 0x01FF)
        } else {
            sign | 0x7C00
        };
    }
    if exp == 0 {
        // f32 subnormals (< 2^-126) are far below the f16 subnormal
        // floor (2^-24): they all round to signed zero.
        return sign;
    }
    man |= 0x0080_0000; // implicit leading 1
    let e = exp - 127; // unbiased exponent
    if e > 15 {
        return sign | 0x7C00; // |x| >= 2^16: infinity
    }
    if e < -24 {
        // Below half the smallest subnormal — except the exact halfway
        // point 2^-25, which ties to even (zero).
        if e == -25 && man > 0x0080_0000 {
            return sign | 0x0001; // rounds up to the smallest subnormal
        }
        return sign;
    }
    // Normal f16 (e >= -14) drops 13 mantissa bits; subnormals drop
    // more as the exponent sinks below -14.
    let shift = (if e >= -14 { 13 } else { 13 + (-14 - e) }) as u32;
    let half_exp: u16 = if e >= -14 { ((e + 15) as u16) << 10 } else { 0 };
    let kept = (man >> shift) as u16 & 0x03FF;
    let rem = man & ((1u32 << shift) - 1);
    let halfway = 1u32 << (shift - 1);
    let h = sign | half_exp | kept;
    if rem > halfway || (rem == halfway && (h & 1) == 1) {
        // The carry propagates mantissa -> exponent; 65504 + ulp/2
        // correctly becomes the infinity encoding.
        h.wrapping_add(1)
    } else {
        h
    }
}

/// IEEE binary16 bits -> f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // signed zero
        }
        // Subnormal: man * 2^-24, exact in f32 (man <= 1023).
        let v = man as f32 * f32::from_bits(0x3380_0000); // 2^-24
        return if sign != 0 { -v } else { v };
    }
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

// ---------------------------------------------------------------------------
// Block-scaled int8 — fixed 64-element blocks, bf16-snapped amax/127
// scale, round-to-nearest-even codes in [-127, 127].
// ---------------------------------------------------------------------------

/// Elements per int8 scaling block.  Block boundaries are fixed
/// windows of the flat buffer starting at index 0 — part of the
/// determinism contract (same data, same blocks, same bits).
pub const INT8_BLOCK: usize = 64;

/// Bytes of one per-block scale (stored as f32).
pub const INT8_SCALE_BYTES: u64 = 4;

/// The per-block scale: `amax / 127`, snapped to bf16 precision
/// (round-to-nearest-even on the low 16 mantissa bits) but stored as
/// f32.  The snap is load-bearing, not cosmetic: with an
/// 8-bit-mantissa scale and 8-bit codes every `code * scale` product
/// is **exact** in f32 (<= 15 significand bits), so
/// requantize(dequantize(codes)) reproduces the codes *and* the scale
/// bitwise — without it the recomputed `amax/127` can drift by 1 ulp
/// and break checkpoint/engine round-trips.  `amax == 0` (or small
/// enough that the snapped quotient underflows to zero) yields scale
/// 0: the all-zero block.
pub fn int8_block_scale(amax: f32) -> f32 {
    if amax == 0.0 || !amax.is_finite() {
        return 0.0;
    }
    bf16_bits_to_f32(f32_to_bf16_bits(amax / 127.0))
}

/// Quantize one value against a block scale: round-to-nearest-even to
/// an integer code, clamped to the symmetric range `[-127, 127]`
/// (-128 is never produced, so every stored code is a fixed point of
/// quantize(dequantize(..))).  A zero scale (all-zero block) or a
/// non-finite quotient yields code 0.
pub fn int8_quantize(x: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    let t = x / scale;
    if !t.is_finite() {
        return 0;
    }
    // Round-to-nearest-even without `round_ties_even` (rust 1.75).
    let f = t.floor();
    let d = t - f;
    let mut q = f as i32;
    if d > 0.5 || (d == 0.5 && q % 2 != 0) {
        q += 1;
    }
    q.clamp(-127, 127) as i8
}

/// Dequantize one code: `code * scale`, exact in f32 (8-bit code x
/// 8-bit-mantissa scale).  Code 0 is exactly 0.0 regardless of scale.
#[inline]
pub fn int8_dequantize(code: i8, scale: f32) -> f32 {
    if code == 0 {
        0.0
    } else {
        code as f32 * scale
    }
}

/// Blockwise store rounding of one <= 64-element window in place:
/// quantize against the block's own scale, widen back.  This is the
/// int8 arm of [`Precision::round_slice_in_place`] and the reference
/// semantics [`ScaledBlockVec::from_f32`] packs to.
fn int8_round_block_in_place(block: &mut [f32]) {
    let amax = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = int8_block_scale(amax);
    for x in block.iter_mut() {
        *x = int8_dequantize(int8_quantize(*x, scale), scale);
    }
}

/// Shape-less block-scaled int8 buffer: one `i8` code per element,
/// one f32 scale per [`INT8_BLOCK`]-element block.  The int8 sibling
/// of the u16-packed [`PackedVec::Half`] payload, and the storage the
/// [`PackedVec::Int8`] / [`PackedTensor`] int8 variants rest on.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaledBlockVec {
    codes: Vec<i8>,
    scales: Vec<f32>,
}

impl ScaledBlockVec {
    /// Quantize-on-store construction: fixed block boundaries, scale
    /// = bf16-snapped `amax/127` per block, RNE codes.  Idempotent:
    /// `from_f32(&v.to_f32()) == v` bitwise.
    pub fn from_f32(vals: &[f32]) -> ScaledBlockVec {
        let mut codes = Vec::with_capacity(vals.len());
        let mut scales = Vec::with_capacity(vals.len().div_ceil(INT8_BLOCK));
        for block in vals.chunks(INT8_BLOCK) {
            let amax = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = int8_block_scale(amax);
            scales.push(scale);
            for &x in block {
                codes.push(int8_quantize(x, scale));
            }
        }
        ScaledBlockVec { codes, scales }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// At-rest bytes: one byte per code + 4 bytes per block scale —
    /// exactly [`Precision::storage_bytes`] for
    /// [`Precision::Int8`].
    pub fn bytes(&self) -> u64 {
        self.codes.len() as u64 + INT8_SCALE_BYTES * self.scales.len() as u64
    }

    /// One element, dequantized (exact product).
    #[inline]
    pub fn get(&self, idx: usize) -> f32 {
        int8_dequantize(self.codes[idx], self.scales[idx / INT8_BLOCK])
    }

    /// Widen-on-load copy (exact per element given the stored scale).
    pub fn to_f32(&self) -> Vec<f32> {
        (0..self.codes.len()).map(|i| self.get(i)).collect()
    }

    /// The raw per-block scales (test/diagnostic access).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// The raw codes (test/diagnostic access).
    pub fn codes(&self) -> &[i8] {
        &self.codes
    }
}

/// A shaped block-scaled int8 tensor — the int8 counterpart of the
/// u16-packed [`PackedTensor`] payload.  Blocks run over the flat
/// row-major buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaledBlockTensor {
    pub shape: Vec<usize>,
    data: ScaledBlockVec,
}

impl ScaledBlockTensor {
    pub fn from_tensor(t: &Tensor) -> ScaledBlockTensor {
        ScaledBlockTensor {
            shape: t.shape.clone(),
            data: ScaledBlockVec::from_f32(&t.data),
        }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.to_f32() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> u64 {
        self.data.bytes()
    }

    #[inline]
    pub fn get(&self, idx: usize) -> f32 {
        self.data.get(idx)
    }
}

// ---------------------------------------------------------------------------
// Packed storage
// ---------------------------------------------------------------------------

/// Shape-less packed f32 buffer — the shared storage primitive of the
/// mixed-precision path (the optimizer's moment buffers and any other
/// flat storage build on this, so the per-element rounding contract
/// has a single source of truth: [`Precision::quantize_bits`] /
/// [`Precision::widen_bits`]).
#[derive(Debug, Clone, PartialEq)]
pub enum PackedVec {
    F32(Vec<f32>),
    Half(Precision, Vec<u16>),
    Int8(ScaledBlockVec),
}

impl PackedVec {
    pub fn zeros(prec: Precision, n: usize) -> PackedVec {
        match prec {
            Precision::F32 => PackedVec::F32(vec![0.0; n]),
            Precision::Int8 => PackedVec::Int8(ScaledBlockVec::from_f32(&vec![0.0; n])),
            p => PackedVec::Half(p, vec![p.quantize_bits(0.0); n]),
        }
    }

    pub fn empty(prec: Precision) -> PackedVec {
        PackedVec::zeros(prec, 0)
    }

    /// Round-on-store construction from f32 values (blockwise
    /// quantize-on-store for int8).
    pub fn from_f32(prec: Precision, vals: &[f32]) -> PackedVec {
        match prec {
            Precision::F32 => PackedVec::F32(vals.to_vec()),
            Precision::Int8 => PackedVec::Int8(ScaledBlockVec::from_f32(vals)),
            p => PackedVec::Half(p, vals.iter().map(|&x| p.quantize_bits(x)).collect()),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn len(&self) -> usize {
        match self {
            PackedVec::F32(v) => v.len(),
            PackedVec::Half(_, v) => v.len(),
            PackedVec::Int8(v) => v.len(),
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            PackedVec::F32(_) => Precision::F32,
            PackedVec::Half(p, _) => *p,
            PackedVec::Int8(_) => Precision::Int8,
        }
    }

    /// Bytes at rest — what the on-chip accounting charges (includes
    /// the int8 per-block scales).
    pub fn bytes(&self) -> u64 {
        self.precision().storage_bytes(self.len() as u64)
    }

    /// Widen-on-load copy (exact for every format given the stored
    /// representation).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            PackedVec::F32(v) => v.clone(),
            PackedVec::Half(p, bits) => bits.iter().map(|&b| p.widen_bits(b)).collect(),
            PackedVec::Int8(v) => v.to_f32(),
        }
    }

    /// The stored values as f32: a zero-copy borrow for the f32
    /// variant, an exact widening for the packed formats.
    pub fn view(&self) -> Cow<'_, [f32]> {
        match self {
            PackedVec::F32(v) => Cow::Borrowed(v.as_slice()),
            PackedVec::Half(p, bits) => {
                Cow::Owned(bits.iter().map(|&b| p.widen_bits(b)).collect())
            }
            PackedVec::Int8(v) => Cow::Owned(v.to_f32()),
        }
    }

    /// Re-store the buffer at a (possibly different) precision.  Values
    /// already representable at `prec` survive bitwise (re-quantizing a
    /// fixed point of the rounding is the identity — for int8 this
    /// holds blockwise because the bf16-snapped scale recomputes
    /// bitwise from its own dequantized block).
    pub fn set_precision(&mut self, prec: Precision) {
        if self.precision() != prec {
            *self = PackedVec::from_f32(prec, &self.to_f32());
        }
    }

    /// Run one update over the buffer as f32 values: **in place** for
    /// the f32 variant (the hot default path — no allocation, no
    /// copy), widen/compute/round-on-store for the packed variants.
    pub fn update_in_place(&mut self, f: impl FnOnce(&mut [f32])) {
        match self {
            PackedVec::F32(v) => f(v),
            PackedVec::Half(p, bits) => {
                let mut vals: Vec<f32> = bits.iter().map(|&b| p.widen_bits(b)).collect();
                f(&mut vals);
                for (b, &x) in bits.iter_mut().zip(&vals) {
                    *b = p.quantize_bits(x);
                }
            }
            PackedVec::Int8(v) => {
                let mut vals = v.to_f32();
                f(&mut vals);
                *v = ScaledBlockVec::from_f32(&vals);
            }
        }
    }
}

/// A tensor at rest in storage precision: f32 tensors keep their
/// buffer — borrowable at **zero cost** via [`PackedTensor::view`], so
/// the default full-precision hot path never copies a cache — while
/// half-precision tensors are genuinely packed to `u16` (the realized
/// half-width Eq. 21 cache) and widen exactly on load.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTensor {
    repr: Repr,
}

#[derive(Debug, Clone, PartialEq)]
enum Repr {
    F32(Tensor),
    Half {
        prec: Precision,
        shape: Vec<usize>,
        bits: Vec<u16>,
    },
    Int8(ScaledBlockTensor),
}

impl PackedTensor {
    /// Pack a tensor, consuming it (move — no copy — for f32).
    pub fn pack_owned(t: Tensor, precision: Precision) -> PackedTensor {
        let repr = match precision {
            Precision::F32 => Repr::F32(t),
            Precision::Int8 => Repr::Int8(ScaledBlockTensor::from_tensor(&t)),
            p => Repr::Half {
                prec: p,
                bits: t.data.iter().map(|&x| p.quantize_bits(x)).collect(),
                shape: t.shape,
            },
        };
        PackedTensor { repr }
    }

    /// Pack by reference (clones the f32 buffer).
    pub fn pack(t: &Tensor, precision: Precision) -> PackedTensor {
        PackedTensor::pack_owned(t.clone(), precision)
    }

    pub fn shape(&self) -> &[usize] {
        match &self.repr {
            Repr::F32(t) => &t.shape,
            Repr::Half { shape, .. } => shape,
            Repr::Int8(t) => &t.shape,
        }
    }

    /// The stored tensor as f32: a zero-copy borrow for f32 storage,
    /// an exact widening for the packed formats — the widen-on-load
    /// side of the compute contract.
    pub fn view(&self) -> Cow<'_, Tensor> {
        match &self.repr {
            Repr::F32(t) => Cow::Borrowed(t),
            Repr::Half { prec, shape, bits } => Cow::Owned(Tensor {
                shape: shape.clone(),
                data: bits.iter().map(|&b| prec.widen_bits(b)).collect(),
            }),
            Repr::Int8(t) => Cow::Owned(t.to_tensor()),
        }
    }

    /// Owned widened copy (prefer [`PackedTensor::view`] where a
    /// borrow suffices).
    pub fn unpack(&self) -> Tensor {
        self.view().into_owned()
    }

    pub fn numel(&self) -> usize {
        match &self.repr {
            Repr::F32(t) => t.data.len(),
            Repr::Half { bits, .. } => bits.len(),
            Repr::Int8(t) => t.numel(),
        }
    }

    pub fn precision(&self) -> Precision {
        match &self.repr {
            Repr::F32(_) => Precision::F32,
            Repr::Half { prec, .. } => *prec,
            Repr::Int8(_) => Precision::Int8,
        }
    }

    /// Bytes this tensor occupies at rest — the quantity the on-chip
    /// accounting charges (includes the int8 per-block scales).
    pub fn bytes(&self) -> u64 {
        self.precision().storage_bytes(self.numel() as u64)
    }

    /// One stored element, widened to f32.  Lets sparse readers (e.g.
    /// the TTM embedding's per-token core slices) widen only the
    /// elements they touch instead of the whole core.
    #[inline]
    pub fn get(&self, idx: usize) -> f32 {
        match &self.repr {
            Repr::F32(t) => t.data[idx],
            Repr::Half { prec, bits, .. } => prec.widen_bits(bits[idx]),
            Repr::Int8(t) => t.get(idx),
        }
    }

    /// Run one update over the flat buffer as f32 values: in place for
    /// the f32 variant, widen/compute/round-on-store for the packed
    /// formats.  Updating with values already representable at the
    /// stored precision (the optimizer rounds on store; int8
    /// requantization is blockwise idempotent) is lossless.
    pub fn update_in_place(&mut self, f: impl FnOnce(&mut Vec<f32>)) {
        match &mut self.repr {
            Repr::F32(t) => f(&mut t.data),
            Repr::Half { prec, bits, .. } => {
                let mut vals: Vec<f32> = bits.iter().map(|&b| prec.widen_bits(b)).collect();
                f(&mut vals);
                assert_eq!(vals.len(), bits.len(), "update changed the element count");
                for (b, &x) in bits.iter_mut().zip(&vals) {
                    *b = prec.quantize_bits(x);
                }
            }
            Repr::Int8(t) => {
                let mut vals = t.data.to_f32();
                f(&mut vals);
                assert_eq!(vals.len(), t.numel(), "update changed the element count");
                t.data = ScaledBlockVec::from_f32(&vals);
            }
        }
    }

    /// Re-store at a (possibly different) precision.  Values already
    /// representable at `prec` survive bitwise.
    pub fn set_precision(&mut self, prec: Precision) {
        if self.precision() != prec {
            *self = PackedTensor::pack_owned(self.unpack(), prec);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn f32_round_is_identity() {
        for x in [0.0f32, -1.5, 3.25e7, f32::INFINITY] {
            assert_eq!(Precision::F32.round(x), x);
        }
    }

    #[test]
    fn bf16_known_values_and_ties_to_even() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3F80);
        assert_eq!(bf16_bits_to_f32(0x3F80), 1.0);
        assert_eq!(f32_to_bf16_bits(-2.0), 0xC000);
        // Exactly halfway between 0x3F80 and 0x3F81: even stays.
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8000)), 0x3F80);
        // Halfway above an odd mantissa rounds up to even.
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F81_8000)), 0x3F82);
        // Just above halfway always rounds up.
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3F80_8001)), 0x3F81);
        // Relative error bound: 2^-8.
        let p = std::f32::consts::PI;
        assert!((Precision::Bf16.round(p) - p).abs() <= p * 2.0f32.powi(-8));
    }

    #[test]
    fn bf16_specials() {
        assert_eq!(Precision::Bf16.round(f32::INFINITY), f32::INFINITY);
        assert_eq!(Precision::Bf16.round(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(Precision::Bf16.round(f32::NAN).is_nan());
        assert_eq!(Precision::Bf16.round(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(Precision::Bf16.round(-0.0).to_bits(), (-0.0f32).to_bits());
        // Overflow past the largest finite bf16 carries into infinity.
        assert_eq!(Precision::Bf16.round(3.4e38), f32::INFINITY);
    }

    #[test]
    fn f16_known_values_and_ties_to_even() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(Precision::F16.round(65504.0), 65504.0); // max finite
        // 2049 is halfway between 2048 and 2050: even mantissa wins.
        assert_eq!(Precision::F16.round(2049.0), 2048.0);
        // 2051 is halfway between 2050 (odd mantissa) and 2052: up.
        assert_eq!(Precision::F16.round(2051.0), 2052.0);
        // Relative error bound: 2^-11.
        let p = std::f32::consts::PI;
        assert!((Precision::F16.round(p) - p).abs() <= p * 2.0f32.powi(-11));
    }

    #[test]
    fn f16_overflow_subnormals_and_specials() {
        assert_eq!(Precision::F16.round(65520.0), f32::INFINITY); // RNE boundary
        assert_eq!(Precision::F16.round(65519.0), 65504.0); // just under it
        assert_eq!(Precision::F16.round(1e6), f32::INFINITY);
        assert_eq!(Precision::F16.round(-1e6), f32::NEG_INFINITY);
        assert!(Precision::F16.round(f32::NAN).is_nan());
        assert_eq!(Precision::F16.round(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(Precision::F16.round(-0.0).to_bits(), (-0.0f32).to_bits());
        // Smallest subnormal is 2^-24.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(Precision::F16.round(tiny), tiny);
        assert_eq!(Precision::F16.round(6.0e-8), tiny); // nearest
        assert_eq!(Precision::F16.round(2.9e-8), 0.0); // below half of it
        assert_eq!(Precision::F16.round(1e-10), 0.0);
        // The exact halfway point 2^-25 ties to even (zero).
        assert_eq!(Precision::F16.round(2.0f32.powi(-25)), 0.0);
    }

    #[test]
    fn rounding_is_idempotent_and_deterministic() {
        prop::check(61, 40, |rng| {
            for prec in [Precision::Bf16, Precision::F16] {
                for _ in 0..64 {
                    // Spread across magnitudes, incl. the f16 subnormal range.
                    let x = (rng.normal() as f32) * 10f32.powi(rng.below(16) as i32 - 8);
                    let once = prec.round(x);
                    assert_eq!(
                        prec.round(once).to_bits(),
                        once.to_bits(),
                        "{prec:?}: rounding not idempotent at {x}"
                    );
                    // Deterministic: repeated conversion is bitwise equal.
                    assert_eq!(prec.round(x).to_bits(), once.to_bits());
                }
            }
        });
    }

    #[test]
    fn rne_never_moves_more_than_one_ulp_gap() {
        // |round(x) - x| is at most half the gap to the next
        // representable value: bounded by |x| * 2^-8 (bf16) / 2^-11
        // (f16) for normals.
        prop::check(62, 30, |rng| {
            for _ in 0..64 {
                let x = rng.normal() as f32;
                let b = Precision::Bf16.round(x);
                assert!((b - x).abs() <= x.abs() * 2.0f32.powi(-8) + 1e-45);
                let h = Precision::F16.round(x);
                assert!((h - x).abs() <= x.abs() * 2.0f32.powi(-11) + 2.0f32.powi(-25));
            }
        });
    }

    #[test]
    fn packed_tensor_roundtrip_and_bytes() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(63);
        let t = Tensor::randn(&[3, 5], 1.0, &mut rng);
        // f32: lossless, 4 bytes/elem, and view() borrows (no copy).
        let p32 = PackedTensor::pack(&t, Precision::F32);
        assert_eq!(p32.unpack(), t);
        assert_eq!(p32.bytes(), 15 * 4);
        assert!(matches!(p32.view(), Cow::Borrowed(_)), "f32 view must be zero-copy");
        for prec in [Precision::Bf16, Precision::F16] {
            let p = PackedTensor::pack(&t, prec);
            assert_eq!(p.bytes(), 15 * 2, "{prec:?}: not half-width");
            assert_eq!(p.shape(), &[3, 5]);
            assert!(matches!(p.view(), Cow::Owned(_)));
            let back = p.unpack();
            // unpack(pack(x)) == round(x), and repacking is lossless.
            for (a, &b) in back.data.iter().zip(&t.data) {
                assert_eq!(a.to_bits(), prec.round(b).to_bits());
            }
            assert_eq!(PackedTensor::pack(&back, prec).unpack(), back);
        }
    }

    #[test]
    fn packed_vec_update_in_place_and_roundtrip() {
        let vals = [0.123456789f32, -2.5, 7.0];
        for prec in Precision::all() {
            let mut pv = PackedVec::from_f32(prec, &vals);
            assert_eq!(pv.len(), 3);
            assert_eq!(pv.bytes(), prec.storage_bytes(3));
            if prec != Precision::Int8 {
                // Scalar formats: stored == round(input) per element.
                // (Int8 rounding is a block property, checked below.)
                for (got, &want) in pv.to_f32().iter().zip(&vals) {
                    assert_eq!(got.to_bits(), prec.round(want).to_bits());
                }
            }
            pv.update_in_place(|v| {
                for x in v.iter_mut() {
                    *x *= 2.0;
                }
            });
            // Every stored buffer is a fixed point of the store
            // rounding: re-storing the widened values is the identity
            // (blockwise for int8, per-scalar otherwise).
            let stored = pv.to_f32();
            let again = PackedVec::from_f32(prec, &stored);
            for (a, b) in stored.iter().zip(again.to_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{prec:?}: store not idempotent");
            }
        }
        assert!(PackedVec::empty(Precision::Bf16).is_empty());
    }

    #[test]
    fn packed_tensor_get_update_and_reprecision() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(64);
        let t = Tensor::randn(&[2, 4], 1.0, &mut rng);
        for prec in Precision::all() {
            let mut p = PackedTensor::pack(&t, prec);
            // get() widens exactly the stored value.
            let widened = p.unpack();
            for i in 0..t.data.len() {
                assert_eq!(p.get(i).to_bits(), widened.data[i].to_bits());
                if prec != Precision::Int8 {
                    assert_eq!(p.get(i).to_bits(), prec.round(t.data[i]).to_bits());
                }
            }
            // Updating with values rounded at the store points is
            // bitwise reproducible (for int8 the blockwise
            // round_slice_in_place is the store rounding).
            p.update_in_place(|v| {
                for x in v.iter_mut() {
                    *x *= 3.0;
                }
                prec.round_slice_in_place(v);
            });
            let mut reference = widened.data.clone();
            for x in reference.iter_mut() {
                *x *= 3.0;
            }
            prec.round_slice_in_place(&mut reference);
            for (got, want) in p.unpack().data.iter().zip(&reference) {
                assert_eq!(got.to_bits(), want.to_bits(), "{prec:?}: rounded update drifted");
            }
            // Re-precision to the same format is the identity; a round
            // trip through f32 and back is bitwise lossless.
            let snapshot = p.clone();
            p.set_precision(prec);
            assert_eq!(p, snapshot);
            p.set_precision(Precision::F32);
            assert_eq!(p.precision(), Precision::F32);
            p.set_precision(prec);
            assert_eq!(p.unpack(), snapshot.unpack());
        }
    }

    #[test]
    fn packed_vec_view_and_reprecision() {
        let vals = [1.5f32, -0.375, 1024.0];
        let mut pv = PackedVec::from_f32(Precision::F32, &vals);
        assert!(matches!(pv.view(), Cow::Borrowed(_)), "f32 view must be zero-copy");
        pv.set_precision(Precision::Bf16);
        assert_eq!(pv.bytes(), 3 * 2);
        // These values are bf16-representable: the round trip is exact.
        assert_eq!(pv.view().as_ref(), &vals);
        pv.set_precision(Precision::F32);
        assert_eq!(pv.view().as_ref(), &vals);
    }

    #[test]
    fn parse_roundtrips_and_aliases() {
        for prec in Precision::all() {
            assert_eq!(Precision::parse(prec.name()).unwrap(), prec);
        }
        assert_eq!(Precision::parse("fp16").unwrap(), Precision::F16);
        assert_eq!(Precision::parse("bfloat16").unwrap(), Precision::Bf16);
        assert_eq!(Precision::parse("FP32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8);
        assert_eq!(Precision::parse("i8").unwrap(), Precision::Int8);
        assert!(Precision::parse("fp8").is_err());
    }

    #[test]
    fn int8_scale_is_bf16_snapped_and_products_are_exact() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(71);
        for _ in 0..200 {
            let amax = (rng.normal().abs() as f32 + 1e-6) * 10f32.powi(rng.below(12) as i32 - 6);
            let s = int8_block_scale(amax);
            // The scale is a bf16 fixed point (low 16 mantissa bits 0)
            // within one bf16 ulp of amax/127.
            assert_eq!(s.to_bits() & 0xFFFF, 0, "scale {s} not bf16-snapped");
            let snap_tol = (amax / 127.0) * 2.0f32.powi(-8) + f32::MIN_POSITIVE;
            assert!((s - amax / 127.0).abs() <= snap_tol);
            // code * scale is exact: dividing back recovers the code.
            for q in [-127i8, -64, -3, 1, 77, 127] {
                let v = int8_dequantize(q, s);
                assert_eq!((v / s) as i32, q as i32, "q*s not exact at s={s}");
            }
        }
    }

    #[test]
    fn int8_block_quantize_roundtrip_properties() {
        use crate::util::rng::SplitMix64;
        let mut rng = SplitMix64::new(72);
        let vals: Vec<f32> = (0..200).map(|_| rng.normal() as f32 * 3.0).collect();
        let sb = ScaledBlockVec::from_f32(&vals);
        assert_eq!(sb.len(), 200);
        assert_eq!(sb.scales().len(), 4); // ceil(200 / 64)
        assert_eq!(sb.bytes(), 200 + 4 * 4);
        // Quantization error per element is at most scale/2 (+ the
        // clamp-free guarantee: every |code| <= 127).
        for (i, &x) in vals.iter().enumerate() {
            let s = sb.scales()[i / INT8_BLOCK];
            assert!(sb.codes()[i] >= -127);
            assert!((sb.get(i) - x).abs() <= s * 0.5 + 1e-30, "elem {i}");
        }
        // Idempotence: requantizing the dequantized buffer reproduces
        // codes, scales and values bitwise.
        let again = ScaledBlockVec::from_f32(&sb.to_f32());
        assert_eq!(again, sb);
        // round_slice_in_place agrees with pack/unpack (same blocks).
        let mut rounded = vals.clone();
        Precision::Int8.round_slice_in_place(&mut rounded);
        for (a, b) in rounded.iter().zip(sb.to_f32()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn int8_zero_and_subnormal_blocks() {
        // amax == 0: zero scale, zero codes, exact zero round trip.
        let zeros = vec![0.0f32; 96];
        let sb = ScaledBlockVec::from_f32(&zeros);
        assert!(sb.scales().iter().all(|&s| s == 0.0));
        assert!(sb.codes().iter().all(|&q| q == 0));
        assert!(sb.to_f32().iter().all(|&v| v.to_bits() == 0));
        // A subnormal-only block either flushes to zero (scale
        // underflow) or stays within the scale/2 error bound — in both
        // cases deterministically and idempotently.
        let tiny: Vec<f32> = (1u32..65)
            .map(|i| f32::from_bits(i) * if i % 2 == 0 { -1.0 } else { 1.0 })
            .collect();
        let sb = ScaledBlockVec::from_f32(&tiny);
        let s = sb.scales()[0];
        for (i, &x) in tiny.iter().enumerate() {
            assert!((sb.get(i) - x).abs() <= s * 0.5 + f32::MIN_POSITIVE);
        }
        assert_eq!(ScaledBlockVec::from_f32(&sb.to_f32()), sb);
        // Non-finite amax degrades to the all-zero block rather than
        // emitting NaN (the loss-scaler guard keeps real training data
        // finite before it ever reaches storage).
        let bad = vec![f32::INFINITY, 1.0, -2.0];
        let sb = ScaledBlockVec::from_f32(&bad);
        assert!(sb.to_f32().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn int8_every_code_survives_quantize_dequantize() {
        // quantize(dequantize(q)) == q for every representable code,
        // across a spread of block scales (the satellite property).
        for s in [int8_block_scale(1.0), int8_block_scale(3.7e-3), int8_block_scale(8.1e4)] {
            for q in -127i32..=127 {
                let v = int8_dequantize(q as i8, s);
                assert_eq!(int8_quantize(v, s) as i32, q, "code {q} at scale {s}");
            }
        }
    }
}
