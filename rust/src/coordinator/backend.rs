//! Backend abstraction for the training coordinator.
//!
//! The paper's FP -> BP -> PU step can execute on two engines:
//!
//! * the **PJRT engine** ([`crate::runtime::Engine`], `pjrt` feature) —
//!   runs the fused HLO artifact produced by the JAX/Pallas AOT build;
//! * the **native trainer** ([`crate::train::NativeTrainer`]) — the
//!   hand-derived rust backward pass over the TT/TTM tensor substrate,
//!   needing no XLA, no Python and no artifacts.
//!
//! [`Trainer`](super::Trainer) is generic over this trait, so epochs,
//! metrics, evaluation and checkpointing are written once and drive
//! either engine interchangeably.

use crate::config::ModelConfig;
use anyhow::Result;
use std::path::Path;

/// Result of one training step (any backend).
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    pub loss: f32,
    /// Wall-clock seconds spent inside the step's compute (PJRT execute,
    /// or the native forward + backward + update).
    pub execute_secs: f64,
    /// Wall-clock seconds of host-side data handling around the step.
    pub host_secs: f64,
}

/// A training/evaluation engine the coordinator can drive.
pub trait TrainBackend {
    /// Short backend identifier ("pjrt" / "native") for logs.
    fn backend_name(&self) -> &'static str;

    /// The model configuration this backend was built for.
    fn config(&self) -> &ModelConfig;

    /// Whether this backend accepts a runtime mini-batch of `batch`
    /// examples.  The PJRT engine executes an HLO artifact compiled for
    /// a fixed `config().batch`; the native trainer accepts any `B >= 1`
    /// (the contraction K dimension carries `B * S`).
    fn supports_batch(&self, batch: usize) -> bool {
        batch == self.config().batch.max(1)
    }

    /// One optimizer step (FP -> BP -> PU) on a mini-batch.
    ///
    /// `tokens`/`slots` are `(batch, seq)` row-major, `intent` is
    /// `(batch,)`.  Updates parameters in place.
    fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<StepOutput>;

    /// Inference: `(intent_logits (B*n_intents), slot_logits
    /// (B*S*n_slots))` row-major.
    fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Persist the current parameters as one `.npy` per array.
    fn save_checkpoint(&self, dir: &Path) -> Result<()>;

    /// Restore parameters saved by [`TrainBackend::save_checkpoint`]
    /// (implementations verify the embedded parameter names).
    fn load_checkpoint(&mut self, dir: &Path) -> Result<()>;
}
