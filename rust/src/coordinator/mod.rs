//! L3 training coordinator (the paper's accelerator control plane).
//!
//! * [`trainer`] — FP/BP/PU stage loop over the PJRT engine, epochs,
//!   evaluation (Table III metrics), loss-curve capture (Fig. 13).
//! * [`metrics`] — loss/accuracy/timing records and CSV export.

pub mod metrics;
pub mod trainer;

pub use metrics::Metrics;
pub use trainer::{EvalResult, Trainer};
