//! L3 training coordinator (the paper's accelerator control plane).
//!
//! * [`backend`] — the [`TrainBackend`] abstraction: one trait driving
//!   either the PJRT engine or the rust-native trainer (including the
//!   per-backend mini-batch capability, `supports_batch`).
//! * [`trainer`] — FP/BP/PU stage loop over any backend: mini-batch
//!   packing, epochs, evaluation (Table III metrics), loss-curve
//!   capture (Fig. 13).
//! * [`metrics`] — loss/accuracy/timing/throughput records (tokens/sec,
//!   per-epoch wall-clock) and CSV export.

pub mod backend;
pub mod metrics;
pub mod trainer;

pub use backend::{StepOutput, TrainBackend};
pub use metrics::Metrics;
pub use trainer::{EvalResult, Trainer};
