//! Training metrics: loss curves, joint intent/slot accuracy, timing,
//! throughput (tokens/sec) and per-epoch wall-clock.

use std::fmt::Write as _;

/// Rolling record of one training run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    /// (epoch, intent_acc, slot_acc) evaluation points.
    pub evals: Vec<(usize, f64, f64)>,
    /// Cumulative seconds inside backend execute (PJRT or native
    /// FP+BP+PU).
    pub execute_secs: f64,
    /// Per-step execute seconds, in step order — kept alongside the
    /// cumulative sum so tail latency (p50/p95 via [`percentile`]) is
    /// reportable, not just the mean.
    pub execute_samples: Vec<f64>,
    /// Cumulative seconds of host-side overhead (batch packing +
    /// backend host work).
    pub host_secs: f64,
    pub steps: usize,
    /// Token/slot positions processed (`B * S` per step).
    pub tokens: usize,
    /// Wall-clock seconds of each completed epoch.
    pub epoch_secs: Vec<f64>,
}

impl Metrics {
    pub fn record_step(&mut self, loss: f32, execute_secs: f64, host_secs: f64, tokens: usize) {
        self.losses.push((self.steps, loss));
        self.execute_secs += execute_secs;
        self.execute_samples.push(execute_secs);
        self.host_secs += host_secs;
        self.steps += 1;
        self.tokens += tokens;
    }

    /// Nearest-rank percentile of per-step execute seconds (NaN before
    /// the first step).
    pub fn execute_percentile_secs(&self, p: f64) -> f64 {
        percentile(&self.execute_samples, p)
    }

    pub fn record_eval(&mut self, epoch: usize, intent_acc: f64, slot_acc: f64) {
        self.evals.push((epoch, intent_acc, slot_acc));
    }

    /// Record one epoch's wall-clock seconds.
    pub fn record_epoch_secs(&mut self, secs: f64) {
        self.epoch_secs.push(secs);
    }

    /// Mean loss over the last `n` steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32
    }

    /// Host overhead as a fraction of total step time (perf target <5%).
    pub fn host_overhead_frac(&self) -> f64 {
        let total = self.execute_secs + self.host_secs;
        if total == 0.0 {
            0.0
        } else {
            self.host_secs / total
        }
    }

    /// Optimizer steps per second of step time (execute + host).
    pub fn steps_per_sec(&self) -> f64 {
        let total = self.execute_secs + self.host_secs;
        if total == 0.0 {
            0.0
        } else {
            self.steps as f64 / total
        }
    }

    /// Token/slot positions per second of step time (execute + host).
    pub fn tokens_per_sec(&self) -> f64 {
        let total = self.execute_secs + self.host_secs;
        if total == 0.0 {
            0.0
        } else {
            self.tokens as f64 / total
        }
    }

    /// Mean wall-clock seconds per completed epoch (NaN before the
    /// first epoch finishes).
    pub fn mean_epoch_secs(&self) -> f64 {
        if self.epoch_secs.is_empty() {
            return f64::NAN;
        }
        self.epoch_secs.iter().sum::<f64>() / self.epoch_secs.len() as f64
    }

    /// Loss curve as CSV (step,loss) for EXPERIMENTS.md / plotting.
    pub fn loss_csv(&self) -> String {
        let mut out = String::from("step,loss\n");
        for &(s, l) in &self.losses {
            let _ = writeln!(out, "{s},{l}");
        }
        out
    }

    pub fn eval_csv(&self) -> String {
        let mut out = String::from("epoch,intent_acc,slot_acc\n");
        for &(e, ia, sa) in &self.evals {
            let _ = writeln!(out, "{e},{ia:.4},{sa:.4}");
        }
        out
    }
}

/// Argmax helper for logits rows.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Nearest-rank percentile of a sample set (`p` in `0..=100`): the
/// smallest sample such that at least `p%` of the samples are `<=` it
/// — p50 of `[1, 2, 3, 4]` is `2`, p100 is the maximum, p0 the
/// minimum.  The single shared definition for the serve bench, the
/// load generator and `serve_native` (replacing their ad-hoc
/// sorted-index arithmetic).  Returns NaN on an empty set.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Format a metric value for a hand-rolled JSON document: finite
/// values print with `decimals` fraction digits, non-finite values —
/// e.g. the [`percentile`] of an empty sample set, or a 0/0 rate —
/// print as `null`.  Bare `NaN`/`inf` tokens are not valid JSON and
/// corrupt the whole BENCH document for every downstream parser, so
/// every writer that can see an empty sample path must route floats
/// through this.
pub fn json_num(v: f64, decimals: usize) -> String {
    if v.is_finite() {
        format!("{v:.decimals$}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recent_loss_window() {
        let mut m = Metrics::default();
        for l in [4.0f32, 3.0, 2.0, 1.0] {
            m.record_step(l, 0.01, 0.001, 32);
        }
        assert_eq!(m.recent_loss(2), 1.5);
        assert_eq!(m.steps, 4);
        assert_eq!(m.tokens, 128);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn percentile_single_sample() {
        // n = 1: every percentile is that sample.
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(&[7.5], p), 7.5);
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let s = [4.0, 1.0, 3.0, 2.0]; // unsorted on purpose
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 25.0), 1.0);
        assert_eq!(percentile(&s, 50.0), 2.0);
        assert_eq!(percentile(&s, 75.0), 3.0);
        assert_eq!(percentile(&s, 99.0), 4.0);
        assert_eq!(percentile(&s, 100.0), 4.0);
    }

    #[test]
    fn percentile_ties_and_empty() {
        let ties = [2.0, 2.0, 2.0, 9.0];
        assert_eq!(percentile(&ties, 50.0), 2.0);
        assert_eq!(percentile(&ties, 75.0), 2.0);
        assert_eq!(percentile(&ties, 100.0), 9.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn execute_percentiles_track_step_samples() {
        let mut m = Metrics::default();
        assert!(m.execute_percentile_secs(50.0).is_nan());
        for secs in [0.04, 0.01, 0.03, 0.02] {
            m.record_step(1.0, secs, 0.0, 32);
        }
        assert_eq!(m.execute_samples.len(), 4);
        assert_eq!(m.execute_percentile_secs(50.0), 0.02);
        assert_eq!(m.execute_percentile_secs(95.0), 0.04);
        // The cumulative sum and the sample list agree.
        assert!((m.execute_samples.iter().sum::<f64>() - m.execute_secs).abs() < 1e-12);
    }

    #[test]
    fn empty_sample_percentile_serializes_as_null_not_nan() {
        // The regression: an empty sample set (zero completed requests
        // / steps) gives a NaN percentile, and a writer that formats it
        // with `{:.4}` emits a bare `NaN` token — invalid JSON.  The
        // shared formatter must turn every non-finite into `null`.
        let p = percentile(&[], 50.0);
        assert!(p.is_nan());
        assert_eq!(json_num(p, 4), "null");
        assert_eq!(json_num(f64::INFINITY, 2), "null");
        assert_eq!(json_num(f64::NEG_INFINITY, 2), "null");
        assert_eq!(json_num(0.25, 3), "0.250");
        assert_eq!(json_num(3.0, 0), "3");
    }

    #[test]
    fn csv_well_formed() {
        let mut m = Metrics::default();
        m.record_step(1.0, 0.0, 0.0, 32);
        m.record_eval(0, 0.5, 0.25);
        assert!(m.loss_csv().lines().count() == 2);
        assert!(m.eval_csv().contains("0,0.5000,0.2500"));
    }

    #[test]
    fn overhead_fraction() {
        let mut m = Metrics::default();
        m.record_step(1.0, 0.9, 0.1, 32);
        assert!((m.host_overhead_frac() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn throughput_counters() {
        let mut m = Metrics::default();
        // 2 steps of batch 4 x seq 8 = 32 tokens each, 0.5 s total each.
        m.record_step(1.0, 0.4, 0.1, 32);
        m.record_step(0.9, 0.4, 0.1, 32);
        assert!((m.tokens_per_sec() - 64.0).abs() < 1e-9);
        assert!((m.steps_per_sec() - 2.0).abs() < 1e-9);
        assert!(m.mean_epoch_secs().is_nan());
        m.record_epoch_secs(2.0);
        m.record_epoch_secs(4.0);
        assert!((m.mean_epoch_secs() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_time_throughput_is_defined() {
        let m = Metrics::default();
        assert_eq!(m.tokens_per_sec(), 0.0);
        assert_eq!(m.steps_per_sec(), 0.0);
    }
}
