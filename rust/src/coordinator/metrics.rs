//! Training metrics: loss curves, joint intent/slot accuracy, timing.

use std::fmt::Write as _;

/// Rolling record of one training run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    /// (epoch, intent_acc, slot_acc) evaluation points.
    pub evals: Vec<(usize, f64, f64)>,
    /// Cumulative seconds inside PJRT execute.
    pub execute_secs: f64,
    /// Cumulative seconds of host-side overhead.
    pub host_secs: f64,
    pub steps: usize,
}

impl Metrics {
    pub fn record_step(&mut self, loss: f32, execute_secs: f64, host_secs: f64) {
        self.losses.push((self.steps, loss));
        self.execute_secs += execute_secs;
        self.host_secs += host_secs;
        self.steps += 1;
    }

    pub fn record_eval(&mut self, epoch: usize, intent_acc: f64, slot_acc: f64) {
        self.evals.push((epoch, intent_acc, slot_acc));
    }

    /// Mean loss over the last `n` steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32
    }

    /// Host overhead as a fraction of total step time (perf target <5%).
    pub fn host_overhead_frac(&self) -> f64 {
        let total = self.execute_secs + self.host_secs;
        if total == 0.0 {
            0.0
        } else {
            self.host_secs / total
        }
    }

    /// Loss curve as CSV (step,loss) for EXPERIMENTS.md / plotting.
    pub fn loss_csv(&self) -> String {
        let mut out = String::from("step,loss\n");
        for &(s, l) in &self.losses {
            let _ = writeln!(out, "{s},{l}");
        }
        out
    }

    pub fn eval_csv(&self) -> String {
        let mut out = String::from("epoch,intent_acc,slot_acc\n");
        for &(e, ia, sa) in &self.evals {
            let _ = writeln!(out, "{e},{ia:.4},{sa:.4}");
        }
        out
    }
}

/// Argmax helper for logits rows.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recent_loss_window() {
        let mut m = Metrics::default();
        for l in [4.0f32, 3.0, 2.0, 1.0] {
            m.record_step(l, 0.01, 0.001);
        }
        assert_eq!(m.recent_loss(2), 1.5);
        assert_eq!(m.steps, 4);
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[2.0]), 0);
    }

    #[test]
    fn csv_well_formed() {
        let mut m = Metrics::default();
        m.record_step(1.0, 0.0, 0.0);
        m.record_eval(0, 0.5, 0.25);
        assert!(m.loss_csv().lines().count() == 2);
        assert!(m.eval_csv().contains("0,0.5000,0.2500"));
    }

    #[test]
    fn overhead_fraction() {
        let mut m = Metrics::default();
        m.record_step(1.0, 0.9, 0.1);
        assert!((m.host_overhead_frac() - 0.1).abs() < 1e-9);
    }
}
