//! Training coordinator: the L3 analogue of the paper's accelerator
//! control flow (Fig. 8) — it owns the FP -> BP -> PU stage loop, feeds
//! batches, tracks metrics and checkpoints.
//!
//! The coordinator is generic over [`TrainBackend`]: the three training
//! stages either run as a single fused PJRT executable
//! (`<variant>_train.hlo.txt`, exactly like the paper fuses them into one
//! fabric pass) or natively in rust via [`crate::train::NativeTrainer`];
//! the coordinator sequences samples and epochs around either engine.

use super::backend::TrainBackend;
use super::metrics::{argmax, Metrics};
use crate::data::Dataset;
use anyhow::{anyhow, Result};

/// Epoch-level training driver over any [`TrainBackend`].
pub struct Trainer<B: TrainBackend> {
    pub backend: B,
    pub metrics: Metrics,
    pub lr: f32,
}

/// Joint evaluation result (paper Table III columns).
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub intent_acc: f64,
    /// Token-level slot accuracy over non-PAD positions (excluding CLS).
    pub slot_acc: f64,
    pub n: usize,
}

impl<B: TrainBackend> Trainer<B> {
    pub fn new(backend: B, lr: f32) -> Trainer<B> {
        Trainer { backend, metrics: Metrics::default(), lr }
    }

    /// One pass over (a prefix of) the dataset; returns mean loss.
    pub fn train_epoch(&mut self, data: &Dataset, limit: Option<usize>) -> Result<f32> {
        let n = limit.unwrap_or(data.len()).min(data.len());
        let mut total = 0.0f32;
        for ex in data.examples.iter().take(n) {
            let out = self
                .backend
                .train_step(&ex.tokens, &[ex.intent], &ex.slots, self.lr)?;
            self.metrics
                .record_step(out.loss, out.execute_secs, out.host_secs);
            total += out.loss;
        }
        Ok(total / n.max(1) as f32)
    }

    /// Train for a fixed number of steps, cycling the dataset and
    /// continuing from wherever previous step-driven calls stopped (the
    /// cursor is the metrics' global step count, so chunked progress
    /// loops advance through the split instead of retraining its head).
    /// Returns the running mean loss over these steps (0.0 for zero
    /// steps, like [`Trainer::train_epoch`] on an empty prefix).
    pub fn train_steps(&mut self, data: &Dataset, steps: usize) -> Result<f32> {
        if steps > 0 && data.is_empty() {
            return Err(anyhow!("train_steps: dataset is empty"));
        }
        let mut total = 0.0f32;
        for _ in 0..steps {
            let ex = &data.examples[self.metrics.steps % data.len()];
            let out = self
                .backend
                .train_step(&ex.tokens, &[ex.intent], &ex.slots, self.lr)?;
            self.metrics
                .record_step(out.loss, out.execute_secs, out.host_secs);
            total += out.loss;
        }
        Ok(total / steps.max(1) as f32)
    }

    /// Joint intent/slot accuracy on (a prefix of) a dataset.
    pub fn evaluate(&self, data: &Dataset, limit: Option<usize>) -> Result<EvalResult> {
        let cfg = self.backend.config().clone();
        let n = limit.unwrap_or(data.len()).min(data.len());
        let mut intent_hits = 0usize;
        let mut slot_hits = 0usize;
        let mut slot_total = 0usize;
        for ex in data.examples.iter().take(n) {
            let (intent_logits, slot_logits) = self.backend.eval(&ex.tokens)?;
            if argmax(&intent_logits) == ex.intent as usize {
                intent_hits += 1;
            }
            // slot_logits: (S, n_slots) row-major (batch 1).
            for pos in 1..cfg.seq_len {
                if ex.tokens[pos] == cfg.pad_id {
                    continue;
                }
                let row = &slot_logits[pos * cfg.n_slots..(pos + 1) * cfg.n_slots];
                if argmax(row) == ex.slots[pos] as usize {
                    slot_hits += 1;
                }
                slot_total += 1;
            }
        }
        Ok(EvalResult {
            intent_acc: intent_hits as f64 / n.max(1) as f64,
            slot_acc: slot_hits as f64 / slot_total.max(1) as f64,
            n,
        })
    }
}
