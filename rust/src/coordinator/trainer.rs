//! Training coordinator: the L3 analogue of the paper's accelerator
//! control flow (Fig. 8) — it owns the FP -> BP -> PU stage loop, feeds
//! mini-batches, tracks metrics and checkpoints.
//!
//! The coordinator is generic over [`TrainBackend`]: the three training
//! stages either run as a single fused PJRT executable
//! (`<variant>_train.hlo.txt`, exactly like the paper fuses them into one
//! fabric pass) or natively in rust via [`crate::train::NativeTrainer`];
//! the coordinator sequences batches and epochs around either engine.
//!
//! Mini-batching is a coordinator concern: examples are packed into
//! `(B, S)` row-major blocks before the backend step (the native
//! trainer widens the contraction K dimension to `B * S`; the PJRT
//! engine takes whatever batch its artifact was compiled for —
//! [`TrainBackend::supports_batch`] arbitrates).

use super::backend::TrainBackend;
use super::metrics::{argmax, Metrics};
use crate::config::TrainConfig;
use crate::data::{Dataset, Example};
use anyhow::{anyhow, Result};
use std::time::Instant;

/// Epoch-level training driver over any [`TrainBackend`].
pub struct Trainer<B: TrainBackend> {
    pub backend: B,
    pub metrics: Metrics,
    pub lr: f32,
    /// Mini-batch size used by [`Trainer::train_epoch`] /
    /// [`Trainer::train_steps`] (the final batch of an epoch may be
    /// smaller).
    pub batch_size: usize,
    /// Example cursor for step-driven training: chunked
    /// [`Trainer::train_steps`] calls continue through the split instead
    /// of retraining its head.
    cursor: usize,
}

/// Joint evaluation result (paper Table III columns).
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub intent_acc: f64,
    /// Token-level slot accuracy over non-PAD positions (excluding CLS).
    pub slot_acc: f64,
    pub n: usize,
}

impl<B: TrainBackend> Trainer<B> {
    pub fn new(backend: B, lr: f32) -> Trainer<B> {
        Trainer::with_batch(backend, lr, 1)
    }

    /// Trainer with an explicit mini-batch size.
    pub fn with_batch(backend: B, lr: f32, batch_size: usize) -> Trainer<B> {
        Trainer {
            backend,
            metrics: Metrics::default(),
            lr,
            batch_size: batch_size.max(1),
            cursor: 0,
        }
    }

    /// Evaluation-only construction: no learning rate to pick — the
    /// (unused) step hypers come from [`TrainConfig::default`], the
    /// single source of truth for training fallbacks.
    pub fn evaluator(backend: B) -> Trainer<B> {
        Trainer::new(backend, TrainConfig::default().lr)
    }

    /// Pack a batch of examples into `(B, S)` blocks and run one
    /// backend step.  Returns the step's (batch-mean) loss.
    fn step_batch(&mut self, batch: &[&Example]) -> Result<f32> {
        let b = batch.len();
        if !self.backend.supports_batch(b) {
            return Err(anyhow!(
                "backend '{}' does not support batch size {b} (compiled batch: {})",
                self.backend.backend_name(),
                self.backend.config().batch
            ));
        }
        let s = self.backend.config().seq_len;
        let t0 = Instant::now();
        let mut tokens = Vec::with_capacity(b * s);
        let mut intents = Vec::with_capacity(b);
        let mut slots = Vec::with_capacity(b * s);
        for ex in batch {
            tokens.extend_from_slice(&ex.tokens);
            intents.push(ex.intent);
            slots.extend_from_slice(&ex.slots);
        }
        let pack_secs = t0.elapsed().as_secs_f64();
        let out = self
            .backend
            .train_step(&tokens, &intents, &slots, self.lr)?;
        self.metrics
            .record_step(out.loss, out.execute_secs, out.host_secs + pack_secs, b * s);
        Ok(out.loss)
    }

    /// One pass over (a prefix of) the dataset in `batch_size` blocks;
    /// returns the per-example mean loss and records the epoch's
    /// wall-clock in the metrics.  A final partial block that the
    /// backend cannot take (fixed-batch PJRT artifacts) is dropped, like
    /// a drop-remainder data loader — not an error mid-epoch.
    pub fn train_epoch(&mut self, data: &Dataset, limit: Option<usize>) -> Result<f32> {
        let n = limit.unwrap_or(data.len()).min(data.len());
        let t0 = Instant::now();
        let mut total = 0.0f32;
        let mut seen = 0usize;
        for batch in data.examples[..n].chunks(self.batch_size) {
            if batch.len() < self.batch_size && !self.backend.supports_batch(batch.len()) {
                break; // drop the remainder for fixed-batch backends
            }
            let refs: Vec<&Example> = batch.iter().collect();
            let loss = self.step_batch(&refs)?;
            total += loss * batch.len() as f32;
            seen += batch.len();
        }
        if seen == 0 && n > 0 {
            // Every chunk was an unsupported partial batch: failing loud
            // beats reporting a 0.0-loss epoch that trained nothing.
            return Err(anyhow!(
                "train_epoch: {n} examples cannot fill one batch of {} for backend '{}'",
                self.batch_size,
                self.backend.backend_name()
            ));
        }
        self.metrics.record_epoch_secs(t0.elapsed().as_secs_f64());
        Ok(total / seen.max(1) as f32)
    }

    /// Train for a fixed number of optimizer steps, cycling the dataset
    /// in `batch_size` blocks and continuing from wherever previous
    /// step-driven calls stopped.  Returns the running mean loss over
    /// these steps (0.0 for zero steps, like [`Trainer::train_epoch`] on
    /// an empty prefix).
    pub fn train_steps(&mut self, data: &Dataset, steps: usize) -> Result<f32> {
        if steps > 0 && data.is_empty() {
            return Err(anyhow!("train_steps: dataset is empty"));
        }
        let mut total = 0.0f32;
        for _ in 0..steps {
            let refs: Vec<&Example> = (0..self.batch_size)
                .map(|j| &data.examples[(self.cursor + j) % data.len()])
                .collect();
            self.cursor = (self.cursor + self.batch_size) % data.len();
            total += self.step_batch(&refs)?;
        }
        Ok(total / steps.max(1) as f32)
    }

    /// Joint intent/slot accuracy on (a prefix of) a dataset.
    pub fn evaluate(&self, data: &Dataset, limit: Option<usize>) -> Result<EvalResult> {
        let cfg = self.backend.config().clone();
        let n = limit.unwrap_or(data.len()).min(data.len());
        let mut intent_hits = 0usize;
        let mut slot_hits = 0usize;
        let mut slot_total = 0usize;
        for ex in data.examples.iter().take(n) {
            let (intent_logits, slot_logits) = self.backend.eval(&ex.tokens)?;
            if argmax(&intent_logits) == ex.intent as usize {
                intent_hits += 1;
            }
            // slot_logits: (S, n_slots) row-major (batch 1).
            for pos in 1..cfg.seq_len {
                if ex.tokens[pos] == cfg.pad_id {
                    continue;
                }
                let row = &slot_logits[pos * cfg.n_slots..(pos + 1) * cfg.n_slots];
                if argmax(row) == ex.slots[pos] as usize {
                    slot_hits += 1;
                }
                slot_total += 1;
            }
        }
        Ok(EvalResult {
            intent_acc: intent_hits as f64 / n.max(1) as f64,
            slot_acc: slot_hits as f64 / slot_total.max(1) as f64,
            n,
        })
    }
}
