//! The shared batched forward engine — single source of truth for the
//! `(B, S)` inference pass (TTM embedding → fused-QKV encoder stack →
//! pooler → intent/slot heads).
//!
//! Three consumers run this exact computation:
//!
//! * **training** ([`crate::train::NativeTrainModel`]) layers activation
//!   caching and the hand-derived backward on top of the same blocks
//!   ([`crate::train::layers`], [`crate::tensor::ops`]); its `eval` is
//!   pinned bitwise-equal to [`NativeEngine::forward`] by parity tests;
//! * **single-example predict** (the deployment path of
//!   `examples/serve_native.rs` and the paper's on-device setting);
//! * **the serving scheduler** ([`crate::serve`]), which coalesces
//!   concurrent requests into dynamic micro-batches and needs one dense
//!   `(B, S')` forward per bucket.
//!
//! The engine honors the same two knobs as training:
//!
//! * [`ComputePath`] — fused QKV (one shared input-side merge and one
//!   `Z2 = X Z1ᵀ` across Q/K/V when the input cores are tied) and
//!   batched attention vs the looped reference schedule;
//! * [`Precision`] — weights at rest and every intermediate that
//!   training would *store* are rounded at the same program points
//!   (round-to-nearest-even to bf16/f16), so half-precision serving
//!   reproduces the training forward bit-for-bit.
//!
//! **Bitwise parity by construction.**  Training's
//! [`crate::train::layers::TTLinear::forward_ckpt`] computes
//! `xq = round(x)`, merges the chains with round-on-store
//! (`merge_{left,right}_chain_prec`), rounds `Z2 = xq Z1ᵀ`, and emits
//! `Y = Z2 Z3ᵀ + b` unrounded.  [`MergedLinear`] keeps only the *final*
//! chain states (Z3, Z1) — which are exactly the values training folds
//! through — and mirrors the same rounding points, so its outputs are
//! bitwise identical at every [`Precision`] and both [`ComputePath`]s.
//! Merging happens once at load (the accelerator's on-chip core
//! buffers); per-request work is the two K-wide applies of Eq. 20
//! without the Eq. 21 cache charge
//! ([`crate::costmodel::LinearShape::btt_serve_muls`]).
//!
//! **Variable sequence length.**  [`NativeEngine::forward_len`] runs the
//! stack at any `S' ≤ S`: every op is per-row except attention, where
//! pad keys receive an exact-zero probability (additive `-inf` bias), so
//! trimming trailing pads is value-preserving — the serving layer
//! buckets requests by padded length to keep the `bmm*` kernels dense
//! without changing any prediction.

use crate::config::ModelConfig;
use crate::coordinator::metrics::argmax;
use crate::tensor::{ops, PackedTensor, PackedVec, Precision, Tensor, TTMEmbedding, TTMatrix};
use crate::train::{blocks, layers};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap};

/// Flat parameter map: manifest name -> (shape, data).  The naming
/// scheme is shared by the AOT manifest (`python/compile/model.py`),
/// native checkpoints and [`crate::train::NativeTrainModel::to_params`].
pub type ParamMap = BTreeMap<String, (Vec<usize>, Vec<f32>)>;

/// Compute-schedule selection for the batched forward (training and
/// serving).  Both knobs default to the fast path; the looped settings
/// reproduce the pre-fusion schedule for parity tests and benchmark
/// baselines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ComputePath {
    /// Share the input-side merge chain and `Z2` across Q/K/V
    /// ([`crate::train::layers::forward_qkv_fused`]).  Applies per
    /// layer, only where the input cores are tied — untied checkpoints
    /// fall back to three separate forwards automatically.
    pub fused_qkv: bool,
    /// Run attention as one batched `(B, heads, S, S)` block instead of
    /// `B` per-example calls.
    pub batched_attention: bool,
    /// Fuse the elementwise tail of each encoder block — bias add,
    /// residual add and LayerNorm (resp. bias add + GELU) — into the
    /// adjacent TT-apply output loop
    /// ([`crate::train::blocks::bias_residual_layer_norm_fwd`],
    /// [`crate::tensor::ops::bias_gelu`]), so the post-bias and
    /// post-residual intermediates never round-trip through memory.
    /// Bitwise identical to the unfused chain at every precision (the
    /// fused lanes execute the same scalar sequence).
    pub fused_elementwise: bool,
}

impl Default for ComputePath {
    fn default() -> Self {
        ComputePath { fused_qkv: true, batched_attention: true, fused_elementwise: true }
    }
}

impl ComputePath {
    /// The fast path (default): fused QKV + batched attention + fused
    /// elementwise lanes.
    pub fn fused() -> ComputePath {
        ComputePath::default()
    }

    /// The pre-fusion reference schedule: three separate TT forwards,
    /// a per-example attention loop, and materialized elementwise
    /// intermediates.
    pub fn looped() -> ComputePath {
        ComputePath { fused_qkv: false, batched_attention: false, fused_elementwise: false }
    }
}

/// Key mask (1.0 = keep, 0.0 = pad) for a token block — the single
/// definition shared by training and the engine.
pub fn pad_mask(tokens: &[i32], pad_id: i32) -> Vec<f32> {
    tokens
        .iter()
        .map(|&t| if t == pad_id { 0.0 } else { 1.0 })
        .collect()
}

/// A TT linear layer with pre-merged BTT factors — the final states of
/// the round-on-store merge chains training folds through, cached once
/// at load like the accelerator's on-chip core buffers.
pub struct MergedLinear {
    /// Z3 (M, r_d) — merged output-mode cores (left chain tail),
    /// packed at the engine's storage width (u16-backed under bf16/f16;
    /// the chain rounds every state on store, so packing is lossless).
    z3: PackedTensor,
    /// Z1 (r_d, N) — merged input-mode cores (right chain tail), packed
    /// like Z3.
    z1: PackedTensor,
    bias: PackedVec,
}

impl MergedLinear {
    /// Merge a TT matrix at storage precision `prec`: the chains are
    /// folded with round-on-store (`merge_*_chain_prec`), exactly as
    /// the training forward builds them, and only the final states are
    /// retained — **packed** at `prec`, so the at-rest factors occupy
    /// half the bytes under a half width.  Widening on load is exact
    /// (the states are rounded at `prec`), so outputs stay bitwise
    /// identical to the f32-resident representation.
    pub fn from_tt_prec(tt: &TTMatrix, bias: Vec<f32>, prec: Precision) -> Result<MergedLinear> {
        let z3 = tt.merge_left_chain_prec(prec)?.pop().expect("d >= 1");
        let z1 = tt.merge_right_chain_prec(prec)?.pop().expect("d >= 1");
        Ok(MergedLinear {
            z3: PackedTensor::pack_owned(z3, prec),
            z1: PackedTensor::pack_owned(z1, prec),
            bias: PackedVec::from_f32(prec, &bias),
        })
    }

    /// Shared intermediate `Z2 = Xq Z1ᵀ (K, r_d)`, rounded on store —
    /// the same program point as training's `build_btt_states`.
    /// `xq` must already be rounded to `prec` (rounding is idempotent).
    fn z2_from(&self, xq: &Tensor, prec: Precision) -> Result<Tensor> {
        Ok(prec.round_tensor_owned(xq.matmul(&self.z1.view().t()?)?))
    }

    /// Raw output apply `Y = Z2 Z3ᵀ (K, M)` without the bias row —
    /// feeds the fused elementwise lanes, which add the bias inside
    /// their own output loop.
    fn apply_z2_raw(&self, z2: &Tensor) -> Result<Tensor> {
        z2.matmul(&self.z3.view().t()?)
    }

    /// Output apply `Y = Z2 Z3ᵀ + b (K, M)` — unrounded, as in
    /// training.
    fn apply_z2(&self, z2: &Tensor) -> Result<Tensor> {
        Ok(ops::add_row(&self.apply_z2_raw(z2)?, &self.bias.view()))
    }

    /// `y = W x + b` with x as rows: (K, N) -> (K, M), through the
    /// rounded Z2 — bitwise the training forward's output.
    pub fn apply(&self, x: &Tensor, prec: Precision) -> Result<Tensor> {
        let xq = prec.round_tensor(x);
        self.apply_z2(&self.z2_from(&xq, prec)?)
    }

    /// `y = W x` (no bias) for the fused elementwise lanes.
    fn apply_raw(&self, x: &Tensor, prec: Precision) -> Result<Tensor> {
        let xq = prec.round_tensor(x);
        self.apply_z2_raw(&self.z2_from(&xq, prec)?)
    }

    /// Measured at-rest bytes of the packed merged factors + bias.
    pub fn bytes(&self) -> u64 {
        self.z3.bytes() + self.z1.bytes() + self.bias.bytes()
    }
}

/// One encoder block with pre-merged projections.
struct EngineLayer {
    wq: MergedLinear,
    wk: MergedLinear,
    wv: MergedLinear,
    wo: MergedLinear,
    w1: MergedLinear,
    w2: MergedLinear,
    ln1_g: PackedVec,
    ln1_b: PackedVec,
    ln2_g: PackedVec,
    ln2_b: PackedVec,
    /// Input-side cores bitwise tied across Q/K/V at load time — the
    /// precondition of the fused schedule, checked once here instead of
    /// per forward.
    qkv_tied: bool,
}

/// The shared batched inference engine: parameters assembled from a
/// flat name->array map, merged once, then served read-only (the
/// struct is `Send + Sync`; the scheduler shares it across threads via
/// `Arc`).
pub struct NativeEngine {
    pub cfg: ModelConfig,
    /// Compute-schedule selection (fused/batched by default).
    pub compute_path: ComputePath,
    /// Storage precision the merges and intermediates are rounded to
    /// (f32 default = bitwise full precision).
    pub precision: Precision,
    embedding: TTMEmbedding,
    pos: PackedTensor, // (S, H)
    layers: Vec<EngineLayer>,
    pool: MergedLinear,
    intent_w: PackedTensor, // (n_intents, H)
    intent_b: PackedVec,
    slot_w: PackedTensor, // (n_slots, H)
    slot_b: PackedVec,
}

impl NativeEngine {
    /// Assemble from named parameters at full precision with the
    /// default (fused) compute path — the drop-in replacement for the
    /// retired single-example `inference::NativeModel`.
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeEngine> {
        NativeEngine::from_params_with(cfg, params, ComputePath::default(), Precision::F32)
    }

    /// Assemble from named parameters under an explicit compute path
    /// and storage precision.  Under a half precision the raw
    /// parameters are rounded at rest first (idempotent for
    /// checkpoints trained at that precision — training's
    /// `set_precision` stores rounded weights, so `to_params` round
    /// trips bitwise), then the merge chains fold with round-on-store.
    pub fn from_params_with(
        cfg: &ModelConfig,
        params: &ParamMap,
        compute_path: ComputePath,
        precision: Precision,
    ) -> Result<NativeEngine> {
        let get = |name: &str| -> Result<(&Vec<usize>, &Vec<f32>)> {
            params
                .get(name)
                .map(|(s, d)| (s, d))
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))
        };
        let quant = |mut v: Vec<f32>| -> Vec<f32> {
            if precision.is_half() {
                precision.round_slice_in_place(&mut v);
            }
            v
        };
        let tensor = |name: &str| -> Result<Tensor> {
            let (shape, data) = get(name)?;
            Tensor::from_vec(quant(data.clone()), shape)
        };
        let vec1 = |name: &str| -> Result<Vec<f32>> { Ok(quant(get(name)?.1.clone())) };

        // TTM embedding cores.
        let d = cfg.ttm_vocab_modes.len();
        let mut ttm_cores = Vec::with_capacity(d);
        for k in 0..d {
            ttm_cores.push(tensor(&format!("embed.ttm.{k}"))?);
        }
        let mut ranks = vec![cfg.ttm_rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        let embedding = TTMEmbedding {
            cores: ttm_cores
                .into_iter()
                .map(|t| PackedTensor::pack_owned(t, precision))
                .collect(),
            hid_modes: cfg.ttm_hid_modes.clone(),
            vocab_modes: cfg.ttm_vocab_modes.clone(),
            ranks,
        };

        // Raw TT matrices first (the fused-schedule tie check compares
        // cores, which the merges destroy), then merge.
        let tt_matrix = |prefix: &str| -> Result<TTMatrix> {
            let d2 = cfg.tt_m.len() + cfg.tt_n.len();
            let mut cores = Vec::with_capacity(d2);
            for k in 0..d2 {
                cores.push(tensor(&format!("{prefix}.cores.{k}"))?);
            }
            Ok(TTMatrix {
                cores,
                m_modes: cfg.tt_m.clone(),
                n_modes: cfg.tt_n.clone(),
                ranks: cfg.tt_ranks(),
            })
        };
        let merged = |prefix: &str, tt: &TTMatrix| -> Result<MergedLinear> {
            MergedLinear::from_tt_prec(tt, vec1(&format!("{prefix}.bias"))?, precision)
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |name: &str| format!("layers.{i}.{name}");
            let q_tt = tt_matrix(&p("wq"))?;
            let k_tt = tt_matrix(&p("wk"))?;
            let v_tt = tt_matrix(&p("wv"))?;
            let qkv_tied = layers::tt_input_cores_tied(&q_tt, &k_tt, &v_tt);
            layers.push(EngineLayer {
                wq: merged(&p("wq"), &q_tt)?,
                wk: merged(&p("wk"), &k_tt)?,
                wv: merged(&p("wv"), &v_tt)?,
                wo: merged(&p("wo"), &tt_matrix(&p("wo"))?)?,
                w1: merged(&p("w1"), &tt_matrix(&p("w1"))?)?,
                w2: merged(&p("w2"), &tt_matrix(&p("w2"))?)?,
                ln1_g: PackedVec::from_f32(precision, &vec1(&p("ln1.g"))?),
                ln1_b: PackedVec::from_f32(precision, &vec1(&p("ln1.b"))?),
                ln2_g: PackedVec::from_f32(precision, &vec1(&p("ln2.g"))?),
                ln2_b: PackedVec::from_f32(precision, &vec1(&p("ln2.b"))?),
                qkv_tied,
            });
        }

        Ok(NativeEngine {
            cfg: cfg.clone(),
            compute_path,
            precision,
            embedding,
            pos: PackedTensor::pack_owned(tensor("embed.pos")?, precision),
            layers,
            pool: merged("cls.pool", &tt_matrix("cls.pool")?)?,
            intent_w: PackedTensor::pack_owned(tensor("cls.intent_w")?, precision),
            intent_b: PackedVec::from_f32(precision, &vec1("cls.intent_b")?),
            slot_w: PackedTensor::pack_owned(tensor("cls.slot_w")?, precision),
            slot_b: PackedVec::from_f32(precision, &vec1("cls.slot_b")?),
        })
    }

    /// **Measured** at-rest parameter bytes of the serving engine: the
    /// summed sizes of the actual packed buffers (TTM cores, positional
    /// table, merged Z3/Z1 factors, biases, LN and classifier tables) —
    /// u16-backed under a half storage width, f32 otherwise.
    pub fn param_bytes(&self) -> u64 {
        let mut total = self.embedding.bytes() + self.pos.bytes();
        for layer in &self.layers {
            for lin in [
                &layer.wq, &layer.wk, &layer.wv, &layer.wo, &layer.w1, &layer.w2,
            ] {
                total += lin.bytes();
            }
            total += layer.ln1_g.bytes()
                + layer.ln1_b.bytes()
                + layer.ln2_g.bytes()
                + layer.ln2_b.bytes();
        }
        total
            + self.pool.bytes()
            + self.intent_w.bytes()
            + self.intent_b.bytes()
            + self.slot_w.bytes()
            + self.slot_b.bytes()
    }

    /// Batched forward over a `(B, S)` token block (row-major, full
    /// configured sequence length).  Returns `(intent_logits
    /// (B*n_intents), slot_logits (B*S*n_slots))` row-major — the same
    /// contract as [`crate::train::NativeTrainModel::eval`], to which
    /// it is bitwise identical.
    pub fn forward(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.forward_len(tokens, self.cfg.seq_len)
    }

    /// Batched forward over a `(B, S')` token block at an explicit
    /// padded length `1 <= S' <= S`.  Positional rows `0..S'` are a
    /// prefix of the fixed table and pad keys carry exact-zero
    /// attention probability, so a request padded to a shorter bucket
    /// produces the same logits for its valid positions as the full-S
    /// padding — this is what lets the serving scheduler bucket by
    /// length and keep the `bmm*` kernels dense.
    pub fn forward_len(&self, tokens: &[i32], seq: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let _sp = crate::trace::span("engine", "forward");
        let cfg = &self.cfg;
        let h = cfg.d_hid;
        if seq == 0 || seq > cfg.seq_len {
            return Err(anyhow!(
                "padded length {seq} out of range 1..={}",
                cfg.seq_len
            ));
        }
        if tokens.is_empty() || tokens.len() % seq != 0 {
            return Err(anyhow!(
                "tokens must be (B, {seq}) row-major, got {} ids",
                tokens.len()
            ));
        }
        let b = tokens.len() / seq;
        let k_rows = b * seq;
        let prec = self.precision;
        let mask = pad_mask(tokens, cfg.pad_id);

        // Embedding: TTM lookup memoized per unique token id (the
        // round-on-store chain's final state is the embedding row) +
        // positional table per slot.
        let pos = self.pos.view();
        let mut x = Tensor::zeros(&[k_rows, h]);
        let mut rows: HashMap<i32, Vec<f32>> = HashMap::new();
        for (i, &t) in tokens.iter().enumerate() {
            if !rows.contains_key(&t) {
                let (_, states) = self.embedding.lookup_cached_prec(t as usize, prec)?;
                rows.insert(t, states.into_iter().last().expect("nonempty").data);
            }
            let row = &rows[&t];
            let p = i % seq;
            for j in 0..h {
                x.data[i * h + j] = row[j] + pos.at2(p, j);
            }
        }

        let bias = ops::attention_bias_from_mask(&mask);
        for layer in &self.layers {
            // QKV projections: fused schedule (one rounded Z2 shared by
            // the three output applies) when selected and tied, else
            // three separate applies — both bitwise the training paths.
            let (q, k, v) = if self.compute_path.fused_qkv && layer.qkv_tied {
                let xq = prec.round_tensor(&x);
                let z2 = layer.wq.z2_from(&xq, prec)?;
                (
                    layer.wq.apply_z2(&z2)?,
                    layer.wk.apply_z2(&z2)?,
                    layer.wv.apply_z2(&z2)?,
                )
            } else {
                (
                    layer.wq.apply(&x, prec)?,
                    layer.wk.apply(&x, prec)?,
                    layer.wv.apply(&x, prec)?,
                )
            };
            // Attention never mixes examples: one batched
            // (B, heads, S', S') block or the looped per-example
            // reference, per the selected path.
            let ctx = if self.compute_path.batched_attention {
                ops::multi_head_attention_batched(&q, &k, &v, &bias, cfg.n_heads, b)?.0
            } else {
                let mut ctx = Tensor::zeros(&[k_rows, h]);
                for e in 0..b {
                    let slice = |t: &Tensor| -> Result<Tensor> {
                        Tensor::from_vec(t.data[e * seq * h..(e + 1) * seq * h].to_vec(), &[seq, h])
                    };
                    let (ctx_e, _) = ops::multi_head_attention(
                        &slice(&q)?,
                        &slice(&k)?,
                        &slice(&v)?,
                        &mask[e * seq..(e + 1) * seq],
                        cfg.n_heads,
                    )?;
                    ctx.data[e * seq * h..(e + 1) * seq * h].copy_from_slice(&ctx_e.data);
                }
                ctx
            };
            // Elementwise tail: fused lanes (bias + residual + LN and
            // bias + GELU in one output pass) or the materialized
            // reference — the same shared block entry points as
            // training, so bits are identical either way.
            x = if self.compute_path.fused_elementwise {
                let o_raw = layer.wo.apply_raw(&ctx, prec)?;
                let (x1, _) = blocks::bias_residual_layer_norm_fwd(
                    &o_raw,
                    &layer.wo.bias.view(),
                    &x,
                    &layer.ln1_g.view(),
                    &layer.ln1_b.view(),
                    1e-5,
                );
                let h1_raw = layer.w1.apply_raw(&x1, prec)?;
                let (_h1, g1) = ops::bias_gelu(&h1_raw, &layer.w1.bias.view());
                let ffn_raw = layer.w2.apply_raw(&g1, prec)?;
                let (x2, _) = blocks::bias_residual_layer_norm_fwd(
                    &ffn_raw,
                    &layer.w2.bias.view(),
                    &x1,
                    &layer.ln2_g.view(),
                    &layer.ln2_b.view(),
                    1e-5,
                );
                x2
            } else {
                let o = layer.wo.apply(&ctx, prec)?;
                let (x1, _) = blocks::layer_norm_fwd(
                    &ops::add(&x, &o),
                    &layer.ln1_g.view(),
                    &layer.ln1_b.view(),
                    1e-5,
                );
                let h1 = layer.w1.apply(&x1, prec)?;
                let ffn = layer.w2.apply(&ops::gelu(&h1), prec)?;
                let (x2, _) = blocks::layer_norm_fwd(
                    &ops::add(&x1, &ffn),
                    &layer.ln2_g.view(),
                    &layer.ln2_b.view(),
                    1e-5,
                );
                x2
            };
        }

        // Classifier: shared TT pooler + heads; per-example CLS rows
        // drive the intent head.
        let pooled = ops::tanh(&self.pool.apply(&x, prec)?);
        let cls = ops::cls_rows(&pooled, b, seq)?;
        let intent = ops::add_row(&cls.matmul(&self.intent_w.view().t()?)?, &self.intent_b.view());
        let slots = ops::add_row(&pooled.matmul(&self.slot_w.view().t()?)?, &self.slot_b.view());
        Ok((intent.data, slots.data))
    }

    /// Greedy predictions `(intent_id, slot_ids)` for one sequence of
    /// `1..=S` token ids (trailing pads may be trimmed — the logits for
    /// the remaining positions are unchanged).
    pub fn predict(&self, tokens: &[i32]) -> Result<(usize, Vec<usize>)> {
        let (il, sl) = self.forward_len(tokens, tokens.len())?;
        let ns = self.cfg.n_slots;
        Ok((argmax(&il), sl.chunks(ns).map(argmax).collect()))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    pub(crate) fn put(
        map: &mut ParamMap,
        rng: &mut SplitMix64,
        name: &str,
        shape: Vec<usize>,
        std: f32,
    ) {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32 * std).collect();
        map.insert(name.to_string(), (shape, data));
    }

    fn put_const(map: &mut ParamMap, name: &str, shape: Vec<usize>, value: f32) {
        let n: usize = shape.iter().product();
        map.insert(name.to_string(), (shape, vec![value; n]));
    }

    fn put_linear(map: &mut ParamMap, rng: &mut SplitMix64, cfg: &ModelConfig, prefix: &str) {
        let modes: Vec<usize> = cfg.tt_m.iter().chain(&cfg.tt_n).copied().collect();
        let ranks = cfg.tt_ranks();
        for k in 0..modes.len() {
            put(
                map,
                rng,
                &format!("{prefix}.cores.{k}"),
                vec![ranks[k], modes[k], ranks[k + 1]],
                0.3,
            );
        }
        put(map, rng, &format!("{prefix}.bias"), vec![cfg.d_hid], 0.01);
    }

    /// Build a random ParamMap at a small config for unit tests.
    pub(crate) fn tiny_params(cfg: &ModelConfig, seed: u64) -> ParamMap {
        let mut rng = SplitMix64::new(seed);
        let mut map = ParamMap::new();
        let d = cfg.ttm_vocab_modes.len();
        let mut rr = vec![cfg.ttm_rank; d + 1];
        rr[0] = 1;
        rr[d] = 1;
        for k in 0..d {
            put(
                &mut map,
                &mut rng,
                &format!("embed.ttm.{k}"),
                vec![rr[k], cfg.ttm_hid_modes[k], cfg.ttm_vocab_modes[k], rr[k + 1]],
                0.25,
            );
        }
        put(&mut map, &mut rng, "embed.pos", vec![cfg.seq_len, cfg.d_hid], 0.02);
        for i in 0..cfg.n_layers {
            for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
                put_linear(&mut map, &mut rng, cfg, &format!("layers.{i}.{w}"));
            }
            put_const(&mut map, &format!("layers.{i}.ln1.g"), vec![cfg.d_hid], 1.0);
            put_const(&mut map, &format!("layers.{i}.ln1.b"), vec![cfg.d_hid], 0.0);
            put_const(&mut map, &format!("layers.{i}.ln2.g"), vec![cfg.d_hid], 1.0);
            put_const(&mut map, &format!("layers.{i}.ln2.b"), vec![cfg.d_hid], 0.0);
        }
        put_linear(&mut map, &mut rng, cfg, "cls.pool");
        put(&mut map, &mut rng, "cls.intent_w", vec![cfg.n_intents, cfg.d_hid], 0.05);
        put_const(&mut map, "cls.intent_b", vec![cfg.n_intents], 0.0);
        put(&mut map, &mut rng, "cls.slot_w", vec![cfg.n_slots, cfg.d_hid], 0.05);
        put_const(&mut map, "cls.slot_b", vec![cfg.n_slots], 0.0);
        map
    }

    pub(crate) fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_layers: 1,
            d_hid: 48,
            n_heads: 4,
            seq_len: 8,
            batch: 1,
            vocab: 27,
            n_intents: 5,
            n_slots: 7,
            tt_m: vec![4, 4, 3],
            tt_n: vec![3, 4, 4],
            tt_rank: 3,
            ttm_vocab_modes: vec![3, 3, 3],
            ttm_hid_modes: vec![4, 4, 3],
            ttm_rank: 4,
            pad_id: 0,
            cls_id: 1,
            unk_id: 2,
        }
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 1)).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let (il, sl) = engine.forward(&tokens).unwrap();
        assert_eq!(il.len(), cfg.n_intents);
        assert_eq!(sl.len(), cfg.seq_len * cfg.n_slots);
        assert!(il.iter().all(|v| v.is_finite()));
        assert!(sl.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_deterministic() {
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 2)).unwrap();
        let tokens = vec![1, 3, 4, 5, 6, 0, 0, 0];
        assert_eq!(engine.forward(&tokens).unwrap(), engine.forward(&tokens).unwrap());
    }

    #[test]
    fn padding_is_inert() {
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 3)).unwrap();
        let tokens = vec![1, 0, 0, 0, 0, 0, 0, 0];
        let (il, _) = engine.forward(&tokens).unwrap();
        assert!(il.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predict_ranges() {
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 4)).unwrap();
        let tokens = vec![1, 7, 8, 2, 11, 0, 0, 0];
        let (intent, slots) = engine.predict(&tokens).unwrap();
        assert!(intent < cfg.n_intents);
        assert_eq!(slots.len(), cfg.seq_len);
        assert!(slots.iter().all(|&s| s < cfg.n_slots));
    }

    #[test]
    fn missing_param_is_reported() {
        let cfg = tiny_cfg();
        let mut p = tiny_params(&cfg, 5);
        p.remove("cls.intent_w");
        let err = match NativeEngine::from_params(&cfg, &p) {
            Err(e) => e,
            Ok(_) => panic!("expected missing-parameter error"),
        };
        assert!(err.to_string().contains("cls.intent_w"));
    }

    #[test]
    fn batched_forward_matches_singles() {
        // A (2, S) block is the per-example forwards concatenated —
        // exactly (the blocked kernels accumulate per output row).
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 6)).unwrap();
        let a = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let b = vec![1, 3, 2, 7, 11, 26, 4, 0];
        let both: Vec<i32> = a.iter().chain(&b).copied().collect();
        let (il, sl) = engine.forward(&both).unwrap();
        let (il_a, sl_a) = engine.forward(&a).unwrap();
        let (il_b, sl_b) = engine.forward(&b).unwrap();
        assert_eq!(il[..cfg.n_intents], il_a[..]);
        assert_eq!(il[cfg.n_intents..], il_b[..]);
        assert_eq!(sl[..cfg.seq_len * cfg.n_slots], sl_a[..]);
        assert_eq!(sl[cfg.seq_len * cfg.n_slots..], sl_b[..]);
    }

    #[test]
    fn trimmed_padding_is_value_preserving() {
        // forward_len at a shorter padded length reproduces the full-S
        // logits for the surviving positions: pad keys carry an exact
        // zero probability, every other op is per-row.
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 7)).unwrap();
        let full = vec![1, 7, 8, 2, 0, 0, 0, 0]; // eff = 4
        let (il_full, sl_full) = engine.forward(&full).unwrap();
        for seq in 4..cfg.seq_len {
            let (il, sl) = engine.forward_len(&full[..seq], seq).unwrap();
            assert_eq!(il, il_full, "intent logits diverge at S'={seq}");
            assert_eq!(
                sl[..],
                sl_full[..seq * cfg.n_slots],
                "slot logits diverge at S'={seq}"
            );
        }
    }

    #[test]
    fn forward_len_rejects_bad_lengths() {
        let cfg = tiny_cfg();
        let engine = NativeEngine::from_params(&cfg, &tiny_params(&cfg, 8)).unwrap();
        assert!(engine.forward_len(&[1, 2, 3], 0).is_err());
        assert!(engine.forward_len(&[1; 9], 9).is_err()); // > seq_len
        assert!(engine.forward_len(&[1, 2, 3], 2).is_err()); // not a multiple
        assert!(engine.forward(&[1; 12]).is_err()); // not a multiple of S
    }

    #[test]
    fn compute_paths_agree_on_untied_params() {
        // Random (untied) parameters: the fused knob falls back to
        // separate applies, and batched vs looped attention is pinned
        // bitwise equal — so every path selection yields identical
        // logits here.
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 9);
        let tokens = vec![1, 5, 9, 13, 2, 0, 0, 0, 1, 3, 4, 0, 0, 0, 0, 0];
        let fused =
            NativeEngine::from_params_with(&cfg, &params, ComputePath::fused(), Precision::F32)
                .unwrap();
        let looped =
            NativeEngine::from_params_with(&cfg, &params, ComputePath::looped(), Precision::F32)
                .unwrap();
        assert_eq!(fused.forward(&tokens).unwrap(), looped.forward(&tokens).unwrap());
    }

    #[test]
    fn half_precision_forward_is_finite_and_deterministic() {
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 10);
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        for prec in [Precision::Bf16, Precision::F16] {
            let engine =
                NativeEngine::from_params_with(&cfg, &params, ComputePath::fused(), prec).unwrap();
            let (il, sl) = engine.forward(&tokens).unwrap();
            assert!(il.iter().chain(&sl).all(|v| v.is_finite()));
            assert_eq!((il, sl), engine.forward(&tokens).unwrap());
        }
    }
}
