//! [`NativeTrainer`]: the rust-native [`TrainBackend`] — end-to-end
//! on-device training with no XLA, no Python and no HLO artifacts.

use super::model::NativeTrainModel;
use crate::config::ModelConfig;
use crate::coordinator::backend::{StepOutput, TrainBackend};
use crate::engine::{NativeEngine, ParamMap};
use crate::optim::{OptimConfig, OptimKind};
use crate::tensor::{ContractionStats, Precision};
use crate::util::npy;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

/// Native training backend over [`NativeTrainModel`].
pub struct NativeTrainer {
    pub model: NativeTrainModel,
    /// Instrumentation of the most recent step (forward Eqs. 20/21 +
    /// backward 2x counts, summed over every TT layer).
    pub last_stats: ContractionStats,
    /// Merged-factor inference engine for eval, built lazily (via
    /// [`NativeTrainModel::engine`], inheriting the model's compute
    /// path and precision) and invalidated whenever parameters — or
    /// the captured schedule/precision — change; evaluation reuses the
    /// merged Z1/Z3 factors instead of re-merging per call.
    eval_model: RefCell<Option<NativeEngine>>,
}

impl NativeTrainer {
    pub fn new(model: NativeTrainModel) -> NativeTrainer {
        NativeTrainer {
            model,
            last_stats: ContractionStats::default(),
            eval_model: RefCell::new(None),
        }
    }

    /// Fresh model with seeded random parameters — training from scratch
    /// requires nothing but a [`ModelConfig`].
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Result<NativeTrainer> {
        Ok(NativeTrainer::new(NativeTrainModel::random_init(cfg, seed)?))
    }

    /// Build from a flat parameter map (e.g. exported from a live PJRT
    /// engine, for cross-backend parity).
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeTrainer> {
        Ok(NativeTrainer::new(NativeTrainModel::from_params(cfg, params)?))
    }

    /// Swap the PU-stage update rule (builder style); existing optimizer
    /// state is dropped.  `set_optim` applies the config's storage
    /// precision model-wide (possibly rounding parameters), so the
    /// cached eval engine is invalidated.
    pub fn with_optim(mut self, cfg: OptimConfig) -> NativeTrainer {
        self.model.set_optim(cfg);
        *self.eval_model.borrow_mut() = None;
        self
    }

    /// Select the compute schedule (builder style): the fused/batched
    /// hot path (default) or the pre-fusion looped reference — the
    /// baseline the `native-train` bench compares against.  The cached
    /// eval engine captures the schedule at build time, so it is
    /// invalidated here.
    pub fn with_compute_path(mut self, path: crate::train::ComputePath) -> NativeTrainer {
        self.model.compute_path = path;
        *self.eval_model.borrow_mut() = None;
        self
    }

    /// Select the storage precision of the mixed-precision path
    /// (builder style): caches, moments and stored parameters at
    /// `prec`, f32 accumulation throughout.  Model and PU-stage
    /// precision always move together (`with_optim` applies its
    /// config's precision the same way), so builder order cannot
    /// desync them — the last precision written wins for both.
    /// Entering a half format rounds the current parameters once, so
    /// the cached eval engine is invalidated.
    pub fn with_precision(mut self, prec: Precision) -> NativeTrainer {
        self.model.set_precision(prec);
        *self.eval_model.borrow_mut() = None;
        self
    }

    /// Select the gradient-checkpointing policy for the Eq. 21 caches
    /// (builder style).  Policy only affects what the forward retains
    /// for the BP stage — parameters and gradients are untouched (f32
    /// gradients are bitwise identical across policies), so the cached
    /// eval engine stays valid.  Like `--precision`, the policy is
    /// applied **before** any `--init-ckpt` load and survives
    /// [`NativeTrainer::load_checkpoint`].
    pub fn with_checkpoint(mut self, policy: crate::train::CheckpointPolicy) -> NativeTrainer {
        self.model.checkpoint = policy;
        self
    }

    /// Drop the cached eval engine.  Required whenever `model`'s
    /// parameters are mutated from outside [`TrainBackend::train_step`]
    /// — e.g. the replica step, which applies reduced gradients via
    /// [`NativeTrainModel::apply_grads`] directly.
    pub fn invalidate_eval_cache(&self) {
        *self.eval_model.borrow_mut() = None;
    }
}

/// Checkpoint-name prefix of optimizer-state entries
/// (`optim.state.<param-name>.<slot>`); parameters never collide with
/// it (the manifest naming scheme has no `optim.` namespace).
const OPTIM_STATE_PREFIX: &str = "optim.state.";
/// Checkpoint entry recording which update rule the state belongs to.
const OPTIM_KIND_ENTRY: &str = "optim.kind";
/// Checkpoint entry holding the dynamic loss-scaler state
/// (`[scale, good_steps]`, [`crate::optim::LossScaler::export`]).
/// Written only when the scaler has moved off its power-on default, so
/// untrained checkpoints keep the historical file set; absence on load
/// means "default scaler", which is exactly what a fresh model holds.
const LOSS_SCALE_ENTRY: &str = "optim.loss_scale";

impl TrainBackend for NativeTrainer {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    /// The native trainer takes any runtime batch size — the contraction
    /// K dimension simply becomes `B * S`.
    fn supports_batch(&self, batch: usize) -> bool {
        batch >= 1
    }

    fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        let _sp = crate::trace::span("step", "train_step");
        let (loss, stats) = self.model.train_step(tokens, intent, slots, lr)?;
        self.last_stats = stats;
        *self.eval_model.borrow_mut() = None; // parameters moved
        Ok(StepOutput {
            loss,
            execute_secs: t0.elapsed().as_secs_f64(),
            host_secs: 0.0,
        })
    }

    /// Inference through the cached merged-factor engine — one batched
    /// `(B, S)` forward (the engine's native contract), bitwise the
    /// training model's own `eval`.
    fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut cached = self.eval_model.borrow_mut();
        if cached.is_none() {
            *cached = Some(self.model.engine()?);
        }
        cached.as_ref().expect("just built").forward(tokens)
    }

    /// One `.npy` per parameter, named `%04d.<name>.npy` in canonical
    /// (sorted-name) order — interchangeable with the PJRT engine's
    /// checkpoints, which are matched by name, not position.  When the
    /// PU stage holds state (momentum / Adam moments), it is appended
    /// as `optim.state.<param>.<slot>` entries plus an `optim.kind`
    /// marker, so `--optimizer adam` training resumes exactly; the
    /// dynamic loss-scaler state rides along as an `optim.loss_scale`
    /// entry once it has moved off its default (guarded-step skips
    /// back it off, good steps advance its growth counter), so a
    /// resumed run keeps the exact overflow-guard posture.  Untrained
    /// plain-SGD checkpoints stay byte-identical to the historical
    /// format.
    fn save_checkpoint(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut next = 0usize;
        let mut write = |name: &str, shape: &[usize], data: &[f32]| -> Result<()> {
            let safe = npy::safe_param_name(name);
            npy::write_npy_f32(&dir.join(format!("{next:04}.{safe}.npy")), data, shape)?;
            next += 1;
            Ok(())
        };
        for (name, (shape, data)) in self.model.to_params().iter() {
            write(name, shape, data)?;
        }
        let state = self.model.optim.export_state();
        if !state.is_empty() {
            let kind_code = self.model.optim.cfg.kind.code() as f32;
            write(OPTIM_KIND_ENTRY, &[1], &[kind_code])?;
            for (key, vals) in &state {
                write(&format!("{OPTIM_STATE_PREFIX}{key}"), &[vals.len()], vals)?;
            }
        }
        if self.model.scaler != crate::optim::LossScaler::new() {
            let scaler = self.model.scaler.export();
            write(LOSS_SCALE_ENTRY, &[scaler.len()], &scaler)?;
        }
        Ok(())
    }

    /// Rebuild the model from a checkpoint directory, keyed by each
    /// file's embedded parameter name (a renamed file is an error, not a
    /// silent mix-up).  Optimizer-state entries are restored into the
    /// PU stage when their `optim.kind` matches the configured rule
    /// (exact training resume); state from a *different* rule — or a
    /// parameter-only checkpoint, e.g. a PJRT export — starts the
    /// configured rule fresh.
    fn load_checkpoint(&mut self, dir: &Path) -> Result<()> {
        let mut params = ParamMap::new();
        let mut optim_entries: Vec<(String, Vec<f32>)> = Vec::new();
        let mut optim_kind: Option<u32> = None;
        let mut loss_scale: Option<Vec<f32>> = None;
        for (name, path) in npy::checkpoint_entries(dir)? {
            let (shape, data) = npy::read_npy_f32(&path)?;
            if name == OPTIM_KIND_ENTRY {
                optim_kind = data.first().map(|&c| c as u32);
                continue;
            }
            if name == LOSS_SCALE_ENTRY {
                loss_scale = Some(data);
                continue;
            }
            if let Some(key) = name.strip_prefix(OPTIM_STATE_PREFIX) {
                optim_entries.push((key.to_string(), data));
                continue;
            }
            if params.insert(name.clone(), (shape, data)).is_some() {
                return Err(anyhow!("duplicate parameter '{name}' in checkpoint {dir:?}"));
            }
        }
        let optim_cfg = self.model.optim.cfg.clone();
        let compute_path = self.model.compute_path;
        let checkpoint = self.model.checkpoint.clone();
        self.model = NativeTrainModel::from_params(&self.model.cfg, &params)?;
        // from_params builds with default schedule/precision/policy:
        // restore the trainer's configured compute path and
        // checkpointing policy (so `--checkpoint recompute` composes
        // with `--init-ckpt`, like the `--precision` ordering), and
        // re-apply the storage path via set_optim (which syncs the
        // precision and rounds the loaded parameters — idempotent for
        // checkpoints trained at this precision).
        self.model.compute_path = compute_path;
        self.model.checkpoint = checkpoint;
        self.model.set_optim(optim_cfg.clone());
        if optim_kind.and_then(OptimKind::from_code) == Some(optim_cfg.kind)
            && !optim_entries.is_empty()
        {
            // Name + length + completeness verification before touching
            // the PU stage: every state entry must key a real
            // parameter, moment buffers must match that parameter's
            // element count, and each restored parameter must carry the
            // rule's *full* slot set — a truncated, mis-keyed or
            // partially-deleted state is a load-time error, never a
            // half-restored slot that aborts mid-training.
            let mut slots_by_param: std::collections::BTreeMap<&str, Vec<&str>> =
                std::collections::BTreeMap::new();
            for (key, vals) in &optim_entries {
                let (pname, slot) = key.rsplit_once('.').ok_or_else(|| {
                    anyhow!("malformed optimizer-state entry 'optim.state.{key}'")
                })?;
                let (_, data) = params.get(pname).ok_or_else(|| {
                    anyhow!("optimizer state for unknown parameter '{pname}' in {dir:?}")
                })?;
                if slot != "t" && vals.len() != data.len() {
                    return Err(anyhow!(
                        "optimizer state '{key}' has {} elements, parameter has {}",
                        vals.len(),
                        data.len()
                    ));
                }
                slots_by_param.entry(pname).or_default().push(slot);
            }
            let expected: &[&str] = match optim_cfg.kind {
                OptimKind::Sgd => &[],
                OptimKind::Momentum => &["v"],
                OptimKind::Adam | OptimKind::AdamW => &["m", "t", "v"],
            };
            for (pname, mut slots) in slots_by_param {
                slots.sort_unstable();
                if slots != expected {
                    return Err(anyhow!(
                        "optimizer state for '{pname}' has slots {slots:?}, \
                         expected {expected:?} for {}",
                        optim_cfg.kind.name()
                    ));
                }
            }
            self.model.optim.import_state(&optim_entries)?;
        }
        // from_params starts the scaler at its default; a checkpointed
        // entry restores the exact overflow-guard posture.
        if let Some(vals) = loss_scale {
            self.model.scaler.import(&vals)?;
        }
        *self.eval_model.borrow_mut() = None; // parameters replaced
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::model::tests::tiny_cfg;

    #[test]
    fn checkpoint_roundtrip_preserves_params() {
        let cfg = tiny_cfg();
        let mut t = NativeTrainer::random_init(&cfg, 31).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let slots = vec![0i32; 8];
        t.train_step(&tokens, &[1], &slots, 0.01).unwrap();
        let before = t.eval(&tokens).unwrap();

        let dir = std::env::temp_dir().join(format!("native_ckpt_{}", std::process::id()));
        t.save_checkpoint(&dir).unwrap();
        // Perturb, then restore.
        t.train_step(&tokens, &[1], &slots, 0.5).unwrap();
        assert_ne!(t.eval(&tokens).unwrap(), before);
        t.load_checkpoint(&dir).unwrap();
        assert_eq!(t.eval(&tokens).unwrap(), before);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn optimizer_state_checkpoint_resumes_adam_exactly() {
        // Train A for 3 Adam steps, checkpoint (params + moments + step
        // count), restore into a fresh trainer B: the next steps of A
        // and B must stay bitwise identical — exact training resume.
        use crate::optim::OptimKind;
        let cfg = tiny_cfg();
        let tokens = vec![1, 5, 9, 13, 4, 0, 0, 0];
        let slots = vec![0, 1, 2, 3, 1, 0, 0, 0];
        let adam = OptimConfig { kind: OptimKind::Adam, ..Default::default() };
        let mut a = NativeTrainer::random_init(&cfg, 33).unwrap().with_optim(adam.clone());
        for _ in 0..3 {
            a.train_step(&tokens, &[2], &slots, 1e-2).unwrap();
        }
        let dir = std::env::temp_dir().join(format!("native_ckpt_opt_{}", std::process::id()));
        a.save_checkpoint(&dir).unwrap();
        // Different seed on purpose: everything must come from the ckpt.
        let mut b = NativeTrainer::random_init(&cfg, 99).unwrap().with_optim(adam);
        b.load_checkpoint(&dir).unwrap();
        assert_eq!(a.model.to_params(), b.model.to_params(), "params differ after load");
        assert_eq!(
            a.model.optim.allocated_state_elems(),
            b.model.optim.allocated_state_elems(),
            "moments not restored"
        );
        for _ in 0..2 {
            a.train_step(&tokens, &[2], &slots, 1e-2).unwrap();
            b.train_step(&tokens, &[2], &slots, 1e-2).unwrap();
            assert_eq!(
                a.model.to_params(),
                b.model.to_params(),
                "resumed Adam trajectory diverged"
            );
        }
        // A different update rule ignores the foreign state instead of
        // resuming with mismatched buffers.
        let mut c = NativeTrainer::random_init(&cfg, 7)
            .unwrap()
            .with_optim(OptimConfig { kind: OptimKind::Momentum, ..Default::default() });
        c.load_checkpoint(&dir).unwrap();
        assert_eq!(c.model.optim.allocated_state_elems(), 0, "foreign state imported");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renamed_checkpoint_file_is_rejected() {
        let cfg = tiny_cfg();
        let mut t = NativeTrainer::random_init(&cfg, 32).unwrap();
        let dir = std::env::temp_dir().join(format!("native_ckpt_ren_{}", std::process::id()));
        t.save_checkpoint(&dir).unwrap();
        // Rename one file's name component: the load must fail loudly.
        let victim = dir.join("0000.cls.intent_b.npy");
        assert!(victim.exists(), "canonical first entry moved?");
        std::fs::rename(&victim, dir.join("0000.cls.intent_x.npy")).unwrap();
        let err = t.load_checkpoint(&dir);
        assert!(err.is_err(), "renamed parameter silently accepted");
        std::fs::remove_dir_all(&dir).ok();
    }
}
