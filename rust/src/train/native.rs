//! [`NativeTrainer`]: the rust-native [`TrainBackend`] — end-to-end
//! on-device training with no XLA, no Python and no HLO artifacts.

use super::model::NativeTrainModel;
use crate::config::ModelConfig;
use crate::coordinator::backend::{StepOutput, TrainBackend};
use crate::inference::{NativeModel, ParamMap};
use crate::optim::OptimConfig;
use crate::tensor::ContractionStats;
use crate::util::npy;
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

/// Native training backend over [`NativeTrainModel`].
pub struct NativeTrainer {
    pub model: NativeTrainModel,
    /// Instrumentation of the most recent step (forward Eqs. 20/21 +
    /// backward 2x counts, summed over every TT layer).
    pub last_stats: ContractionStats,
    /// Merged-factor inference engine for eval, built lazily and
    /// invalidated whenever parameters change — evaluation loops reuse
    /// the merged Z1/Z3 factors instead of re-merging per example.
    eval_model: RefCell<Option<NativeModel>>,
}

impl NativeTrainer {
    pub fn new(model: NativeTrainModel) -> NativeTrainer {
        NativeTrainer {
            model,
            last_stats: ContractionStats::default(),
            eval_model: RefCell::new(None),
        }
    }

    /// Fresh model with seeded random parameters — training from scratch
    /// requires nothing but a [`ModelConfig`].
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Result<NativeTrainer> {
        Ok(NativeTrainer::new(NativeTrainModel::random_init(cfg, seed)?))
    }

    /// Build from a flat parameter map (e.g. exported from a live PJRT
    /// engine, for cross-backend parity).
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeTrainer> {
        Ok(NativeTrainer::new(NativeTrainModel::from_params(cfg, params)?))
    }

    /// Swap the PU-stage update rule (builder style); existing optimizer
    /// state is dropped.
    pub fn with_optim(mut self, cfg: OptimConfig) -> NativeTrainer {
        self.model.set_optim(cfg);
        self
    }

    /// Select the compute schedule (builder style): the fused/batched
    /// hot path (default) or the pre-fusion looped reference — the
    /// baseline the `native-train` bench compares against.
    pub fn with_compute_path(mut self, path: crate::train::ComputePath) -> NativeTrainer {
        self.model.compute_path = path;
        self
    }
}

impl TrainBackend for NativeTrainer {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn config(&self) -> &ModelConfig {
        &self.model.cfg
    }

    /// The native trainer takes any runtime batch size — the contraction
    /// K dimension simply becomes `B * S`.
    fn supports_batch(&self, batch: usize) -> bool {
        batch >= 1
    }

    fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        let (loss, stats) = self.model.train_step(tokens, intent, slots, lr)?;
        self.last_stats = stats;
        *self.eval_model.borrow_mut() = None; // parameters moved
        Ok(StepOutput {
            loss,
            execute_secs: t0.elapsed().as_secs_f64(),
            host_secs: 0.0,
        })
    }

    /// Inference through the cached merged-factor engine.  Accepts a
    /// `(B, S)` block: the engine runs per example and the logits are
    /// concatenated row-major, matching the trait contract.
    fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let s = self.model.cfg.seq_len;
        if tokens.is_empty() || tokens.len() % s != 0 {
            return Err(anyhow!("eval needs (B, {s}) tokens, got {}", tokens.len()));
        }
        let mut cached = self.eval_model.borrow_mut();
        if cached.is_none() {
            *cached = Some(NativeModel::from_params(&self.model.cfg, &self.model.to_params())?);
        }
        let engine = cached.as_ref().expect("just built");
        let mut intents = Vec::new();
        let mut slots = Vec::new();
        for chunk in tokens.chunks(s) {
            let (il, sl) = engine.forward(chunk)?;
            intents.extend_from_slice(&il);
            slots.extend_from_slice(&sl);
        }
        Ok((intents, slots))
    }

    /// One `.npy` per parameter, named `%04d.<name>.npy` in canonical
    /// (sorted-name) order — interchangeable with the PJRT engine's
    /// checkpoints, which are matched by name, not position.
    fn save_checkpoint(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        for (i, (name, (shape, data))) in self.model.to_params().iter().enumerate() {
            let safe = npy::safe_param_name(name);
            npy::write_npy_f32(&dir.join(format!("{i:04}.{safe}.npy")), data, shape)?;
        }
        Ok(())
    }

    /// Rebuild the model from a checkpoint directory, keyed by each
    /// file's embedded parameter name (a renamed file is an error, not a
    /// silent mix-up).  The PU-stage update rule is kept; its state is
    /// reset (checkpoints carry parameters only — optimizer-state
    /// persistence is a ROADMAP follow-up).
    fn load_checkpoint(&mut self, dir: &Path) -> Result<()> {
        let mut params = ParamMap::new();
        for (name, path) in npy::checkpoint_entries(dir)? {
            let (shape, data) = npy::read_npy_f32(&path)?;
            if params.insert(name.clone(), (shape, data)).is_some() {
                return Err(anyhow!("duplicate parameter '{name}' in checkpoint {dir:?}"));
            }
        }
        let optim_cfg = self.model.optim.cfg.clone();
        self.model = NativeTrainModel::from_params(&self.model.cfg, &params)?;
        self.model.set_optim(optim_cfg);
        *self.eval_model.borrow_mut() = None; // parameters replaced
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::model::tests::tiny_cfg;

    #[test]
    fn checkpoint_roundtrip_preserves_params() {
        let cfg = tiny_cfg();
        let mut t = NativeTrainer::random_init(&cfg, 31).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let slots = vec![0i32; 8];
        t.train_step(&tokens, &[1], &slots, 0.01).unwrap();
        let before = t.eval(&tokens).unwrap();

        let dir = std::env::temp_dir().join(format!("native_ckpt_{}", std::process::id()));
        t.save_checkpoint(&dir).unwrap();
        // Perturb, then restore.
        t.train_step(&tokens, &[1], &slots, 0.5).unwrap();
        assert_ne!(t.eval(&tokens).unwrap(), before);
        t.load_checkpoint(&dir).unwrap();
        assert_eq!(t.eval(&tokens).unwrap(), before);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn renamed_checkpoint_file_is_rejected() {
        let cfg = tiny_cfg();
        let mut t = NativeTrainer::random_init(&cfg, 32).unwrap();
        let dir = std::env::temp_dir().join(format!("native_ckpt_ren_{}", std::process::id()));
        t.save_checkpoint(&dir).unwrap();
        // Rename one file's name component: the load must fail loudly.
        let victim = dir.join("0000.cls.intent_b.npy");
        assert!(victim.exists(), "canonical first entry moved?");
        std::fs::rename(&victim, dir.join("0000.cls.intent_x.npy")).unwrap();
        let err = t.load_checkpoint(&dir);
        assert!(err.is_err(), "renamed parameter silently accepted");
        std::fs::remove_dir_all(&dir).ok();
    }
}
