//! The trainable tensorized transformer: forward with activation
//! caching, hand-derived backward, and a fused SGD update — the paper's
//! FP -> BP -> PU loop executed natively on the rust tensor substrate.
//!
//! The parameter naming scheme is identical to the AOT manifest
//! (`python/compile/model.py` / [`crate::inference::NativeModel`]), so a
//! trained native model exports straight into the inference engine and
//! native checkpoints interchange with PJRT ones.

use crate::config::ModelConfig;
use crate::inference::ParamMap;
use crate::tensor::{ops, ContractionStats, Tensor, TTMEmbedding, TTMatrix};
use crate::train::blocks::{self, LayerNormCache};
use crate::train::layers::{TTLinear, TTLinearCache};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, Result};

/// One trainable encoder block (paper Eq. 1).
pub struct TrainEncoderLayer {
    pub wq: TTLinear,
    pub wk: TTLinear,
    pub wv: TTLinear,
    pub wo: TTLinear,
    pub w1: TTLinear,
    pub w2: TTLinear,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

/// The full trainable model (batch 1, the paper's on-device setting).
pub struct NativeTrainModel {
    pub cfg: ModelConfig,
    pub embedding: TTMEmbedding,
    pub pos: Tensor,
    pub layers: Vec<TrainEncoderLayer>,
    pub pool: TTLinear,
    pub intent_w: Tensor,
    pub intent_b: Vec<f32>,
    pub slot_w: Tensor,
    pub slot_b: Vec<f32>,
}

/// Per-block forward activations kept for the BP stage.
struct LayerFwd {
    q: Tensor,
    k: Tensor,
    v: Tensor,
    probs: Tensor,
    wq_c: TTLinearCache,
    wk_c: TTLinearCache,
    wv_c: TTLinearCache,
    wo_c: TTLinearCache,
    ln1_c: LayerNormCache,
    /// Post-LN1 activations (input of the FFN and of residual 2).
    x1: Tensor,
    /// FFN hidden pre-GELU.
    h1: Tensor,
    w1_c: TTLinearCache,
    w2_c: TTLinearCache,
    ln2_c: LayerNormCache,
}

/// Whole-step forward cache.
struct ForwardCaches {
    mask: Vec<f32>,
    emb_states: Vec<Vec<Tensor>>,
    layer_fwd: Vec<LayerFwd>,
    pool_c: TTLinearCache,
    pooled: Tensor,
    intent_logits: Vec<f32>,
    slot_logits: Tensor,
}

fn sgd_vec(w: &mut [f32], g: &[f32], lr: f32) {
    for (wi, &gi) in w.iter_mut().zip(g) {
        *wi -= lr * gi;
    }
}

fn validate_cfg(cfg: &ModelConfig) -> Result<()> {
    let tt_m: usize = cfg.tt_m.iter().product();
    let tt_n: usize = cfg.tt_n.iter().product();
    let ttm_h: usize = cfg.ttm_hid_modes.iter().product();
    let ttm_v: usize = cfg.ttm_vocab_modes.iter().product();
    if tt_m != cfg.d_hid || tt_n != cfg.d_hid || ttm_h != cfg.d_hid {
        return Err(anyhow!(
            "mode products ({tt_m}, {tt_n}, {ttm_h}) must equal d_hid {}",
            cfg.d_hid
        ));
    }
    if ttm_v < cfg.vocab {
        return Err(anyhow!("vocab modes cover {ttm_v} < vocab {}", cfg.vocab));
    }
    if cfg.batch != 1 {
        return Err(anyhow!("the native trainer is batch-1 (got batch {})", cfg.batch));
    }
    Ok(())
}

impl NativeTrainModel {
    /// Seeded random initialization mirroring
    /// `python/compile/model.py::init_params` (TTM/pos std 0.02, linear
    /// target std sqrt(1/d_hid), LayerNorm (1, 0), head std
    /// sqrt(1/d_hid)).
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Result<NativeTrainModel> {
        validate_cfg(cfg)?;
        let mut rng = SplitMix64::new(seed);
        let lin_std = (1.0 / cfg.d_hid as f32).sqrt();
        let linear =
            |rng: &mut SplitMix64| TTLinear::randn(&cfg.tt_m, &cfg.tt_n, cfg.tt_rank, lin_std, rng);
        let embedding = TTMEmbedding::randn(
            &cfg.ttm_hid_modes,
            &cfg.ttm_vocab_modes,
            cfg.ttm_rank,
            0.02,
            &mut rng,
        );
        let pos = Tensor::randn(&[cfg.seq_len, cfg.d_hid], 0.02, &mut rng);
        let layers = (0..cfg.n_layers)
            .map(|_| TrainEncoderLayer {
                wq: linear(&mut rng),
                wk: linear(&mut rng),
                wv: linear(&mut rng),
                wo: linear(&mut rng),
                w1: linear(&mut rng),
                w2: linear(&mut rng),
                ln1_g: vec![1.0; cfg.d_hid],
                ln1_b: vec![0.0; cfg.d_hid],
                ln2_g: vec![1.0; cfg.d_hid],
                ln2_b: vec![0.0; cfg.d_hid],
            })
            .collect();
        let pool = linear(&mut rng);
        let head_std = (1.0 / cfg.d_hid as f32).sqrt();
        Ok(NativeTrainModel {
            cfg: cfg.clone(),
            embedding,
            pos,
            layers,
            pool,
            intent_w: Tensor::randn(&[cfg.n_intents, cfg.d_hid], head_std, &mut rng),
            intent_b: vec![0.0; cfg.n_intents],
            slot_w: Tensor::randn(&[cfg.n_slots, cfg.d_hid], head_std, &mut rng),
            slot_b: vec![0.0; cfg.n_slots],
        })
    }

    /// Assemble from a flat name -> array map (manifest naming scheme).
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeTrainModel> {
        validate_cfg(cfg)?;
        let get = |name: &str| -> Result<(&Vec<usize>, &Vec<f32>)> {
            params
                .get(name)
                .map(|(s, d)| (s, d))
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))
        };
        let tensor = |name: &str| -> Result<Tensor> {
            let (shape, data) = get(name)?;
            Tensor::from_vec(data.clone(), shape)
        };
        let vec1 = |name: &str| -> Result<Vec<f32>> { Ok(get(name)?.1.clone()) };

        let d = cfg.ttm_vocab_modes.len();
        let mut ttm_cores = Vec::with_capacity(d);
        for k in 0..d {
            ttm_cores.push(tensor(&format!("embed.ttm.{k}"))?);
        }
        let mut ranks = vec![cfg.ttm_rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        let embedding = TTMEmbedding {
            cores: ttm_cores,
            hid_modes: cfg.ttm_hid_modes.clone(),
            vocab_modes: cfg.ttm_vocab_modes.clone(),
            ranks,
        };

        let tt_linear = |prefix: &str| -> Result<TTLinear> {
            let d2 = cfg.tt_m.len() + cfg.tt_n.len();
            let mut cores = Vec::with_capacity(d2);
            for k in 0..d2 {
                cores.push(tensor(&format!("{prefix}.cores.{k}"))?);
            }
            let tt = TTMatrix {
                cores,
                m_modes: cfg.tt_m.clone(),
                n_modes: cfg.tt_n.clone(),
                ranks: cfg.tt_ranks(),
            };
            TTLinear::new(tt, vec1(&format!("{prefix}.bias"))?)
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |name: &str| format!("layers.{i}.{name}");
            layers.push(TrainEncoderLayer {
                wq: tt_linear(&p("wq"))?,
                wk: tt_linear(&p("wk"))?,
                wv: tt_linear(&p("wv"))?,
                wo: tt_linear(&p("wo"))?,
                w1: tt_linear(&p("w1"))?,
                w2: tt_linear(&p("w2"))?,
                ln1_g: vec1(&p("ln1.g"))?,
                ln1_b: vec1(&p("ln1.b"))?,
                ln2_g: vec1(&p("ln2.g"))?,
                ln2_b: vec1(&p("ln2.b"))?,
            });
        }

        Ok(NativeTrainModel {
            cfg: cfg.clone(),
            embedding,
            pos: tensor("embed.pos")?,
            layers,
            pool: tt_linear("cls.pool")?,
            intent_w: tensor("cls.intent_w")?,
            intent_b: vec1("cls.intent_b")?,
            slot_w: tensor("cls.slot_w")?,
            slot_b: vec1("cls.slot_b")?,
        })
    }

    /// Export all parameters as a flat name -> array map (the inverse of
    /// [`NativeTrainModel::from_params`]; feeds
    /// [`crate::inference::NativeModel`] and checkpointing).
    pub fn to_params(&self) -> ParamMap {
        let mut map = ParamMap::new();
        let put_t = |map: &mut ParamMap, name: String, t: &Tensor| {
            map.insert(name, (t.shape.clone(), t.data.clone()));
        };
        let put_v = |map: &mut ParamMap, name: String, v: &[f32]| {
            map.insert(name, (vec![v.len()], v.to_vec()));
        };
        for (k, core) in self.embedding.cores.iter().enumerate() {
            put_t(&mut map, format!("embed.ttm.{k}"), core);
        }
        put_t(&mut map, "embed.pos".to_string(), &self.pos);
        for (i, layer) in self.layers.iter().enumerate() {
            let lins = [
                ("wq", &layer.wq),
                ("wk", &layer.wk),
                ("wv", &layer.wv),
                ("wo", &layer.wo),
                ("w1", &layer.w1),
                ("w2", &layer.w2),
            ];
            for (name, lin) in lins {
                for (k, core) in lin.tt.cores.iter().enumerate() {
                    put_t(&mut map, format!("layers.{i}.{name}.cores.{k}"), core);
                }
                put_v(&mut map, format!("layers.{i}.{name}.bias"), &lin.bias);
            }
            put_v(&mut map, format!("layers.{i}.ln1.g"), &layer.ln1_g);
            put_v(&mut map, format!("layers.{i}.ln1.b"), &layer.ln1_b);
            put_v(&mut map, format!("layers.{i}.ln2.g"), &layer.ln2_g);
            put_v(&mut map, format!("layers.{i}.ln2.b"), &layer.ln2_b);
        }
        for (k, core) in self.pool.tt.cores.iter().enumerate() {
            put_t(&mut map, format!("cls.pool.cores.{k}"), core);
        }
        put_v(&mut map, "cls.pool.bias".to_string(), &self.pool.bias);
        put_t(&mut map, "cls.intent_w".to_string(), &self.intent_w);
        put_v(&mut map, "cls.intent_b".to_string(), &self.intent_b);
        put_t(&mut map, "cls.slot_w".to_string(), &self.slot_w);
        put_v(&mut map, "cls.slot_b".to_string(), &self.slot_b);
        map
    }

    /// Forward pass with full activation caching (batch 1).
    fn forward_train(&self, tokens: &[i32], stats: &mut ContractionStats) -> Result<ForwardCaches> {
        let cfg = &self.cfg;
        let (s, h) = (cfg.seq_len, cfg.d_hid);
        if tokens.len() != s {
            return Err(anyhow!("expected {s} tokens, got {}", tokens.len()));
        }
        let mask: Vec<f32> = tokens
            .iter()
            .map(|&t| if t == cfg.pad_id { 0.0 } else { 1.0 })
            .collect();

        // Embedding: TTM lookup (cached) + positional table.
        let mut x = Tensor::zeros(&[s, h]);
        let mut emb_states = Vec::with_capacity(s);
        for (i, &t) in tokens.iter().enumerate() {
            let (row, states) = self.embedding.lookup_cached(t as usize)?;
            for j in 0..h {
                x.data[i * h + j] = row.data[j] + self.pos.at2(i, j);
            }
            emb_states.push(states);
        }

        let mut layer_fwd = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let (q, wq_c) = layer.wq.forward(&x, stats)?;
            let (k, wk_c) = layer.wk.forward(&x, stats)?;
            let (v, wv_c) = layer.wv.forward(&x, stats)?;
            let (ctx, probs) = ops::multi_head_attention(&q, &k, &v, &mask, cfg.n_heads)?;
            let (o, wo_c) = layer.wo.forward(&ctx, stats)?;
            let res1 = ops::add(&x, &o);
            let (x1, ln1_c) = blocks::layer_norm_fwd(&res1, &layer.ln1_g, &layer.ln1_b, 1e-5);
            let (h1, w1_c) = layer.w1.forward(&x1, stats)?;
            let g1 = ops::gelu(&h1);
            let (ffn, w2_c) = layer.w2.forward(&g1, stats)?;
            let res2 = ops::add(&x1, &ffn);
            let (x2, ln2_c) = blocks::layer_norm_fwd(&res2, &layer.ln2_g, &layer.ln2_b, 1e-5);
            layer_fwd.push(LayerFwd {
                q,
                k,
                v,
                probs,
                wq_c,
                wk_c,
                wv_c,
                wo_c,
                ln1_c,
                x1,
                h1,
                w1_c,
                w2_c,
                ln2_c,
            });
            x = x2;
        }

        let (pool_pre, pool_c) = self.pool.forward(&x, stats)?;
        let pooled = ops::tanh(&pool_pre);
        let cls_row = Tensor::from_vec(pooled.data[..h].to_vec(), &[1, h])?;
        let intent = ops::add_row(&cls_row.matmul(&self.intent_w.t()?)?, &self.intent_b);
        let slots = ops::add_row(&pooled.matmul(&self.slot_w.t()?)?, &self.slot_b);
        Ok(ForwardCaches {
            mask,
            emb_states,
            layer_fwd,
            pool_c,
            pooled,
            intent_logits: intent.data,
            slot_logits: slots,
        })
    }

    /// Inference (same contract as the PJRT engine's eval): returns
    /// `(intent_logits, slot_logits (S * n_slots))`.
    pub fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;
        Ok((fwd.intent_logits, fwd.slot_logits.data))
    }

    /// One fused SGD step (FP -> BP -> PU): forward with caching, joint
    /// cross-entropy, hand-derived backward, and in-place updates as
    /// each gradient becomes available.  Returns `(loss, step stats)`.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<(f32, ContractionStats)> {
        let cfg_nh = self.cfg.n_heads;
        let (s, h) = (self.cfg.seq_len, self.cfg.d_hid);
        let ns = self.cfg.n_slots;
        if intent.len() != 1 || slots.len() != s {
            return Err(anyhow!(
                "native train_step is batch-1: need 1 intent / {s} slots, got {} / {}",
                intent.len(),
                slots.len()
            ));
        }
        if intent[0] < 0 || intent[0] as usize >= self.cfg.n_intents {
            return Err(anyhow!("intent label {} out of range", intent[0]));
        }
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;

        // ---- Joint loss and logit gradients (paper loss_fn) ----------
        let denom: f32 = fwd.mask.iter().sum::<f32>();
        let denom = denom.max(1.0);
        let (loss_intent, d_il) =
            blocks::cross_entropy_logits(&fwd.intent_logits, intent[0] as usize)?;
        let mut loss_slots = 0.0f32;
        let mut d_slot = Tensor::zeros(&[s, ns]);
        for p in 0..s {
            if fwd.mask[p] == 0.0 {
                continue;
            }
            if slots[p] < 0 || slots[p] as usize >= ns {
                return Err(anyhow!("slot label {} out of range at {p}", slots[p]));
            }
            let row = &fwd.slot_logits.data[p * ns..(p + 1) * ns];
            let (l, dl) = blocks::cross_entropy_logits(row, slots[p] as usize)?;
            loss_slots += l / denom;
            for (o, &dv) in d_slot.data[p * ns..(p + 1) * ns].iter_mut().zip(&dl) {
                *o = dv / denom;
            }
        }
        let loss = loss_intent + loss_slots;

        // ---- Classifier heads ----------------------------------------
        // d_pooled from both heads, computed before any head update.
        let mut d_pooled = d_slot.matmul(&self.slot_w)?; // (S, H)
        for (c, &dil) in d_il.iter().enumerate() {
            for j in 0..h {
                d_pooled.data[j] += dil * self.intent_w.at2(c, j);
            }
        }
        let d_slot_w = d_slot.t()?.matmul(&fwd.pooled)?; // (n_slots, H)
        let mut d_slot_b = vec![0.0f32; ns];
        for row in d_slot.data.chunks(ns) {
            for (b, &v) in d_slot_b.iter_mut().zip(row) {
                *b += v;
            }
        }
        for (c, &dil) in d_il.iter().enumerate() {
            for j in 0..h {
                self.intent_w.data[c * h + j] -= lr * dil * fwd.pooled.data[j];
            }
        }
        sgd_vec(&mut self.intent_b, &d_il, lr);
        for (w, &g) in self.slot_w.data.iter_mut().zip(&d_slot_w.data) {
            *w -= lr * g;
        }
        sgd_vec(&mut self.slot_b, &d_slot_b, lr);

        // ---- Pooler --------------------------------------------------
        let d_pool_pre = blocks::tanh_vjp(&fwd.pooled, &d_pooled);
        let (mut dx, pool_grads) = self.pool.backward(&d_pool_pre, &fwd.pool_c, &mut stats)?;
        self.pool.sgd_update(&pool_grads, lr);

        // ---- Encoder blocks, reversed --------------------------------
        for (layer, f) in self.layers.iter_mut().zip(fwd.layer_fwd.iter()).rev() {
            let (d_res2, dg2, db2) = blocks::layer_norm_vjp(&f.ln2_c, &layer.ln2_g, &dx);
            sgd_vec(&mut layer.ln2_g, &dg2, lr);
            sgd_vec(&mut layer.ln2_b, &db2, lr);
            let (d_g1, w2_grads) = layer.w2.backward(&d_res2, &f.w2_c, &mut stats)?;
            layer.w2.sgd_update(&w2_grads, lr);
            let d_h1 = blocks::gelu_vjp(&f.h1, &d_g1);
            let (d_x1_ffn, w1_grads) = layer.w1.backward(&d_h1, &f.w1_c, &mut stats)?;
            layer.w1.sgd_update(&w1_grads, lr);
            let d_x1 = ops::add(&d_res2, &d_x1_ffn);
            let (d_res1, dg1, db1) = blocks::layer_norm_vjp(&f.ln1_c, &layer.ln1_g, &d_x1);
            sgd_vec(&mut layer.ln1_g, &dg1, lr);
            sgd_vec(&mut layer.ln1_b, &db1, lr);
            let (d_ctx, wo_grads) = layer.wo.backward(&d_res1, &f.wo_c, &mut stats)?;
            layer.wo.sgd_update(&wo_grads, lr);
            let (dq, dk, dv) =
                blocks::multi_head_attention_vjp(&f.q, &f.k, &f.v, &f.probs, &d_ctx, cfg_nh)?;
            let (dx_q, wq_grads) = layer.wq.backward(&dq, &f.wq_c, &mut stats)?;
            layer.wq.sgd_update(&wq_grads, lr);
            let (dx_k, wk_grads) = layer.wk.backward(&dk, &f.wk_c, &mut stats)?;
            layer.wk.sgd_update(&wk_grads, lr);
            let (dx_v, wv_grads) = layer.wv.backward(&dv, &f.wv_c, &mut stats)?;
            layer.wv.sgd_update(&wv_grads, lr);
            dx = ops::add(&ops::add(&ops::add(&d_res1, &dx_q), &dx_k), &dx_v);
        }

        // ---- Embedding + positional table ----------------------------
        let mut emb_grads: Vec<Tensor> = self
            .embedding
            .cores
            .iter()
            .map(|c| Tensor::zeros(&c.shape))
            .collect();
        for (i, &t) in tokens.iter().enumerate() {
            let d_row = &dx.data[i * h..(i + 1) * h];
            self.embedding
                .lookup_vjp(t as usize, &fwd.emb_states[i], d_row, &mut emb_grads)?;
        }
        for (core, g) in self.embedding.cores.iter_mut().zip(&emb_grads) {
            for (w, &dw) in core.data.iter_mut().zip(&g.data) {
                *w -= lr * dw;
            }
        }
        for (w, &dw) in self.pos.data.iter_mut().zip(&dx.data) {
            *w -= lr * dw;
        }

        Ok((loss, stats))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::inference::NativeModel;

    pub(crate) fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_layers: 1,
            d_hid: 48,
            n_heads: 4,
            seq_len: 8,
            batch: 1,
            vocab: 27,
            n_intents: 5,
            n_slots: 7,
            tt_m: vec![4, 4, 3],
            tt_n: vec![3, 4, 4],
            tt_rank: 3,
            ttm_vocab_modes: vec![3, 3, 3],
            ttm_hid_modes: vec![4, 4, 3],
            ttm_rank: 4,
            pad_id: 0,
            cls_id: 1,
            unk_id: 2,
        }
    }

    #[test]
    fn params_roundtrip_preserves_model() {
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 7).unwrap();
        let map = model.to_params();
        let back = NativeTrainModel::from_params(&cfg, &map).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        assert_eq!(model.eval(&tokens).unwrap(), back.eval(&tokens).unwrap());
    }

    #[test]
    fn eval_matches_inference_engine() {
        // The trainable model and the merged-factor inference engine run
        // the same forward math on the same parameters.
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 8).unwrap();
        let infer = NativeModel::from_params(&cfg, &model.to_params()).unwrap();
        for tokens in [vec![1, 5, 9, 13, 0, 0, 0, 0], vec![1, 3, 2, 7, 11, 26, 0, 0]] {
            let (il_t, sl_t) = model.eval(&tokens).unwrap();
            let (il_i, sl_i) = infer.forward(&tokens).unwrap();
            let d_i = il_t
                .iter()
                .zip(&il_i)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let d_s = sl_t
                .iter()
                .zip(&sl_i)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d_i < 1e-5, "intent logits diverge: {d_i}");
            assert!(d_s < 1e-5, "slot logits diverge: {d_s}");
        }
    }

    #[test]
    fn train_step_reports_positive_finite_loss_and_updates() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 9).unwrap();
        let tokens = vec![1, 5, 9, 13, 4, 0, 0, 0];
        let slots = vec![0, 1, 2, 3, 1, 0, 0, 0];
        let before = model.eval(&tokens).unwrap();
        let (loss, stats) = model.train_step(&tokens, &[2], &slots, 0.05).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!(stats.muls > 0);
        let after = model.eval(&tokens).unwrap();
        assert_ne!(before, after, "parameters did not move");
    }

    #[test]
    fn rejects_bad_labels() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 10).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let slots = vec![0i32; 8];
        assert!(model.train_step(&tokens, &[99], &slots, 0.01).is_err());
        let bad_slots = vec![0, 99, 0, 0, 0, 0, 0, 0];
        assert!(model.train_step(&tokens, &[1], &bad_slots, 0.01).is_err());
    }
}
