//! The trainable tensorized transformer: forward with activation
//! caching, hand-derived backward, and a pluggable parameter update —
//! the paper's FP -> BP -> PU loop executed natively on the rust tensor
//! substrate.
//!
//! Mini-batches ride the contraction K dimension: a `(B, S)` token
//! block runs every TT linear layer at `K = B * S` (the BTT cost model
//! is linear in K, Eqs. 20/21), attention and the CLS pooling are
//! applied per example, and the loss-level gradients carry `1/B` so
//! every parameter gradient downstream is the batch **mean**,
//! accumulated in ascending example order by the deterministic blocked
//! kernels.
//!
//! The PU stage dispatches through [`crate::optim::ModelOptim`]:
//! SGD / momentum / Adam / AdamW, with per-parameter state in the same
//! compressed core layout as the weights.
//!
//! The parameter naming scheme is identical to the AOT manifest
//! (`python/compile/model.py` / [`crate::inference::NativeModel`]), so a
//! trained native model exports straight into the inference engine and
//! native checkpoints interchange with PJRT ones.

use crate::config::ModelConfig;
use crate::inference::ParamMap;
use crate::optim::{ModelOptim, OptimConfig};
use crate::tensor::{ops, ContractionStats, Tensor, TTMEmbedding, TTMatrix};
use crate::train::blocks::{self, LayerNormCache};
use crate::train::layers::{TTLinear, TTLinearCache};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, Result};

/// One trainable encoder block (paper Eq. 1).
pub struct TrainEncoderLayer {
    pub wq: TTLinear,
    pub wk: TTLinear,
    pub wv: TTLinear,
    pub wo: TTLinear,
    pub w1: TTLinear,
    pub w2: TTLinear,
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
}

/// The full trainable model (any runtime batch size; the paper's
/// on-device setting is B = 1).
pub struct NativeTrainModel {
    pub cfg: ModelConfig,
    pub embedding: TTMEmbedding,
    pub pos: Tensor,
    pub layers: Vec<TrainEncoderLayer>,
    pub pool: TTLinear,
    pub intent_w: Tensor,
    pub intent_b: Vec<f32>,
    pub slot_w: Tensor,
    pub slot_b: Vec<f32>,
    /// The PU stage: pluggable per-parameter update rules + state.
    pub optim: ModelOptim,
}

/// Per-block forward activations kept for the BP stage (all `(B*S, H)`
/// except the per-example attention probabilities).
struct LayerFwd {
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Attention probabilities, one `(heads, S, S)` tensor per example.
    probs: Vec<Tensor>,
    wq_c: TTLinearCache,
    wk_c: TTLinearCache,
    wv_c: TTLinearCache,
    wo_c: TTLinearCache,
    ln1_c: LayerNormCache,
    /// Post-LN1 activations (input of the FFN and of residual 2).
    x1: Tensor,
    /// FFN hidden pre-GELU.
    h1: Tensor,
    w1_c: TTLinearCache,
    w2_c: TTLinearCache,
    ln2_c: LayerNormCache,
}

/// Whole-step forward cache.
struct ForwardCaches {
    /// Examples in this block.
    batch: usize,
    mask: Vec<f32>,
    emb_states: Vec<Vec<Tensor>>,
    layer_fwd: Vec<LayerFwd>,
    pool_c: TTLinearCache,
    pooled: Tensor,
    /// CLS rows of `pooled`, `(B, H)`.
    cls: Tensor,
    /// `(B, n_intents)` row-major.
    intent_logits: Tensor,
    /// `(B*S, n_slots)` row-major.
    slot_logits: Tensor,
}

/// Copy `nrows` rows starting at `r0` out of a 2-D tensor.
fn rows(t: &Tensor, r0: usize, nrows: usize) -> Result<Tensor> {
    let w = t.shape[1];
    Tensor::from_vec(t.data[r0 * w..(r0 + nrows) * w].to_vec(), &[nrows, w])
}

fn validate_cfg(cfg: &ModelConfig) -> Result<()> {
    let tt_m: usize = cfg.tt_m.iter().product();
    let tt_n: usize = cfg.tt_n.iter().product();
    let ttm_h: usize = cfg.ttm_hid_modes.iter().product();
    let ttm_v: usize = cfg.ttm_vocab_modes.iter().product();
    if tt_m != cfg.d_hid || tt_n != cfg.d_hid || ttm_h != cfg.d_hid {
        return Err(anyhow!(
            "mode products ({tt_m}, {tt_n}, {ttm_h}) must equal d_hid {}",
            cfg.d_hid
        ));
    }
    if ttm_v < cfg.vocab {
        return Err(anyhow!("vocab modes cover {ttm_v} < vocab {}", cfg.vocab));
    }
    Ok(())
}

impl NativeTrainModel {
    /// Seeded random initialization mirroring
    /// `python/compile/model.py::init_params` (TTM/pos std 0.02, linear
    /// target std sqrt(1/d_hid), LayerNorm (1, 0), head std
    /// sqrt(1/d_hid)).
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Result<NativeTrainModel> {
        validate_cfg(cfg)?;
        let mut rng = SplitMix64::new(seed);
        let lin_std = (1.0 / cfg.d_hid as f32).sqrt();
        let linear =
            |rng: &mut SplitMix64| TTLinear::randn(&cfg.tt_m, &cfg.tt_n, cfg.tt_rank, lin_std, rng);
        let embedding = TTMEmbedding::randn(
            &cfg.ttm_hid_modes,
            &cfg.ttm_vocab_modes,
            cfg.ttm_rank,
            0.02,
            &mut rng,
        );
        let pos = Tensor::randn(&[cfg.seq_len, cfg.d_hid], 0.02, &mut rng);
        let layers = (0..cfg.n_layers)
            .map(|_| TrainEncoderLayer {
                wq: linear(&mut rng),
                wk: linear(&mut rng),
                wv: linear(&mut rng),
                wo: linear(&mut rng),
                w1: linear(&mut rng),
                w2: linear(&mut rng),
                ln1_g: vec![1.0; cfg.d_hid],
                ln1_b: vec![0.0; cfg.d_hid],
                ln2_g: vec![1.0; cfg.d_hid],
                ln2_b: vec![0.0; cfg.d_hid],
            })
            .collect();
        let pool = linear(&mut rng);
        let head_std = (1.0 / cfg.d_hid as f32).sqrt();
        Ok(NativeTrainModel {
            cfg: cfg.clone(),
            embedding,
            pos,
            layers,
            pool,
            intent_w: Tensor::randn(&[cfg.n_intents, cfg.d_hid], head_std, &mut rng),
            intent_b: vec![0.0; cfg.n_intents],
            slot_w: Tensor::randn(&[cfg.n_slots, cfg.d_hid], head_std, &mut rng),
            slot_b: vec![0.0; cfg.n_slots],
            optim: ModelOptim::new(OptimConfig::default()),
        })
    }

    /// Assemble from a flat name -> array map (manifest naming scheme).
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeTrainModel> {
        validate_cfg(cfg)?;
        let get = |name: &str| -> Result<(&Vec<usize>, &Vec<f32>)> {
            params
                .get(name)
                .map(|(s, d)| (s, d))
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))
        };
        let tensor = |name: &str| -> Result<Tensor> {
            let (shape, data) = get(name)?;
            Tensor::from_vec(data.clone(), shape)
        };
        let vec1 = |name: &str| -> Result<Vec<f32>> { Ok(get(name)?.1.clone()) };

        let d = cfg.ttm_vocab_modes.len();
        let mut ttm_cores = Vec::with_capacity(d);
        for k in 0..d {
            ttm_cores.push(tensor(&format!("embed.ttm.{k}"))?);
        }
        let mut ranks = vec![cfg.ttm_rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        let embedding = TTMEmbedding {
            cores: ttm_cores,
            hid_modes: cfg.ttm_hid_modes.clone(),
            vocab_modes: cfg.ttm_vocab_modes.clone(),
            ranks,
        };

        let tt_linear = |prefix: &str| -> Result<TTLinear> {
            let d2 = cfg.tt_m.len() + cfg.tt_n.len();
            let mut cores = Vec::with_capacity(d2);
            for k in 0..d2 {
                cores.push(tensor(&format!("{prefix}.cores.{k}"))?);
            }
            let tt = TTMatrix {
                cores,
                m_modes: cfg.tt_m.clone(),
                n_modes: cfg.tt_n.clone(),
                ranks: cfg.tt_ranks(),
            };
            TTLinear::new(tt, vec1(&format!("{prefix}.bias"))?)
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |name: &str| format!("layers.{i}.{name}");
            layers.push(TrainEncoderLayer {
                wq: tt_linear(&p("wq"))?,
                wk: tt_linear(&p("wk"))?,
                wv: tt_linear(&p("wv"))?,
                wo: tt_linear(&p("wo"))?,
                w1: tt_linear(&p("w1"))?,
                w2: tt_linear(&p("w2"))?,
                ln1_g: vec1(&p("ln1.g"))?,
                ln1_b: vec1(&p("ln1.b"))?,
                ln2_g: vec1(&p("ln2.g"))?,
                ln2_b: vec1(&p("ln2.b"))?,
            });
        }

        Ok(NativeTrainModel {
            cfg: cfg.clone(),
            embedding,
            pos: tensor("embed.pos")?,
            layers,
            pool: tt_linear("cls.pool")?,
            intent_w: tensor("cls.intent_w")?,
            intent_b: vec1("cls.intent_b")?,
            slot_w: tensor("cls.slot_w")?,
            slot_b: vec1("cls.slot_b")?,
            optim: ModelOptim::new(OptimConfig::default()),
        })
    }

    /// Swap the PU-stage update rule.  Existing optimizer state is
    /// dropped (it belongs to the previous rule).
    pub fn set_optim(&mut self, cfg: OptimConfig) {
        self.optim = ModelOptim::new(cfg);
    }

    /// Export all parameters as a flat name -> array map (the inverse of
    /// [`NativeTrainModel::from_params`]; feeds
    /// [`crate::inference::NativeModel`] and checkpointing).
    pub fn to_params(&self) -> ParamMap {
        let mut map = ParamMap::new();
        let put_t = |map: &mut ParamMap, name: String, t: &Tensor| {
            map.insert(name, (t.shape.clone(), t.data.clone()));
        };
        let put_v = |map: &mut ParamMap, name: String, v: &[f32]| {
            map.insert(name, (vec![v.len()], v.to_vec()));
        };
        for (k, core) in self.embedding.cores.iter().enumerate() {
            put_t(&mut map, format!("embed.ttm.{k}"), core);
        }
        put_t(&mut map, "embed.pos".to_string(), &self.pos);
        for (i, layer) in self.layers.iter().enumerate() {
            let lins = [
                ("wq", &layer.wq),
                ("wk", &layer.wk),
                ("wv", &layer.wv),
                ("wo", &layer.wo),
                ("w1", &layer.w1),
                ("w2", &layer.w2),
            ];
            for (name, lin) in lins {
                for (k, core) in lin.tt.cores.iter().enumerate() {
                    put_t(&mut map, format!("layers.{i}.{name}.cores.{k}"), core);
                }
                put_v(&mut map, format!("layers.{i}.{name}.bias"), &lin.bias);
            }
            put_v(&mut map, format!("layers.{i}.ln1.g"), &layer.ln1_g);
            put_v(&mut map, format!("layers.{i}.ln1.b"), &layer.ln1_b);
            put_v(&mut map, format!("layers.{i}.ln2.g"), &layer.ln2_g);
            put_v(&mut map, format!("layers.{i}.ln2.b"), &layer.ln2_b);
        }
        for (k, core) in self.pool.tt.cores.iter().enumerate() {
            put_t(&mut map, format!("cls.pool.cores.{k}"), core);
        }
        put_v(&mut map, "cls.pool.bias".to_string(), &self.pool.bias);
        put_t(&mut map, "cls.intent_w".to_string(), &self.intent_w);
        put_v(&mut map, "cls.intent_b".to_string(), &self.intent_b);
        put_t(&mut map, "cls.slot_w".to_string(), &self.slot_w);
        put_v(&mut map, "cls.slot_b".to_string(), &self.slot_b);
        map
    }

    /// Forward pass with full activation caching over a `(B, S)` token
    /// block (row-major).  Every TT linear layer runs at `K = B * S`;
    /// attention and pooling are applied per example.
    fn forward_train(&self, tokens: &[i32], stats: &mut ContractionStats) -> Result<ForwardCaches> {
        let cfg = &self.cfg;
        let (s, h) = (cfg.seq_len, cfg.d_hid);
        if tokens.is_empty() || tokens.len() % s != 0 {
            return Err(anyhow!(
                "tokens must be (B, {s}) row-major, got {} ids",
                tokens.len()
            ));
        }
        let b = tokens.len() / s;
        let k_rows = b * s;
        let mask: Vec<f32> = tokens
            .iter()
            .map(|&t| if t == cfg.pad_id { 0.0 } else { 1.0 })
            .collect();

        // Embedding: TTM lookup (cached) + positional table (per slot).
        let mut x = Tensor::zeros(&[k_rows, h]);
        let mut emb_states = Vec::with_capacity(k_rows);
        for (i, &t) in tokens.iter().enumerate() {
            let (row, states) = self.embedding.lookup_cached(t as usize)?;
            let p = i % s;
            for j in 0..h {
                x.data[i * h + j] = row.data[j] + self.pos.at2(p, j);
            }
            emb_states.push(states);
        }

        let mut layer_fwd = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let (q, wq_c) = layer.wq.forward(&x, stats)?;
            let (k, wk_c) = layer.wk.forward(&x, stats)?;
            let (v, wv_c) = layer.wv.forward(&x, stats)?;
            // Attention never mixes examples: per-example heads over the
            // (S, H) slices of the K-stacked projections.
            let mut ctx = Tensor::zeros(&[k_rows, h]);
            let mut probs = Vec::with_capacity(b);
            for e in 0..b {
                let qe = rows(&q, e * s, s)?;
                let ke = rows(&k, e * s, s)?;
                let ve = rows(&v, e * s, s)?;
                let (ctx_e, probs_e) = ops::multi_head_attention(
                    &qe,
                    &ke,
                    &ve,
                    &mask[e * s..(e + 1) * s],
                    cfg.n_heads,
                )?;
                ctx.data[e * s * h..(e + 1) * s * h].copy_from_slice(&ctx_e.data);
                probs.push(probs_e);
            }
            let (o, wo_c) = layer.wo.forward(&ctx, stats)?;
            let res1 = ops::add(&x, &o);
            let (x1, ln1_c) = blocks::layer_norm_fwd(&res1, &layer.ln1_g, &layer.ln1_b, 1e-5);
            let (h1, w1_c) = layer.w1.forward(&x1, stats)?;
            let g1 = ops::gelu(&h1);
            let (ffn, w2_c) = layer.w2.forward(&g1, stats)?;
            let res2 = ops::add(&x1, &ffn);
            let (x2, ln2_c) = blocks::layer_norm_fwd(&res2, &layer.ln2_g, &layer.ln2_b, 1e-5);
            layer_fwd.push(LayerFwd {
                q,
                k,
                v,
                probs,
                wq_c,
                wk_c,
                wv_c,
                wo_c,
                ln1_c,
                x1,
                h1,
                w1_c,
                w2_c,
                ln2_c,
            });
            x = x2;
        }

        let (pool_pre, pool_c) = self.pool.forward(&x, stats)?;
        let pooled = ops::tanh(&pool_pre);
        // Per-example CLS rows drive the intent head.
        let mut cls = Tensor::zeros(&[b, h]);
        for e in 0..b {
            cls.data[e * h..(e + 1) * h].copy_from_slice(&pooled.data[e * s * h..e * s * h + h]);
        }
        let intent = ops::add_row(&cls.matmul(&self.intent_w.t()?)?, &self.intent_b);
        let slots = ops::add_row(&pooled.matmul(&self.slot_w.t()?)?, &self.slot_b);
        Ok(ForwardCaches {
            batch: b,
            mask,
            emb_states,
            layer_fwd,
            pool_c,
            pooled,
            cls,
            intent_logits: intent,
            slot_logits: slots,
        })
    }

    /// Inference (same contract as the PJRT engine's eval): returns
    /// `(intent_logits (B*n_intents), slot_logits (B*S*n_slots))`
    /// row-major for a `(B, S)` token block.
    pub fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;
        Ok((fwd.intent_logits.data, fwd.slot_logits.data))
    }

    /// One training step (FP -> BP -> PU) over a `(B, S)` mini-batch:
    /// forward with caching, joint cross-entropy averaged over the
    /// batch, hand-derived backward at `K = B * S`, and in-place
    /// optimizer updates as each gradient becomes available.  Returns
    /// `(mean loss, step stats)`.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<(f32, ContractionStats)> {
        let cfg_nh = self.cfg.n_heads;
        let (s, h) = (self.cfg.seq_len, self.cfg.d_hid);
        let ns = self.cfg.n_slots;
        let ni = self.cfg.n_intents;
        let b = intent.len();
        if b == 0 || tokens.len() != b * s || slots.len() != b * s {
            return Err(anyhow!(
                "train_step: need (B, {s}) tokens/slots and (B,) intents, got {} / {} / {b}",
                tokens.len(),
                slots.len()
            ));
        }
        for &iv in intent {
            if iv < 0 || iv as usize >= ni {
                return Err(anyhow!("intent label {iv} out of range"));
            }
        }
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;
        debug_assert_eq!(fwd.batch, b);
        let inv_b = 1.0 / b as f32;

        // ---- Joint loss and logit gradients (paper loss_fn, batch mean)
        let mut loss = 0.0f32;
        let mut d_il = Tensor::zeros(&[b, ni]);
        let mut d_slot = Tensor::zeros(&[b * s, ns]);
        for e in 0..b {
            let irow = &fwd.intent_logits.data[e * ni..(e + 1) * ni];
            let (li, dli) = blocks::cross_entropy_logits(irow, intent[e] as usize)?;
            loss += li * inv_b;
            for (o, &v) in d_il.data[e * ni..(e + 1) * ni].iter_mut().zip(&dli) {
                *o = v * inv_b;
            }
            let m = &fwd.mask[e * s..(e + 1) * s];
            let denom = m.iter().sum::<f32>().max(1.0);
            for p in 0..s {
                if m[p] == 0.0 {
                    continue;
                }
                let gp = e * s + p;
                if slots[gp] < 0 || slots[gp] as usize >= ns {
                    return Err(anyhow!("slot label {} out of range at {p}", slots[gp]));
                }
                let row = &fwd.slot_logits.data[gp * ns..(gp + 1) * ns];
                let (l, dl) = blocks::cross_entropy_logits(row, slots[gp] as usize)?;
                loss += l * inv_b / denom;
                for (o, &dv) in d_slot.data[gp * ns..(gp + 1) * ns].iter_mut().zip(&dl) {
                    *o = dv * inv_b / denom;
                }
            }
        }

        let hyper = self.optim.hyper(lr);

        // ---- Classifier heads ----------------------------------------
        // d_pooled from both heads, computed before any head update.
        let mut d_pooled = d_slot.matmul(&self.slot_w)?; // (B*S, H)
        for e in 0..b {
            for (c, &dil) in d_il.data[e * ni..(e + 1) * ni].iter().enumerate() {
                for j in 0..h {
                    d_pooled.data[e * s * h + j] += dil * self.intent_w.at2(c, j);
                }
            }
        }
        let d_slot_w = d_slot.t()?.matmul(&fwd.pooled)?; // (n_slots, H)
        let mut d_slot_b = vec![0.0f32; ns];
        for row in d_slot.data.chunks(ns) {
            for (bb, &v) in d_slot_b.iter_mut().zip(row) {
                *bb += v;
            }
        }
        let d_intent_w = d_il.t()?.matmul(&fwd.cls)?; // (n_intents, H)
        let mut d_intent_b = vec![0.0f32; ni];
        for row in d_il.data.chunks(ni) {
            for (bb, &v) in d_intent_b.iter_mut().zip(row) {
                *bb += v;
            }
        }
        self.optim.step("cls.intent_w", &mut self.intent_w.data, &d_intent_w.data, &hyper);
        self.optim.step("cls.intent_b", &mut self.intent_b, &d_intent_b, &hyper);
        self.optim.step("cls.slot_w", &mut self.slot_w.data, &d_slot_w.data, &hyper);
        self.optim.step("cls.slot_b", &mut self.slot_b, &d_slot_b, &hyper);

        // ---- Pooler --------------------------------------------------
        let d_pool_pre = blocks::tanh_vjp(&fwd.pooled, &d_pooled);
        let (mut dx, pool_grads) = self.pool.backward(&d_pool_pre, &fwd.pool_c, &mut stats)?;
        self.pool.apply_update(&pool_grads, &mut self.optim, "cls.pool", &hyper);

        // ---- Encoder blocks, reversed --------------------------------
        for (li, (layer, f)) in self
            .layers
            .iter_mut()
            .zip(fwd.layer_fwd.iter())
            .enumerate()
            .rev()
        {
            let p = |name: &str| format!("layers.{li}.{name}");
            let (d_res2, dg2, db2) = blocks::layer_norm_vjp(&f.ln2_c, &layer.ln2_g, &dx);
            self.optim.step(&p("ln2.g"), &mut layer.ln2_g, &dg2, &hyper);
            self.optim.step(&p("ln2.b"), &mut layer.ln2_b, &db2, &hyper);
            let (d_g1, w2_grads) = layer.w2.backward(&d_res2, &f.w2_c, &mut stats)?;
            layer.w2.apply_update(&w2_grads, &mut self.optim, &p("w2"), &hyper);
            let d_h1 = blocks::gelu_vjp(&f.h1, &d_g1);
            let (d_x1_ffn, w1_grads) = layer.w1.backward(&d_h1, &f.w1_c, &mut stats)?;
            layer.w1.apply_update(&w1_grads, &mut self.optim, &p("w1"), &hyper);
            let d_x1 = ops::add(&d_res2, &d_x1_ffn);
            let (d_res1, dg1, db1) = blocks::layer_norm_vjp(&f.ln1_c, &layer.ln1_g, &d_x1);
            self.optim.step(&p("ln1.g"), &mut layer.ln1_g, &dg1, &hyper);
            self.optim.step(&p("ln1.b"), &mut layer.ln1_b, &db1, &hyper);
            let (d_ctx, wo_grads) = layer.wo.backward(&d_res1, &f.wo_c, &mut stats)?;
            layer.wo.apply_update(&wo_grads, &mut self.optim, &p("wo"), &hyper);
            // Attention backward, per example (like the forward).
            let mut dq = Tensor::zeros(&[b * s, h]);
            let mut dk = Tensor::zeros(&[b * s, h]);
            let mut dv = Tensor::zeros(&[b * s, h]);
            for e in 0..b {
                let qe = rows(&f.q, e * s, s)?;
                let ke = rows(&f.k, e * s, s)?;
                let ve = rows(&f.v, e * s, s)?;
                let d_ctx_e = rows(&d_ctx, e * s, s)?;
                let (dqe, dke, dve) = blocks::multi_head_attention_vjp(
                    &qe,
                    &ke,
                    &ve,
                    &f.probs[e],
                    &d_ctx_e,
                    cfg_nh,
                )?;
                dq.data[e * s * h..(e + 1) * s * h].copy_from_slice(&dqe.data);
                dk.data[e * s * h..(e + 1) * s * h].copy_from_slice(&dke.data);
                dv.data[e * s * h..(e + 1) * s * h].copy_from_slice(&dve.data);
            }
            let (dx_q, wq_grads) = layer.wq.backward(&dq, &f.wq_c, &mut stats)?;
            layer.wq.apply_update(&wq_grads, &mut self.optim, &p("wq"), &hyper);
            let (dx_k, wk_grads) = layer.wk.backward(&dk, &f.wk_c, &mut stats)?;
            layer.wk.apply_update(&wk_grads, &mut self.optim, &p("wk"), &hyper);
            let (dx_v, wv_grads) = layer.wv.backward(&dv, &f.wv_c, &mut stats)?;
            layer.wv.apply_update(&wv_grads, &mut self.optim, &p("wv"), &hyper);
            dx = ops::add(&ops::add(&ops::add(&d_res1, &dx_q), &dx_k), &dx_v);
        }

        // ---- Embedding + positional table ----------------------------
        let mut emb_grads: Vec<Tensor> = self
            .embedding
            .cores
            .iter()
            .map(|c| Tensor::zeros(&c.shape))
            .collect();
        for (i, &t) in tokens.iter().enumerate() {
            let d_row = &dx.data[i * h..(i + 1) * h];
            self.embedding
                .lookup_vjp(t as usize, &fwd.emb_states[i], d_row, &mut emb_grads)?;
        }
        for (k, (core, g)) in self.embedding.cores.iter_mut().zip(&emb_grads).enumerate() {
            self.optim.step(&format!("embed.ttm.{k}"), &mut core.data, &g.data, &hyper);
        }
        // Positional-table gradient: sum over examples (ascending order).
        let mut d_pos = vec![0.0f32; s * h];
        for e in 0..b {
            for (dp, &dv) in d_pos.iter_mut().zip(&dx.data[e * s * h..(e + 1) * s * h]) {
                *dp += dv;
            }
        }
        self.optim.step("embed.pos", &mut self.pos.data, &d_pos, &hyper);

        Ok((loss, stats))
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::inference::NativeModel;
    use crate::optim::OptimKind;

    pub(crate) fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_layers: 1,
            d_hid: 48,
            n_heads: 4,
            seq_len: 8,
            batch: 1,
            vocab: 27,
            n_intents: 5,
            n_slots: 7,
            tt_m: vec![4, 4, 3],
            tt_n: vec![3, 4, 4],
            tt_rank: 3,
            ttm_vocab_modes: vec![3, 3, 3],
            ttm_hid_modes: vec![4, 4, 3],
            ttm_rank: 4,
            pad_id: 0,
            cls_id: 1,
            unk_id: 2,
        }
    }

    #[test]
    fn params_roundtrip_preserves_model() {
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 7).unwrap();
        let map = model.to_params();
        let back = NativeTrainModel::from_params(&cfg, &map).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        assert_eq!(model.eval(&tokens).unwrap(), back.eval(&tokens).unwrap());
    }

    #[test]
    fn eval_matches_inference_engine() {
        // The trainable model and the merged-factor inference engine run
        // the same forward math on the same parameters.
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 8).unwrap();
        let infer = NativeModel::from_params(&cfg, &model.to_params()).unwrap();
        for tokens in [vec![1, 5, 9, 13, 0, 0, 0, 0], vec![1, 3, 2, 7, 11, 26, 0, 0]] {
            let (il_t, sl_t) = model.eval(&tokens).unwrap();
            let (il_i, sl_i) = infer.forward(&tokens).unwrap();
            let d_i = il_t
                .iter()
                .zip(&il_i)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let d_s = sl_t
                .iter()
                .zip(&sl_i)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(d_i < 1e-5, "intent logits diverge: {d_i}");
            assert!(d_s < 1e-5, "slot logits diverge: {d_s}");
        }
    }

    #[test]
    fn train_step_reports_positive_finite_loss_and_updates() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 9).unwrap();
        let tokens = vec![1, 5, 9, 13, 4, 0, 0, 0];
        let slots = vec![0, 1, 2, 3, 1, 0, 0, 0];
        let before = model.eval(&tokens).unwrap();
        let (loss, stats) = model.train_step(&tokens, &[2], &slots, 0.05).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!(stats.muls > 0);
        let after = model.eval(&tokens).unwrap();
        assert_ne!(before, after, "parameters did not move");
    }

    #[test]
    fn rejects_bad_labels() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 10).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let slots = vec![0i32; 8];
        assert!(model.train_step(&tokens, &[99], &slots, 0.01).is_err());
        let bad_slots = vec![0, 99, 0, 0, 0, 0, 0, 0];
        assert!(model.train_step(&tokens, &[1], &bad_slots, 0.01).is_err());
        // Mismatched batch shapes must fail loudly.
        assert!(model.train_step(&tokens, &[1, 2], &slots, 0.01).is_err());
        assert!(model.train_step(&tokens[..4], &[1], &slots, 0.01).is_err());
    }

    /// Two examples at the tiny config: tokens + per-position slots.
    fn two_examples() -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let tokens = vec![
            1, 5, 9, 13, 4, 0, 0, 0, // example 0
            1, 3, 2, 7, 11, 26, 6, 0, // example 1
        ];
        let intents = vec![2, 4];
        let slots = vec![
            0, 1, 2, 3, 1, 0, 0, 0, //
            0, 2, 2, 4, 5, 6, 1, 0, //
        ];
        (tokens, intents, slots)
    }

    #[test]
    fn batched_eval_matches_per_example_eval() {
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 11).unwrap();
        let (tokens, _, _) = two_examples();
        let (il, sl) = model.eval(&tokens).unwrap();
        assert_eq!(il.len(), 2 * cfg.n_intents);
        assert_eq!(sl.len(), 2 * cfg.seq_len * cfg.n_slots);
        for e in 0..2 {
            let (il_e, sl_e) = model.eval(&tokens[e * 8..(e + 1) * 8]).unwrap();
            let di = il[e * cfg.n_intents..(e + 1) * cfg.n_intents]
                .iter()
                .zip(&il_e)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let ds = sl[e * 8 * cfg.n_slots..(e + 1) * 8 * cfg.n_slots]
                .iter()
                .zip(&sl_e)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(di < 1e-5 && ds < 1e-5, "example {e}: di {di} ds {ds}");
        }
    }

    #[test]
    fn batched_loss_is_mean_of_per_example_losses() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 12).unwrap();
        let (tokens, intents, slots) = two_examples();
        // lr = 0 probes the loss without moving parameters.
        let mut per_example = Vec::new();
        for e in 0..2 {
            let (l, _) = model
                .train_step(
                    &tokens[e * 8..(e + 1) * 8],
                    &intents[e..e + 1],
                    &slots[e * 8..(e + 1) * 8],
                    0.0,
                )
                .unwrap();
            per_example.push(l);
        }
        let (batch_loss, _) = model.train_step(&tokens, &intents, &slots, 0.0).unwrap();
        let mean = (per_example[0] + per_example[1]) / 2.0;
        assert!(
            (batch_loss - mean).abs() < 1e-5,
            "batch loss {batch_loss} vs per-example mean {mean}"
        );
    }

    #[test]
    fn batched_step_is_bitwise_deterministic() {
        let cfg = tiny_cfg();
        let (tokens, intents, slots) = two_examples();
        let run = || {
            let mut model = NativeTrainModel::random_init(&cfg, 13).unwrap();
            model.set_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
            for _ in 0..3 {
                model.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
            }
            model.to_params()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "repeated batched Adam training diverged bitwise");
    }

    #[test]
    fn adam_state_is_twice_the_compressed_param_count() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 14).unwrap();
        model.set_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        let (tokens, intents, slots) = two_examples();
        assert_eq!(model.optim.allocated_state_elems(), 0);
        model.train_step(&tokens, &intents, &slots, 1e-3).unwrap();
        // After one full step every trainable tensor has a slot: Adam
        // state is exactly 2x the compressed parameter count.
        assert_eq!(
            model.optim.allocated_state_elems(),
            2 * cfg.tensor_params() as u64
        );
    }

    #[test]
    fn stateful_optimizers_fit_a_batch_and_reduce_loss() {
        // Overfit one 2-example batch: every stateful rule must cut the
        // joint loss well below its cold-start value (lr per rule:
        // momentum's effective rate is lr / (1 - mu)).
        let cfg = tiny_cfg();
        let (tokens, intents, slots) = two_examples();
        for (kind, lr) in [
            (OptimKind::Momentum, 5e-3f32),
            (OptimKind::Adam, 1e-2),
            (OptimKind::AdamW, 1e-2),
        ] {
            let mut model = NativeTrainModel::random_init(&cfg, 15).unwrap();
            model.set_optim(OptimConfig { kind, weight_decay: 1e-4, ..Default::default() });
            let (first, _) = model.train_step(&tokens, &intents, &slots, lr).unwrap();
            let mut last = first;
            for _ in 0..60 {
                let (l, _) = model.train_step(&tokens, &intents, &slots, lr).unwrap();
                last = l;
            }
            assert!(
                last < 0.6 * first,
                "{kind:?}: loss {last} vs start {first} after 60 batched steps"
            );
        }
    }
}
