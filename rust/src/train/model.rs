//! The trainable tensorized transformer: forward with activation
//! caching, hand-derived backward, and a pluggable parameter update —
//! the paper's FP -> BP -> PU loop executed natively on the rust tensor
//! substrate.
//!
//! Mini-batches ride the contraction K dimension: a `(B, S)` token
//! block runs every TT linear layer at `K = B * S` (the BTT cost model
//! is linear in K, Eqs. 20/21), and the loss-level gradients carry
//! `1/B` so every parameter gradient downstream is the batch **mean**,
//! accumulated in ascending example order by the deterministic blocked
//! kernels.
//!
//! The hot path runs the **fused schedule** ([`ComputePath`], the
//! default): Q/K/V share one input-side merge and one `Z2 = X Z1^T`
//! when their input cores are tied (`random_init` ties them; the Fig. 9
//! rescheduling as executed compute,
//! [`crate::train::layers::forward_qkv_fused`]), attention runs as one
//! batched `(B, heads, S, S)` block through the `bmm*` kernels with the
//! pad mask as an additive `-inf` bias, and TTM embedding lookups are
//! memoized per unique token id within the batch (pad tokens dominate
//! ATIS rows).  The pre-fusion reference schedule (three separate TT
//! forwards + per-example attention) stays selectable for parity tests
//! and the fused-vs-looped benchmark rows, and is the automatic
//! fallback for checkpoints whose Q/K/V input cores are not tied.
//!
//! The PU stage dispatches through [`crate::optim::ModelOptim`]:
//! SGD / momentum / Adam / AdamW, with per-parameter state in the same
//! compressed core layout as the weights.
//!
//! The parameter naming scheme is identical to the AOT manifest
//! (`python/compile/model.py` / [`crate::engine::NativeEngine`]), so a
//! trained native model exports straight into the inference engine and
//! native checkpoints interchange with PJRT ones.

use crate::config::ModelConfig;
use crate::engine::{pad_mask, ComputePath, NativeEngine, ParamMap};
use crate::optim::{LossScaler, ModelOptim, OptimConfig};
use crate::tensor::{
    ops, ContractionStats, PackedTensor, PackedVec, Precision, Tensor, TTMEmbedding, TTMatrix,
};
use crate::trace;
use crate::train::blocks::{self, LayerNormCache};
use crate::train::layers::{
    self, CheckpointMode, QkvFusedCache, QkvFusedGrads, TTLinear, TTLinearCache, TTLinearGrads,
};
use crate::util::rng::SplitMix64;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap};

/// Flat `optimizer slot name -> f32 gradient` map produced by
/// [`NativeTrainModel::forward_backward`] and consumed by
/// [`NativeTrainModel::apply_grads`].  Keys are exactly the per-core
/// PU-stage state names (the manifest naming scheme, e.g.
/// `layers.0.wq.cores.3`); under the fused QKV schedule the tied
/// input-side cores appear **once**, under `wq`'s canonical names.
/// `BTreeMap` iteration is sorted, so walking a `GradMap` — and
/// therefore the replica all-reduce built on it
/// ([`crate::replica::allreduce_fixed_order`]) — is deterministic.
pub type GradMap = BTreeMap<String, Vec<f32>>;

/// One trainable encoder block (paper Eq. 1).
#[derive(Clone)]
pub struct TrainEncoderLayer {
    pub wq: TTLinear,
    pub wk: TTLinear,
    pub wv: TTLinear,
    pub wo: TTLinear,
    pub w1: TTLinear,
    pub w2: TTLinear,
    pub ln1_g: PackedVec,
    pub ln1_b: PackedVec,
    pub ln2_g: PackedVec,
    pub ln2_b: PackedVec,
}

/// Gradient-checkpointing policy for the Eq. 21 activation caches —
/// the model-level companion of [`CheckpointMode`].  `Recompute`
/// trades the at-rest cache bytes for one extra (output-apply-free)
/// forward contraction per layer in the BP stage
/// ([`crate::costmodel::LinearShape::btt_recompute_muls`]); because the
/// rebuilt states take the exact same deterministic fold order and
/// round-on-store precision as the cached ones, f32 gradients are
/// **bitwise identical** between the two policies.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointPolicy {
    /// Every layer stores its full Eq. 21 cache (the default; the
    /// paper's schedule).
    CacheAll,
    /// Every TT linear — and the TTM embedding chains — stores only
    /// its input; the BP stage recomputes the chain states.
    Recompute,
    /// Per-encoder-block selection (index = block).  Blocks beyond the
    /// vector, and the embedding/pooler caches, stay cached.
    PerLayer(Vec<CheckpointMode>),
}

impl CheckpointPolicy {
    /// Checkpointing mode of encoder block `li`.
    pub fn layer_mode(&self, li: usize) -> CheckpointMode {
        match self {
            CheckpointPolicy::CacheAll => CheckpointMode::CacheAll,
            CheckpointPolicy::Recompute => CheckpointMode::Recompute,
            CheckpointPolicy::PerLayer(modes) => {
                modes.get(li).copied().unwrap_or(CheckpointMode::CacheAll)
            }
        }
    }

    /// Mode of the auxiliary caches outside the encoder stack (the TTM
    /// embedding chains and the pooler): they follow the global stance;
    /// `PerLayer` keeps them cached.
    pub fn aux_mode(&self) -> CheckpointMode {
        match self {
            CheckpointPolicy::Recompute => CheckpointMode::Recompute,
            CheckpointPolicy::CacheAll | CheckpointPolicy::PerLayer(_) => CheckpointMode::CacheAll,
        }
    }

    /// CLI spelling: `cache` (alias `cache-all`) or `recompute`.
    pub fn parse(s: &str) -> Result<CheckpointPolicy> {
        match s {
            "cache" | "cache-all" => Ok(CheckpointPolicy::CacheAll),
            "recompute" => Ok(CheckpointPolicy::Recompute),
            other => Err(anyhow!("unknown --checkpoint '{other}' (cache|recompute)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CheckpointPolicy::CacheAll => "cache",
            CheckpointPolicy::Recompute => "recompute",
            CheckpointPolicy::PerLayer(_) => "per-layer",
        }
    }
}

/// The full trainable model (any runtime batch size; the paper's
/// on-device setting is B = 1).
pub struct NativeTrainModel {
    pub cfg: ModelConfig,
    pub embedding: TTMEmbedding,
    pub pos: PackedTensor,
    pub layers: Vec<TrainEncoderLayer>,
    pub pool: TTLinear,
    pub intent_w: PackedTensor,
    pub intent_b: PackedVec,
    pub slot_w: PackedTensor,
    pub slot_b: PackedVec,
    /// The PU stage: pluggable per-parameter update rules + state.
    pub optim: ModelOptim,
    /// Dynamic loss scaler + non-finite step guard (the f16 overflow
    /// fix): [`NativeTrainModel::apply_grads_guarded`] skips any step
    /// whose loss or gradients are non-finite and backs the scale off,
    /// so one overflowed batch can no longer poison the moments.
    /// Checkpointed with the optimizer state (`optim.loss_scale`).
    pub scaler: LossScaler,
    /// Compute-schedule selection (fused/batched by default).
    pub compute_path: ComputePath,
    /// Storage precision of the mixed-precision path (f32 default):
    /// Eq. 21 caches, TTM chain states, optimizer moments and updated
    /// parameters are rounded/packed to this width; compute always
    /// accumulates in f32.  Set via [`NativeTrainModel::set_precision`].
    pub precision: Precision,
    /// Gradient-checkpointing policy for the Eq. 21 caches
    /// (`CacheAll` default).  Composes orthogonally with
    /// [`NativeTrainModel::precision`]: bf16 storage x `Recompute` is
    /// the full memory story.
    pub checkpoint: CheckpointPolicy,
}

/// The three separate per-projection caches of the reference schedule.
struct SeparateQkvCaches {
    wq_c: TTLinearCache,
    wk_c: TTLinearCache,
    wv_c: TTLinearCache,
}

/// QKV projection cache: fused (shared input side, stored once) or the
/// boxed separate caches of the reference schedule.
enum QkvFwd {
    Fused(QkvFusedCache),
    Separate(Box<SeparateQkvCaches>),
}

/// Attention probabilities: one batched `(B*heads, S, S)` tensor, or
/// one `(heads, S, S)` tensor per example (looped reference).
enum AttnFwd {
    Batched(Tensor),
    PerExample(Vec<Tensor>),
}

/// Per-block forward activations kept for the BP stage (all `(B*S, H)`
/// except the attention probabilities).
struct LayerFwd {
    q: Tensor,
    k: Tensor,
    v: Tensor,
    attn: AttnFwd,
    qkv: QkvFwd,
    wo_c: TTLinearCache,
    ln1_c: LayerNormCache,
    /// Post-LN1 activations (input of the FFN and of residual 2).
    x1: Tensor,
    /// FFN hidden pre-GELU.
    h1: Tensor,
    w1_c: TTLinearCache,
    w2_c: TTLinearCache,
    ln2_c: LayerNormCache,
}

/// Whole-step forward cache.
struct ForwardCaches {
    /// Examples in this block.
    batch: usize,
    mask: Vec<f32>,
    /// TTM chain states per **unique** token id in the block
    /// (first-appearance order) — the memoized embedding cache.
    emb_unique: Vec<(i32, Vec<Tensor>)>,
    /// Per-position index into `emb_unique`.
    emb_index: Vec<usize>,
    layer_fwd: Vec<LayerFwd>,
    pool_c: TTLinearCache,
    pooled: Tensor,
    /// CLS rows of `pooled`, `(B, H)`.
    cls: Tensor,
    /// `(B, n_intents)` row-major.
    intent_logits: Tensor,
    /// `(B*S, n_slots)` row-major.
    slot_logits: Tensor,
}

/// Copy `nrows` rows starting at `r0` out of a 2-D tensor.  Only the
/// **looped reference schedule** materializes per-example sub-tensors
/// this way; the batched hot path slices the K-stacked buffers directly
/// inside [`ops::pack_heads_batched`].
fn rows(t: &Tensor, r0: usize, nrows: usize) -> Result<Tensor> {
    let w = t.shape[1];
    Tensor::from_vec(t.data[r0 * w..(r0 + nrows) * w].to_vec(), &[nrows, w])
}

/// Fetch a flat-vector gradient from the map, enforcing presence and
/// length (the optimizer only debug-asserts lengths, so the release
/// path must check here).
fn expect_grad<'a>(grads: &'a GradMap, name: &str, len: usize) -> Result<&'a Vec<f32>> {
    let g = grads
        .get(name)
        .ok_or_else(|| anyhow!("apply_grads: missing gradient '{name}'"))?;
    if g.len() != len {
        return Err(anyhow!(
            "apply_grads: gradient '{name}' has {} elements, parameter has {len}",
            g.len()
        ));
    }
    Ok(g)
}

/// Move one TT linear's gradients into the map under its per-core
/// slot names (`{prefix}.cores.{k}` / `{prefix}.bias`).
fn insert_linear_grads(map: &mut GradMap, prefix: &str, g: TTLinearGrads) {
    for (k, core) in g.cores.into_iter().enumerate() {
        map.insert(format!("{prefix}.cores.{k}"), core.data);
    }
    map.insert(format!("{prefix}.bias"), g.bias);
}

/// Move a fused-QKV gradient set into the map: per-projection output
/// cores and biases under their own names, the **shared** input-side
/// core gradients (already summed over q/k/v) once under `wq`'s
/// canonical slots — exactly the state keys
/// [`layers::apply_update_qkv_fused`] steps, so the map mirrors the
/// PU-stage footprint (1x, not 3x, for the tied cores).
fn insert_qkv_fused_grads(map: &mut GradMap, layer_prefix: &str, g: QkvFusedGrads) {
    let d = g.n_cores.len();
    let QkvFusedGrads { m_cores, n_cores, bias } = g;
    for ((cores, b), name) in m_cores.into_iter().zip(bias).zip(["wq", "wk", "wv"]) {
        for (k, core) in cores.into_iter().enumerate() {
            map.insert(format!("{layer_prefix}.{name}.cores.{k}"), core.data);
        }
        map.insert(format!("{layer_prefix}.{name}.bias"), b);
    }
    for (k, core) in n_cores.into_iter().enumerate() {
        map.insert(format!("{layer_prefix}.wq.cores.{}", d + k), core.data);
    }
}

/// Rebuild a [`TTLinearGrads`] for `lin` from the map (inverse of
/// [`insert_linear_grads`]); a missing name or a shape mismatch is a
/// hard error, never a silently skipped update.
fn gather_linear_grads(grads: &GradMap, prefix: &str, lin: &TTLinear) -> Result<TTLinearGrads> {
    let tt = lin.tt();
    let mut cores = Vec::with_capacity(tt.cores.len());
    for (k, core) in tt.cores.iter().enumerate() {
        let name = format!("{prefix}.cores.{k}");
        let g = grads
            .get(&name)
            .ok_or_else(|| anyhow!("apply_grads: missing gradient '{name}'"))?;
        cores.push(Tensor::from_vec(g.clone(), &core.shape)?);
    }
    let name = format!("{prefix}.bias");
    let bias = grads
        .get(&name)
        .ok_or_else(|| anyhow!("apply_grads: missing gradient '{name}'"))?;
    if bias.len() != tt.m() {
        return Err(anyhow!(
            "apply_grads: gradient '{name}' has {} elements, bias has {}",
            bias.len(),
            tt.m()
        ));
    }
    Ok(TTLinearGrads { cores, bias: bias.clone() })
}

/// Rebuild a [`QkvFusedGrads`] from the map (inverse of
/// [`insert_qkv_fused_grads`]).
fn gather_qkv_fused_grads(
    grads: &GradMap,
    layer_prefix: &str,
    layer: &TrainEncoderLayer,
) -> Result<QkvFusedGrads> {
    let fetch = |name: String, shape: &[usize]| -> Result<Tensor> {
        let g = grads
            .get(&name)
            .ok_or_else(|| anyhow!("apply_grads: missing gradient '{name}'"))?;
        Tensor::from_vec(g.clone(), shape)
    };
    let qtt = layer.wq.tt();
    let d = qtt.d();
    let mut m_cores: [Vec<Tensor>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    let mut bias: [Vec<f32>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for (i, (name, lin)) in [("wq", &layer.wq), ("wk", &layer.wk), ("wv", &layer.wv)]
        .into_iter()
        .enumerate()
    {
        let tt = lin.tt();
        for k in 0..d {
            m_cores[i]
                .push(fetch(format!("{layer_prefix}.{name}.cores.{k}"), &tt.cores[k].shape)?);
        }
        let bname = format!("{layer_prefix}.{name}.bias");
        let b = grads
            .get(&bname)
            .ok_or_else(|| anyhow!("apply_grads: missing gradient '{bname}'"))?;
        if b.len() != tt.m() {
            return Err(anyhow!(
                "apply_grads: gradient '{bname}' has {} elements, bias has {}",
                b.len(),
                tt.m()
            ));
        }
        bias[i] = b.clone();
    }
    let mut n_cores = Vec::with_capacity(d);
    for k in 0..d {
        n_cores.push(fetch(
            format!("{layer_prefix}.wq.cores.{}", d + k),
            &qtt.cores[d + k].shape,
        )?);
    }
    Ok(QkvFusedGrads { m_cores, n_cores, bias })
}

fn validate_cfg(cfg: &ModelConfig) -> Result<()> {
    let tt_m: usize = cfg.tt_m.iter().product();
    let tt_n: usize = cfg.tt_n.iter().product();
    let ttm_h: usize = cfg.ttm_hid_modes.iter().product();
    let ttm_v: usize = cfg.ttm_vocab_modes.iter().product();
    if tt_m != cfg.d_hid || tt_n != cfg.d_hid || ttm_h != cfg.d_hid {
        return Err(anyhow!(
            "mode products ({tt_m}, {tt_n}, {ttm_h}) must equal d_hid {}",
            cfg.d_hid
        ));
    }
    if ttm_v < cfg.vocab {
        return Err(anyhow!("vocab modes cover {ttm_v} < vocab {}", cfg.vocab));
    }
    Ok(())
}

impl NativeTrainModel {
    /// Seeded random initialization mirroring
    /// `python/compile/model.py::init_params` (TTM/pos std 0.02, linear
    /// target std sqrt(1/d_hid), LayerNorm (1, 0), head std
    /// sqrt(1/d_hid)), with the Q/K/V input-side cores **tied** so the
    /// fused schedule applies ([`NativeTrainModel::random_init_untied`]
    /// keeps the paper's independent parameterization).
    pub fn random_init(cfg: &ModelConfig, seed: u64) -> Result<NativeTrainModel> {
        Self::random_init_impl(cfg, seed, true)
    }

    /// [`NativeTrainModel::random_init`] without the Q/K/V input-core
    /// tying: the paper's (and the pre-fusion trainer's) independent
    /// parameterization, bitwise-identical to the old init at the same
    /// seed.  Such a model runs separate QKV forwards regardless of
    /// [`ComputePath::fused_qkv`] — use it when loss trajectories must
    /// be comparable to independent-QKV baselines.
    pub fn random_init_untied(cfg: &ModelConfig, seed: u64) -> Result<NativeTrainModel> {
        Self::random_init_impl(cfg, seed, false)
    }

    fn random_init_impl(cfg: &ModelConfig, seed: u64, tie_qkv: bool) -> Result<NativeTrainModel> {
        validate_cfg(cfg)?;
        let mut rng = SplitMix64::new(seed);
        let lin_std = (1.0 / cfg.d_hid as f32).sqrt();
        let linear =
            |rng: &mut SplitMix64| TTLinear::randn(&cfg.tt_m, &cfg.tt_n, cfg.tt_rank, lin_std, rng);
        let embedding = TTMEmbedding::randn(
            &cfg.ttm_hid_modes,
            &cfg.ttm_vocab_modes,
            cfg.ttm_rank,
            0.02,
            &mut rng,
        );
        let pos = Tensor::randn(&[cfg.seq_len, cfg.d_hid], 0.02, &mut rng);
        let layers = (0..cfg.n_layers)
            .map(|_| {
                let wq = linear(&mut rng);
                let mut wk = linear(&mut rng);
                let mut wv = linear(&mut rng);
                // Tie the input-side cores across Q/K/V: the fused QKV
                // schedule shares one right merge and one Z2 across the
                // three projections (Fig. 9 rescheduling, executed);
                // `apply_update_qkv_fused` keeps the tie in lockstep.
                // (wk/wv draw their full randn first so the RNG stream —
                // and therefore every untied tensor — is identical
                // between the tied and untied inits.)
                if tie_qkv {
                    let src = wq.tt().into_owned();
                    let d = src.d();
                    for w in [&mut wk, &mut wv] {
                        w.update_tt(|tt| {
                            for c in d..2 * d {
                                tt.cores[c] = src.cores[c].clone();
                            }
                        });
                    }
                }
                TrainEncoderLayer {
                    wq,
                    wk,
                    wv,
                    wo: linear(&mut rng),
                    w1: linear(&mut rng),
                    w2: linear(&mut rng),
                    ln1_g: PackedVec::from_f32(Precision::F32, &vec![1.0; cfg.d_hid]),
                    ln1_b: PackedVec::from_f32(Precision::F32, &vec![0.0; cfg.d_hid]),
                    ln2_g: PackedVec::from_f32(Precision::F32, &vec![1.0; cfg.d_hid]),
                    ln2_b: PackedVec::from_f32(Precision::F32, &vec![0.0; cfg.d_hid]),
                }
            })
            .collect();
        let pool = linear(&mut rng);
        let head_std = (1.0 / cfg.d_hid as f32).sqrt();
        Ok(NativeTrainModel {
            cfg: cfg.clone(),
            embedding,
            pos: PackedTensor::pack_owned(pos, Precision::F32),
            layers,
            pool,
            intent_w: PackedTensor::pack_owned(
                Tensor::randn(&[cfg.n_intents, cfg.d_hid], head_std, &mut rng),
                Precision::F32,
            ),
            intent_b: PackedVec::from_f32(Precision::F32, &vec![0.0; cfg.n_intents]),
            slot_w: PackedTensor::pack_owned(
                Tensor::randn(&[cfg.n_slots, cfg.d_hid], head_std, &mut rng),
                Precision::F32,
            ),
            slot_b: PackedVec::from_f32(Precision::F32, &vec![0.0; cfg.n_slots]),
            optim: ModelOptim::new(OptimConfig::default()),
            scaler: LossScaler::new(),
            compute_path: ComputePath::default(),
            precision: Precision::F32,
            checkpoint: CheckpointPolicy::CacheAll,
        })
    }

    /// Assemble from a flat name -> array map (manifest naming scheme).
    pub fn from_params(cfg: &ModelConfig, params: &ParamMap) -> Result<NativeTrainModel> {
        validate_cfg(cfg)?;
        let get = |name: &str| -> Result<(&Vec<usize>, &Vec<f32>)> {
            params
                .get(name)
                .map(|(s, d)| (s, d))
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))
        };
        let tensor = |name: &str| -> Result<Tensor> {
            let (shape, data) = get(name)?;
            Tensor::from_vec(data.clone(), shape)
        };
        let vec1 = |name: &str| -> Result<Vec<f32>> { Ok(get(name)?.1.clone()) };

        let d = cfg.ttm_vocab_modes.len();
        let mut ttm_cores = Vec::with_capacity(d);
        for k in 0..d {
            ttm_cores.push(tensor(&format!("embed.ttm.{k}"))?);
        }
        let mut ranks = vec![cfg.ttm_rank; d + 1];
        ranks[0] = 1;
        ranks[d] = 1;
        let embedding = TTMEmbedding {
            cores: ttm_cores
                .into_iter()
                .map(|t| PackedTensor::pack_owned(t, Precision::F32))
                .collect(),
            hid_modes: cfg.ttm_hid_modes.clone(),
            vocab_modes: cfg.ttm_vocab_modes.clone(),
            ranks,
        };

        let tt_linear = |prefix: &str| -> Result<TTLinear> {
            let d2 = cfg.tt_m.len() + cfg.tt_n.len();
            let mut cores = Vec::with_capacity(d2);
            for k in 0..d2 {
                cores.push(tensor(&format!("{prefix}.cores.{k}"))?);
            }
            let tt = TTMatrix {
                cores,
                m_modes: cfg.tt_m.clone(),
                n_modes: cfg.tt_n.clone(),
                ranks: cfg.tt_ranks(),
            };
            TTLinear::new(tt, vec1(&format!("{prefix}.bias"))?)
        };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |name: &str| format!("layers.{i}.{name}");
            layers.push(TrainEncoderLayer {
                wq: tt_linear(&p("wq"))?,
                wk: tt_linear(&p("wk"))?,
                wv: tt_linear(&p("wv"))?,
                wo: tt_linear(&p("wo"))?,
                w1: tt_linear(&p("w1"))?,
                w2: tt_linear(&p("w2"))?,
                ln1_g: PackedVec::from_f32(Precision::F32, &vec1(&p("ln1.g"))?),
                ln1_b: PackedVec::from_f32(Precision::F32, &vec1(&p("ln1.b"))?),
                ln2_g: PackedVec::from_f32(Precision::F32, &vec1(&p("ln2.g"))?),
                ln2_b: PackedVec::from_f32(Precision::F32, &vec1(&p("ln2.b"))?),
            });
        }

        Ok(NativeTrainModel {
            cfg: cfg.clone(),
            embedding,
            pos: PackedTensor::pack_owned(tensor("embed.pos")?, Precision::F32),
            layers,
            pool: tt_linear("cls.pool")?,
            intent_w: PackedTensor::pack_owned(tensor("cls.intent_w")?, Precision::F32),
            intent_b: PackedVec::from_f32(Precision::F32, &vec1("cls.intent_b")?),
            slot_w: PackedTensor::pack_owned(tensor("cls.slot_w")?, Precision::F32),
            slot_b: PackedVec::from_f32(Precision::F32, &vec1("cls.slot_b")?),
            optim: ModelOptim::new(OptimConfig::default()),
            scaler: LossScaler::new(),
            // Fused by default; layers whose loaded Q/K/V input cores
            // are not tied fall back to separate forwards per layer.
            compute_path: ComputePath::default(),
            precision: Precision::F32,
            checkpoint: CheckpointPolicy::CacheAll,
        })
    }

    /// Swap the PU-stage update rule.  Existing optimizer state is
    /// dropped (it belongs to the previous rule).  The config's storage
    /// precision is applied to the whole model ([`
    /// NativeTrainModel::set_precision`]), so model and PU-stage
    /// precision can never desync regardless of builder order — the
    /// last precision written (here or via `set_precision`) wins for
    /// both.
    pub fn set_optim(&mut self, cfg: OptimConfig) {
        let prec = cfg.precision;
        self.optim = ModelOptim::new(cfg);
        self.set_precision(prec);
    }

    /// Visit every trainable parameter buffer exactly once (widened to
    /// f32 for the duration of the visit) — the same parameter set
    /// [`NativeTrainModel::to_params`] exports and the PU stage updates.
    /// Test-only: production code touches the packed stores directly;
    /// the visitor exists so the structural walk/export agreement stays
    /// pinned (`param_visitor_covers_exactly_the_exported_set`).
    #[cfg(test)]
    fn for_each_param_mut(&mut self, mut f: impl FnMut(&mut [f32])) {
        for core in &mut self.embedding.cores {
            core.update_in_place(|d| f(d));
        }
        self.pos.update_in_place(|d| f(d));
        for layer in &mut self.layers {
            for lin in [
                &mut layer.wq,
                &mut layer.wk,
                &mut layer.wv,
                &mut layer.wo,
                &mut layer.w1,
                &mut layer.w2,
            ] {
                lin.update_tt(|tt| {
                    for core in &mut tt.cores {
                        f(&mut core.data);
                    }
                });
                lin.update_bias(|b| f(b));
            }
            layer.ln1_g.update_in_place(|d| f(d));
            layer.ln1_b.update_in_place(|d| f(d));
            layer.ln2_g.update_in_place(|d| f(d));
            layer.ln2_b.update_in_place(|d| f(d));
        }
        self.pool.update_tt(|tt| {
            for core in &mut tt.cores {
                f(&mut core.data);
            }
        });
        self.pool.update_bias(|b| f(b));
        self.intent_w.update_in_place(|d| f(d));
        self.intent_b.update_in_place(|d| f(d));
        self.slot_w.update_in_place(|d| f(d));
        self.slot_b.update_in_place(|d| f(d));
    }

    /// Select the storage precision of the whole mixed-precision path:
    /// Eq. 21 caches and TTM chain states are packed at this width, the
    /// PU stage keeps its moments at this width and rounds every
    /// updated parameter on store — and every parameter store is
    /// physically **re-packed** at the new width.  Entering a half
    /// format therefore both rounds every current parameter once
    /// (weights at rest are exactly representable from the first step)
    /// and actually halves the at-rest parameter bytes: TT/BTT cores,
    /// biases and the LN/positional/classifier tables live in u16
    /// buffers, widened to f32 on load for the unchanged f32-accumulate
    /// kernels.  Compute accumulates in f32 throughout;
    /// `Precision::F32` restores the bitwise full-precision path
    /// (widening is exact, so already-rounded parameters are not
    /// altered).
    pub fn set_precision(&mut self, p: Precision) {
        self.precision = p;
        // Re-packs any already-allocated moment buffers too, so the
        // PU-stage state width tracks the model mid-lifecycle.
        self.optim.set_precision(p);
        self.embedding.set_precision(p);
        self.pos.set_precision(p);
        for layer in &mut self.layers {
            for lin in [
                &mut layer.wq,
                &mut layer.wk,
                &mut layer.wv,
                &mut layer.wo,
                &mut layer.w1,
                &mut layer.w2,
            ] {
                lin.set_precision(p);
            }
            layer.ln1_g.set_precision(p);
            layer.ln1_b.set_precision(p);
            layer.ln2_g.set_precision(p);
            layer.ln2_b.set_precision(p);
        }
        self.pool.set_precision(p);
        self.intent_w.set_precision(p);
        self.intent_b.set_precision(p);
        self.slot_w.set_precision(p);
        self.slot_b.set_precision(p);
    }

    /// Export all parameters as a flat name -> array map (the inverse of
    /// [`NativeTrainModel::from_params`]; feeds
    /// [`crate::engine::NativeEngine`] and checkpointing).
    pub fn to_params(&self) -> ParamMap {
        let mut map = ParamMap::new();
        let put_t = |map: &mut ParamMap, name: String, t: &Tensor| {
            map.insert(name, (t.shape.clone(), t.data.clone()));
        };
        let put_v = |map: &mut ParamMap, name: String, v: &[f32]| {
            map.insert(name, (vec![v.len()], v.to_vec()));
        };
        for (k, core) in self.embedding.cores.iter().enumerate() {
            put_t(&mut map, format!("embed.ttm.{k}"), &core.view());
        }
        put_t(&mut map, "embed.pos".to_string(), &self.pos.view());
        for (i, layer) in self.layers.iter().enumerate() {
            let lins = [
                ("wq", &layer.wq),
                ("wk", &layer.wk),
                ("wv", &layer.wv),
                ("wo", &layer.wo),
                ("w1", &layer.w1),
                ("w2", &layer.w2),
            ];
            for (name, lin) in lins {
                let tt = lin.tt();
                for (k, core) in tt.cores.iter().enumerate() {
                    put_t(&mut map, format!("layers.{i}.{name}.cores.{k}"), core);
                }
                put_v(&mut map, format!("layers.{i}.{name}.bias"), &lin.bias());
            }
            put_v(&mut map, format!("layers.{i}.ln1.g"), &layer.ln1_g.view());
            put_v(&mut map, format!("layers.{i}.ln1.b"), &layer.ln1_b.view());
            put_v(&mut map, format!("layers.{i}.ln2.g"), &layer.ln2_g.view());
            put_v(&mut map, format!("layers.{i}.ln2.b"), &layer.ln2_b.view());
        }
        let pool_tt = self.pool.tt();
        for (k, core) in pool_tt.cores.iter().enumerate() {
            put_t(&mut map, format!("cls.pool.cores.{k}"), core);
        }
        put_v(&mut map, "cls.pool.bias".to_string(), &self.pool.bias());
        put_t(&mut map, "cls.intent_w".to_string(), &self.intent_w.view());
        put_v(&mut map, "cls.intent_b".to_string(), &self.intent_b.view());
        put_t(&mut map, "cls.slot_w".to_string(), &self.slot_w.view());
        put_v(&mut map, "cls.slot_b".to_string(), &self.slot_b.view());
        map
    }

    /// Forward pass with full activation caching over a `(B, S)` token
    /// block (row-major).  Every TT linear layer runs at `K = B * S`;
    /// attention runs batched over `(B, heads, S, S)` without mixing
    /// examples (pooling stays per example), per the selected
    /// [`ComputePath`].
    fn forward_train(&self, tokens: &[i32], stats: &mut ContractionStats) -> Result<ForwardCaches> {
        let cfg = &self.cfg;
        let (s, h) = (cfg.seq_len, cfg.d_hid);
        if tokens.is_empty() || tokens.len() % s != 0 {
            return Err(anyhow!(
                "tokens must be (B, {s}) row-major, got {} ids",
                tokens.len()
            ));
        }
        let b = tokens.len() / s;
        let k_rows = b * s;
        let mask = pad_mask(tokens, cfg.pad_id);

        // Embedding: TTM lookup memoized per **unique** token id in the
        // block (pad tokens dominate ATIS rows, so most of the B*S
        // positions reuse a chain that was already contracted) +
        // positional table per slot.  Under a half-precision storage
        // path each chain state is rounded on store *before* the next
        // fold consumes it (lookup_cached_prec), so the stored chain is
        // exactly the chain the forward computed through.
        let prec = self.precision;
        let aux_recompute = self.checkpoint.aux_mode() == CheckpointMode::Recompute;
        let sp_embed = trace::span("train", "fp.embed");
        // Widen the positional table once per forward (Borrowed at f32).
        let pos = self.pos.view();
        let mut x = Tensor::zeros(&[k_rows, h]);
        let mut emb_unique: Vec<(i32, Vec<Tensor>)> = Vec::new();
        let mut emb_index = Vec::with_capacity(k_rows);
        let mut index_of: HashMap<i32, usize> = HashMap::new();
        for (i, &t) in tokens.iter().enumerate() {
            let ui = match index_of.get(&t) {
                Some(&ui) => ui,
                None => {
                    let (_, mut states) = self.embedding.lookup_cached_prec(t as usize, prec)?;
                    // Recompute policy: keep only the final chain state
                    // (the embedding row consumed below); the VJP
                    // re-runs the lookup chain per unique token.
                    if aux_recompute && states.len() > 1 {
                        states.drain(..states.len() - 1);
                    }
                    emb_unique.push((t, states));
                    index_of.insert(t, emb_unique.len() - 1);
                    emb_unique.len() - 1
                }
            };
            // The last chain state is the embedding row (hidden, 1).
            let row = &emb_unique[ui].1.last().expect("nonempty").data;
            let p = i % s;
            for j in 0..h {
                x.data[i * h + j] = row[j] + pos.at2(p, j);
            }
            emb_index.push(ui);
        }
        drop(sp_embed);

        let bias = ops::attention_bias_from_mask(&mask);
        let mut layer_fwd = Vec::with_capacity(self.layers.len());
        for (li, layer) in self.layers.iter().enumerate() {
            let _sp_layer = trace::span_fmt("train", || format!("fp.layer{li}"));
            // Per-block checkpointing mode: what this block's TT caches
            // retain for the BP stage.
            let mode = self.checkpoint.layer_mode(li);
            // QKV projections: the fused schedule shares the input-side
            // merge and Z2 across Q/K/V whenever the input cores are
            // tied; otherwise (or when the looped reference schedule is
            // selected) run three separate TT forwards.
            let (q, k, v, qkv) = if self.compute_path.fused_qkv
                && layers::qkv_input_cores_shared(&layer.wq, &layer.wk, &layer.wv)
            {
                let ([q, k, v], c) = layers::forward_qkv_fused_ckpt(
                    &layer.wq, &layer.wk, &layer.wv, &x, prec, mode, stats,
                )?;
                (q, k, v, QkvFwd::Fused(c))
            } else {
                let (q, wq_c) = layer.wq.forward_ckpt(&x, prec, mode, stats)?;
                let (k, wk_c) = layer.wk.forward_ckpt(&x, prec, mode, stats)?;
                let (v, wv_c) = layer.wv.forward_ckpt(&x, prec, mode, stats)?;
                let caches = Box::new(SeparateQkvCaches { wq_c, wk_c, wv_c });
                (q, k, v, QkvFwd::Separate(caches))
            };
            // Attention never mixes examples: the batched kernel runs
            // the whole (B, heads, S, S) block with the pad mask as an
            // additive bias; the looped reference slices per example.
            let (ctx, attn) = if self.compute_path.batched_attention {
                let (ctx, probs) =
                    ops::multi_head_attention_batched(&q, &k, &v, &bias, cfg.n_heads, b)?;
                (ctx, AttnFwd::Batched(probs))
            } else {
                let mut ctx = Tensor::zeros(&[k_rows, h]);
                let mut probs = Vec::with_capacity(b);
                for e in 0..b {
                    let qe = rows(&q, e * s, s)?;
                    let ke = rows(&k, e * s, s)?;
                    let ve = rows(&v, e * s, s)?;
                    let (ctx_e, probs_e) = ops::multi_head_attention(
                        &qe,
                        &ke,
                        &ve,
                        &mask[e * s..(e + 1) * s],
                        cfg.n_heads,
                    )?;
                    ctx.data[e * s * h..(e + 1) * s * h].copy_from_slice(&ctx_e.data);
                    probs.push(probs_e);
                }
                (ctx, AttnFwd::PerExample(probs))
            };
            // Elementwise tail of the block: fused lanes run the bias
            // add, residual add and LayerNorm (resp. bias add + GELU)
            // inside one pass over the TT-apply output, so the
            // post-bias/post-residual intermediates never round-trip
            // through memory; the unfused reference materializes each.
            // Same scalar order per element, so the two are bitwise
            // identical at every precision (pinned by parity tests).
            let (x2, wo_c, ln1_c, x1, h1, w1_c, w2_c, ln2_c) = if self
                .compute_path
                .fused_elementwise
            {
                let (o_raw, wo_c) = layer.wo.forward_ckpt_raw(&ctx, prec, mode, stats)?;
                let (x1, ln1_c) = blocks::bias_residual_layer_norm_fwd(
                    &o_raw,
                    &layer.wo.bias(),
                    &x,
                    &layer.ln1_g.view(),
                    &layer.ln1_b.view(),
                    1e-5,
                );
                let (h1_raw, w1_c) = layer.w1.forward_ckpt_raw(&x1, prec, mode, stats)?;
                let (h1, g1) = ops::bias_gelu(&h1_raw, &layer.w1.bias());
                let (ffn_raw, w2_c) = layer.w2.forward_ckpt_raw(&g1, prec, mode, stats)?;
                let (x2, ln2_c) = blocks::bias_residual_layer_norm_fwd(
                    &ffn_raw,
                    &layer.w2.bias(),
                    &x1,
                    &layer.ln2_g.view(),
                    &layer.ln2_b.view(),
                    1e-5,
                );
                (x2, wo_c, ln1_c, x1, h1, w1_c, w2_c, ln2_c)
            } else {
                let (o, wo_c) = layer.wo.forward_ckpt(&ctx, prec, mode, stats)?;
                let res1 = ops::add(&x, &o);
                let (x1, ln1_c) =
                    blocks::layer_norm_fwd(&res1, &layer.ln1_g.view(), &layer.ln1_b.view(), 1e-5);
                let (h1, w1_c) = layer.w1.forward_ckpt(&x1, prec, mode, stats)?;
                let g1 = ops::gelu(&h1);
                let (ffn, w2_c) = layer.w2.forward_ckpt(&g1, prec, mode, stats)?;
                let res2 = ops::add(&x1, &ffn);
                let (x2, ln2_c) =
                    blocks::layer_norm_fwd(&res2, &layer.ln2_g.view(), &layer.ln2_b.view(), 1e-5);
                (x2, wo_c, ln1_c, x1, h1, w1_c, w2_c, ln2_c)
            };
            layer_fwd.push(LayerFwd {
                q,
                k,
                v,
                attn,
                qkv,
                wo_c,
                ln1_c,
                x1,
                h1,
                w1_c,
                w2_c,
                ln2_c,
            });
            x = x2;
        }

        let _sp_heads = trace::span("train", "fp.heads");
        let (pool_pre, pool_c) =
            self.pool.forward_ckpt(&x, prec, self.checkpoint.aux_mode(), stats)?;
        let pooled = ops::tanh(&pool_pre);
        // Per-example CLS rows drive the intent head.
        let cls = ops::cls_rows(&pooled, b, s)?;
        let intent = ops::add_row(&cls.matmul(&self.intent_w.view().t()?)?, &self.intent_b.view());
        let slots = ops::add_row(&pooled.matmul(&self.slot_w.view().t()?)?, &self.slot_b.view());
        Ok(ForwardCaches {
            batch: b,
            mask,
            emb_unique,
            emb_index,
            layer_fwd,
            pool_c,
            pooled,
            cls,
            intent_logits: intent,
            slot_logits: slots,
        })
    }

    /// Run a cached forward over a `(B, S)` token block and return the
    /// summed [`TTLinearCache::stored_bytes`] /
    /// [`QkvFusedCache::stored_bytes`] of every live Eq. 21 cache
    /// (QKV + wo/w1/w2 per encoder block, plus the pooler) — the
    /// **executed** counterpart of
    /// [`crate::fpga::resources::ResourceReport::eq21_cache_bytes`].
    /// The caches are the single source of truth: for the default
    /// fused-QKV schedule (which the resource report models) the
    /// analytic formula is property-tested equal to this sum; an
    /// untied/looped model stores three separate per-projection caches
    /// per layer and measures higher than the fused-schedule report.
    /// (The TTM embedding chain states are not Eq. 21 memory and are
    /// excluded, as in the resource model.)
    pub fn measure_eq21_cache_bytes(&self, tokens: &[i32]) -> Result<u64> {
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;
        Ok(Self::eq21_bytes_of(&fwd))
    }

    /// Summed at-rest bytes of a forward's live Eq. 21 caches — shared
    /// by [`NativeTrainModel::measure_eq21_cache_bytes`] and the
    /// `eq21_cache_bytes` gauge [`NativeTrainModel::train_step`] samples
    /// at the FP -> BP boundary (so the gauge observes the step's own
    /// caches instead of paying a second forward).
    fn eq21_bytes_of(fwd: &ForwardCaches) -> u64 {
        let mut total = fwd.pool_c.stored_bytes();
        for f in &fwd.layer_fwd {
            total += match &f.qkv {
                QkvFwd::Fused(c) => c.stored_bytes(),
                QkvFwd::Separate(c) => {
                    c.wq_c.stored_bytes() + c.wk_c.stored_bytes() + c.wv_c.stored_bytes()
                }
            };
            total += f.wo_c.stored_bytes() + f.w1_c.stored_bytes() + f.w2_c.stored_bytes();
        }
        total
    }

    /// **Measured** at-rest parameter bytes: the sum of the actual
    /// packed buffer sizes of every trainable store
    /// [`NativeTrainModel::to_params`] exports (TT/TTM cores, biases,
    /// LN/positional/classifier tables) — u16-backed under a half
    /// storage width, f32 otherwise.  Because every exported parameter
    /// is physically packed, this agrees exactly with the analytic
    /// `element count x Precision::bytes` convention the
    /// width-parameterized U50 report uses (pinned by the
    /// `param_bytes` gauge cross-check test).
    pub fn param_bytes(&self) -> u64 {
        let mut total = self.embedding.bytes() + self.pos.bytes();
        for layer in &self.layers {
            for lin in [
                &layer.wq, &layer.wk, &layer.wv, &layer.wo, &layer.w1, &layer.w2,
            ] {
                total += lin.param_bytes();
            }
            total += layer.ln1_g.bytes()
                + layer.ln1_b.bytes()
                + layer.ln2_g.bytes()
                + layer.ln2_b.bytes();
        }
        total
            + self.pool.param_bytes()
            + self.intent_w.bytes()
            + self.intent_b.bytes()
            + self.slot_w.bytes()
            + self.slot_b.bytes()
    }

    /// Inference (same contract as the PJRT engine's eval): returns
    /// `(intent_logits (B*n_intents), slot_logits (B*S*n_slots))`
    /// row-major for a `(B, S)` token block.
    pub fn eval(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;
        Ok((fwd.intent_logits.data, fwd.slot_logits.data))
    }

    /// Snapshot the current parameters into a serving
    /// [`NativeEngine`] inheriting this model's [`ComputePath`] and
    /// [`Precision`].  The engine's merged-factor forward is bitwise
    /// identical to [`NativeTrainModel::eval`] (the merge chains and
    /// rounding points coincide; pinned by parity tests), so training
    /// and deployment cannot drift.
    pub fn engine(&self) -> Result<NativeEngine> {
        NativeEngine::from_params_with(
            &self.cfg,
            &self.to_params(),
            self.compute_path,
            self.precision,
        )
    }

    /// One training step (FP -> BP -> PU) over a `(B, S)` mini-batch:
    /// forward with caching, joint cross-entropy averaged over the
    /// batch, hand-derived backward at `K = B * S`, and optimizer
    /// updates on the full gradient set.  Returns
    /// `(mean loss, step stats)`.
    ///
    /// Implemented as [`Self::forward_backward`] followed by
    /// [`Self::apply_grads`].  This split is **bitwise identical** to
    /// the historical interleaved schedule (each update fired as soon
    /// as its gradient existed): the backward reads every parameter
    /// strictly before that parameter's own update, and per-parameter
    /// optimizer slots are independent, so deferring all PU work after
    /// the full BP changes no value anywhere.  The split is what lets
    /// [`crate::replica`] run N backward passes concurrently and step
    /// once on the reduced gradients.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
        lr: f32,
    ) -> Result<(f32, ContractionStats)> {
        let (loss, grads, stats) = self.forward_backward(tokens, intent, slots)?;
        self.apply_grads_guarded(loss, &grads, lr)?;
        // PU -> next-FP stage boundary: moments now reflect this step.
        if trace::enabled() {
            trace::gauge_set("optim_state_bytes", self.optim.allocated_state_bytes());
            trace::counter_add("train_steps_total", 1);
        }
        Ok((loss, stats))
    }

    /// PU stage behind the overflow guard: scans the loss and every
    /// gradient for non-finite values before any state is touched.  A
    /// clean step applies normally and feeds the [`LossScaler`]'s
    /// good-step run; an overflowed step (f16 forward past 65504, a
    /// poisoned batch, …) is **skipped entirely** — parameters and
    /// moments untouched, loss scale backed off — so one bad batch can
    /// no longer write inf/NaN into the Adam moments and every packed
    /// store after them.  Returns `true` iff the update was applied.
    ///
    /// On finite steps this is bitwise [`Self::apply_grads`]; every
    /// single-model and data-parallel PU path
    /// ([`Self::train_step`], [`crate::replica::ReplicaGroup`]) goes
    /// through here so the guard cannot be bypassed by construction.
    pub fn apply_grads_guarded(&mut self, loss: f32, grads: &GradMap, lr: f32) -> Result<bool> {
        let finite = LossScaler::step_is_finite(loss, grads.values().flatten());
        if !finite {
            self.scaler.on_overflow();
            if trace::enabled() {
                trace::counter_add("train_steps_skipped_nonfinite", 1);
                trace::gauge_set("loss_scale", self.scaler.scale() as u64);
            }
            return Ok(false);
        }
        self.apply_grads(grads, lr)?;
        self.scaler.on_good_step();
        Ok(true)
    }

    /// FP + BP only: forward with caching, joint cross-entropy, and
    /// the hand-derived backward — **no parameter or optimizer-state
    /// mutation** (`&self`).  Returns the mean loss, the flat
    /// [`GradMap`] (one entry per optimizer slot; tied fused-QKV input
    /// cores appear once, under `wq`'s names), and the contraction
    /// stats.  Feed the map to [`Self::apply_grads`] — directly for a
    /// single-replica step, or after
    /// [`crate::replica::allreduce_fixed_order`] under data
    /// parallelism.
    pub fn forward_backward(
        &self,
        tokens: &[i32],
        intent: &[i32],
        slots: &[i32],
    ) -> Result<(f32, GradMap, ContractionStats)> {
        let cfg_nh = self.cfg.n_heads;
        let (s, h) = (self.cfg.seq_len, self.cfg.d_hid);
        let ns = self.cfg.n_slots;
        let ni = self.cfg.n_intents;
        let b = intent.len();
        if b == 0 || tokens.len() != b * s || slots.len() != b * s {
            return Err(anyhow!(
                "train_step: need (B, {s}) tokens/slots and (B,) intents, got {} / {} / {b}",
                tokens.len(),
                slots.len()
            ));
        }
        for &iv in intent {
            if iv < 0 || iv as usize >= ni {
                return Err(anyhow!("intent label {iv} out of range"));
            }
        }
        let mut stats = ContractionStats::default();
        let fwd = self.forward_train(tokens, &mut stats)?;
        debug_assert_eq!(fwd.batch, b);
        // FP -> BP stage boundary: publish the measured on-chip bytes
        // (observation only — gauges never feed back into compute, so
        // traced and untraced steps are bitwise identical).
        if trace::enabled() {
            trace::gauge_set("eq21_cache_bytes", Self::eq21_bytes_of(&fwd));
            trace::gauge_set("param_bytes", self.param_bytes());
        }
        let inv_b = 1.0 / b as f32;

        // ---- Joint loss and logit gradients (paper loss_fn, batch mean)
        let sp_bp_heads = trace::span("train", "bp.heads");
        let mut loss = 0.0f32;
        let mut d_il = Tensor::zeros(&[b, ni]);
        let mut d_slot = Tensor::zeros(&[b * s, ns]);
        for e in 0..b {
            let irow = &fwd.intent_logits.data[e * ni..(e + 1) * ni];
            let (li, dli) = blocks::cross_entropy_logits(irow, intent[e] as usize)?;
            loss += li * inv_b;
            for (o, &v) in d_il.data[e * ni..(e + 1) * ni].iter_mut().zip(&dli) {
                *o = v * inv_b;
            }
            let m = &fwd.mask[e * s..(e + 1) * s];
            let denom = m.iter().sum::<f32>().max(1.0);
            for p in 0..s {
                if m[p] == 0.0 {
                    continue;
                }
                let gp = e * s + p;
                if slots[gp] < 0 || slots[gp] as usize >= ns {
                    return Err(anyhow!("slot label {} out of range at {p}", slots[gp]));
                }
                let row = &fwd.slot_logits.data[gp * ns..(gp + 1) * ns];
                let (l, dl) = blocks::cross_entropy_logits(row, slots[gp] as usize)?;
                loss += l * inv_b / denom;
                for (o, &dv) in d_slot.data[gp * ns..(gp + 1) * ns].iter_mut().zip(&dl) {
                    *o = dv * inv_b / denom;
                }
            }
        }

        let mut grads = GradMap::new();

        // ---- Classifier heads ----------------------------------------
        // d_pooled from both heads, computed before any head update.
        let mut d_pooled = d_slot.matmul(&self.slot_w.view())?; // (B*S, H)
        {
            let intent_w = self.intent_w.view();
            for e in 0..b {
                for (c, &dil) in d_il.data[e * ni..(e + 1) * ni].iter().enumerate() {
                    for j in 0..h {
                        d_pooled.data[e * s * h + j] += dil * intent_w.at2(c, j);
                    }
                }
            }
        }
        let d_slot_w = d_slot.t()?.matmul(&fwd.pooled)?; // (n_slots, H)
        let mut d_slot_b = vec![0.0f32; ns];
        for row in d_slot.data.chunks(ns) {
            for (bb, &v) in d_slot_b.iter_mut().zip(row) {
                *bb += v;
            }
        }
        let d_intent_w = d_il.t()?.matmul(&fwd.cls)?; // (n_intents, H)
        let mut d_intent_b = vec![0.0f32; ni];
        for row in d_il.data.chunks(ni) {
            for (bb, &v) in d_intent_b.iter_mut().zip(row) {
                *bb += v;
            }
        }
        drop(sp_bp_heads);
        grads.insert("cls.intent_w".to_string(), d_intent_w.data);
        grads.insert("cls.intent_b".to_string(), d_intent_b);
        grads.insert("cls.slot_w".to_string(), d_slot_w.data);
        grads.insert("cls.slot_b".to_string(), d_slot_b);

        // ---- Pooler --------------------------------------------------
        let sp_bp_pool = trace::span("train", "bp.pool");
        let d_pool_pre = blocks::tanh_vjp(&fwd.pooled, &d_pooled);
        let (mut dx, pool_grads) = self.pool.backward(&d_pool_pre, &fwd.pool_c, &mut stats)?;
        drop(sp_bp_pool);
        insert_linear_grads(&mut grads, "cls.pool", pool_grads);

        // ---- Encoder blocks, reversed --------------------------------
        for (li, (layer, f)) in self.layers.iter().zip(fwd.layer_fwd.iter()).enumerate().rev() {
            let p = |name: &str| format!("layers.{li}.{name}");
            // Pure backward: one bp span covers the whole block; the
            // matching pu span lives in `apply_grads`.
            let _sp = trace::span_fmt("train", || format!("bp.layer{li}"));
            let (d_res2, dg2, db2) = blocks::layer_norm_vjp(&f.ln2_c, &layer.ln2_g.view(), &dx);
            grads.insert(p("ln2.g"), dg2);
            grads.insert(p("ln2.b"), db2);
            let (d_g1, w2_grads) = layer.w2.backward(&d_res2, &f.w2_c, &mut stats)?;
            insert_linear_grads(&mut grads, &p("w2"), w2_grads);
            let d_h1 = blocks::gelu_vjp(&f.h1, &d_g1);
            let (d_x1_ffn, w1_grads) = layer.w1.backward(&d_h1, &f.w1_c, &mut stats)?;
            insert_linear_grads(&mut grads, &p("w1"), w1_grads);
            // Fused lane: the residual-join sum d_res2 + d_x1_ffn feeds
            // the LN1 VJP inline instead of materializing first —
            // bitwise identical to the unfused reference.
            let (d_res1, dg1, db1) = if self.compute_path.fused_elementwise {
                blocks::layer_norm_vjp2(&f.ln1_c, &layer.ln1_g.view(), &d_res2, &d_x1_ffn)
            } else {
                let d_x1 = ops::add(&d_res2, &d_x1_ffn);
                blocks::layer_norm_vjp(&f.ln1_c, &layer.ln1_g.view(), &d_x1)
            };
            grads.insert(p("ln1.g"), dg1);
            grads.insert(p("ln1.b"), db1);
            let (d_ctx, wo_grads) = layer.wo.backward(&d_res1, &f.wo_c, &mut stats)?;
            insert_linear_grads(&mut grads, &p("wo"), wo_grads);
            // Attention backward, mirroring the forward's schedule.
            let (dq, dk, dv) = match &f.attn {
                AttnFwd::Batched(probs) => blocks::multi_head_attention_vjp_batched(
                    &f.q, &f.k, &f.v, probs, &d_ctx, cfg_nh, b,
                )?,
                AttnFwd::PerExample(probs) => {
                    let mut dq = Tensor::zeros(&[b * s, h]);
                    let mut dk = Tensor::zeros(&[b * s, h]);
                    let mut dv = Tensor::zeros(&[b * s, h]);
                    for e in 0..b {
                        let qe = rows(&f.q, e * s, s)?;
                        let ke = rows(&f.k, e * s, s)?;
                        let ve = rows(&f.v, e * s, s)?;
                        let d_ctx_e = rows(&d_ctx, e * s, s)?;
                        let (dqe, dke, dve) = blocks::multi_head_attention_vjp(
                            &qe,
                            &ke,
                            &ve,
                            &probs[e],
                            &d_ctx_e,
                            cfg_nh,
                        )?;
                        dq.data[e * s * h..(e + 1) * s * h].copy_from_slice(&dqe.data);
                        dk.data[e * s * h..(e + 1) * s * h].copy_from_slice(&dke.data);
                        dv.data[e * s * h..(e + 1) * s * h].copy_from_slice(&dve.data);
                    }
                    (dq, dk, dv)
                }
            };
            // QKV backward, fused or separate to match the forward.
            let dx_qkv = match &f.qkv {
                QkvFwd::Fused(cache) => {
                    let (dx_qkv, qkv_grads) = layers::backward_qkv_fused(
                        &layer.wq, &layer.wk, &layer.wv, &dq, &dk, &dv, cache, &mut stats,
                    )?;
                    insert_qkv_fused_grads(&mut grads, &format!("layers.{li}"), qkv_grads);
                    dx_qkv
                }
                QkvFwd::Separate(c) => {
                    let (dx_q, wq_grads) = layer.wq.backward(&dq, &c.wq_c, &mut stats)?;
                    insert_linear_grads(&mut grads, &p("wq"), wq_grads);
                    let (dx_k, wk_grads) = layer.wk.backward(&dk, &c.wk_c, &mut stats)?;
                    insert_linear_grads(&mut grads, &p("wk"), wk_grads);
                    let (dx_v, wv_grads) = layer.wv.backward(&dv, &c.wv_c, &mut stats)?;
                    insert_linear_grads(&mut grads, &p("wv"), wv_grads);
                    ops::add(&ops::add(&dx_q, &dx_k), &dx_v)
                }
            };
            dx = ops::add(&d_res1, &dx_qkv);
        }

        // ---- Embedding + positional table ----------------------------
        // Memoized VJP: row gradients are summed per unique token id
        // (ascending position order), then each unique chain is
        // unrolled once — `lookup_vjp` is linear in the row gradient,
        // so this matches the per-position walk at a fraction of the
        // contractions.
        let sp_bp_embed = trace::span("train", "bp.embed");
        let mut emb_grads: Vec<Tensor> = self
            .embedding
            .cores
            .iter()
            .map(|c| Tensor::zeros(c.shape()))
            .collect();
        let mut d_rows = vec![vec![0.0f32; h]; fwd.emb_unique.len()];
        for (i, &ui) in fwd.emb_index.iter().enumerate() {
            for (o, &v) in d_rows[ui].iter_mut().zip(&dx.data[i * h..(i + 1) * h]) {
                *o += v;
            }
        }
        for ((t, states), d_row) in fwd.emb_unique.iter().zip(&d_rows) {
            if states.len() == self.embedding.cores.len() {
                self.embedding
                    .lookup_vjp(*t as usize, states, d_row, &mut emb_grads)?;
            } else {
                // Recompute policy: the forward kept only the final
                // chain state.  Rebuild the chain (same fold order and
                // round-on-store precision; the cores are unchanged
                // until the update below) before unrolling it.
                let (_, full) = self
                    .embedding
                    .lookup_cached_prec(*t as usize, self.precision)?;
                self.embedding
                    .lookup_vjp(*t as usize, &full, d_row, &mut emb_grads)?;
            }
        }
        for (k, g) in emb_grads.into_iter().enumerate() {
            grads.insert(format!("embed.ttm.{k}"), g.data);
        }
        drop(sp_bp_embed);
        // Positional-table gradient: sum over examples (ascending order).
        let sp_bp_pos = trace::span("train", "bp.embed");
        let mut d_pos = vec![0.0f32; s * h];
        for e in 0..b {
            for (dp, &dv) in d_pos.iter_mut().zip(&dx.data[e * s * h..(e + 1) * s * h]) {
                *dp += dv;
            }
        }
        grads.insert("embed.pos".to_string(), d_pos);
        drop(sp_bp_pos);

        Ok((loss, grads, stats))
    }

    /// PU stage: one optimizer step over a full [`GradMap`] — the
    /// exact complement of [`Self::forward_backward`].  Updates walk
    /// the same schedule order as the historical interleaved step
    /// (heads, pooler, encoder blocks high-to-low, embedding,
    /// positional), so the composition is bitwise identical to it.
    /// Every expected slot must be present with the right length /
    /// shape; a mismatch is a hard error and **no prefix of the
    /// updates is rolled back**, so callers should treat an `Err` as
    /// fatal for this model instance.
    pub fn apply_grads(&mut self, grads: &GradMap, lr: f32) -> Result<()> {
        let hyper = self.optim.hyper(lr);
        let (s, h) = (self.cfg.seq_len, self.cfg.d_hid);
        let (ni, ns) = (self.cfg.n_intents, self.cfg.n_slots);

        // ---- Classifier heads ----------------------------------------
        {
            let _sp = trace::span("train", "pu.heads");
            let d_intent_w = expect_grad(grads, "cls.intent_w", ni * h)?;
            let d_intent_b = expect_grad(grads, "cls.intent_b", ni)?;
            let d_slot_w = expect_grad(grads, "cls.slot_w", ns * h)?;
            let d_slot_b = expect_grad(grads, "cls.slot_b", ns)?;
            let optim = &mut self.optim;
            self.intent_w
                .update_in_place(|v| optim.step("cls.intent_w", v, d_intent_w, &hyper));
            self.intent_b
                .update_in_place(|v| optim.step("cls.intent_b", v, d_intent_b, &hyper));
            self.slot_w.update_in_place(|v| optim.step("cls.slot_w", v, d_slot_w, &hyper));
            self.slot_b.update_in_place(|v| optim.step("cls.slot_b", v, d_slot_b, &hyper));
        }

        // ---- Pooler --------------------------------------------------
        {
            let _sp = trace::span("train", "pu.pool");
            let g = gather_linear_grads(grads, "cls.pool", &self.pool)?;
            self.pool.apply_update(&g, &mut self.optim, "cls.pool", &hyper);
        }

        // ---- Encoder blocks, reversed (same order as the backward) ---
        let d = self.cfg.tt_m.len();
        for li in (0..self.layers.len()).rev() {
            let _sp = trace::span_fmt("train", || format!("pu.layer{li}"));
            let p = |name: &str| format!("layers.{li}.{name}");
            {
                let layer = &mut self.layers[li];
                let dg2 = expect_grad(grads, &p("ln2.g"), h)?;
                let db2 = expect_grad(grads, &p("ln2.b"), h)?;
                let optim = &mut self.optim;
                layer.ln2_g.update_in_place(|v| optim.step(&p("ln2.g"), v, dg2, &hyper));
                layer.ln2_b.update_in_place(|v| optim.step(&p("ln2.b"), v, db2, &hyper));
            }
            let g2 = gather_linear_grads(grads, &p("w2"), &self.layers[li].w2)?;
            self.layers[li].w2.apply_update(&g2, &mut self.optim, &p("w2"), &hyper);
            let g1 = gather_linear_grads(grads, &p("w1"), &self.layers[li].w1)?;
            self.layers[li].w1.apply_update(&g1, &mut self.optim, &p("w1"), &hyper);
            {
                let layer = &mut self.layers[li];
                let dg1 = expect_grad(grads, &p("ln1.g"), h)?;
                let db1 = expect_grad(grads, &p("ln1.b"), h)?;
                let optim = &mut self.optim;
                layer.ln1_g.update_in_place(|v| optim.step(&p("ln1.g"), v, dg1, &hyper));
                layer.ln1_b.update_in_place(|v| optim.step(&p("ln1.b"), v, db1, &hyper));
            }
            let go = gather_linear_grads(grads, &p("wo"), &self.layers[li].wo)?;
            self.layers[li].wo.apply_update(&go, &mut self.optim, &p("wo"), &hyper);
            // Fused-vs-separate QKV is recovered from the map itself:
            // under the fused schedule the tied input cores exist only
            // under `wq`'s names, so `wk.cores.{d}` is absent.
            let fused = !grads.contains_key(&p(&format!("wk.cores.{d}")));
            if fused {
                let g = gather_qkv_fused_grads(grads, &format!("layers.{li}"), &self.layers[li])?;
                let layer = &mut self.layers[li];
                layers::apply_update_qkv_fused(
                    &mut layer.wq,
                    &mut layer.wk,
                    &mut layer.wv,
                    &g,
                    &mut self.optim,
                    &format!("layers.{li}"),
                    &hyper,
                );
            } else {
                let gq = gather_linear_grads(grads, &p("wq"), &self.layers[li].wq)?;
                self.layers[li].wq.apply_update(&gq, &mut self.optim, &p("wq"), &hyper);
                let gk = gather_linear_grads(grads, &p("wk"), &self.layers[li].wk)?;
                self.layers[li].wk.apply_update(&gk, &mut self.optim, &p("wk"), &hyper);
                let gv = gather_linear_grads(grads, &p("wv"), &self.layers[li].wv)?;
                self.layers[li].wv.apply_update(&gv, &mut self.optim, &p("wv"), &hyper);
            }
        }

        // ---- Embedding + positional table ----------------------------
        {
            let _sp = trace::span("train", "pu.embed");
            let optim = &mut self.optim;
            for (k, core) in self.embedding.cores.iter_mut().enumerate() {
                let name = format!("embed.ttm.{k}");
                let numel: usize = core.shape().iter().product();
                let g = grads
                    .get(&name)
                    .ok_or_else(|| anyhow!("apply_grads: missing gradient '{name}'"))?;
                if g.len() != numel {
                    return Err(anyhow!(
                        "apply_grads: gradient '{name}' has {} elements, core has {numel}",
                        g.len()
                    ));
                }
                core.update_in_place(|v| optim.step(&name, v, g, &hyper));
            }
        }
        {
            let _sp = trace::span("train", "pu.embed");
            let d_pos = expect_grad(grads, "embed.pos", s * h)?;
            let optim = &mut self.optim;
            self.pos.update_in_place(|v| optim.step("embed.pos", v, d_pos, &hyper));
        }
        Ok(())
    }

    /// Overwrite this model's parameters (and storage precision) with
    /// `src`'s — the replica broadcast primitive.  Optimizer state,
    /// compute path and checkpoint policy are deliberately untouched:
    /// under data parallelism the moments live once, on the model that
    /// ran [`Self::apply_grads`]; followers only mirror parameters.
    pub fn copy_params_from(&mut self, src: &NativeTrainModel) {
        self.embedding = src.embedding.clone();
        self.pos = src.pos.clone();
        self.layers = src.layers.clone();
        self.pool = src.pool.clone();
        self.intent_w = src.intent_w.clone();
        self.intent_b = src.intent_b.clone();
        self.slot_w = src.slot_w.clone();
        self.slot_b = src.slot_b.clone();
        self.precision = src.precision;
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::optim::OptimKind;

    pub(crate) fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            n_layers: 1,
            d_hid: 48,
            n_heads: 4,
            seq_len: 8,
            batch: 1,
            vocab: 27,
            n_intents: 5,
            n_slots: 7,
            tt_m: vec![4, 4, 3],
            tt_n: vec![3, 4, 4],
            tt_rank: 3,
            ttm_vocab_modes: vec![3, 3, 3],
            ttm_hid_modes: vec![4, 4, 3],
            ttm_rank: 4,
            pad_id: 0,
            cls_id: 1,
            unk_id: 2,
        }
    }

    #[test]
    fn params_roundtrip_preserves_model() {
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 7).unwrap();
        let map = model.to_params();
        let back = NativeTrainModel::from_params(&cfg, &map).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        assert_eq!(model.eval(&tokens).unwrap(), back.eval(&tokens).unwrap());
    }

    #[test]
    fn eval_matches_inference_engine() {
        // The trainable model and the merged-factor inference engine
        // fold through the same chain states and round at the same
        // program points, so their logits are **bitwise identical** on
        // the same parameters — at every precision and compute path.
        let cfg = tiny_cfg();
        for path in [ComputePath::fused(), ComputePath::looped()] {
            for prec in Precision::all() {
                let mut model = NativeTrainModel::random_init(&cfg, 8).unwrap();
                model.compute_path = path;
                model.set_precision(prec);
                let engine = model.engine().unwrap();
                assert_eq!(engine.compute_path, path);
                assert_eq!(engine.precision, prec);
                for tokens in [vec![1, 5, 9, 13, 0, 0, 0, 0], vec![1, 3, 2, 7, 11, 26, 0, 0]] {
                    assert_eq!(
                        model.eval(&tokens).unwrap(),
                        engine.forward(&tokens).unwrap(),
                        "diverged at {path:?} / {}",
                        prec.name()
                    );
                }
            }
        }
    }

    #[test]
    fn train_step_reports_positive_finite_loss_and_updates() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 9).unwrap();
        let tokens = vec![1, 5, 9, 13, 4, 0, 0, 0];
        let slots = vec![0, 1, 2, 3, 1, 0, 0, 0];
        let before = model.eval(&tokens).unwrap();
        let (loss, stats) = model.train_step(&tokens, &[2], &slots, 0.05).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!(stats.muls > 0);
        let after = model.eval(&tokens).unwrap();
        assert_ne!(before, after, "parameters did not move");
    }

    #[test]
    fn rejects_bad_labels() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 10).unwrap();
        let tokens = vec![1, 5, 9, 13, 0, 0, 0, 0];
        let slots = vec![0i32; 8];
        assert!(model.train_step(&tokens, &[99], &slots, 0.01).is_err());
        let bad_slots = vec![0, 99, 0, 0, 0, 0, 0, 0];
        assert!(model.train_step(&tokens, &[1], &bad_slots, 0.01).is_err());
        // Mismatched batch shapes must fail loudly.
        assert!(model.train_step(&tokens, &[1, 2], &slots, 0.01).is_err());
        assert!(model.train_step(&tokens[..4], &[1], &slots, 0.01).is_err());
    }

    /// Two examples at the tiny config: tokens + per-position slots.
    fn two_examples() -> (Vec<i32>, Vec<i32>, Vec<i32>) {
        let tokens = vec![
            1, 5, 9, 13, 4, 0, 0, 0, // example 0
            1, 3, 2, 7, 11, 26, 6, 0, // example 1
        ];
        let intents = vec![2, 4];
        let slots = vec![
            0, 1, 2, 3, 1, 0, 0, 0, //
            0, 2, 2, 4, 5, 6, 1, 0, //
        ];
        (tokens, intents, slots)
    }

    #[test]
    fn batched_eval_matches_per_example_eval() {
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 11).unwrap();
        let (tokens, _, _) = two_examples();
        let (il, sl) = model.eval(&tokens).unwrap();
        assert_eq!(il.len(), 2 * cfg.n_intents);
        assert_eq!(sl.len(), 2 * cfg.seq_len * cfg.n_slots);
        for e in 0..2 {
            let (il_e, sl_e) = model.eval(&tokens[e * 8..(e + 1) * 8]).unwrap();
            let di = il[e * cfg.n_intents..(e + 1) * cfg.n_intents]
                .iter()
                .zip(&il_e)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let ds = sl[e * 8 * cfg.n_slots..(e + 1) * 8 * cfg.n_slots]
                .iter()
                .zip(&sl_e)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(di < 1e-5 && ds < 1e-5, "example {e}: di {di} ds {ds}");
        }
    }

    #[test]
    fn batched_loss_is_mean_of_per_example_losses() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 12).unwrap();
        let (tokens, intents, slots) = two_examples();
        // lr = 0 probes the loss without moving parameters.
        let mut per_example = Vec::new();
        for e in 0..2 {
            let (l, _) = model
                .train_step(
                    &tokens[e * 8..(e + 1) * 8],
                    &intents[e..e + 1],
                    &slots[e * 8..(e + 1) * 8],
                    0.0,
                )
                .unwrap();
            per_example.push(l);
        }
        let (batch_loss, _) = model.train_step(&tokens, &intents, &slots, 0.0).unwrap();
        let mean = (per_example[0] + per_example[1]) / 2.0;
        assert!(
            (batch_loss - mean).abs() < 1e-5,
            "batch loss {batch_loss} vs per-example mean {mean}"
        );
    }

    #[test]
    fn batched_step_is_bitwise_deterministic() {
        let cfg = tiny_cfg();
        let (tokens, intents, slots) = two_examples();
        let run = || {
            let mut model = NativeTrainModel::random_init(&cfg, 13).unwrap();
            model.set_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
            for _ in 0..3 {
                model.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
            }
            model.to_params()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "repeated batched Adam training diverged bitwise");
    }

    #[test]
    fn adam_state_is_twice_the_distinct_param_count() {
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 14).unwrap();
        model.set_optim(OptimConfig { kind: OptimKind::Adam, ..Default::default() });
        let (tokens, intents, slots) = two_examples();
        assert_eq!(model.optim.allocated_state_elems(), 0);
        model.train_step(&tokens, &intents, &slots, 1e-3).unwrap();
        // After one full step every trainable tensor has a slot: Adam
        // state is exactly 2x the **distinct** parameter count — the
        // fused QKV layers keep one state slot for the tied input-side
        // cores instead of three, so two copies per layer drop out of
        // the per-layer tensor_params accounting.
        let d = cfg.tt_m.len();
        let n_side: usize = model.layers[0].wq.tt().cores[d..].iter().map(|c| c.numel()).sum();
        assert_eq!(
            model.optim.allocated_state_elems(),
            2 * (cfg.tensor_params() - cfg.n_layers * 2 * n_side) as u64
        );
    }

    #[test]
    fn param_visitor_covers_exactly_the_exported_set() {
        // The rounding walk and the checkpoint walk must never drift: a
        // parameter exported by to_params has to be visited (and vice
        // versa), or the weights-at-rest representability invariant of
        // the mixed-precision path would silently break.
        let cfg = tiny_cfg();
        let mut model = NativeTrainModel::random_init(&cfg, 20).unwrap();
        // Compare buffer-length multisets (not just summed elements),
        // so an added parameter cannot mask a dropped one of any other
        // size.
        let mut exported: Vec<usize> =
            model.to_params().values().map(|(_, d)| d.len()).collect();
        let mut visited: Vec<usize> = Vec::new();
        model.for_each_param_mut(|d| visited.push(d.len()));
        exported.sort_unstable();
        visited.sort_unstable();
        assert_eq!(visited, exported, "visitor and to_params walk different sets");
    }

    #[test]
    fn random_init_ties_qkv_input_cores() {
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 16).unwrap();
        for layer in &model.layers {
            assert!(crate::train::layers::qkv_input_cores_shared(
                &layer.wq, &layer.wk, &layer.wv
            ));
        }
    }

    #[test]
    fn fused_schedule_matches_looped_reference() {
        // The fused/batched hot path and the pre-fusion looped schedule
        // compute the same forward on the same parameters: eval logits
        // and the lr = 0 loss probe agree tightly, and the fused
        // schedule is charged strictly fewer contraction muls.  (Post-
        // step parameters are *not* compared: with tied input cores the
        // fused PU applies the summed input-side gradient — the tied
        // parameterization's chain rule — while the looped reference
        // reproduces the pre-fusion independent-copy updates.  The
        // gradient-level relationships are pinned in
        // `train::layers::tests` and the FD checks.)
        let cfg = tiny_cfg();
        let (tokens, intents, slots) = two_examples();
        let run = |path: ComputePath| {
            let mut model = NativeTrainModel::random_init(&cfg, 17).unwrap();
            model.compute_path = path;
            let (il, sl) = model.eval(&tokens).unwrap();
            let (loss, stats) = model.train_step(&tokens, &intents, &slots, 0.0).unwrap();
            (il, sl, loss, stats)
        };
        let (il_f, sl_f, loss_f, stats_f) = run(ComputePath::fused());
        let (il_l, sl_l, loss_l, stats_l) = run(ComputePath::looped());
        let max_diff = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
        };
        assert!(max_diff(&il_f, &il_l) < 1e-5, "intent logits diverge");
        assert!(max_diff(&sl_f, &sl_l) < 1e-5, "slot logits diverge");
        assert!((loss_f - loss_l).abs() < 1e-5, "loss {loss_f} vs {loss_l}");
        assert!(
            stats_f.muls < stats_l.muls,
            "fused {} !< looped {}",
            stats_f.muls,
            stats_l.muls
        );
        assert!(stats_f.stored_intermediate_elems < stats_l.stored_intermediate_elems);
    }

    #[test]
    fn fused_elementwise_is_bitwise_identical_across_precisions() {
        // Toggling ONLY the fused-elementwise lanes (same QKV/attention
        // schedule) must not move a single bit: the fused lanes execute
        // the exact scalar sequence of the unfused chain — forward
        // (bias + residual + LN, bias + GELU), backward (residual-join
        // sum into the LN1 VJP) and therefore the whole Adam
        // trajectory, at every storage precision.
        let cfg = tiny_cfg();
        let (tokens, intents, slots) = two_examples();
        for prec in Precision::all() {
            let run = |fused_elem: bool| {
                let mut model = NativeTrainModel::random_init(&cfg, 21).unwrap();
                model.set_optim(OptimConfig {
                    kind: OptimKind::Adam,
                    precision: prec,
                    ..Default::default()
                });
                model.compute_path.fused_elementwise = fused_elem;
                let logits = model.eval(&tokens).unwrap();
                for _ in 0..3 {
                    model.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
                }
                (logits, model.to_params())
            };
            let (logits_f, params_f) = run(true);
            let (logits_u, params_u) = run(false);
            assert_eq!(logits_f, logits_u, "eval diverged at {}", prec.name());
            assert_eq!(params_f, params_u, "trajectory diverged at {}", prec.name());
        }
    }

    #[test]
    fn memoized_embedding_matches_inference_reference() {
        // Heavy token repetition (duplicates + pads): the training
        // forward's emb_unique/emb_index bookkeeping must match the
        // engine run per example, whose independent (HashMap-keyed)
        // memoization cannot share a wrong index mapping with it.
        // (The memoized VJP is pinned by the finite-difference check on
        // `embed.ttm.1` in rust/tests/native_training.rs, whose example
        // repeats the pad token four times.)
        let cfg = tiny_cfg();
        let model = NativeTrainModel::random_init(&cfg, 18).unwrap();
        let infer = model.engine().unwrap();
        let tokens = vec![1, 5, 5, 5, 9, 0, 0, 0, 1, 9, 9, 5, 5, 0, 0, 0];
        let (il, sl) = model.eval(&tokens).unwrap();
        let mut il_ref = Vec::new();
        let mut sl_ref = Vec::new();
        for chunk in tokens.chunks(cfg.seq_len) {
            let (il_e, sl_e) = infer.forward(chunk).unwrap();
            il_ref.extend(il_e);
            sl_ref.extend(sl_e);
        }
        assert_eq!(il, il_ref, "intent logits diverge");
        assert_eq!(sl, sl_ref, "slot logits diverge");
    }

    #[test]
    fn untied_init_keeps_independent_qkv_and_separate_schedule() {
        let cfg = tiny_cfg();
        let tied = NativeTrainModel::random_init(&cfg, 19).unwrap();
        let mut untied = NativeTrainModel::random_init_untied(&cfg, 19).unwrap();
        for layer in &untied.layers {
            assert!(!crate::train::layers::qkv_input_cores_shared(
                &layer.wq, &layer.wk, &layer.wv
            ));
        }
        // Same RNG stream: everything except wk/wv input cores matches
        // the tied init bitwise.
        assert_eq!(tied.pos, untied.pos);
        assert_eq!(tied.layers[0].wq.tt().cores, untied.layers[0].wq.tt().cores);
        let d = cfg.tt_m.len();
        assert_eq!(
            tied.layers[0].wk.tt().cores[..d],
            untied.layers[0].wk.tt().cores[..d]
        );
        // Training still works (separate-forwards fallback) and keeps
        // the projections independent.
        let (tokens, intents, slots) = two_examples();
        let (loss, _) = untied.train_step(&tokens, &intents, &slots, 1e-2).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        for layer in &untied.layers {
            assert!(!crate::train::layers::qkv_input_cores_shared(
                &layer.wq, &layer.wk, &layer.wv
            ));
        }
    }

    #[test]
    fn stateful_optimizers_fit_a_batch_and_reduce_loss() {
        // Overfit one 2-example batch: every stateful rule must cut the
        // joint loss well below its cold-start value (lr per rule:
        // momentum's effective rate is lr / (1 - mu)).
        let cfg = tiny_cfg();
        let (tokens, intents, slots) = two_examples();
        for (kind, lr) in [
            (OptimKind::Momentum, 5e-3f32),
            (OptimKind::Adam, 1e-2),
            (OptimKind::AdamW, 1e-2),
        ] {
            let mut model = NativeTrainModel::random_init(&cfg, 15).unwrap();
            model.set_optim(OptimConfig { kind, weight_decay: 1e-4, ..Default::default() });
            let (first, _) = model.train_step(&tokens, &intents, &slots, lr).unwrap();
            let mut last = first;
            for _ in 0..60 {
                let (l, _) = model.train_step(&tokens, &intents, &slots, lr).unwrap();
                last = l;
            }
            assert!(
                last < 0.6 * first,
                "{kind:?}: loss {last} vs start {first} after 60 batched steps"
            );
        }
    }
}
