//! Backward passes (VJPs) of the transformer's nonlinear blocks —
//! LayerNorm, GELU, masked softmax, multi-head attention, tanh and the
//! joint cross-entropy objective.  Each mirrors the forward in
//! [`crate::tensor::ops`] and consumes only what a memory-lean BP stage
//! would keep (normalized activations, attention probabilities).

use crate::tensor::{ops, Tensor};
use anyhow::{anyhow, Result};

/// Cache of one LayerNorm application.
pub struct LayerNormCache {
    /// Normalized activations (x - mu) * inv, per row.
    xhat: Tensor,
    /// 1 / sqrt(var + eps), per row.
    inv: Vec<f32>,
}

/// LayerNorm forward that also returns the backward cache.  Produces
/// bitwise the same output as [`ops::layer_norm`].
pub fn layer_norm_fwd(x: &Tensor, g: &[f32], b: &[f32], eps: f32) -> (Tensor, LayerNormCache) {
    let (rows, cols) = (x.shape[0], x.shape[1]);
    debug_assert_eq!(g.len(), cols);
    let mut out = Tensor::zeros(&[rows, cols]);
    let mut xhat = Tensor::zeros(&[rows, cols]);
    let mut inv_all = vec![0.0f32; rows];
    for i in 0..rows {
        let row = &x.data[i * cols..(i + 1) * cols];
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        inv_all[i] = inv;
        for j in 0..cols {
            let xh = (row[j] - mu) * inv;
            xhat.data[i * cols + j] = xh;
            out.data[i * cols + j] = xh * g[j] + b[j];
        }
    }
    (out, LayerNormCache { xhat, inv: inv_all })
}

/// LayerNorm backward: returns `(dx, dg, db)`.
pub fn layer_norm_vjp(
    cache: &LayerNormCache,
    g: &[f32],
    dy: &Tensor,
) -> (Tensor, Vec<f32>, Vec<f32>) {
    let (rows, cols) = (dy.shape[0], dy.shape[1]);
    let mut dx = Tensor::zeros(&[rows, cols]);
    let mut dg = vec![0.0f32; cols];
    let mut db = vec![0.0f32; cols];
    for i in 0..rows {
        let dyr = &dy.data[i * cols..(i + 1) * cols];
        let xhr = &cache.xhat.data[i * cols..(i + 1) * cols];
        let mut m1 = 0.0f32; // mean of dy * g
        let mut m2 = 0.0f32; // mean of dy * g * xhat
        for j in 0..cols {
            let dxh = dyr[j] * g[j];
            m1 += dxh;
            m2 += dxh * xhr[j];
            dg[j] += dyr[j] * xhr[j];
            db[j] += dyr[j];
        }
        m1 /= cols as f32;
        m2 /= cols as f32;
        let inv = cache.inv[i];
        for j in 0..cols {
            let dxh = dyr[j] * g[j];
            dx.data[i * cols + j] = inv * (dxh - m1 - xhr[j] * m2);
        }
    }
    (dx, dg, db)
}

/// GELU backward (tanh approximation, matching [`ops::gelu`]); the
/// derivative lives in [`ops::gelu_grad_scalar`] next to the shared
/// forward scalar so the pair cannot drift apart.
pub fn gelu_vjp(x: &Tensor, dy: &Tensor) -> Tensor {
    debug_assert_eq!(x.shape, dy.shape);
    let mut out = dy.clone();
    for (o, &xv) in out.data.iter_mut().zip(&x.data) {
        *o *= ops::gelu_grad_scalar(xv);
    }
    out
}

/// Fused bias row-add + residual add + LayerNorm forward.  Consumes the
/// raw (bias-free) TT-apply output `y (K, H)`, the layer's output bias
/// and the residual input `x`, and produces bitwise the same
/// `(out, cache)` as `ops::add_row` -> `ops::add(&x, ..)` ->
/// [`layer_norm_fwd`]: per element `t = x + (y + bias)` in that exact
/// order, then the identical row-normalization loops.  The post-bias and
/// post-residual intermediates live only in one row-sized scratch buffer
/// instead of two full `(K, H)` tensors round-tripping through memory.
pub fn bias_residual_layer_norm_fwd(
    y: &Tensor,
    bias: &[f32],
    x: &Tensor,
    g: &[f32],
    b: &[f32],
    eps: f32,
) -> (Tensor, LayerNormCache) {
    let (rows, cols) = (y.shape[0], y.shape[1]);
    debug_assert_eq!(x.shape, y.shape);
    debug_assert_eq!(bias.len(), cols);
    debug_assert_eq!(g.len(), cols);
    let mut out = Tensor::zeros(&[rows, cols]);
    let mut xhat = Tensor::zeros(&[rows, cols]);
    let mut inv_all = vec![0.0f32; rows];
    let mut row = vec![0.0f32; cols];
    for i in 0..rows {
        for j in 0..cols {
            let o = y.data[i * cols + j] + bias[j];
            row[j] = x.data[i * cols + j] + o;
        }
        let mu = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        inv_all[i] = inv;
        for j in 0..cols {
            let xh = (row[j] - mu) * inv;
            xhat.data[i * cols + j] = xh;
            out.data[i * cols + j] = xh * g[j] + b[j];
        }
    }
    (out, LayerNormCache { xhat, inv: inv_all })
}

/// [`layer_norm_vjp`] with the upstream gradient formed inline as
/// `dy = dy_a + dy_b` (the residual-join sum), so the summed gradient
/// tensor never materializes.  Bitwise identical to
/// `ops::add(dy_a, dy_b)` followed by [`layer_norm_vjp`].
pub fn layer_norm_vjp2(
    cache: &LayerNormCache,
    g: &[f32],
    dy_a: &Tensor,
    dy_b: &Tensor,
) -> (Tensor, Vec<f32>, Vec<f32>) {
    debug_assert_eq!(dy_a.shape, dy_b.shape);
    let (rows, cols) = (dy_a.shape[0], dy_a.shape[1]);
    let mut dx = Tensor::zeros(&[rows, cols]);
    let mut dg = vec![0.0f32; cols];
    let mut db = vec![0.0f32; cols];
    for i in 0..rows {
        let ar = &dy_a.data[i * cols..(i + 1) * cols];
        let br = &dy_b.data[i * cols..(i + 1) * cols];
        let xhr = &cache.xhat.data[i * cols..(i + 1) * cols];
        let mut m1 = 0.0f32; // mean of dy * g
        let mut m2 = 0.0f32; // mean of dy * g * xhat
        for j in 0..cols {
            let dyv = ar[j] + br[j];
            let dxh = dyv * g[j];
            m1 += dxh;
            m2 += dxh * xhr[j];
            dg[j] += dyv * xhr[j];
            db[j] += dyv;
        }
        m1 /= cols as f32;
        m2 /= cols as f32;
        let inv = cache.inv[i];
        for j in 0..cols {
            let dyv = ar[j] + br[j];
            let dxh = dyv * g[j];
            dx.data[i * cols + j] = inv * (dxh - m1 - xhr[j] * m2);
        }
    }
    (dx, dg, db)
}

/// Tanh backward from the forward *output* `y`: `dx = dy * (1 - y^2)`.
pub fn tanh_vjp(y: &Tensor, dy: &Tensor) -> Tensor {
    debug_assert_eq!(y.shape, dy.shape);
    let mut out = dy.clone();
    for (o, &yv) in out.data.iter_mut().zip(&y.data) {
        *o *= 1.0 - yv * yv;
    }
    out
}

/// Row-wise softmax backward from probabilities `p` (masked entries have
/// `p = 0` and therefore receive zero gradient): per row,
/// `ds_j = p_j * (dp_j - sum_k p_k dp_k)`.
pub fn softmax_rows_vjp(p: &Tensor, dp: &Tensor) -> Tensor {
    let last = *p.shape.last().expect("softmax needs an axis");
    let mut out = Tensor::zeros(&p.shape);
    for ((orow, prow), dprow) in out
        .data
        .chunks_mut(last)
        .zip(p.data.chunks(last))
        .zip(dp.data.chunks(last))
    {
        let dot: f32 = prow.iter().zip(dprow).map(|(&a, &b)| a * b).sum();
        for ((o, &pv), &dpv) in orow.iter_mut().zip(prow).zip(dprow) {
            *o = pv * (dpv - dot);
        }
    }
    out
}

/// Backward of [`ops::multi_head_attention_batched`]: given the packed
/// probabilities `(B*heads, S, S)` and `d_ctx (B*S, H)`, return
/// `(dq, dk, dv)` on `(B*S, H)`.  The whole mini-batch's attention
/// backward runs in four `bmm` launches (pad columns carry exact-zero
/// probabilities, so they contribute exact-zero gradient — the additive
/// bias itself is constant and needs none).
pub fn multi_head_attention_vjp_batched(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
    d_ctx: &Tensor,
    n_heads: usize,
    batch: usize,
) -> Result<(Tensor, Tensor, Tensor)> {
    let (rows, h) = (q.shape[0], q.shape[1]);
    if batch == 0 || rows % batch != 0 {
        return Err(anyhow!("bad batch {batch} for {rows} rows"));
    }
    let s = rows / batch;
    if probs.ndim() != 3 || probs.shape != [batch * n_heads, s, s] {
        return Err(anyhow!(
            "probs must be ({}, {s}, {s}), got {:?}",
            batch * n_heads,
            probs.shape
        ));
    }
    let dh = h / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let qh = ops::pack_heads_batched(q, batch, n_heads)?;
    let kh = ops::pack_heads_batched(k, batch, n_heads)?;
    let vh = ops::pack_heads_batched(v, batch, n_heads)?;
    let dctx_h = ops::pack_heads_batched(d_ctx, batch, n_heads)?; // (B*heads, S, dh_pad)

    // ctx = P V  =>  dV = P^T dctx, dP = dctx V^T.
    let dv_h = probs.bmm_tn(&dctx_h)?; // (B*heads, S, dh)
    let dp = dctx_h.bmm_nt(&vh)?; // (B*heads, S, S)
    // P = softmax(scale * Q K^T + bias) row-wise.
    let mut ds = softmax_rows_vjp(probs, &dp);
    for x in ds.data.iter_mut() {
        *x *= scale;
    }
    // scores = Q K^T  =>  dQ = dS K, dK = dS^T Q.
    let dq_h = ds.bmm(&kh)?; // (B*heads, S, dh_pad)
    let dk_h = ds.bmm_tn(&qh)?; // (B*heads, S, dh_pad)
    Ok((
        ops::unpack_heads_batched(&dq_h, batch, h)?,
        ops::unpack_heads_batched(&dk_h, batch, h)?,
        ops::unpack_heads_batched(&dv_h, batch, h)?,
    ))
}

/// Backward of [`ops::multi_head_attention`]: the single-example view
/// of [`multi_head_attention_vjp_batched`] (kept for the looped
/// reference schedule and the unit tests).
pub fn multi_head_attention_vjp(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
    d_ctx: &Tensor,
    n_heads: usize,
) -> Result<(Tensor, Tensor, Tensor)> {
    multi_head_attention_vjp_batched(q, k, v, probs, d_ctx, n_heads, 1)
}

/// Cross-entropy over one logits row: returns `(loss, dlogits)` with
/// `dlogits = softmax(logits) - onehot(label)`.
pub fn cross_entropy_logits(logits: &[f32], label: usize) -> Result<(f32, Vec<f32>)> {
    if label >= logits.len() {
        return Err(anyhow!("label {label} out of range {}", logits.len()));
    }
    let maxv = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let sum: f32 = logits.iter().map(|&v| (v - maxv).exp()).sum();
    let lse = maxv + sum.ln();
    let loss = lse - logits[label];
    let mut dl: Vec<f32> = logits.iter().map(|&v| (v - lse).exp()).collect();
    dl[label] -= 1.0;
    Ok((loss, dl))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    /// Central-difference check: `f(w)` evaluates the scalar loss with
    /// the probed parameter set to `w`; the derivative at `center` must
    /// match `analytic`.
    fn fd_check<F: FnMut(f32) -> f32>(mut f: F, center: f32, analytic: f32, tag: &str) {
        let eps = 1e-2f32;
        let up = f(center + eps);
        let dn = f(center - eps);
        let fd = (up - dn) / (2.0 * eps);
        assert!(
            (fd - analytic).abs() < 1e-3 * (1.0 + analytic.abs()),
            "{tag}: fd {fd} vs analytic {analytic}"
        );
    }

    fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn layer_norm_fwd_matches_ops() {
        let mut rng = SplitMix64::new(71);
        let x = Tensor::randn(&[4, 9], 1.0, &mut rng);
        let g: Vec<f32> = (0..9).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let b: Vec<f32> = (0..9).map(|_| 0.1 * rng.normal() as f32).collect();
        let (y, _) = layer_norm_fwd(&x, &g, &b, 1e-5);
        assert_eq!(y, ops::layer_norm(&x, &g, &b, 1e-5));
    }

    #[test]
    fn layer_norm_vjp_finite_difference() {
        let mut rng = SplitMix64::new(72);
        let mut x = Tensor::randn(&[3, 6], 1.0, &mut rng);
        let mut g: Vec<f32> = (0..6).map(|_| 1.0 + 0.2 * rng.normal() as f32).collect();
        let mut b = vec![0.0f32; 6];
        let dy = Tensor::randn(&[3, 6], 1.0, &mut rng);
        let (_, cache) = layer_norm_fwd(&x, &g, &b, 1e-5);
        let (dx, dg, db) = layer_norm_vjp(&cache, &g, &dy);
        for idx in [0usize, 7, 17] {
            let orig = x.data[idx];
            fd_check(
                |w| {
                    x.data[idx] = w;
                    dot(&ops::layer_norm(&x, &g, &b, 1e-5).data, &dy.data)
                },
                orig,
                dx.data[idx],
                "dx",
            );
            x.data[idx] = orig;
        }
        for idx in [0usize, 3, 5] {
            let orig = g[idx];
            fd_check(
                |w| {
                    g[idx] = w;
                    dot(&ops::layer_norm(&x, &g, &b, 1e-5).data, &dy.data)
                },
                orig,
                dg[idx],
                "dg",
            );
            g[idx] = orig;
        }
        let orig = b[2];
        fd_check(
            |w| {
                b[2] = w;
                dot(&ops::layer_norm(&x, &g, &b, 1e-5).data, &dy.data)
            },
            orig,
            db[2],
            "db",
        );
    }

    #[test]
    fn gelu_vjp_finite_difference() {
        let mut rng = SplitMix64::new(73);
        let mut x = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let dy = Tensor::randn(&[2, 5], 1.0, &mut rng);
        let dx = gelu_vjp(&x, &dy);
        for idx in 0..10 {
            let orig = x.data[idx];
            fd_check(
                |w| {
                    x.data[idx] = w;
                    dot(&ops::gelu(&x).data, &dy.data)
                },
                orig,
                dx.data[idx],
                "gelu",
            );
            x.data[idx] = orig;
        }
    }

    #[test]
    fn attention_vjp_finite_difference() {
        let mut rng = SplitMix64::new(74);
        let (s, h, heads) = (5usize, 8usize, 2usize);
        let mut q = Tensor::randn(&[s, h], 0.7, &mut rng);
        let mut k = Tensor::randn(&[s, h], 0.7, &mut rng);
        let mut v = Tensor::randn(&[s, h], 0.7, &mut rng);
        let mask = [1.0, 1.0, 1.0, 1.0, 0.0];
        let d_ctx = Tensor::randn(&[s, h], 1.0, &mut rng);
        let (_, probs) = ops::multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
        let (dq, dk, dv) = multi_head_attention_vjp(&q, &k, &v, &probs, &d_ctx, heads).unwrap();
        for idx in [0usize, 9, 21, 33] {
            let orig = q.data[idx];
            fd_check(
                |w| {
                    q.data[idx] = w;
                    let (ctx, _) = ops::multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
                    dot(&ctx.data, &d_ctx.data)
                },
                orig,
                dq.data[idx],
                "dq",
            );
            q.data[idx] = orig;
        }
        for idx in [2usize, 14, 30] {
            let orig = k.data[idx];
            fd_check(
                |w| {
                    k.data[idx] = w;
                    let (ctx, _) = ops::multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
                    dot(&ctx.data, &d_ctx.data)
                },
                orig,
                dk.data[idx],
                "dk",
            );
            k.data[idx] = orig;
        }
        for idx in [1usize, 18, 35] {
            let orig = v.data[idx];
            fd_check(
                |w| {
                    v.data[idx] = w;
                    let (ctx, _) = ops::multi_head_attention(&q, &k, &v, &mask, heads).unwrap();
                    dot(&ctx.data, &d_ctx.data)
                },
                orig,
                dv.data[idx],
                "dv",
            );
            v.data[idx] = orig;
        }
    }

    #[test]
    fn fused_bias_residual_layer_norm_is_bitwise_identical() {
        let mut rng = SplitMix64::new(91);
        let (rows, cols) = (5usize, 7usize);
        let y = Tensor::randn(&[rows, cols], 0.9, &mut rng);
        let x = Tensor::randn(&[rows, cols], 0.9, &mut rng);
        let bias: Vec<f32> = (0..cols).map(|j| 0.1 * j as f32 - 0.3).collect();
        let g: Vec<f32> = (0..cols).map(|j| 1.0 + 0.05 * j as f32).collect();
        let b: Vec<f32> = (0..cols).map(|j| 0.02 * j as f32).collect();
        // Unfused reference: add_row -> residual add -> layer_norm_fwd.
        let o = ops::add_row(&y, &bias);
        let res = ops::add(&x, &o);
        let (want, want_cache) = layer_norm_fwd(&res, &g, &b, 1e-5);
        let (got, got_cache) = bias_residual_layer_norm_fwd(&y, &bias, &x, &g, &b, 1e-5);
        assert_eq!(want.data, got.data, "fused LN forward must match bitwise");
        assert_eq!(want_cache.xhat.data, got_cache.xhat.data);
        assert_eq!(want_cache.inv, got_cache.inv);
    }

    #[test]
    fn fused_layer_norm_vjp2_is_bitwise_identical() {
        let mut rng = SplitMix64::new(92);
        let (rows, cols) = (4usize, 6usize);
        let x = Tensor::randn(&[rows, cols], 1.1, &mut rng);
        let g: Vec<f32> = (0..cols).map(|j| 1.0 - 0.03 * j as f32).collect();
        let b = vec![0.0f32; cols];
        let (_, cache) = layer_norm_fwd(&x, &g, &b, 1e-5);
        let dy_a = Tensor::randn(&[rows, cols], 0.8, &mut rng);
        let dy_b = Tensor::randn(&[rows, cols], 0.8, &mut rng);
        let dy = ops::add(&dy_a, &dy_b);
        let (want_dx, want_dg, want_db) = layer_norm_vjp(&cache, &g, &dy);
        let (got_dx, got_dg, got_db) = layer_norm_vjp2(&cache, &g, &dy_a, &dy_b);
        assert_eq!(want_dx.data, got_dx.data, "fused LN vjp must match bitwise");
        assert_eq!(want_dg, got_dg);
        assert_eq!(want_db, got_db);
    }

    #[test]
    fn fused_bias_gelu_is_bitwise_identical() {
        let mut rng = SplitMix64::new(93);
        let (rows, cols) = (3usize, 9usize);
        let y = Tensor::randn(&[rows, cols], 1.3, &mut rng);
        let bias: Vec<f32> = (0..cols).map(|j| 0.07 * j as f32 - 0.2).collect();
        let h_ref = ops::add_row(&y, &bias);
        let g_ref = ops::gelu(&h_ref);
        let (h, g) = ops::bias_gelu(&y, &bias);
        assert_eq!(h_ref.data, h.data, "fused pre-activation must match bitwise");
        assert_eq!(g_ref.data, g.data, "fused GELU must match bitwise");
        // The VJP derivative scalar pairs with the same forward scalar.
        let dy = Tensor::randn(&[rows, cols], 1.0, &mut rng);
        let d1 = gelu_vjp(&h_ref, &dy);
        let d2 = gelu_vjp(&h, &dy);
        assert_eq!(d1.data, d2.data);
    }

    #[test]
    fn cross_entropy_gradient_and_value() {
        let logits = [1.0f32, 2.0, 0.5];
        let (loss, dl) = cross_entropy_logits(&logits, 1).unwrap();
        // loss = lse - logits[1]; probabilities sum to 1.
        assert!(loss > 0.0);
        let psum: f32 = dl.iter().sum::<f32>() + 1.0; // undo the -1 at label
        assert!((psum - 1.0).abs() < 1e-5);
        // dl[label] = p_label - 1 < 0; others positive.
        assert!(dl[1] < 0.0 && dl[0] > 0.0 && dl[2] > 0.0);
        assert!(cross_entropy_logits(&logits, 3).is_err());
    }
}
