//! Rust-native training subsystem: the paper's FP -> BP -> PU loop with
//! **hand-derived backward passes** over the TT/TTM tensor substrate.
//!
//! The PJRT path ([`crate::runtime`], `pjrt` feature) executes a fused
//! HLO train step lowered by JAX; this module is its self-contained
//! twin, closing the paper's on-device-training story without a
//! Python/XLA toolchain anywhere in the loop:
//!
//! * [`layers`] — the BTT linear layer: forward caches the merged
//!   Z1/Z3 chain states (the paper's stored intermediates, Eq. 21) and
//!   backward re-walks them, costing exactly `2x` Eq. 20 multiplies
//!   ([`crate::costmodel::LinearShape::btt_bwd_muls`]); everything is
//!   instrumented with the same [`crate::tensor::ContractionStats`] the
//!   forward engines use, so the BP stage validates against the
//!   analytic cost model, not just against finite differences.  The
//!   **fused QKV** entry points ([`forward_qkv_fused`] /
//!   [`backward_qkv_fused`]) execute the paper's Fig. 9 rescheduling:
//!   Q/K/V with tied input-side cores share one right merge and one
//!   `Z2 = X Z1^T` in both directions
//!   ([`crate::costmodel::LinearShape::btt_fwd_qkv_muls`]).
//! * [`blocks`] — VJPs of LayerNorm, GELU, masked softmax, multi-head
//!   attention, tanh and the joint intent+slot cross-entropy.
//! * [`model`] — [`NativeTrainModel`]: the full tensorized transformer
//!   with cached forward and backward over `(B, S)` mini-batches (the
//!   contraction K dimension carries `B * S`), and a pluggable in-place
//!   PU stage ([`crate::optim`]: SGD / momentum / Adam / AdamW, state
//!   in the compressed core layout) that applies each gradient the
//!   moment it is produced.
//! * [`native`] — [`NativeTrainer`]: the
//!   [`crate::coordinator::TrainBackend`] implementation, including
//!   name-verified `.npy` checkpoints interchangeable with the PJRT
//!   engine's.
//!
//! The Eq. 21 caches carry a **gradient-checkpointing** axis
//! ([`CheckpointPolicy`] on the model, [`CheckpointMode`] per layer):
//! under `Recompute` the forward retains only each layer's input and
//! the BP stage rebuilds the chain states through the identical
//! deterministic fold order — f32 gradients are bitwise the cached
//! ones, at `btt_recompute_muls` extra multiplies per layer
//! (`rust/tests/checkpointing.rs` pins both claims).
//!
//! Gradient correctness is pinned two ways: finite-difference checks
//! (unit tests here and `rust/tests/native_training.rs`) and — when HLO
//! artifacts are present — a loss-trajectory parity test against the
//! JAX-autodiff PJRT path.

pub mod blocks;
pub mod layers;
pub mod model;
pub mod native;

pub use layers::{
    backward_qkv_fused, forward_qkv_fused, forward_qkv_fused_ckpt, forward_qkv_fused_prec,
    qkv_input_cores_shared, tt_input_cores_tied, CheckpointMode, QkvFusedCache, QkvFusedGrads,
    TTLinear, TTLinearGrads,
};
// `ComputePath` moved to the shared engine (it selects the *forward*
// schedule, which training and serving now share); re-exported here so
// `crate::train::ComputePath` keeps working.
pub use crate::engine::ComputePath;
pub use model::{CheckpointPolicy, GradMap, NativeTrainModel};
pub use native::NativeTrainer;
