//! TT-format linear layer with a hand-derived backward pass through the
//! bidirectional (BTT) contraction — the paper's BP stage for one layer.
//!
//! Forward (row-major, K = sequence length):
//!
//! ```text
//! Z3 = fold(G_1 .. G_d)          (M, r_d)   left merge, K-independent
//! Z1 = fold(G_2d .. G_{d+1})     (r_d, N)   right merge, K-independent
//! Z2 = X Z1^T                    (K, r_d)
//! Y  = Z2 Z3^T + b               (K, M)
//! ```
//!
//! Backward reuses the cached chain states (the paper's "stored
//! intermediates", Eq. 21) and costs exactly `2x` the forward
//! multiplies — [`crate::costmodel::LinearShape::btt_bwd_muls`] is the
//! analytic form, asserted against the executed
//! [`ContractionStats`] in the tests.

use crate::optim::{Hyper, ModelOptim};
use crate::tensor::{ops, ContractionStats, Tensor, TTMatrix};
use anyhow::{anyhow, Result};

/// A trainable TT-format linear layer (cores + dense bias).
#[derive(Debug, Clone)]
pub struct TTLinear {
    pub tt: TTMatrix,
    pub bias: Vec<f32>,
}

/// Forward activations cached for the BP stage.
pub struct TTLinearCache {
    /// Layer input (K, N).
    pub x: Tensor,
    /// Left-merge chain states; last is Z3 (M, r_d).
    left_chain: Vec<Tensor>,
    /// Right-merge chain states; last is Z1 (r_d, N).
    right_chain: Vec<Tensor>,
    /// Z2 = X Z1^T (K, r_d).
    z2: Tensor,
}

impl TTLinearCache {
    /// Elements this cache stores beyond weights and the layer input —
    /// must equal Eq. 21 (`LinearShape::btt_training_cache_elems`).
    /// The first chain state on each side is a reshaped core (weight
    /// memory, not an activation) and is excluded.
    pub fn stored_elems(&self) -> u64 {
        let chain: usize = self
            .left_chain
            .iter()
            .skip(1)
            .chain(self.right_chain.iter().skip(1))
            .map(Tensor::numel)
            .sum();
        (chain + self.z2.numel()) as u64
    }
}

/// Parameter gradients of one layer.
pub struct TTLinearGrads {
    /// One gradient tensor per TT core (same shapes as the cores).
    pub cores: Vec<Tensor>,
    pub bias: Vec<f32>,
}

impl TTLinear {
    pub fn new(tt: TTMatrix, bias: Vec<f32>) -> Result<TTLinear> {
        if bias.len() != tt.m() {
            return Err(anyhow!("bias len {} != M {}", bias.len(), tt.m()));
        }
        Ok(TTLinear { tt, bias })
    }

    /// Random layer with zero bias (TT cores scaled for `target_std` of
    /// the reconstructed dense matrix).
    pub fn randn(
        m_modes: &[usize],
        n_modes: &[usize],
        rank: usize,
        target_std: f32,
        rng: &mut crate::util::rng::SplitMix64,
    ) -> TTLinear {
        let tt = TTMatrix::randn(m_modes, n_modes, rank, target_std, rng);
        let bias = vec![0.0; tt.m()];
        TTLinear { tt, bias }
    }

    /// Forward pass `Y = X W^T + b` on row-major `x (K, N)`, caching the
    /// BTT intermediates for backward.  Instrumented identically to
    /// [`TTMatrix::matmul_btt`] (the executed counts equal Eqs. 20/21).
    pub fn forward(
        &self,
        x: &Tensor,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearCache)> {
        let d = self.tt.d();
        let (m, n) = (self.tt.m(), self.tt.n());
        if x.ndim() != 2 || x.shape[1] != n {
            return Err(anyhow!("x must be (K, {n}), got {:?}", x.shape));
        }
        let k_dim = x.shape[0];
        let r_d = self.tt.ranks[d];

        let left_chain = self.tt.merge_left_chain()?;
        let right_chain = self.tt.merge_right_chain()?;
        // Merge costs via the shared accounting helper (same source of
        // truth as matmul_btt).
        self.tt.record_merge_stats(stats);

        let z3 = left_chain.last().expect("d >= 1");
        let z1 = right_chain.last().expect("d >= 1");
        let z2 = x.matmul(&z1.t()?)?; // (K, r_d)
        stats.record_step((k_dim * n * r_d) as u64, (k_dim * r_d) as u64, true);
        let y = z2.matmul(&z3.t()?)?; // (K, M)
        stats.record_step((k_dim * r_d * m) as u64, (k_dim * m) as u64, false);
        let y = ops::add_row(&y, &self.bias);
        Ok((
            y,
            TTLinearCache {
                x: x.clone(),
                left_chain,
                right_chain,
                z2,
            },
        ))
    }

    /// Backward pass: given `dY (K, M)` and the forward cache, return
    /// `dX (K, N)` and the parameter gradients.  Executed multiplies are
    /// recorded into `stats` and equal `btt_bwd_muls` (2x Eq. 20).
    pub fn backward(
        &self,
        dy: &Tensor,
        cache: &TTLinearCache,
        stats: &mut ContractionStats,
    ) -> Result<(Tensor, TTLinearGrads)> {
        let d = self.tt.d();
        let d2 = 2 * d;
        let (m, n) = (self.tt.m(), self.tt.n());
        let r_d = self.tt.ranks[d];
        if dy.ndim() != 2 || dy.shape[1] != m || dy.shape[0] != cache.x.shape[0] {
            return Err(anyhow!("dy must be (K, {m}), got {:?}", dy.shape));
        }
        let k_dim = dy.shape[0];

        // Bias gradient: column sums of dY (additions only).
        let mut dbias = vec![0.0f32; m];
        for row in dy.data.chunks(m) {
            for (b, &v) in dbias.iter_mut().zip(row) {
                *b += v;
            }
        }

        let z3 = cache.left_chain.last().expect("d >= 1");
        let z1 = cache.right_chain.last().expect("d >= 1");
        // The four K-wide products (2 K r_d (M + N) multiplies).
        let dz3 = dy.t()?.matmul(&cache.z2)?; // (M, r_d)
        stats.record_step((m * k_dim * r_d) as u64, (m * r_d) as u64, false);
        let dz2 = dy.matmul(z3)?; // (K, r_d)
        stats.record_step((k_dim * m * r_d) as u64, (k_dim * r_d) as u64, false);
        let dz1 = dz2.t()?.matmul(&cache.x)?; // (r_d, N)
        stats.record_step((r_d * k_dim * n) as u64, (r_d * n) as u64, false);
        let dx = dz2.matmul(z1)?; // (K, N)
        stats.record_step((k_dim * r_d * n) as u64, (k_dim * n) as u64, false);

        let mut core_grads: Vec<Tensor> =
            self.tt.cores.iter().map(|c| Tensor::zeros(&c.shape)).collect();

        // Unroll the left merge: dL_k -> (dG_k, dL_{k-1}).
        let mut d_state = dz3;
        for k in (1..d).rev() {
            let g = &self.tt.cores[k];
            let (rp, mk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            let prev = &cache.left_chain[k - 1]; // (m_prev, rp)
            let m_prev = prev.shape[0];
            let dflat = d_state.reshape(&[m_prev, mk * rk])?;
            let dg = prev.t()?.matmul(&dflat)?; // (rp, mk*rk)
            stats.record_step((rp * m_prev * mk * rk) as u64, (rp * mk * rk) as u64, false);
            core_grads[k] = dg.reshape(&[rp, mk, rk])?;
            d_state = dflat.matmul(&g.reshape(&[rp, mk * rk])?.t()?)?; // (m_prev, rp)
            stats.record_step((m_prev * mk * rk * rp) as u64, (m_prev * rp) as u64, false);
        }
        core_grads[0] = d_state.reshape(&self.tt.cores[0].shape)?;

        // Unroll the right merge: dR_j -> (dG_{2d-1-j}, dR_{j-1}).
        let mut d_state = dz1;
        for j in (1..d).rev() {
            let c = d2 - 1 - j;
            let g = &self.tt.cores[c];
            let (rp, nk, rk) = (g.shape[0], g.shape[1], g.shape[2]);
            let prev = &cache.right_chain[j - 1]; // (rk, n_prev)
            let n_prev = prev.shape[1];
            let dflat = d_state.reshape(&[rp * nk, n_prev])?;
            let dg = dflat.matmul(&prev.t()?)?; // (rp*nk, rk)
            stats.record_step((rp * nk * n_prev * rk) as u64, (rp * nk * rk) as u64, false);
            core_grads[c] = dg.reshape(&[rp, nk, rk])?;
            d_state = g.reshape(&[rp * nk, rk])?.t()?.matmul(&dflat)?; // (rk, n_prev)
            stats.record_step((rk * rp * nk * n_prev) as u64, (rk * n_prev) as u64, false);
        }
        core_grads[d2 - 1] = d_state.reshape(&self.tt.cores[d2 - 1].shape)?;

        Ok((dx, TTLinearGrads { cores: core_grads, bias: dbias }))
    }

    /// The paper's PU stage for this layer: dispatch every core (and the
    /// bias) through the pluggable optimizer, in place, as gradients
    /// become available.  `prefix` is the layer's checkpoint/manifest
    /// name (e.g. `layers.0.wq`), which keys the per-core optimizer
    /// state — state buffers mirror the compressed core shapes exactly.
    pub fn apply_update(
        &mut self,
        grads: &TTLinearGrads,
        opt: &mut ModelOptim,
        prefix: &str,
        hyper: &Hyper,
    ) {
        for (k, (core, g)) in self.tt.cores.iter_mut().zip(&grads.cores).enumerate() {
            opt.step(&format!("{prefix}.cores.{k}"), &mut core.data, &g.data, hyper);
        }
        opt.step(&format!("{prefix}.bias"), &mut self.bias, &grads.bias, hyper);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::LinearShape;
    use crate::util::rng::SplitMix64;

    fn layer(rng: &mut SplitMix64) -> TTLinear {
        TTLinear::randn(&[4, 3], &[3, 4], 3, 0.5, rng)
    }

    #[test]
    fn forward_matches_btt_contraction() {
        let mut rng = SplitMix64::new(51);
        let l = layer(&mut rng);
        let x = Tensor::randn(&[5, 12], 1.0, &mut rng); // (K, N)
        let mut stats = ContractionStats::default();
        let (y, _) = l.forward(&x, &mut stats).unwrap();
        // Column-major reference through the instrumented engine.
        let (y_cols, ref_stats) = l.tt.matmul_btt(&x.t().unwrap()).unwrap();
        let y_ref = ops::add_row(&y_cols.t().unwrap(), &l.bias);
        assert!(y.max_abs_diff(&y_ref) < 1e-4);
        assert_eq!(stats.muls, ref_stats.muls);
        assert_eq!(stats.stored_intermediate_elems, ref_stats.stored_intermediate_elems);
    }

    #[test]
    fn backward_stats_match_cost_model() {
        let mut rng = SplitMix64::new(52);
        let l = layer(&mut rng);
        let k_dim = 7usize;
        let x = Tensor::randn(&[k_dim, 12], 1.0, &mut rng);
        let shape = LinearShape {
            m_modes: l.tt.m_modes.clone(),
            n_modes: l.tt.n_modes.clone(),
            ranks: l.tt.ranks.clone(),
        };
        let mut fwd = ContractionStats::default();
        let (y, cache) = l.forward(&x, &mut fwd).unwrap();
        assert_eq!(fwd.muls, shape.btt_muls(k_dim as u64), "Eq.20");
        assert_eq!(
            fwd.stored_intermediate_elems,
            shape.btt_memory(k_dim as u64),
            "Eq.21"
        );
        assert_eq!(cache.stored_elems(), shape.btt_training_cache_elems(k_dim as u64));
        let dy = Tensor::randn(&[k_dim, y.shape[1]], 1.0, &mut rng);
        let mut bwd = ContractionStats::default();
        l.backward(&dy, &cache, &mut bwd).unwrap();
        assert_eq!(bwd.muls, shape.btt_bwd_muls(k_dim as u64), "BP = 2x Eq.20");
    }

    #[test]
    fn dx_matches_dense_gradient() {
        // dX = dY W_dense: the TT backward must agree with the dense
        // chain rule.
        let mut rng = SplitMix64::new(53);
        let l = layer(&mut rng);
        let x = Tensor::randn(&[6, 12], 1.0, &mut rng);
        let mut stats = ContractionStats::default();
        let (y, cache) = l.forward(&x, &mut stats).unwrap();
        let dy = Tensor::randn(&[6, y.shape[1]], 1.0, &mut rng);
        let (dx, grads) = l.backward(&dy, &cache, &mut stats).unwrap();
        let w = l.tt.to_dense().unwrap(); // (M, N)
        let dx_dense = dy.matmul(&w).unwrap();
        assert!(dx.max_abs_diff(&dx_dense) < 1e-4);
        // Bias gradient: column sums of dY.
        for j in 0..y.shape[1] {
            let want: f32 = (0..6).map(|i| dy.at2(i, j)).sum();
            assert!((grads.bias[j] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn optimizer_update_reduces_reconstruction_loss() {
        // PU-stage steps on L = ||Y - Y*||^2 / 2 must reduce L, for the
        // stateless and the stateful update rules alike (each at a
        // learning rate suited to its step-size semantics: momentum's
        // effective rate is lr / (1 - mu), Adam's step is ~lr itself).
        use crate::optim::{OptimConfig, OptimKind};
        for (kind, lr) in [
            (OptimKind::Sgd, 0.01f32),
            (OptimKind::Momentum, 0.003),
            (OptimKind::Adam, 0.05),
            (OptimKind::AdamW, 0.05),
        ] {
            let mut rng = SplitMix64::new(54);
            let mut l = layer(&mut rng);
            let x = Tensor::randn(&[8, 12], 1.0, &mut rng);
            let target = Tensor::randn(&[8, 12], 0.5, &mut rng);
            let mut opt = ModelOptim::new(OptimConfig { kind, ..Default::default() });
            let hyper = opt.hyper(lr);
            let mut first = None;
            let mut last = 0.0f32;
            for _ in 0..80 {
                let mut stats = ContractionStats::default();
                let (y, cache) = l.forward(&x, &mut stats).unwrap();
                let mut dy = y.clone();
                for (d, &t) in dy.data.iter_mut().zip(&target.data) {
                    *d -= t;
                }
                last = 0.5 * dy.norm().powi(2);
                first.get_or_insert(last);
                let (_, grads) = l.backward(&dy, &cache, &mut stats).unwrap();
                l.apply_update(&grads, &mut opt, "probe", &hyper);
            }
            assert!(last < 0.6 * first.unwrap(), "{kind:?}: loss {last} vs {first:?}");
            // One slot per core + bias, state sized by the rule.
            let elems: u64 = l.tt.cores.iter().map(|c| c.numel() as u64).sum::<u64>()
                + l.bias.len() as u64;
            assert_eq!(
                opt.allocated_state_elems(),
                kind.state_multiplier() as u64 * elems
            );
        }
    }
}
